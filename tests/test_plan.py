"""Cohort dispatch planner (core.plan, DESIGN.md §8).

Three contracts under test:

1. **Planner policy units** — pow2 burst quantization, cohort tiering
   (one dispatch per distinct burst), per-cohort fold widths generalizing
   the old ``group_block ∈ {G, 1}`` cliff, and group-axis compaction for
   the kernel path.

2. **Bounded burst-shape vocabulary** — a heavily skewed 1000-submit run
   must mint only pow2 burst shapes in ``[MIN_BURST, batch]``, on the
   fused and the staged (software-coordinated) paths alike, so the jit
   cache cannot churn one compiled program per load level.

3. **Lockstep realignment** — after divergent per-group failovers the
   planner burns the stragglers forward to a common block boundary within
   ``realign_after`` sweeps, the full-width fold re-engages
   (``group_block == G``), and the burned NOP instances never surface in
   ``delivered()``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import PaxosConfig, PaxosContext
from repro.core import plan as plan_mod
from repro.core.plan import (
    MIN_BURST,
    NO_ROUND,
    DispatchPlanner,
    cohort_blocks,
    fold_width_full,
    quantize_burst,
)
from repro.serve.engine import ConsensusService


# ---------------------------------------------------------------------------
# Policy units
# ---------------------------------------------------------------------------
def test_quantize_burst_pow2_floor_and_cap():
    assert quantize_burst(0, 128) == MIN_BURST
    assert quantize_burst(1, 128) == MIN_BURST
    assert quantize_burst(8, 128) == 8
    assert quantize_burst(9, 128) == 16
    assert quantize_burst(100, 128) == 128
    assert quantize_burst(1000, 128) == 128       # capped at batch
    assert quantize_burst(3, 4) == 4              # cap below the floor


def test_fold_width_full_generalizes_the_binary_cliff():
    # full lockstep: the whole capacity folds
    assert fold_width_full([0, 1, 2, 3], [8, 8, 8, 8], 4) == 4
    # two lockstep halves: the historical plan fell to 1; now width 4
    marks = [0, 0, 0, 0, 8, 8, 8, 8]
    assert fold_width_full(list(range(8)), marks, 8) == 4
    # fully divergent: width 1
    assert fold_width_full([0, 1], [0, 8], 2) == 1
    # divergence only among NON-members never constrains the fold
    assert fold_width_full([1, 2, 3], [99, 8, 8, 8], 4) == 4
    # empty member set: unconstrained
    assert fold_width_full([], [0, 1, 2, 3], 4) == 4


def test_cohort_blocks_compacts_the_group_axis():
    marks = [0] * 8
    # a single hot group: one width-1 block, not a full-width sweep
    gb, blocks = cohort_blocks([2], marks, 8)
    assert (gb, blocks) == (1, [2])
    # 7-of-8 cold cohort: one folded full-width block beats 7 single blocks
    gb, blocks = cohort_blocks(list(range(1, 8)), marks, 8)
    assert (gb, blocks) == (8, [0])
    # two divergent lockstep halves fold block-wise at width 4
    marks = [0, 0, 0, 0, 8, 8, 8, 8]
    gb, blocks = cohort_blocks(list(range(8)), marks, 8)
    assert (gb, blocks) == (4, [0, 1])
    # divergent neighbours cannot share a block
    gb, blocks = cohort_blocks([0, 1], [0, 8], 2)
    assert (gb, blocks) == (1, [0, 1])


def test_plan_round_tiers_hot_to_cold():
    p = DispatchPlanner(batch=128, n_instances=4096)
    rp = p.plan_round(
        loads=[128, 2, 0, 7, 128, 1],
        marks=[0] * 6,
        live=[True] * 6,
        crnd=[0] * 6,
    )
    # one dispatch per distinct quantized burst, hot first
    assert [c.burst for c in rp.cohorts] == [128, 8]
    assert rp.cohorts[0].gids == (0, 4)
    assert rp.cohorts[1].gids == (1, 3, 5)
    assert rp.enabled == (True, True, False, True, True, True)
    assert not rp.full_fold                      # two tiers
    assert rp.fragmentation == 1                 # but one watermark class


def test_plan_round_masks_frozen_and_vacant():
    p = DispatchPlanner(batch=32, n_instances=512)
    rp = p.plan_round(
        loads=[4, 4, 4, 4],
        marks=[0, 0, 0, 0],
        live=[True, False, True, True],          # group 1 vacant
        crnd=[0, 0, NO_ROUND, 0],                # group 2 frozen
    )
    assert rp.enabled == (True, False, False, True)
    assert rp.cohorts == (plan_mod.Cohort(gids=(0, 3), burst=8),)
    assert rp.full_fold


def test_realignment_sweep_triggers_after_k_fragmented_rounds():
    p = DispatchPlanner(batch=128, n_instances=4096, realign_after=3)
    marks = [128, 256, 128, 128]
    for _ in range(2):
        rp = p.plan_round([4] * 4, marks, [True] * 4, [0] * 4)
        assert rp.realign == ()                  # below the threshold
        assert rp.fragmentation == 2
    rp = p.plan_round([4] * 4, marks, [True] * 4, [0] * 4)
    # third consecutive fragmented round: burn to the common block boundary
    # (gid 1 already sits on it and is not burned)
    burned = dict(rp.realign)
    assert set(burned) == {0, 2, 3}
    assert all(t == 256 for t in burned.values())
    assert rp.fragmentation == 1
    assert rp.full_fold
    assert p.stats["realignments"] == 1
    # the counter reset: the next fragmented round starts a fresh window
    rp = p.plan_round([4] * 4, [0, 64, 0, 0], [True] * 4, [0] * 4)
    assert rp.realign == ()


def test_realignment_fires_on_lockstep_but_misaligned_watermarks():
    """Fragmentation is not only fold divergence: enabled groups in
    lockstep at a watermark OFF the full-batch block boundary (the residue
    a right-sized sub-batch burst leaves) can never run the block-aligned
    kernel window — the sweep must burn them forward too, and it must fire
    identically on every engine (the trigger reads host scalars only)."""
    p = DispatchPlanner(batch=32, n_instances=512, realign_after=2)
    marks = [8, 8, 8, 8]                         # one class, 8 % 32 != 0
    rp = p.plan_round([4] * 4, marks, [True] * 4, [0] * 4)
    assert rp.realign == ()
    rp = p.plan_round([4] * 4, marks, [True] * 4, [0] * 4)
    burned = dict(rp.realign)
    assert set(burned) == {0, 1, 2, 3}
    assert all(t == 32 for t in burned.values())  # next 32-block boundary
    assert rp.full_fold
    # aligned lockstep marks are NOT fragmented: the counter resets
    rp = p.plan_round([4] * 4, [32] * 4, [True] * 4, [0] * 4)
    assert rp.realign == () and p._fragmented_rounds == 0


def test_realignment_disabled_by_default():
    p = DispatchPlanner(batch=128, n_instances=4096)
    for _ in range(50):
        rp = p.plan_round([4] * 4, [0, 64, 0, 0], [True] * 4, [0] * 4)
        assert rp.realign == ()
    assert p.stats["realignments"] == 0


# ---------------------------------------------------------------------------
# Bounded burst-shape vocabulary (jit-cache churn guard)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernels", [False, True])
def test_skewed_1000_submit_run_mints_bounded_burst_shapes(use_kernels):
    """1000 submits with per-group loads swept across every level — plus a
    stretch under a software coordinator (the staged path) — must resolve
    to pow2 bursts in [MIN_BURST, batch] only: at most
    log2(batch/MIN_BURST)+1 distinct wire shapes ever reach a dispatch."""
    cfg = PaxosConfig(
        n_acceptors=3, n_instances=2048, batch=64, n_groups=4
    )
    ctx = PaxosContext(cfg, use_kernels=use_kernels)
    rng = np.random.default_rng(0)
    submitted = 0
    wave = 0
    while submitted < 1000:
        if wave == 6:
            ctx.fail_coordinator(group=1)        # staged path for group 1
        if wave == 12:
            ctx.restore_hardware_coordinator(group=1)
        for gid in range(4):
            k = int(rng.integers(0, cfg.batch + 1)) if gid else cfg.batch
            for j in range(k):
                ctx.submit(f"w{wave}g{gid}j{j}".encode(), group=gid)
                submitted += 1
        ctx.run_until_quiescent()
        wave += 1
    assert ctx.stats["delivered"] == submitted
    shapes = ctx.planner.stats["burst_shapes"]
    legal = {8, 16, 32, 64}                      # pow2 in [MIN_BURST, batch]
    assert shapes <= legal, shapes
    assert len(shapes) <= 4


# ---------------------------------------------------------------------------
# Lockstep realignment, end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernels", [False, True])
def test_realignment_restores_full_width_fold_after_failover(use_kernels):
    """Scripted divergent failover: after restore, the victim's watermark
    sits off the others' class and the plan fragments; within
    ``realign_after`` loaded sweeps the planner burns the stragglers
    forward, the full-width fold (group_block == G) re-engages, and every
    submitted payload — and nothing else — is delivered."""
    g = 4
    cfg = PaxosConfig(
        n_acceptors=3, n_instances=512, batch=32, n_groups=g,
        realign_after=2,
    )
    ctx = PaxosContext(cfg, use_kernels=use_kernels)
    sent = [[] for _ in range(g)]

    def wave(tag, extra=0):
        for gid in range(g):
            for j in range(1 + (extra if gid == 1 else 0)):
                p = f"{tag}g{gid}j{j}".encode()
                sent[gid].append(p)
                ctx.submit(p, group=gid)
        ctx.run_until_quiescent()

    wave("w0")
    ctx.fail_coordinator(group=1)
    # heavier load on the victim while software-coordinated: its burst
    # right-sizes to 16 where the others advance by 8, so the watermarks
    # genuinely diverge on every backend
    wave("w1", extra=8)
    wave("w2")
    ctx.restore_hardware_coordinator(group=1)
    # the victim's restore-realigned watermark diverges from the others'
    assert len(set(ctx.hw.next_inst_host)) > 1
    for k in range(cfg.realign_after + 1):
        wave(f"r{k}")
    # the sweep fired, the service is back in lockstep, and the dispatch
    # folds the full width again
    assert ctx.planner.stats["realignments"] >= 1
    assert len(set(ctx.hw.next_inst_host)) == 1
    assert ctx.planner.last_plan.full_fold
    assert ctx.hw.last_gb == g
    assert ctx.hw._plan_round(cfg.batch, None)[2] == g
    wave("post")
    # burned instances are NOP holes: never proposed, never delivered —
    # each group's log is exactly its submissions, in order
    for gid in range(g):
        assert [p for _i, p in ctx.group_log[gid]] == sent[gid], gid
    assert not ctx._pending


def test_realignment_burns_never_surface_in_service_delivered():
    """The serving-tier view of the same sweep: sessions routed through
    ``ConsensusService.delivered`` observe exactly their own ops, in
    order, across a failover + realignment — burned instances are holes
    in the instance space, not entries in any session's log."""
    cfg = PaxosConfig(
        n_acceptors=3, n_instances=512, batch=32, n_groups=4,
        realign_after=2,
    )
    svc = ConsensusService(PaxosContext(cfg, use_kernels=True))
    sessions = [f"user-{i}" for i in range(12)]
    victim = svc.group_of(sessions[0])

    def wave(tag):
        for s in sessions:
            svc.submit(s, f"{s}:{tag}".encode())
        svc.run_until_quiescent()

    wave("op0")
    svc.ctx.fail_coordinator(group=victim)
    wave("op1")
    svc.ctx.restore_hardware_coordinator(group=victim)
    for k in range(4):
        wave(f"op{2 + k}")
    report = svc.plan_report()
    assert report["realignments"] >= 1
    assert report["service_loads"] == svc.group_loads()
    for s in sessions:
        mine = [
            p for _i, p in svc.delivered(s)
            if p.startswith(f"{s}:".encode())
        ]
        assert mine == [f"{s}:op{k}".encode() for k in range(6)]


def test_burn_forward_is_monotone_and_plan_is_backend_agnostic():
    cfg = PaxosConfig(n_acceptors=3, n_instances=256, batch=16, n_groups=2)
    ctx = PaxosContext(cfg)
    ctx.hw.burn_forward(1, 32)
    assert ctx.hw.next_inst_host == [0, 32]
    assert int(np.asarray(ctx.hw.cstate.next_inst)[1]) == 32
    with pytest.raises(ValueError):
        ctx.hw.burn_forward(1, 16)
    # the group still serves from the burned watermark
    ctx.submit(b"x", group=1)
    ctx.run_until_quiescent()
    assert [(i, p) for i, p in ctx.group_log[1]] == [(32, b"x")]


# ---------------------------------------------------------------------------
# Dispatch-path hardening (DESIGN.md §11 ride-alongs)
# ---------------------------------------------------------------------------
def test_pack_rows_oversized_chunk_fails_up_front():
    """An oversized chunk must fail before any wire array is built — the
    historical loop raised a bare IndexError after partially writing the
    burst — and the error must name both the chunk length and the burst."""
    rows = [np.full((4,), 7, np.int32) for _ in range(9)]
    with pytest.raises(ValueError) as ei:
        plan_mod.pack_rows(rows, 8, 4)
    assert "9" in str(ei.value) and "8" in str(ei.value)
    # the boundary case still packs
    vals, active = plan_mod.pack_rows(rows[:8], 8, 4)
    assert active.all() and (vals == 7).all()


def test_report_snapshots_service_loads_not_aliases():
    """A report is an observation, not a window onto live planner state:
    mutating a returned report must not perturb the planner, and later
    load observations must not rewrite already-returned reports."""
    p = DispatchPlanner(batch=32, n_instances=512)
    p.observe_service_loads([3, 1, 4])
    r1 = p.report()
    r1["service_loads"].append(99)
    r1["burst_shapes"].append(77)
    assert p.stats["service_loads"] == [3, 1, 4]
    assert p.report()["service_loads"] == [3, 1, 4]
    r2 = p.report()
    p.observe_service_loads([0, 0, 0])
    assert r2["service_loads"] == [3, 1, 4]
    assert p.report()["service_loads"] == [0, 0, 0]


def test_wave_depth_policy_full_batch_and_covered_queues_only():
    """The planner mints K > 1 only for full-batch cohorts whose every
    member has K full chunks queued, clamped by the policy knob and the
    ring (DESIGN.md §11)."""
    p = DispatchPlanner(batch=32, n_instances=128, persistent_rounds=8)
    rp = p.plan_round(
        loads=[32, 32], marks=[0, 0], live=[True] * 2, crnd=[0, 0],
        pending=[160, 96],
    )
    # min(160, 96) // 32 = 3 full chunks each; ring cap 128 // 32 = 4
    assert rp.cohorts == (plan_mod.Cohort(gids=(0, 1), burst=32, rounds=3),)
    assert p.stats["persistent_waves"] == 1
    # a sub-batch burst never goes persistent (numbering would fork)
    rp = p.plan_round(
        loads=[8, 8], marks=[0, 0], live=[True] * 2, crnd=[0, 0],
        pending=[64, 64],
    )
    assert all(c.rounds == 1 for c in rp.cohorts)
    # no pending telemetry -> classic single-round planning
    rp = p.plan_round(loads=[32, 32], marks=[0, 0], live=[True] * 2, crnd=[0, 0])
    assert all(c.rounds == 1 for c in rp.cohorts)
    # the knob off switches the feature off wholesale
    p1 = DispatchPlanner(batch=32, n_instances=128, persistent_rounds=1)
    rp = p1.plan_round(
        loads=[32], marks=[0], live=[True], crnd=[0], pending=[320],
    )
    assert rp.cohorts[0].rounds == 1
    assert p1.stats["persistent_waves"] == 0

# -- load-weighted placement (DESIGN.md §13) ---------------------------------

def test_placement_identity_and_validation():
    pm = plan_mod.PlacementMap.identity(8, 4)
    assert pm.identity_map()
    assert pm.n_groups == 8 and pm.n_shards == 2
    assert [pm.shard_of(g) for g in range(8)] == [0] * 4 + [1] * 4
    assert [pm.row_of(g) for g in range(8)] == [0, 1, 2, 3] * 2
    assert pm.group_of == tuple(range(8))
    with pytest.raises(ValueError):
        plan_mod.PlacementMap((0, 0, 1, 3), 2)   # not a permutation
    with pytest.raises(ValueError):
        plan_mod.PlacementMap((0, 1, 2), 2)      # G not divisible by Gl


def test_weighted_placement_is_ragged_and_load_balanced():
    """LPT greedy: one hot tenant claims a shard while the cold majority
    packs elsewhere — a ragged, non-contiguous assignment, not equal
    contiguous slabs."""
    pm = plan_mod.PlacementMap.weighted([100, 1, 1, 1, 1, 1, 1, 1], 2, 4)
    shards = [pm.shard_of(g) for g in range(8)]
    # the hot group sits alone-ish: its shard hosts the LIGHT tail only
    # after the other shard fills to capacity
    hot = shards[0]
    cold_sum = sum(1 for g in range(1, 8) if shards[g] != hot)
    assert cold_sum == 4  # cold shard filled to Gl before spill-back
    # the assignment is non-contiguous: the hot shard's co-tenants are not
    # a prefix/suffix run of group ids
    mates = sorted(g for g in range(1, 8) if shards[g] == hot)
    assert mates == [5, 6, 7]
    # still a permutation; every backend resolves the same map
    assert sorted(pm.slot_of) == list(range(8))
    assert pm == plan_mod.PlacementMap.weighted(
        [100, 1, 1, 1, 1, 1, 1, 1], 2, 4
    )


def test_weighted_placement_stable_under_equal_loads():
    """Equal loads degrade to round-robin gid i -> shard i % n_shards, so
    an all-idle service keeps the identity-like layout deterministically."""
    for loads in ([0] * 8, [5] * 8):
        pm = plan_mod.PlacementMap.weighted(loads, 2, 4)
        assert [pm.shard_of(g) for g in range(8)] == [g % 2 for g in range(8)]
        # repeated planning is a fixed point
        assert pm == plan_mod.PlacementMap.weighted(loads, 2, 4)


def test_placement_swap_is_migrations_only_mutation():
    pm = plan_mod.PlacementMap.identity(4, 2)
    moved = pm.swapped(0, 3)
    assert moved.slot_of == (3, 1, 2, 0)
    assert moved.shard_of(0) == 1 and moved.shard_of(3) == 0
    # swap back restores identity; a swap never breaks the permutation
    assert moved.swapped(0, 3) == pm
    assert sorted(moved.group_of) == list(range(4))
    with pytest.raises(ValueError):
        plan_mod.PlacementMap.weighted([1, 2, 3], 2, 2)  # wrong cardinality


def test_sharded_planner_clamps_wave_depth_to_one():
    """Pin: a sharded planner never mints K > 1 — the wave would unroll to
    K dispatches anyway, and ``persistent_waves`` must count only waves
    that actually ran device-persistent (DESIGN.md §11)."""
    p = DispatchPlanner(
        batch=32, n_instances=128, persistent_rounds=8, sharded=True
    )
    rp = p.plan_round(
        loads=[32, 32], marks=[0, 0], live=[True] * 2, crnd=[0, 0],
        pending=[160, 96],
    )
    # the identical inputs mint rounds=3 on the unsharded planner (above)
    assert rp.cohorts == (plan_mod.Cohort(gids=(0, 1), burst=32, rounds=1),)
    assert p.stats["persistent_waves"] == 0
