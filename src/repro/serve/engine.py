"""Serving engine: batched prefill + decode over any registry architecture,
plus the consensus-as-a-service front door.

``prefill_step`` and ``serve_step`` are the two lowered entry points of the
inference shapes (``prefill_32k`` lowers prefill; ``decode_32k`` /
``long_500k`` lower one ``serve_step`` against a seq_len-deep cache).  The
host-side ``ServeLoop`` runs continuous batching over them for the examples
and benchmarks.

``ConsensusService`` is the serving tier of the multi-group dataplane
(DESIGN.md §5): client *sessions* hash-route onto the G device-resident
Paxos groups of a multi-group ``PaxosContext``, so millions of independent
session streams share one fused dispatch while each session keeps a total
order within its group.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


def make_prefill_step(cfg) -> Callable:
    mod = registry.family_module(cfg)

    def prefill_step(params, batch: Dict[str, jax.Array]):
        logits, cache = mod.prefill(cfg, params, batch)
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg) -> Callable:
    mod = registry.family_module(cfg)

    def serve_step(params, tokens, cache, pos):
        logits, cache = mod.decode_step(cfg, params, tokens, cache, pos)
        return logits.reshape(tokens.shape[0], -1), cache

    return serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray         # (S,) int32
    max_new: int = 16
    generated: Optional[List[int]] = None


# ---------------------------------------------------------------------------
# Consensus as a service: session -> group routing over the fused dataplane
# ---------------------------------------------------------------------------
_FNV_OFFSET = 0x811C9DC5
_FNV_PRIME = 0x01000193


def session_group(session_id, n_groups: int) -> int:
    """Deterministic session -> consensus-group routing (32-bit FNV-1a).

    Stable across processes and runs (unlike Python's salted ``hash``), cheap
    enough for the submit path, and uniform enough that G groups see balanced
    load from arbitrary session-id distributions.
    """
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    if isinstance(session_id, bytes):
        data = session_id
    elif isinstance(session_id, str):
        data = session_id.encode()
    else:
        # variable-length encoding: arbitrary-width ints (uuid4().int is
        # 128-bit) must not overflow a fixed 8-byte window
        sid = int(session_id)
        data = sid.to_bytes(
            max(1, (sid.bit_length() + 8) // 8), "little", signed=True
        )
    h = _FNV_OFFSET
    for byte in data:
        h = ((h ^ byte) * _FNV_PRIME) & 0xFFFFFFFF
    return h % n_groups


class ConsensusService:
    """Front door of the multi-group consensus dataplane.

    Wraps a (multi-group) ``PaxosContext``: ``submit`` hash-routes a client
    session's value to its group, ``pump``/``run_until_quiescent`` drive the
    shared fused dispatch, and ``delivered`` reads a session's group log —
    the per-group total order every session in that group observes.
    """

    def __init__(self, ctx):
        self.ctx = ctx
        self.n_groups = ctx.cfg.n_groups
        self.stats = {"submitted": 0}
        # bounded introspection state: G counters, not a per-session map —
        # the hash is pure and cheap, and a session universe of millions
        # must not accrete host memory in the routing tier
        self.submits_per_group = [0] * self.n_groups

    def group_of(self, session_id) -> int:
        return session_group(session_id, self.n_groups)

    # -- group -> shard placement (the sharded dataplane, DESIGN.md §6) ------
    def group_placement(self) -> List[int]:
        """group id -> owning mesh shard.  Routing composes as session ->
        group (FNV-1a, placement-independent) -> shard (dataplane
        placement); an unsharded dataplane is the degenerate one-shard
        placement.  Re-placing groups over a different mesh therefore never
        moves a session between groups — only the group's *shard* changes."""
        hw = self.ctx.hw
        if hasattr(hw, "group_placement"):
            return hw.group_placement()
        return [0] * self.n_groups

    def shard_of(self, session_id) -> int:
        """Mesh shard that serves the session's group (O(1): indexes the
        dataplane's placement directly — no per-request list rebuild)."""
        gid = self.group_of(session_id)
        hw = self.ctx.hw
        if hasattr(hw, "shard_of_group"):
            return hw.shard_of_group(gid)
        return 0

    def submit(self, session_id, payload: bytes) -> Tuple[int, int]:
        """Route one value; returns ``(group, client_seq)``."""
        gid = self.group_of(session_id)
        seq = self.ctx.submit(payload, group=gid)
        self.stats["submitted"] += 1
        self.submits_per_group[gid] += 1
        return gid, seq

    def pump(self, rounds: int = 1) -> None:
        self.ctx.pump(rounds)

    def run_until_quiescent(self, max_rounds: int = 64) -> None:
        self.ctx.run_until_quiescent(max_rounds)

    def delivered(self, session_id) -> List[Tuple[int, bytes]]:
        """The (inst, payload) log of the session's group, in decided order."""
        gid = self.group_of(session_id)
        if self.n_groups == 1:
            return list(self.ctx.delivered_log)
        return list(self.ctx.group_log[gid])

    def group_loads(self) -> List[int]:
        """Values submitted per group (load-balance introspection)."""
        return list(self.submits_per_group)


class ServeLoop:
    """Greedy continuous-batching loop (host side, CPU-scale)."""

    def __init__(self, cfg, params, batch_size: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.mod = registry.family_module(cfg)
        self._decode = jax.jit(make_serve_step(cfg))
        self.cache = self.mod.init_cache(cfg, batch_size, max_len, jnp.dtype(cfg.dtype))
        self.steps = 0

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Teacher-forced prefill via decode steps, then greedy generation."""
        out: Dict[int, List[int]] = {}
        for chunk_start in range(0, len(requests), self.batch):
            chunk = requests[chunk_start : chunk_start + self.batch]
            b = len(chunk)
            plen = max(len(r.prompt) for r in chunk)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(chunk):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            cache = self.mod.init_cache(
                self.cfg, self.batch, self.max_len, jnp.dtype(self.cfg.dtype)
            )
            last = None
            for t in range(plen):
                last, cache = self._decode(
                    self.params, jnp.asarray(toks[:, t : t + 1]), cache, jnp.int32(t)
                )
                self.steps += 1
            gen = [[] for _ in range(b)]
            cur = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            max_new = max(r.max_new for r in chunk)
            for s in range(max_new):
                for i in range(b):
                    if s < chunk[i].max_new:
                        gen[i].append(int(cur[i, 0]))
                last, cache = self._decode(
                    self.params, cur, cache, jnp.int32(plen + s)
                )
                self.steps += 1
                cur = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            for i, r in enumerate(chunk):
                out[r.rid] = gen[i]
        return out
