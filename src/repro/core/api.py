"""The drop-in CAANS application API (paper Fig. 4).

    submit(ctx, value, size)          -> propose a value
    ctx.deliver = cb(value, size, inst)  (registered callback)
    recover(ctx, inst, nop, size)     -> learn a previously decided instance

A ``PaxosContext`` wires software proposers/learners to the "hardware"
coordinator/acceptor dataplane.  The dataplane is the jitted batched engine
(or the Pallas kernels when ``use_kernels=True``) — the same hardware/software
divide as the paper: applications only ever see ``submit``/``deliver``/
``recover``; everything between is the network's problem.

Messages between the host roles travel over the fault-injected ``SimNet``;
retransmission on timeout (counted in ``pump`` rounds) and duplicate
suppression at learners implement the paper's §3.1 failure-handling contract.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
from typing import Any
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.contracts import mirror_guard
from . import batched
from . import plan as plan_mod
from .network import SimNet
from .paxos import Coordinator as SoftCoordinator
from .plan import NO_ROUND, NOP_SENTINEL
from .snapshot import GroupSnapshot, RingReclamationMixin, SnapshotStore
from .types import (
    MSG_NOP,
    MSG_P1A,
    MSG_P2A,
    MSG_P2B,
    AcceptorState,
    CoordinatorState,
    MsgBatch,
    PaxosConfig,
)


def _wire_block(b: int) -> int:
    """Kernel batch-block size for a burst of ``b`` messages."""
    return plan_mod.wire_block(b)


def _wire_window_aligned(cfg: PaxosConfig, base: int, b: int) -> bool:
    """True iff a contiguous window [base, base+b) satisfies the Pallas
    ring-blocking invariants — the ONE definition both dataplanes consult
    (``core.plan.window_aligned``, DESIGN.md §2)."""
    return plan_mod.window_aligned(cfg.n_instances, base, b)


@dataclasses.dataclass
class _Pending:
    payload: bytes
    age: int = 0
    group: int = 0


class _DeferredRound:
    """Handle for a dispatched wave whose host read-back is deferred
    (DESIGN.md §11): the dispatch is in flight (or complete) device-side,
    and ``resolve()`` performs the device->host transfer plus the cohort
    row selection.  The double-buffered pump dispatches wave N+1 before
    resolving wave N, overlapping host planning/packing with device
    execution; host watermark mirrors were already advanced at dispatch
    time, so planning never waits on a resolve."""

    def __init__(self, fresh, value, inst, rows=None, axis=0):
        self._fresh = fresh     # device (or host) array, pre-selection
        self._value = value
        self._inst = inst       # host instance windows, already selected
        self._rows = None if rows is None else list(rows)
        self._axis = axis       # cohort-row axis of fresh/value

    @classmethod
    def resolved(cls, fresh, value, inst):
        """An already-host-side result wrapped for interface uniformity
        (the sharded dataplane reads back eagerly)."""
        return cls(fresh, value, inst, rows=None)

    def resolve(self):
        fresh = np.asarray(self._fresh)
        value = np.asarray(self._value)
        if self._rows is not None:
            fresh = np.take(fresh, self._rows, axis=self._axis)
            value = np.take(value, self._rows, axis=self._axis)
        return fresh, self._inst, value


class HardwareDataplane(RingReclamationMixin):
    """The coordinator + acceptor array + learner dedup memory, executing as
    single-dispatch device programs.

    Two execution paths (DESIGN.md §3):

      * ``pipeline()`` — the fused wire path: the whole Phase-2 round
        (sequence -> all-A vote -> quorum -> ring dedup) as ONE program; the
        Pallas megakernel ``kernels.wirepath.wirepath_round`` when
        ``use_kernels``, else the jnp oracle ``batched.fused_round``.  All
        protocol state stays resident in device memory across pump rounds.
      * ``sequence()``/``vote()``/``prepare()`` — the staged path, used when
        votes must surface as messages (per-learner fan-out, recovery,
        software-coordinator failover).  Still one dispatch for the whole
        acceptor array: the historical per-acceptor Python loop (and its
        per-vote ``.at[aid].set`` full-stack rewrites) is gone.

    Liveness is a device-resident runtime mask (``alive_mask``), so
    ``kill_acceptor``/``revive_acceptor`` never trigger recompilation.
    """

    def __init__(self, cfg: PaxosConfig, use_kernels: bool = False):
        self.cfg = cfg
        self.cstate = CoordinatorState.init()
        # acceptor register files, permanently stacked (A, ...) — the paper's
        # per-device BRAM, one shard per acceptor
        one = AcceptorState.init(cfg.n_instances, cfg.value_words)
        self.stack: AcceptorState = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_acceptors,) + x.shape).copy(), one
        )
        self.lstate = batched.LearnerState.init(cfg.n_instances, cfg.value_words)
        self.alive = [True] * cfg.n_acceptors       # host mirror (introspection)
        self.alive_mask = jnp.ones((cfg.n_acceptors,), jnp.bool_)
        self.use_kernels = use_kernels
        # host mirror of the sequencer watermark — lets the kernel path check
        # its block-alignment invariant without a device sync
        self._next_inst_host = 0
        # monotone count of device program launches (wire-path dispatches);
        # the KV tier pins its consensus-free read claim on this staying flat
        self.dispatch_count = 0
        self._seq_base: int | None = None        # provenance hint for vote()
        if use_kernels:
            from repro.kernels import ops as kops

            self._seq = kops.coordinator_sequence
            self._fused_k = jax.jit(kops.fused_round, donate_argnums=(1, 2))
            self._vote_all_k = jax.jit(
                kops.acceptor_phase2_all, donate_argnums=(0,)
            )
        else:
            self._seq = jax.jit(batched.coordinator_sequence)
        self._fused = jax.jit(batched.fused_round, donate_argnums=(1, 2))
        self._vote_all = jax.jit(batched.acceptor_phase2_all, donate_argnums=(0,))
        self._prep_all = jax.jit(batched.acceptor_phase1_all, donate_argnums=(0,))

    # -- wire-path invariants -------------------------------------------------
    def _block(self, b: int) -> int:
        return _wire_block(b)

    def _window_aligned(self, base: int, b: int) -> bool:
        return _wire_window_aligned(self.cfg, base, b)

    # -- ring reclamation: RingReclamationMixin at G == 1 (DESIGN.md §9) -----
    def _seq_marks(self) -> list[int]:
        return [self._next_inst_host]

    @property
    def reclaimed_host(self) -> int | None:
        """Scalar view of the single group's reclamation watermark (None
        while reclamation is disabled) — the historical public surface."""
        marks = self._reclaim_marks
        return None if marks is None else marks[0]

    def set_reclaimed(self, upto: int) -> None:
        """Advance the reclamation watermark: instances below ``upto`` have
        been drained to a snapshot and their ring slots may be re-used."""
        self._reclaim_set(0, upto)

    def _guard_capacity(self, base: int, b: int) -> None:
        self._reclaim_guard(0, base, b)

    # -- fused fast path: whole Phase-2 round in ONE device program ----------
    @mirror_guard
    def pipeline(self, values: np.ndarray, active: np.ndarray):
        """One dispatch: sequence + all acceptor votes + quorum + dedup.

        This is the CAANS wire path — consensus logic fused end-to-end below
        the host boundary (DESIGN.md §3).  Returns host ``(fresh, inst,
        value)`` where ``fresh`` masks non-duplicate deliveries.
        """
        b = values.shape[0]
        self._guard_capacity(self._next_inst_host, b)
        use_k = self.use_kernels and self._window_aligned(self._next_inst_host, b)
        fn = self._fused_k if use_k else self._fused
        args = [
            self.cstate,
            self.stack,
            self.lstate,
            jnp.asarray(values),
            jnp.asarray(active),
            self.alive_mask,
            self.cfg.quorum,
        ]
        if self.reclaimed_host is not None:
            args.append(
                jnp.int32(self.reclaimed_host + self.cfg.n_instances)
            )
        self.dispatch_count += 1
        self.cstate, self.stack, self.lstate, fresh, inst, _win, value = fn(
            *args
        )
        self._next_inst_host += b
        return np.asarray(fresh), np.asarray(inst), np.asarray(value)

    def kill_acceptor(self, aid: int) -> None:
        self.alive[aid] = False
        self.alive_mask = self.alive_mask.at[aid].set(False)

    def revive_acceptor(self, aid: int) -> None:
        self.alive[aid] = True
        self.alive_mask = self.alive_mask.at[aid].set(True)

    def wipe_acceptor(self, aid: int) -> None:
        """Model a crash WITH state loss: zero the acceptor's register file
        (its BRAM), unlike ``kill_acceptor`` which freezes it intact.  The
        revival path rebuilds from snapshot + live ring suffix
        (``core.failover.restore_acceptor``, DESIGN.md §9)."""
        fresh = AcceptorState.init(self.cfg.n_instances, self.cfg.value_words)
        self.stack = jax.tree_util.tree_map(
            lambda s, f: s.at[aid].set(f), self.stack, fresh
        )

    # -- staged path (votes surface as messages) -----------------------------
    @mirror_guard
    def sequence(self, values: np.ndarray, active: np.ndarray) -> MsgBatch:
        self._guard_capacity(self._next_inst_host, values.shape[0])
        self._seq_base = self._next_inst_host
        self.dispatch_count += 1
        self.cstate, p2a = self._seq(
            self.cstate, jnp.asarray(values), jnp.asarray(active)
        )
        self._next_inst_host += values.shape[0]
        return p2a

    def vote(self, p2a: MsgBatch) -> list[MsgBatch | None]:
        """Phase-2 vote of the whole acceptor array, one dispatch.

        Batches produced by ``sequence()`` (contiguous, block-aligned window)
        go through the Pallas wire-path kernel when ``use_kernels``; anything
        else (recovery singletons, software-coordinator batches at arbitrary
        watermarks) takes the general jnp scatter path.  Dead acceptors come
        back as ``None`` — their votes are never sent.
        """
        base, self._seq_base = self._seq_base, None
        b = p2a.batch
        use_k = (
            self.use_kernels
            and base is not None
            and self._window_aligned(base, b)
        )
        fn = self._vote_all_k if use_k else self._vote_all
        self.dispatch_count += 1
        self.stack, votes = fn(self.stack, p2a, self.alive_mask)
        return self._split(votes)

    def prepare(self, p1a: MsgBatch) -> list[MsgBatch | None]:
        self.dispatch_count += 1
        self.stack, outs = self._prep_all(self.stack, p1a, self.alive_mask)
        return self._split(outs)

    def _split(self, stacked: MsgBatch) -> list[MsgBatch | None]:
        """Stacked [A, ...] message batches -> per-acceptor list, None when
        dead (a crashed switch emits nothing)."""
        return [
            jax.tree_util.tree_map(lambda x, aid=aid: x[aid], stacked)
            if self.alive[aid]
            else None
            for aid in range(self.cfg.n_acceptors)
        ]


class _GroupView:
    """Single-group staged-path adapter over one group's slice of the stack.

    Exposes the ``prepare``/``vote``/``cfg`` surface that ``core.failover``
    and the recovery path expect from a ``HardwareDataplane``, but reads and
    writes only group ``gid``'s rows of the multi-group ``(G, A, N)`` state —
    the other groups' registers are never touched.  Not a fast path: recovery
    and failover traffic only.
    """

    def __init__(self, mg: "MultiGroupDataplane", gid: int):
        self.mg = mg
        self.gid = gid

    @property
    def cfg(self) -> PaxosConfig:
        return self.mg.cfg

    def vote(self, p2a: MsgBatch) -> list[MsgBatch | None]:
        mg, gid = self.mg, self.gid
        row = mg._slab_row(gid)
        mg.dispatch_count += 1
        st = jax.tree_util.tree_map(lambda x: x[row], mg.stack)
        st, votes = mg._vote_all(st, p2a, mg.alive_mask[gid])
        mg.stack = jax.tree_util.tree_map(
            lambda s, n: s.at[row].set(n), mg.stack, st
        )
        return self._split(votes)

    def prepare(self, p1a: MsgBatch) -> list[MsgBatch | None]:
        mg, gid = self.mg, self.gid
        row = mg._slab_row(gid)
        mg.dispatch_count += 1
        st = jax.tree_util.tree_map(lambda x: x[row], mg.stack)
        st, outs = mg._prep_all(st, p1a, mg.alive_mask[gid])
        mg.stack = jax.tree_util.tree_map(
            lambda s, n: s.at[row].set(n), mg.stack, st
        )
        return self._split(outs)

    def _split(self, stacked: MsgBatch) -> list[MsgBatch | None]:
        gid = jnp.int32(self.gid)
        return [
            jax.tree_util.tree_map(lambda x, aid=aid: x[aid], stacked).replace(
                gid=gid
            )
            if self.mg.alive[self.gid][aid]
            else None
            for aid in range(self.cfg.n_acceptors)
        ]


class MultiGroupDataplane(RingReclamationMixin):
    """G device-resident Paxos groups sharing one fused dispatch per round —
    consensus as a service, the NetChain-style generalization of
    ``HardwareDataplane`` (DESIGN.md §5).

    State is the single-group layout grown a leading group axis: ``(G,)``
    coordinator watermarks/rounds, ``(G, A, N)`` acceptor rings, ``(G, N)``
    learner rings, a ``(G, A)`` runtime liveness mask.  ``pipeline`` advances
    *every* group one Phase-2 round in one device program — the Pallas
    multi-group megakernel when ``use_kernels`` and every group's watermark
    is block-aligned (folding all groups into each grid step when the host
    watermark mirrors are in lockstep), else the vmapped jnp oracle.

    Per-group failover support: ``freeze_group`` parks a group's coordinator
    round at ``NO_ROUND`` so the shared dispatch can keep running — a frozen
    group's slots are all rejected, deciding (and perturbing) nothing — and
    ``restore_group`` realigns the group's watermark/round after a software
    coordinator hands back control.  ``group_view`` exposes one group's
    staged surface for recovery and takeover.

    Dynamic membership (DESIGN.md §7): ``cfg.n_groups`` is a *capacity* —
    the ``(G_cap, A, N)`` slabs stay allocated at it, and a host-side
    free-list over the group axis lets tenants come and go at runtime.
    ``retire_group`` is host-scalar-only (drain + park at NO_ROUND + free),
    ``create_group`` claims the lowest free slot and zeroes only that slot's
    rings; neither touches any other group's slab state.
    """

    def __init__(self, cfg: PaxosConfig, use_kernels: bool = False):
        if cfg.n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {cfg.n_groups}")
        self.cfg = cfg
        g, a = cfg.n_groups, cfg.n_acceptors
        self.cstate, self.stack, self.lstate = batched.init_multigroup_state(
            g, a, cfg.n_instances, cfg.value_words
        )
        self.alive = [[True] * a for _ in range(g)]   # host mirror
        self.alive_mask = jnp.ones((g, a), jnp.bool_)
        # dynamic membership: every capacity slot starts live; the free-list
        # (sorted, lowest-first: deterministic allocation) holds vacant slots
        self.live_host: list[bool] = [True] * g
        self._free: list[int] = []
        self.use_kernels = use_kernels
        # per-group host mirrors of the sequencer watermark and round — the
        # kernel path's alignment/lockstep decisions cost no device sync
        self.next_inst_host: list[int] = [0] * g
        self.crnd_host: list[int] = [0] * g
        # monotone device-program-launch counter (see HardwareDataplane)
        self.dispatch_count = 0
        self.last_gb: int | None = None   # fold width of the last dispatch
        if use_kernels:
            from repro.kernels import ops as kops

            self._fused_k = jax.jit(
                kops.multigroup_fused_round,
                donate_argnums=(1, 2),
                static_argnames=("group_block",),
            )
            self._cohort_k = jax.jit(
                kops.cohort_fused_round,
                donate_argnums=(0, 1),
                static_argnames=("group_block",),
            )
            self._persist_k = jax.jit(
                kops.persistent_cohort_rounds,
                donate_argnums=(0, 1),
                static_argnames=("group_block", "block_b"),
            )
        self._fused = jax.jit(
            batched.multigroup_fused_round, donate_argnums=(1, 2)
        )
        self._persist_j = jax.jit(
            batched.persistent_multigroup_rounds, donate_argnums=(1, 2)
        )
        self._vote_all = jax.jit(batched.acceptor_phase2_all)
        self._prep_all = jax.jit(batched.acceptor_phase1_all)

    # -- wire-path invariants (shared definition: _wire_window_aligned) ------
    def _block(self, b: int) -> int:
        return _wire_block(b)

    def _window_aligned(self, base: int, b: int) -> bool:
        return _wire_window_aligned(self.cfg, base, b)

    # -- ring reclamation: RingReclamationMixin per group (DESIGN.md §9) -----
    def _seq_marks(self) -> list[int]:
        return self.next_inst_host

    @property
    def reclaimed_host(self) -> list[int] | None:
        """Per-group watermark vector (None while disabled).  The list IS
        the mixin's live state: membership paths (``create_group``/
        ``adopt_group``) reset their slot in place."""
        return self._reclaim_marks

    def set_reclaimed(self, gid: int, upto: int) -> None:
        """Advance group ``gid``'s reclamation watermark after a snapshot
        drain of instances below ``upto``."""
        self._check_gid(gid)
        self._reclaim_set(gid, upto)

    def _reclaim_limits(self) -> jax.Array | None:
        """Device form of the mixin's first-refused-instance vector."""
        lim = self._reclaim_limits_np()
        return None if lim is None else jnp.asarray(lim)

    def _guard_capacity(self, gids, b: int) -> None:
        for gid in gids:
            self._reclaim_guard(gid, self.next_inst_host[gid], b)

    # -- shared pre-dispatch plan (the parity contract between this class
    # and its sharded subclass: both MUST resolve a round identically) ------
    def _fold_width(self) -> int:
        """Groups folded per grid step under lockstep (the whole service
        here; one shard's slab in the sharded subclass)."""
        return self.cfg.n_groups

    def _plan_round(self, b: int, enabled: list[bool] | None):
        """Resolve the enabled mask against membership and frozen rounds,
        decide kernel eligibility from the host watermark mirrors, and pick
        the fold width (``core.plan.fold_width_full`` — the widest divisor
        of the fold cap whose aligned blocks are internally lockstep, not
        the historical all-or-nothing fold).  Returns
        ``(enabled, use_k, group_block)``.

        Only *enabled* groups constrain the plan: a disabled group — frozen,
        vacant (retired), or idle this round — rides the dispatch inert at
        whatever watermark it has (the kernel's enabled-mask path substitutes
        a folded block's ring offset for it), so divergent disabled
        watermarks neither break alignment nor forfeit the lockstep fold."""
        if enabled is None:
            enabled = [
                lv and c != NO_ROUND
                for lv, c in zip(self.live_host, self.crnd_host, strict=True)
            ]
        else:
            enabled = [
                bool(e) and lv and c != NO_ROUND
                for e, lv, c in zip(enabled, self.live_host, self.crnd_host, strict=True)
            ]
        en_gids = [i for i, e in enumerate(enabled) if e]
        use_k = self.use_kernels and all(
            self._window_aligned(self.next_inst_host[g], b) for g in en_gids
        )
        gb = plan_mod.fold_width_full(
            en_gids, self.next_inst_host, self._fold_width()
        )
        return enabled, use_k, gb

    def _empty_round(self, g: int, b: int):
        """The all-disabled result: nothing would decide, skip dispatch."""
        return (
            np.zeros((g, b), np.int32),
            np.zeros((g, b), np.int32),
            np.zeros((g, b, self.cfg.value_words), np.int32),
        )

    # -- fused fast path: ALL groups advance one round in ONE dispatch -------
    @mirror_guard
    def pipeline(
        self,
        values: np.ndarray,
        active: np.ndarray,
        enabled: list[bool] | None = None,
    ):
        """One dispatch for all G groups: sequence + votes + quorum + dedup.

        ``values`` is ``(G, B, V)``, ``active`` ``(G, B)``.  ``enabled``
        masks which groups actually advance this round (default: those whose
        round is not frozen).  A disabled group rides along *inert*: its
        round is presented to the dispatch as NO_ROUND so its acceptors
        reject every slot, and its watermark does not move — so an idle
        group burns no ring instances and its state stays bit-identical to
        an independent deployment that simply wasn't pumped.  Returns host
        ``(fresh, inst, value)`` with a leading group axis.
        """
        g, b = values.shape[0], values.shape[1]
        enabled, use_k, gb = self._plan_round(b, enabled)
        if not any(enabled):
            return self._empty_round(g, b)
        self._guard_capacity(
            [gid for gid in range(g) if enabled[gid]], b
        )
        lim = self._reclaim_limits()
        en = jnp.asarray(enabled)
        if use_k:
            # the kernel takes the membership mask itself (enabled-mask
            # path): it forces disabled rounds to NO_ROUND and substitutes
            # folded-block watermarks for vacant/frozen members
            fn = functools.partial(
                self._fused_k,
                group_block=gb,
                enabled=en.astype(jnp.int32),
                reclaim_limit=lim,
            )
        elif lim is not None:
            fn = functools.partial(self._fused, reclaim_limit=lim)
        else:
            fn = self._fused
        cs = self.cstate
        eff = CoordinatorState(
            next_inst=cs.next_inst, crnd=jnp.where(en, cs.crnd, NO_ROUND)
        )
        self.dispatch_count += 1
        new_c, self.stack, self.lstate, fresh, inst, _win, value = fn(
            eff,
            self.stack,
            self.lstate,
            jnp.asarray(values),
            jnp.asarray(active),
            self.alive_mask,
            self.cfg.quorum,
        )
        # disabled groups keep their watermark and their true round
        self.cstate = CoordinatorState(
            next_inst=jnp.where(en, new_c.next_inst, cs.next_inst),
            crnd=cs.crnd,
        )
        for gid in range(g):
            if enabled[gid]:
                self.next_inst_host[gid] += b
        self.last_gb = gb          # the plan's fold width, engine-agnostic
        return np.asarray(fresh), np.asarray(inst), np.asarray(value)

    # -- cohort dispatch: one tier of a RoundPlan (DESIGN.md §8) -------------
    def _cohort_prologue(self, gids, values: np.ndarray):
        """Shared pre-dispatch resolution for a cohort tier: membership
        mask, kernel eligibility (every member's window aligned for this
        burst), and the per-member instance windows — identical for the
        unsharded and sharded executions, which is half the parity
        contract."""
        gids = list(gids)
        be = values.shape[1]
        assert values.shape[0] == len(gids), (values.shape, len(gids))
        marks = self.next_inst_host
        member = np.zeros((self.cfg.n_groups,), np.int32)
        member[gids] = 1
        use_k = self.use_kernels and all(
            self._window_aligned(marks[gid], be) for gid in gids
        )
        inst = np.stack(
            [
                np.arange(marks[gid], marks[gid] + be, dtype=np.int32)
                for gid in gids
            ]
        )
        return gids, member, use_k, inst

    @mirror_guard
    def pipeline_cohort(
        self, gids, values: np.ndarray, active: np.ndarray,
        defer: bool = False,
    ):
        """Advance exactly the cohort ``gids`` one ``BE``-sized round.

        ``values`` is *compact* ``(len(gids), BE, V)`` (row order = cohort
        order), ``active`` ``(len(gids), BE)``.  Non-members neither move
        nor mutate — a cold group is simply not a member of the hot tier's
        dispatch.  On the kernel path the grid is additionally *compacted*
        over the group axis (``core.plan.cohort_blocks`` +
        ``kernels.wirepath.cohort_wirepath_round``): only the group blocks
        containing members are visited, so a one-hot-group tier costs one
        group's work, not G's.  Returns host ``(fresh, inst, value)`` in
        cohort row order — or, with ``defer=True``, a ``_DeferredRound``
        whose ``resolve()`` yields the same triple one wave later
        (DESIGN.md §11); host watermark mirrors advance at dispatch time
        either way.
        """
        gids, member, use_k, inst = self._cohort_prologue(gids, values)
        g = self.cfg.n_groups
        be = values.shape[1]
        self._guard_capacity(gids, be)
        lim = self._reclaim_limits()
        marks = self.next_inst_host
        # the compact mapping is the dispatch plan whether or not the
        # kernel executes it; last_gb reports its fold width on both
        # engines, so introspection never depends on engine choice
        gb, blocks = plan_mod.cohort_blocks(gids, marks, self._fold_width())
        self.last_gb = gb
        self.dispatch_count += 1
        en = jnp.asarray(member)
        if use_k:
            # compact kernel layout: row j*gb + k <-> group blocks[j]*gb + k
            rowof = {
                blk * gb + k: j * gb + k
                for j, blk in enumerate(blocks)
                for k in range(gb)
            }
            kvals = np.zeros(
                (len(blocks) * gb, be, self.cfg.value_words), np.int32
            )
            kvals[:, :, 0] = NOP_SENTINEL
            for row, gid in enumerate(gids):
                kvals[rowof[gid]] = values[row]
            self.stack, self.lstate, kfresh, _win, kvalue = self._cohort_k(
                self.stack,
                self.lstate,
                jnp.asarray(np.asarray(blocks, np.int32)),
                self.cstate.next_inst,
                self.cstate.crnd,
                self.alive_mask,
                self.cfg.quorum,
                jnp.asarray(kvals),
                en,
                reclaim_limit=lim,
                group_block=gb,
            )
            rows = [rowof[gid] for gid in gids]
            dfresh, dvalue = kfresh, kvalue
        else:
            # jnp oracle: full-width dispatch with non-members held inert
            # (round presented as NO_ROUND) — bit-identical results
            vals_f, act_f = plan_mod.scatter_rows(
                gids, values, active, g, self.cfg.value_words
            )
            cs = self.cstate
            eff = CoordinatorState(
                next_inst=cs.next_inst,
                crnd=jnp.where(en != 0, cs.crnd, NO_ROUND),
            )
            _c, self.stack, self.lstate, ffresh, _i, _w, fvalue = self._fused(
                eff,
                self.stack,
                self.lstate,
                jnp.asarray(vals_f),
                jnp.asarray(act_f),
                self.alive_mask,
                self.cfg.quorum,
                reclaim_limit=lim,
            )
            rows = list(gids)
            dfresh, dvalue = ffresh, fvalue
        memj = jnp.asarray(member != 0)
        self.cstate = CoordinatorState(
            next_inst=jnp.where(
                memj, self.cstate.next_inst + be, self.cstate.next_inst
            ),
            crnd=self.cstate.crnd,
        )
        for gid in gids:
            self.next_inst_host[gid] += be
        handle = _DeferredRound(dfresh, dvalue, inst, rows=rows, axis=0)
        return handle if defer else handle.resolve()

    def _wave_block(self, be: int, bases) -> int:
        """Batch-block size for a persistent wave: upgrade to one grid step
        per round (``bb = be``) when every member's base — and therefore
        every subsequent window base, each round advancing by ``be`` —
        is ``be``-aligned; else the ordinary wire block.  A perf-only
        choice: block size never changes results."""
        if (
            self.cfg.n_instances % be == 0
            and all(base % be == 0 for base in bases)
        ):
            return be
        return self._block(be)

    @mirror_guard
    def pipeline_persistent(
        self, gids, values: np.ndarray, active: np.ndarray,
        defer: bool = False,
    ):
        """Advance the cohort ``gids`` K back-to-back full rounds in ONE
        device dispatch (DESIGN.md §11): the wave descriptor (per-round
        window bases + participation) rides scalar prefetch, the chunk
        queue rides device-resident, and results sync back to host once
        per wave instead of once per round.

        ``values`` is ``(K, len(gids), BE, V)`` — round-major, row order =
        cohort order — and ``active`` ``(K, len(gids), BE)``.  Every member
        participates in every round (the planner only mints K > 1 when each
        member has K full chunks queued), windows are consecutive
        ``BE``-slices from each member's watermark, and delivery is
        bit-identical to K sequential ``pipeline_cohort`` calls.  Returns
        host ``(fresh[K, M, BE], inst[K, M, BE], value[K, M, BE, V])``, or
        a ``_DeferredRound`` with ``defer=True``.
        """
        k, be = values.shape[0], values.shape[2]
        if k * be > self.cfg.n_instances:
            raise ValueError(
                f"persistent wave of {k} x {be} instances would lap the "
                f"{self.cfg.n_instances}-instance ring"
            )
        gids, member, use_k, _inst0 = self._cohort_prologue(gids, values[0])
        g = self.cfg.n_groups
        marks = self.next_inst_host
        # guard the wave's LAST window up front: an over-watermark wave
        # must fail before any state moves, never mid-wave
        for gid in gids:
            self._reclaim_guard(gid, marks[gid] + (k - 1) * be, be)
        lim = self._reclaim_limits()
        gb, blocks = plan_mod.cohort_blocks(gids, marks, self._fold_width())
        self.last_gb = gb
        self.dispatch_count += 1
        # wave descriptor: cumulative window-base table + participation
        # (rows for non-members are ignored — the kernel substitutes the
        # folded block's lockstep base for them)
        wni = np.zeros((k, g), np.int32)
        wen = np.zeros((k, g), np.int32)
        steps = np.arange(k, dtype=np.int32) * be
        for gid in gids:
            wni[:, gid] = marks[gid] + steps
            wen[:, gid] = 1
        inst = np.stack(
            [
                np.stack(
                    [
                        np.arange(w, w + be, dtype=np.int32)
                        for w in wni[r, gids]
                    ]
                )
                for r in range(k)
            ]
        )
        if use_k:
            rowof = {
                blk * gb + kk: j * gb + kk
                for j, blk in enumerate(blocks)
                for kk in range(gb)
            }
            kvals = np.zeros(
                (k, len(blocks) * gb, be, self.cfg.value_words), np.int32
            )
            kvals[:, :, :, 0] = NOP_SENTINEL
            for row, gid in enumerate(gids):
                kvals[:, rowof[gid]] = values[:, row]
            self.stack, self.lstate, kfresh, _win, kvalue = self._persist_k(
                self.stack,
                self.lstate,
                jnp.asarray(np.asarray(blocks, np.int32)),
                jnp.asarray(wni),
                jnp.asarray(wen),
                self.cstate.crnd,
                self.alive_mask,
                self.cfg.quorum,
                jnp.asarray(kvals),
                reclaim_limit=lim,
                group_block=gb,
                block_b=self._wave_block(be, [marks[gid] for gid in gids]),
            )
            rows = [rowof[gid] for gid in gids]
            dfresh, dvalue = kfresh, kvalue
        else:
            # jnp oracle: full-width scatter per round, K-unrolled under
            # one jit — still one dispatch, bit-identical results
            per_round = [
                plan_mod.scatter_rows(
                    gids, values[r], active[r], g, self.cfg.value_words
                )
                for r in range(k)
            ]
            vals_f = np.stack([v for v, _ in per_round])
            act_f = np.stack([a for _, a in per_round])
            _c, self.stack, self.lstate, pfresh, _pi, _pw, pvalue = (
                self._persist_j(
                    self.cstate,
                    self.stack,
                    self.lstate,
                    jnp.asarray(vals_f),
                    jnp.asarray(act_f),
                    self.alive_mask,
                    self.cfg.quorum,
                    enabled_rounds=jnp.asarray(wen != 0),
                    reclaim_limit=lim,
                )
            )
            rows = list(gids)
            dfresh, dvalue = pfresh, pvalue
        memj = jnp.asarray(member != 0)
        self.cstate = CoordinatorState(
            next_inst=jnp.where(
                memj, self.cstate.next_inst + k * be, self.cstate.next_inst
            ),
            crnd=self.cstate.crnd,
        )
        for gid in gids:
            self.next_inst_host[gid] += k * be
        handle = _DeferredRound(dfresh, dvalue, inst, rows=rows, axis=1)
        return handle if defer else handle.resolve()

    @mirror_guard
    def burn_forward(self, gid: int, target: int) -> None:
        """Advance a group's sequencer watermark to ``target`` without
        proposing anything: the skipped instances are NOP holes, never
        decided and recoverable as no-ops (paper §3.1 gap fill).  The
        planner's realignment sweep uses this to bring divergent groups
        back to a common block boundary so the full-width fold re-engages
        (DESIGN.md §8)."""
        self._check_gid(gid)
        if target < self.next_inst_host[gid]:
            raise ValueError(
                f"burn_forward moves only forward: {target} < "
                f"{self.next_inst_host[gid]} (group {gid})"
            )
        self.cstate = CoordinatorState(
            next_inst=self.cstate.next_inst.at[gid].set(target),
            crnd=self.cstate.crnd,
        )
        self.next_inst_host[gid] = target

    # -- per-group liveness and failover -------------------------------------
    def _check_gid(self, gid: int) -> None:
        if not 0 <= gid < self.cfg.n_groups:
            raise ValueError(f"group {gid} out of range [0, {self.cfg.n_groups})")

    def kill_acceptor(self, gid: int, aid: int) -> None:
        self._check_gid(gid)
        self.alive[gid][aid] = False
        self.alive_mask = self.alive_mask.at[gid, aid].set(False)

    def revive_acceptor(self, gid: int, aid: int) -> None:
        self._check_gid(gid)
        self.alive[gid][aid] = True
        self.alive_mask = self.alive_mask.at[gid, aid].set(True)

    def wipe_acceptor(self, gid: int, aid: int) -> None:
        """Crash WITH state loss: zero one acceptor's register rows of one
        group (its BRAM); revival rebuilds from snapshot + live ring suffix
        (``core.failover.restore_acceptor``, DESIGN.md §9)."""
        self._check_gid(gid)
        row = self._slab_row(gid)
        fresh = AcceptorState.init(self.cfg.n_instances, self.cfg.value_words)
        self.stack = jax.tree_util.tree_map(
            lambda s, f: s.at[row, aid].set(f), self.stack, fresh
        )

    @mirror_guard
    def freeze_group(self, gid: int) -> None:
        """Park a group's hardware round at NO_ROUND while a software
        coordinator owns it: every slot the shared dispatch sequences for the
        group is rejected by its acceptors (NO_ROUND < any promised round),
        so nothing is decided and no state mutates — the group is inert in
        the pipeline without recompiling or excluding it."""
        self._check_gid(gid)
        self.cstate = CoordinatorState(
            next_inst=self.cstate.next_inst,
            crnd=self.cstate.crnd.at[gid].set(NO_ROUND),
        )
        self.crnd_host[gid] = NO_ROUND

    @mirror_guard
    def restore_group(self, gid: int, next_inst: int, crnd: int) -> None:
        """Hand a group back to the hardware sequencer at the watermark and
        round the software coordinator reached (block-realigned on the kernel
        path — the skipped instances are never proposed and are recoverable
        as no-ops, exactly as in the single-group restore)."""
        self._check_gid(gid)
        if self.use_kernels:
            bb = self._block(self.cfg.batch)
            next_inst = -(-next_inst // bb) * bb
        self.cstate = CoordinatorState(
            next_inst=self.cstate.next_inst.at[gid].set(next_inst),
            crnd=self.cstate.crnd.at[gid].set(crnd),
        )
        self.next_inst_host[gid] = next_inst
        self.crnd_host[gid] = crnd

    def group_view(self, gid: int) -> _GroupView:
        """The staged single-group surface over group ``gid`` (recovery and
        takeover traffic; the fast path stays in ``pipeline``)."""
        self._check_gid(gid)
        return _GroupView(self, gid)

    # -- dynamic membership: a free-list over the group axis (DESIGN.md §7) --
    def _check_live(self, gid: int) -> None:
        self._check_gid(gid)
        if not self.live_host[gid]:
            raise ValueError(f"group {gid} is retired")

    def live_groups(self) -> list[int]:
        """Currently live group ids, ascending (the routing domain)."""
        return [g for g in range(self.cfg.n_groups) if self.live_host[g]]

    def _slab_row(self, gid: int) -> int:
        """Physical slab row of group ``gid``.  Identity here; the sharded
        subclass translates through its ``PlacementMap`` so every slab
        access (recovery views, wipes, slot resets, ring drains) lands on
        the group's current placement (DESIGN.md §13)."""
        return gid

    def _reset_group_slab(self, gid: int) -> None:
        """Zero ONE group's acceptor and learner rings — a fresh tenant's
        slot.  Touches only the group's slab row (the sharded subclass
        re-pins placement before its next fused dispatch, exactly like the
        staged recovery surface)."""
        n, v, a = (
            self.cfg.n_instances,
            self.cfg.value_words,
            self.cfg.n_acceptors,
        )
        row = self._slab_row(gid)
        one = AcceptorState.init(n, v)
        fresh = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (a,) + x.shape), one
        )
        self.stack = jax.tree_util.tree_map(
            lambda s, f: s.at[row].set(f), self.stack, fresh
        )
        self.lstate = jax.tree_util.tree_map(
            lambda s, f: s.at[row].set(f),
            self.lstate,
            batched.LearnerState.init(n, v),
        )

    @mirror_guard
    def create_group(self) -> int:
        """Claim a free slot on the group axis: zeroed rings, fresh
        watermark/round, all acceptors alive.  Deterministic (lowest free
        gid first).  Raises when the service is at capacity."""
        if not self._free:
            raise RuntimeError(
                f"no free group slots (capacity n_groups={self.cfg.n_groups})"
            )
        gid = self._free.pop(0)
        self._reset_group_slab(gid)
        self.live_host[gid] = True
        for aid in range(self.cfg.n_acceptors):
            self.revive_acceptor(gid, aid)
        # fresh sequencer: watermark 0, round 0 (restore_group also resyncs
        # the device/host scalar mirrors, polymorphically per subclass)
        self.restore_group(gid, 0, 0)
        if self.reclaimed_host is not None:
            self.reclaimed_host[gid] = 0
        return gid

    @mirror_guard
    def adopt_group(self, watermark: int) -> int:
        """Claim a free slot for a tenant bootstrapping from a transferred
        snapshot (vertical-Paxos state transfer, DESIGN.md §9): the slot's
        rings are zeroed and both the sequencer watermark and the
        reclamation watermark start at the snapshot's — the history below
        it lives in the ``SnapshotStore``; instances below the watermark
        are never proposed again.  Requires reclamation to be enabled
        (without it a wrapped snapshot watermark has no meaning).  Returns
        the claimed gid.  On the kernel path the sequencer realigns up to
        the next block boundary — the gap instances are permanent NOP
        holes, exactly as in ``restore_group``."""
        if self.reclaimed_host is None:
            raise ValueError("adopt_group requires reclamation enabled")
        if watermark < 0:
            raise ValueError(f"negative snapshot watermark {watermark}")
        gid = self.create_group()
        self.restore_group(gid, watermark, 0)
        self.reclaimed_host[gid] = watermark
        return gid

    def retire_group(self, gid: int) -> list[tuple[int, bytes]]:
        """Retire a live group: drain its learner ring to a host log, park
        its round at ``NO_ROUND`` (inert in the shared dispatch, exactly
        like freeze), and return the slot to the free-list.  Host scalars
        only — no other group's slab state is touched, and the slabs
        themselves do not move (the slot is zeroed lazily at the next
        ``create_group``).  Returns the drained ``(inst, value_bytes)``
        pairs in instance order — the decided values still resident in the
        retiring group's dedup ring."""
        self._check_live(gid)
        row = self._slab_row(gid)
        ld = np.asarray(self.lstate.delivered[row])
        li = np.asarray(self.lstate.inst[row])
        lv = np.asarray(self.lstate.value[row])
        slots = np.nonzero(ld != 0)[0]
        order = slots[np.argsort(li[slots], kind="stable")]
        drained = [(int(li[s]), lv[s].tobytes()) for s in order]
        self.live_host[gid] = False
        self.freeze_group(gid)
        bisect.insort(self._free, gid)
        return drained


class ShardedMultiGroupDataplane(MultiGroupDataplane):
    """``MultiGroupDataplane`` with the group axis partitioned over a device
    mesh (DESIGN.md §6): the ``(G, A, N)`` acceptor rings, ``(G, N)`` learner
    rings and per-group burst slabs shard over a ``groups`` mesh axis via
    ``shard_map``, so the number of device-resident groups scales linearly
    with device count instead of one chip's VMEM/HBM.

    Placement is contiguous slabs: shard ``s`` owns groups
    ``[s*Gl, (s+1)*Gl)`` with ``Gl = G / n_shards``.  Per-group scalar
    control state — the watermark/round vectors and the ``(G, A)`` liveness
    mask — is *host-authoritative* numpy, entering each dispatch replicated;
    ``freeze_group``/``restore_group``/``kill_acceptor`` therefore flip host
    scalars only and reach the owning shard with the next dispatch — no
    global device round-trip, and the big slabs never move.  On a 1-device
    mesh every dispatch reduces bit-exactly to ``MultiGroupDataplane``, so
    the existing parity suites double as its regression net.
    """

    def __init__(
        self,
        cfg: PaxosConfig,
        mesh=None,
        axis: str = "groups",
        use_kernels: bool = False,
    ):
        if mesh is None:
            from repro.launch.mesh import make_group_mesh

            mesh = make_group_mesh()
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
        n_sh = mesh.shape[axis]
        if cfg.n_groups % n_sh:
            raise ValueError(
                f"n_groups={cfg.n_groups} must be divisible by the {axis!r} "
                f"mesh axis size {n_sh}"
            )
        super().__init__(cfg, use_kernels=use_kernels)
        self.mesh = mesh
        self.axis = axis
        self.n_shards = n_sh
        self.groups_per_shard = cfg.n_groups // n_sh
        g, a = cfg.n_groups, cfg.n_acceptors
        # host-authoritative scalar control state (mirrors next_inst_host /
        # crnd_host, which the parent already maintains)
        self.cstate = CoordinatorState(
            next_inst=np.zeros((g,), np.int32), crnd=np.zeros((g,), np.int32)
        )
        self.alive_mask = np.ones((g, a), np.int32)
        # big slabs: device-resident, leading group axis sharded over the mesh
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        self._slab_sharding = NamedSharding(mesh, P(axis))
        self.stack = jax.device_put(self.stack, self._slab_sharding)
        self.lstate = jax.device_put(self.lstate, self._slab_sharding)
        self._dispatches: dict[tuple[bool, int], Any] = {}
        self._packed_dispatches: dict[bool, Any] = {}
        # group -> physical slot permutation (DESIGN.md §13); identity at
        # boot, mutated only by ``migrate_group`` slot swaps.  Device slabs
        # are SLOT-indexed; every host mirror stays gid-indexed and the
        # translation happens exactly once, at the dispatch/slab boundary.
        self._placement = plan_mod.PlacementMap.identity(
            cfg.n_groups, self.groups_per_shard
        )

    def _fold_width(self) -> int:
        # lockstep folds one shard's slab per grid step (a block has a
        # single ring offset, and a shard sees only its own slab); on a
        # 1-device mesh this is the parent's full-service fold
        return self.groups_per_shard

    # -- placement (consumed by serve.ConsensusService) ----------------------
    @property
    def placement(self) -> plan_mod.PlacementMap:
        return self._placement

    def _slab_row(self, gid: int) -> int:
        return self._placement.slot_of[gid]

    def shard_of_group(self, gid: int) -> int:
        """Mesh shard owning group ``gid`` under the current placement."""
        self._check_gid(gid)
        return self._placement.shard_of(gid)

    def group_placement(self) -> list[int]:
        """group id -> owning shard, for the whole service."""
        pm = self._placement
        return [pm.shard_of(g) for g in range(self.cfg.n_groups)]

    def plan_placement(self, loads: Sequence[int]) -> plan_mod.PlacementMap:
        """The load-weighted placement this service *would* adopt for the
        given per-group loads (``PlacementMap.weighted``); pure planning —
        adopting it is a sequence of ``migrate_group`` slot swaps."""
        return plan_mod.PlacementMap.weighted(
            loads, self.n_shards, self.groups_per_shard
        )

    # -- dispatch construction ----------------------------------------------
    def _dispatch(self, use_k: bool, gb: int):
        key = (use_k, gb)
        fn = self._dispatches.get(key)
        if fn is None:
            from .fabric import make_sharded_multigroup_round

            fn = make_sharded_multigroup_round(
                self.mesh,
                n_groups=self.cfg.n_groups,
                quorum=self.cfg.quorum,
                axis=self.axis,
                use_kernels=use_k,
                group_block=gb,
            )
            self._dispatches[key] = fn
        return fn

    def _packed_dispatch(self, use_k: bool):
        fn = self._packed_dispatches.get(use_k)
        if fn is None:
            from .fabric import make_packed_sharded_round

            fn = make_packed_sharded_round(
                self.mesh,
                quorum=self.cfg.quorum,
                axis=self.axis,
                use_kernels=use_k,
            )
            self._packed_dispatches[use_k] = fn
        return fn

    def _ensure_placement(self) -> None:
        # recovery/failover traffic (``group_view``) rewrites one group's
        # slab with gather/scatter updates whose output sharding is
        # unconstrained; re-pin before the next sharded dispatch (a no-op
        # when placement is already correct)
        self.stack = jax.device_put(self.stack, self._slab_sharding)
        self.lstate = jax.device_put(self.lstate, self._slab_sharding)

    # -- fused fast path: all shards advance their slabs in ONE dispatch ----
    @mirror_guard
    def pipeline(
        self,
        values: np.ndarray,
        active: np.ndarray,
        enabled: list[bool] | None = None,
    ):
        """Same contract (and bit-identical results) as
        ``MultiGroupDataplane.pipeline``, executed as one ``shard_map``
        program over the group slabs."""
        g, b = values.shape[0], values.shape[1]
        enabled, use_k, _ = self._plan_round(b, enabled)
        if not any(enabled):
            return self._empty_round(g, b)
        self._guard_capacity(
            [gid for gid in range(g) if enabled[gid]], b
        )
        pm = self._placement
        # the fold's lockstep blocks are SLOT blocks (the kernel walks
        # physical slab rows), so the width derives from slot-ordered marks
        perm = list(pm.group_of)       # slot -> gid
        marks_slot = [self.next_inst_host[gid] for gid in perm]
        slots = [pm.slot_of[gid] for gid in range(g) if enabled[gid]]
        gb = plan_mod.fold_width_full(slots, marks_slot, self._fold_width())
        plan_gb = gb               # reported engine-agnostically (last_gb)
        if not use_k:
            gb = 1
        self._ensure_placement()
        ni = np.asarray(self.next_inst_host, np.int32)[perm]
        en = np.asarray(enabled, np.int32)[perm]
        eff_crnd = np.where(
            en != 0, np.asarray(self.crnd_host, np.int32)[perm], NO_ROUND
        ).astype(np.int32)
        lim = self._reclaim_limits_np()
        fn = self._dispatch(use_k, gb)
        self.dispatch_count += 1
        self.stack, self.lstate, fresh, inst, _win, value = fn(
            ni,
            eff_crnd,
            en,
            self.alive_mask[perm],
            self.stack,
            self.lstate,
            jnp.asarray(np.asarray(values)[perm]),
            jnp.asarray(np.asarray(active)[perm]),
            reclaim_limit=None if lim is None else lim[perm],
        )
        for gid in range(g):
            if enabled[gid]:
                self.next_inst_host[gid] += b
        self._sync_cstate()
        self.last_gb = plan_gb
        inv = list(pm.slot_of)         # gid -> slot: gather back to gid order
        return (
            np.asarray(fresh)[inv],
            np.asarray(inst)[inv],
            np.asarray(value)[inv],
        )

    # -- cohort dispatch (DESIGN.md §8), sharded execution -------------------
    @mirror_guard
    def pipeline_cohort(
        self, gids, values: np.ndarray, active: np.ndarray,
        defer: bool = False,
    ):
        """Same contract (and bit-identical results) as the unsharded
        ``pipeline_cohort``, executed as one *packed* ``shard_map`` program
        (DESIGN.md §13).

        Historically this path ran every shard's full ``Gl``-row slab with
        non-members held inert, so a cold one-group cohort paid full-width
        slab cost on every shard.  Packed dispatch restores proportional
        cost under shard_map's shape uniformity via input packing: each
        shard advances ``C`` lanes (the cohort's max per-shard residency,
        pow2-quantized), each lane routed to its physical slab row by a
        ``segids`` table riding scalar prefetch; shards with fewer resident
        members ride inert pad lanes.  The burst still right-sizes per
        tier, so cohort cost is ``O(C x BE)`` instead of ``O(Gl x BE)``."""
        gids, member, use_k, inst = self._cohort_prologue(gids, values)
        be = values.shape[1]
        self._guard_capacity(gids, be)
        marks = self.next_inst_host
        pm = self._placement
        n_sh, gl = self.n_shards, self.groups_per_shard
        # pack the cohort into per-shard lane tables: C = max residency,
        # pow2-quantized (bounded retrace vocabulary), capped by the slab
        lanes: list[list[int]] = [[] for _ in range(n_sh)]
        for row, gid in enumerate(gids):
            lanes[pm.shard_of(gid)].append(row)
        cmax = max(len(ls) for ls in lanes)
        c = min(1 << max(0, cmax - 1).bit_length(), gl)
        if c >= gl:
            # crossover: a saturated cohort's packed table visits as many
            # slab rows as the full-width fold but pays one grid step per
            # lane, so the fat folded dispatch is strictly cheaper
            return self._cohort_full_width(
                gids, member, use_k, inst, values, active, defer
            )
        # the full-width fold over slot-ordered marks remains the reported
        # plan (engine-agnostic, comparable across rounds); packed
        # execution itself needs no fold — lanes carry their own offsets
        marks_slot = [marks[gid] for gid in pm.group_of]
        plan_gb = plan_mod.fold_width_full(
            [pm.slot_of[gid] for gid in gids], marks_slot, self._fold_width()
        )
        a, v = self.cfg.n_acceptors, self.cfg.value_words
        seg = np.zeros((n_sh, c), np.int32)
        enp = np.zeros((n_sh, c), np.int32)
        nip = np.zeros((n_sh, c), np.int32)
        crp = np.full((n_sh, c), NO_ROUND, np.int32)
        alp = np.ones((n_sh, c, a), np.int32)
        limnp = self._reclaim_limits_np()
        limp = np.full((n_sh, c), np.iinfo(np.int32).max, np.int32)
        valsp = np.zeros((n_sh, c, be, v), np.int32)
        valsp[:, :, :, 0] = NOP_SENTINEL
        lane_of: dict[int, tuple[int, int]] = {}
        for s in range(n_sh):
            for j, row in enumerate(lanes[s]):
                gid = gids[row]
                seg[s, j] = pm.row_of(gid)
                enp[s, j] = 1
                nip[s, j] = marks[gid]
                crp[s, j] = self.crnd_host[gid]
                alp[s, j] = self.alive_mask[gid]
                if limnp is not None:
                    limp[s, j] = limnp[gid]
                valsp[s, j] = values[row]
                lane_of[gid] = (s, j)
        self._ensure_placement()
        fn = self._packed_dispatch(use_k)
        self.dispatch_count += 1
        self.stack, self.lstate, fresh, _inst_d, _win, value = fn(
            seg,
            nip,
            crp,
            enp,
            alp,
            self.stack,
            self.lstate,
            jnp.asarray(valsp),
            reclaim_limit=limp,
        )
        fresh = np.asarray(fresh).reshape(n_sh, c, be)
        value = np.asarray(value).reshape(n_sh, c, be, v)
        fresh = np.stack([fresh[lane_of[gid]] for gid in gids])
        value = np.stack([value[lane_of[gid]] for gid in gids])
        for gid in gids:
            self.next_inst_host[gid] += be
        self._sync_cstate()
        self.last_gb = plan_gb
        if defer:
            return _DeferredRound.resolved(fresh, value, inst)
        return fresh, inst, value

    @mirror_guard
    def _cohort_full_width(
        self, gids, member, use_k, inst, values, active, defer: bool,
    ):
        """Full-width folded execution for saturated cohorts: non-members
        ride the dispatch inert (NOP sentinel rows, membership-masked
        crnd), exactly the unsharded cohort oracle's packing convention
        (``plan.scatter_rows``), permuted into slot order for the slabs."""
        g = self.cfg.n_groups
        be = values.shape[1]
        pm = self._placement
        marks = self.next_inst_host
        perm = list(pm.group_of)       # slot -> gid
        marks_slot = [marks[gid] for gid in perm]
        plan_gb = plan_mod.fold_width_full(
            [pm.slot_of[gid] for gid in gids], marks_slot, self._fold_width()
        )
        gb = plan_gb if use_k else 1
        vals_f, act_f = plan_mod.scatter_rows(
            gids, values, active, g, self.cfg.value_words
        )
        memp = np.asarray(member, np.int32)[perm]
        eff_crnd = np.where(
            memp != 0, np.asarray(self.crnd_host, np.int32)[perm], NO_ROUND
        ).astype(np.int32)
        lim = self._reclaim_limits_np()
        self._ensure_placement()
        fn = self._dispatch(use_k, gb)
        self.dispatch_count += 1
        self.stack, self.lstate, fresh, _inst_d, _win, value = fn(
            np.asarray(marks, np.int32)[perm],
            eff_crnd,
            memp,
            self.alive_mask[perm],
            self.stack,
            self.lstate,
            jnp.asarray(vals_f[perm]),
            jnp.asarray(act_f[perm]),
            reclaim_limit=None if lim is None else lim[perm],
        )
        inv = list(pm.slot_of)         # gid -> slot: gather back to gid order
        fresh = np.asarray(fresh)[inv][gids]
        value = np.asarray(value)[inv][gids]
        for gid in gids:
            self.next_inst_host[gid] += be
        self._sync_cstate()
        self.last_gb = plan_gb
        if defer:
            return _DeferredRound.resolved(fresh, value, inst)
        return fresh, inst, value

    def pipeline_persistent(
        self, gids, values: np.ndarray, active: np.ndarray,
        defer: bool = False,
    ):
        """The documented K=1 fallback (DESIGN.md §11): shard_map needs
        uniform per-shard shapes and host-authoritative control scalars
        enter every dispatch, so the sharded engine executes a persistent
        wave as K sequential cohort dispatches — delivery and numbering
        stay bit-identical to the unsharded wave; only ``dispatch_count``
        (K launches instead of one) and latency differ."""
        k, be = values.shape[0], values.shape[2]
        gids = list(gids)
        if k * be > self.cfg.n_instances:
            raise ValueError(
                f"persistent wave of {k} x {be} instances would lap the "
                f"{self.cfg.n_instances}-instance ring"
            )
        # same up-front whole-wave guard as the unsharded path: fail
        # before any round of the wave mutates state
        marks = self.next_inst_host
        for gid in gids:
            self._reclaim_guard(gid, marks[gid] + (k - 1) * be, be)
        outs = [
            self.pipeline_cohort(gids, values[r], active[r])
            for r in range(k)
        ]
        fresh = np.stack([o[0] for o in outs])
        inst = np.stack([o[1] for o in outs])
        value = np.stack([o[2] for o in outs])
        if defer:
            return _DeferredRound.resolved(fresh, value, inst)
        return fresh, inst, value

    @mirror_guard
    def burn_forward(self, gid: int, target: int) -> None:
        """Host-scalar-only realignment burn (the sharded control-state
        discipline of DESIGN.md §6): the new watermark reaches the owning
        shard with the next dispatch."""
        self._check_gid(gid)
        if target < self.next_inst_host[gid]:
            raise ValueError(
                f"burn_forward moves only forward: {target} < "
                f"{self.next_inst_host[gid]} (group {gid})"
            )
        self.next_inst_host[gid] = target
        self._sync_cstate()

    # -- per-group control: host scalars only, no device round-trip ----------
    def _sync_cstate(self) -> None:
        self.cstate = CoordinatorState(
            next_inst=np.asarray(self.next_inst_host, np.int32),
            crnd=np.asarray(self.crnd_host, np.int32),
        )

    def kill_acceptor(self, gid: int, aid: int) -> None:
        self._check_gid(gid)
        self.alive[gid][aid] = False
        self.alive_mask[gid, aid] = 0

    def revive_acceptor(self, gid: int, aid: int) -> None:
        self._check_gid(gid)
        self.alive[gid][aid] = True
        self.alive_mask[gid, aid] = 1

    @mirror_guard
    def freeze_group(self, gid: int) -> None:
        self._check_gid(gid)
        self.crnd_host[gid] = NO_ROUND
        self._sync_cstate()

    @mirror_guard
    def restore_group(self, gid: int, next_inst: int, crnd: int) -> None:
        self._check_gid(gid)
        if self.use_kernels:
            bb = self._block(self.cfg.batch)
            next_inst = -(-next_inst // bb) * bb
        self.next_inst_host[gid] = next_inst
        self.crnd_host[gid] = crnd
        self._sync_cstate()

    # -- live slab migration (DESIGN.md §13) ---------------------------------
    @mirror_guard
    def migrate_group(self, gid: int, dst_shard: int) -> None:
        """Move a live tenant's slab to ``dst_shard`` between waves.

        Placement-only state transfer: the caller has already drained the
        group to its reclamation watermark (ring history absorbed into the
        ``SnapshotStore`` — enforced here), so the slab rows carry no
        information the store does not.  The move is then a slot *swap*
        with a vacant (retired) group placed on the destination shard —
        gid keeps its identity (session hashes, log segments and twin
        numbering are placement-blind), only ``_slab_row`` changes:

          1. swap slots with the lowest vacant group on ``dst_shard``;
          2. zero the adopted slot (it holds the vacant group's stale
             retired rows — exactly ``create_group``'s lazy reset);
          3. re-seat the sequencer at the drain watermark (block-realigned
             on the kernel path, as in ``restore_group``/``adopt_group``).

        No other group's slab state, watermark or placement is touched, so
        the rest of the service keeps dispatching normally around the swap
        — there is no stop-the-world."""
        self._check_live(gid)
        if not 0 <= dst_shard < self.n_shards:
            raise ValueError(
                f"shard {dst_shard} out of range [0, {self.n_shards})"
            )
        if self.reclaimed_host is None:
            raise ValueError("migrate_group requires reclamation enabled")
        wm = self.next_inst_host[gid]
        if self.reclaimed_host[gid] != wm:
            raise ValueError(
                f"group {gid} not drained: reclamation watermark "
                f"{self.reclaimed_host[gid]} != sequencer watermark {wm}"
            )
        pm = self._placement
        if pm.shard_of(gid) == dst_shard:
            return
        vacant = [
            h
            for h in range(self.cfg.n_groups)
            if pm.shard_of(h) == dst_shard and not self.live_host[h]
        ]
        if not vacant:
            raise RuntimeError(
                f"no vacant slot on shard {dst_shard} to migrate group "
                f"{gid} into (retire or migrate a tenant off it first)"
            )
        self._placement = pm.swapped(gid, vacant[0])
        self._reset_group_slab(gid)        # the newly adopted slot
        self._ensure_placement()
        self.restore_group(gid, wm, self.crnd_host[gid])


class PaxosContext:
    """Drop-in replacement context (the paper's ``paxos_ctx``)."""

    def __init__(
        self,
        cfg: PaxosConfig | None = None,
        deliver: Callable[[bytes, int, int], None] | None = None,
        net: SimNet | None = None,
        use_kernels: bool = False,
        retransmit_after: int = 3,
        n_learners: int = 1,
        fused: bool = False,
        mesh=None,
        snapshots: bool = False,
    ):
        self.cfg = cfg or PaxosConfig()
        self.deliver_cb = deliver
        self.net = net or SimNet()
        self.n_groups = self.cfg.n_groups
        # the group-keyed surface engages for any multi-group config AND for
        # a sharded single-group one (the sharded dataplane is group-keyed
        # by construction, G = 1 included)
        self.grouped = self.n_groups > 1 or mesh is not None
        if self.grouped:
            # the multi-group service is wire-path only: all groups ride one
            # fused dispatch; staged traffic exists per group for recovery
            # and failover (group views), not as a peer execution mode
            if n_learners != 1:
                raise ValueError(
                    "multi-group context drives the fused wire path and a "
                    "single learner role per group (n_learners must be 1)"
                )
            if mesh is not None:
                # groups-sharded service: the G slabs partition over the
                # mesh's ``groups`` axis (DESIGN.md §6)
                self.hw: HardwareDataplane = ShardedMultiGroupDataplane(  # type: ignore[assignment]
                    self.cfg, mesh=mesh, use_kernels=use_kernels
                )
            else:
                self.hw = MultiGroupDataplane(  # type: ignore[assignment]
                    self.cfg, use_kernels=use_kernels
                )
            self.fused = True
            self._softco_g: dict[int, SoftCoordinator] = {}
            # the group-keyed learn surface
            self.learned_g: list[dict[int, bytes]] = [
                dict() for _ in range(self.n_groups)
            ]
            self._partial_g: list[dict[int, dict[int, tuple[int, bytes]]]] = [
                dict() for _ in range(self.n_groups)
            ]
        else:
            self.hw = HardwareDataplane(self.cfg, use_kernels=use_kernels)
            self.fused = fused
        # the dispatch planner owns burst sizing, cohort tiering and the
        # realignment sweep for the group-keyed pump (DESIGN.md §8); the
        # single-group context is the degenerate one-cohort case and only
        # shares the burst quantizer
        self.planner: plan_mod.DispatchPlanner | None = (
            plan_mod.DispatchPlanner(
                batch=self.cfg.batch,
                n_instances=self.cfg.n_instances,
                realign_after=self.cfg.realign_after,
                persistent_rounds=self.cfg.persistent_rounds,
                sharded=mesh is not None,
            )
            if self.grouped
            else None
        )
        # the per-group delivery log is uniform across context shapes: an
        # ungrouped single-group context logs into group_log[0], so readers
        # (serve.ConsensusService.delivered) never need a G == 1 special case
        self.group_log: list[list[tuple[int, bytes]]] = [
            [] for _ in range(self.n_groups)
        ]
        self._delivered_seqs: set = set()
        self.retransmit_after = retransmit_after
        self.n_learners = n_learners
        # learner state (software role), one per learner
        self.learned: list[dict[int, bytes]] = [dict() for _ in range(n_learners)]
        self._partial: list[dict[int, dict[int, tuple[int, bytes]]]] = [
            dict() for _ in range(n_learners)
        ]
        self.delivered_log: list[tuple[int, bytes]] = []
        # client-seq -> payload; multi-group contexts key by (group, seq) —
        # each group is an independent Paxos, with its own sequence space
        self._pending: dict[Any, _Pending] = {}
        self._next_client_seq = 0
        self._next_client_seq_g = [0] * self.n_groups
        self._next_epoch = 1                      # round-allocator epochs
        self._softco: SoftCoordinator | None = None  # failover coordinator
        # snapshot/compaction subsystem (DESIGN.md §9): when enabled the
        # rings are watermark-gated (no silent overwrite-on-wrap) and
        # ``snapshot_group`` drains the delivered prefix into the store;
        # ``full_group_log`` stitches store prefix + live log uniformly
        self.snapshots: SnapshotStore | None = None
        if snapshots:
            if not self.fused:
                # the drain source is the device learner ring, which only the
                # fused wire path maintains; the staged path's software
                # learners have no ring to reclaim
                raise ValueError(
                    "snapshots require the fused wire path "
                    "(fused=True, or any grouped context)"
                )
            self.snapshots = SnapshotStore()
            self.hw.enable_reclamation()
        self.stats = {"submitted": 0, "delivered": 0, "retransmits": 0}

    # -- paper API -----------------------------------------------------------
    def _check_group(self, group: int) -> None:
        if not 0 <= group < self.n_groups:
            raise ValueError(f"group {group} out of range [0, {self.n_groups})")
        if self.grouped and not self.hw.live_host[group]:
            raise ValueError(f"group {group} is retired")

    def submit(self, payload: bytes, group: int = 0) -> int:
        """paxos_submit(ctx, value, size) — ``group`` selects which of the
        device-resident consensus groups sequences the value (0 is the only
        group of a single-group context).

        Oversized payloads are a client error and fail HERE, at the door,
        with the limit named — not downstream at pack time mid-pump, where
        the raise would abort a whole wave of other sessions' traffic."""
        self._check_group(group)
        limit = self.cfg.max_payload_bytes
        if len(payload) > limit:
            raise ValueError(
                f"payload is {len(payload)} bytes but value_words="
                f"{self.cfg.value_words} carries at most {limit} payload "
                f"bytes per value ({self.cfg.value_words * 4}-byte value "
                f"minus the 8-byte seq/len header) — raise "
                f"PaxosConfig.value_words"
            )
        if self.grouped:
            seq = self._next_client_seq_g[group]
            self._next_client_seq_g[group] += 1
            self._pending[(group, seq)] = _Pending(payload, group=group)
        else:
            seq = self._next_client_seq
            self._next_client_seq += 1
            self._pending[seq] = _Pending(payload)
        self.net.send("coordinator", ("submit", seq, payload, group))
        self.stats["submitted"] += 1
        return seq

    def recover(self, inst: int, nop: bytes = b"\x00", group: int = 0) -> None:
        """paxos_recover(ctx, iid, nop_value, size): phase 1+2 with a no-op."""
        self._check_group(group)
        self.net.send("coordinator", ("recover", inst, nop, group))

    # -- event loop ----------------------------------------------------------
    def pump(self, rounds: int = 1) -> None:
        """Drive the fabric: drain submits through the hardware dataplane,
        route votes to learners, fire deliver callbacks, retransmit losses."""
        for _ in range(rounds):
            self._pump_coordinator()
            self._pump_learners()
            self._retransmit()

    def quiescent(self) -> bool:
        """True when nothing is in flight: no pending client sequences and
        no undelivered fabric traffic."""
        return not self._pending and self.net.pending() == 0

    def run_until_quiescent(self, max_rounds: int = 64) -> None:
        for _ in range(max_rounds):
            if self.quiescent():
                return
            self.pump()

    # -- internals -----------------------------------------------------------
    def _pump_coordinator(self) -> None:
        inbox = self.net.recv_all("coordinator")
        submits = [
            (m[1], m[2], m[3] if len(m) > 3 else 0)
            for m in inbox
            if m[0] == "submit"
        ]
        recovers = [
            (m[1], m[2], m[3] if len(m) > 3 else 0)
            for m in inbox
            if m[0] == "recover"
        ]
        if self.grouped:
            self._pump_coordinator_groups(submits, recovers)
            return

        for inst, nop, _gid in recovers:
            self._run_recover(inst, nop)
        submits = [(seq, payload) for seq, payload, _gid in submits]

        b = self.cfg.batch
        for i in range(0, len(submits), b):
            chunk = submits[i : i + b]
            # the fused path right-sizes the burst on BOTH engines
            # (engine-agnostic quantization, core.plan); the staged path
            # keeps the full batch.  A sub-batch burst can leave the
            # watermark off the full-batch block boundary, in which case
            # later full bursts take the jnp fallback (bit-identical,
            # slower) — the grouped pump's realignment sweep recovers the
            # kernel window; a single-group deployment accepts the
            # fallback (or burns forward via fail/restore).
            be = self._burst_size(len(chunk)) if self.fused else b
            vals, active = self._pack_chunk(chunk, be)
            if self.fused and self._softco is None:
                # the CAANS wire path: the whole Phase-2 round below the host
                # boundary, one dispatch — votes never surface as messages
                fresh, inst, value = self.hw.pipeline(vals, active)
                for j in range(len(fresh)):
                    if not fresh[j]:
                        continue
                    raw = value[j].tobytes()
                    for lid in range(self.n_learners):
                        if int(inst[j]) not in self.learned[lid]:
                            self.learned[lid][int(inst[j])] = raw
                    self._deliver(int(inst[j]), raw)
                continue
            if self._softco is not None:
                p2a = self._soft_sequence(vals, active)
            else:
                p2a = self.hw.sequence(vals, active)
            votes = self.hw.vote(p2a)
            for aid, v in enumerate(votes):
                if v is None:
                    continue
                for lid in range(self.n_learners):
                    self.net.send(("learner", lid), ("votes", aid, _to_host(v)))

    def _pump_learners(self) -> None:
        for lid in range(self.n_learners):
            for m in self.net.recv_all(("learner", lid)):
                _, aid, votes = m
                self._learn(lid, aid, votes)

    def _learn(self, lid: int, aid: int, votes: dict) -> None:
        self._quorum_learn(
            self.learned[lid],
            self._partial[lid],
            aid,
            votes,
            self._deliver if lid == 0 else None,
        )

    def _quorum_learn(
        self,
        learned: dict[int, bytes],
        partial: dict[int, dict[int, tuple[int, bytes]]],
        aid: int,
        votes: dict,
        deliver: Callable[[int, bytes], None] | None,
    ) -> None:
        """The software learner: fold one acceptor's vote batch into the
        partial-quorum table; at quorum, record the decision and (when this
        learner delivers) fire ``deliver(inst, raw)``.  Shared by the
        per-learner and per-group learn surfaces."""
        quorum = self.cfg.quorum
        for i in range(len(votes["msgtype"])):
            if votes["msgtype"][i] != MSG_P2B:
                continue
            inst = int(votes["inst"][i])
            if inst in learned:
                continue  # duplicate suppression
            slot = partial.setdefault(inst, {})
            slot[aid] = (int(votes["vrnd"][i]), votes["value"][i].tobytes())
            by_rnd: dict[int, int] = {}
            for vr, _ in slot.values():
                by_rnd[vr] = by_rnd.get(vr, 0) + 1
            for vr, cnt in by_rnd.items():
                if cnt >= quorum:
                    raw = next(v for r, v in slot.values() if r == vr)
                    learned[inst] = raw
                    partial.pop(inst, None)
                    if deliver is not None:
                        deliver(inst, raw)
                    break

    # -- multi-group internals (G device-resident groups, fused dispatch) ----
    def _pump_coordinator_groups(
        self,
        submits: list[tuple[int, bytes, int]],
        recovers: list[tuple[int, bytes, int]],
    ) -> None:
        """Group-keyed coordinator pump: recovery first, then groups under a
        software coordinator (staged, per group), then one fused multi-group
        dispatch per burst for everything hardware-sequenced."""
        # traffic addressed to a retired group is dropped at the door: the
        # slot may already belong to the free-list (or a future tenant), and
        # a retired group must never sequence — in-flight submits died with
        # the tenant (clients re-route at the membership epoch bump)
        live = self.hw.live_host
        submits = [s for s in submits if live[s[2]]]
        recovers = [r for r in recovers if live[r[2]]]
        for inst, nop, gid in recovers:
            self._run_recover_group(gid, inst, nop)
        queues: list[list[tuple[int, bytes]]] = [
            [] for _ in range(self.n_groups)
        ]
        for seq, payload, gid in submits:
            queues[gid].append((seq, payload))
        b = self.cfg.batch

        for gid in list(self._softco_g):
            q, queues[gid] = queues[gid], []
            for i in range(0, len(q), b):
                be = self._burst_size(len(q[i : i + b]))
                vals, active = self._pack_chunk(q[i : i + b], be)
                p2a = self._soft_sequence_group(gid, vals, active)
                for aid, v in enumerate(self.hw.group_view(gid).vote(p2a)):
                    if v is not None:
                        # learners route on the header's group id, not on
                        # ambient context — the switch model (paper Fig. 5)
                        self._learn_group(int(v.gid), aid, _to_host(v))

        # the whole service advances together, tiered by the dispatch
        # planner (DESIGN.md §8): each chunk wave partitions the loaded
        # groups into cohorts — one dispatch per distinct right-sized
        # burst, hot cohorts at the full block-aligned batch, cold cohorts
        # coalesced into a shared small burst — instead of padding every
        # cold group up to the hottest group's burst.  Frozen (software-
        # coordinated), vacant and idle groups are simply not members of
        # any cohort: they burn no ring instances and stay bit-identical
        # to not being pumped.  Burst sizes are engine-agnostic, so every
        # backend — and G independent per-group oracles — resolves the
        # wave identically.
        # The wave loop is double-buffered (DESIGN.md §11) when
        # ``cfg.async_pump``: wave N's host read-back is deferred until
        # wave N+1 has been dispatched, so host planning/packing overlaps
        # device execution.  Planning reads only host mirrors (advanced at
        # dispatch time), never a resolve, and every in-flight wave is
        # drained before pump() returns — the pump stays externally
        # synchronous, with delivery order identical to the serial loop.
        # A cohort planned as a K-round persistent wave consumes K - 1
        # further batch-sized slices from its members' queues and rides
        # ONE dispatch (``pipeline_persistent``).
        hw = self.hw
        async_on = self.cfg.async_pump
        in_flight: list[tuple[tuple[int, ...], Any]] = []
        while any(queues):
            pending = [len(q) for q in queues]
            chunks = [q[:b] for q in queues]
            queues = [q[b:] for q in queues]
            rp = self.planner.plan_round(
                [len(c) for c in chunks],
                hw.next_inst_host,
                hw.live_host,
                hw.crnd_host,
                pending=pending,
            )
            for gid, target in rp.realign:
                hw.burn_forward(gid, target)
            wave: list[tuple[tuple[int, ...], Any]] = []
            for cohort in rp.cohorts:
                kk = self._wave_depth_clamped(cohort)
                if kk > 1:
                    rounds = [[chunks[gid] for gid in cohort.gids]]
                    for _ in range(kk - 1):
                        rounds.append(
                            [queues[gid][:b] for gid in cohort.gids]
                        )
                        for gid in cohort.gids:
                            queues[gid] = queues[gid][b:]
                    packed = [
                        [self._pack_chunk(c, cohort.burst) for c in row]
                        for row in rounds
                    ]
                    vals = np.stack(
                        [np.stack([v for v, _ in row]) for row in packed]
                    )
                    act = np.stack(
                        [np.stack([a for _, a in row]) for row in packed]
                    )
                    handle = hw.pipeline_persistent(
                        cohort.gids, vals, act, defer=True
                    )
                else:
                    packed = [
                        self._pack_chunk(chunks[gid], cohort.burst)
                        for gid in cohort.gids
                    ]
                    vals = np.stack([v for v, _ in packed])
                    act = np.stack([a for _, a in packed])
                    handle = hw.pipeline_cohort(
                        cohort.gids, vals, act, defer=True
                    )
                wave.append((cohort.gids, handle))
            if async_on:
                # this wave is in flight: resolve and deliver the PREVIOUS
                # wave while the device works on this one
                for gids_, handle in in_flight:
                    self._resolve_wave(gids_, handle)
                in_flight = wave
            else:
                for gids_, handle in wave:
                    self._resolve_wave(gids_, handle)
        for gids_, handle in in_flight:
            self._resolve_wave(gids_, handle)

    def _wave_depth_clamped(self, cohort: plan_mod.Cohort) -> int:
        """The pump-side clamp on a cohort's planned wave depth: reclaim
        headroom (instances until the first unreclaimed slot) may cap K
        below the planner's choice.  Host-scalar arithmetic on mirrors that
        are identical across backends, so every engine clamps identically;
        chunks beyond the clamp simply stay queued for the next wave."""
        kk = cohort.rounds
        if kk <= 1:
            return kk
        lim = self.hw._reclaim_limits_np()
        if lim is not None:
            for gid in cohort.gids:
                head = (
                    int(lim[gid]) - self.hw.next_inst_host[gid]
                ) // cohort.burst
                kk = min(kk, head)
        return max(1, kk)

    def _resolve_wave(self, gids: tuple[int, ...], handle: Any) -> None:
        """Host read-back + delivery for one dispatched cohort wave.
        Persistent waves deliver rounds-then-rows — exactly the order K
        sequential single-round dispatches would have produced."""
        fresh, inst, value = handle.resolve()
        if fresh.ndim == 2:            # single-round wave: (M, BE)
            fresh, inst, value = fresh[None], inst[None], value[None]
        for r in range(fresh.shape[0]):
            for row, gid in enumerate(gids):
                for j in range(fresh.shape[2]):
                    if not fresh[r, row, j]:
                        continue
                    raw = value[r, row, j].tobytes()
                    ii = int(inst[r, row, j])
                    if ii not in self.learned_g[gid]:
                        self.learned_g[gid][ii] = raw
                    self._deliver_group(gid, ii, raw)

    def _burst_size(self, longest: int) -> int:
        """Wire-burst sizing, engine-agnostic (``core.plan.quantize_burst``):
        the jnp oracle and the Pallas kernel path see identical burst
        shapes, so burst sizing can never fork the backends' delivery logs;
        pow2 quantization bounds both the NOP-filler waste and the jit
        cache (one compiled program per distinct shape)."""
        be = plan_mod.quantize_burst(longest, self.cfg.batch)
        if self.planner is not None:
            self.planner.note_burst(be)
        return be

    def _pack_chunk(
        self, chunk: list[tuple[int, bytes]], be: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """Pack (seq, payload) pairs into a (BE, V) wire burst; unfilled
        slots carry the NOP sentinel and are inactive."""
        return plan_mod.pack_rows(
            [self._encode(seq, payload) for seq, payload in chunk],
            be,
            self.cfg.value_words,
        )

    def _soft_sequence_group(
        self, gid: int, vals: np.ndarray, active: np.ndarray
    ) -> MsgBatch:
        return self._soft_p2a(self._softco_g[gid], vals, active, gid=gid)

    def _learn_group(self, gid: int, aid: int, votes: dict) -> None:
        """Per-group software learner (staged traffic: failover, recovery)."""
        self._quorum_learn(
            self.learned_g[gid],
            self._partial_g[gid],
            aid,
            votes,
            functools.partial(self._deliver_group, gid),
        )

    def _deliver_group(self, gid: int, inst: int, raw: bytes) -> None:
        self._deliver_value(inst, raw, group=gid)

    def _run_recover_group(self, gid: int, inst: int, nop: bytes) -> None:
        """Per-group recovery: the shared engine against one group's view,
        learning decided votes directly into the group's learn surface."""
        votes = self._recover_votes(self.hw.group_view(gid), inst, nop, gid=gid)
        for aid, v in enumerate(votes or []):
            if v is not None:
                self._learn_group(int(v.gid), aid, _to_host(v))

    def _deliver(self, inst: int, raw: bytes) -> None:
        self._deliver_value(inst, raw)

    def _deliver_value(
        self, inst: int, raw: bytes, group: int | None = None
    ) -> None:
        """The delivery contract, shared by the single-group and group-keyed
        paths: discard internal fillers, suppress duplicates (retransmit
        decided twice — paper §3.1), settle the pending entry, log, and fire
        the application callback.  ``group`` selects the per-group sequence
        space and delivery log."""
        words = np.frombuffer(raw, "<i4")
        if words[0] == NOP_SENTINEL:
            return  # internal filler — discarded by the library
        seq = int(words[0])
        key: Any = seq if group is None else (group, seq)
        if key in self._delivered_seqs:
            return
        self._delivered_seqs.add(key)
        payload = raw[8 : 8 + int(words[1])]
        self._pending.pop(key, None)
        self.delivered_log.append((inst, payload))
        self.group_log[0 if group is None else group].append((inst, payload))
        self.stats["delivered"] += 1
        if self.deliver_cb:
            self.deliver_cb(payload, len(payload), inst)

    def _retransmit(self) -> None:
        for key, p in list(self._pending.items()):
            p.age += 1
            if p.age >= self.retransmit_after:
                p.age = 0
                self.stats["retransmits"] += 1
                seq = key[1] if isinstance(key, tuple) else key
                self.net.send("coordinator", ("submit", seq, p.payload, p.group))

    def _encode(self, seq: int, payload: bytes) -> np.ndarray:
        nbytes = self.cfg.value_words * 4
        if len(payload) > nbytes - 8:
            raise ValueError(
                f"value too large: {len(payload)} > {nbytes - 8} "
                f"(increase PaxosConfig.value_words)"
            )
        head = np.array([seq, len(payload)], np.int32).tobytes()
        return np.frombuffer((head + payload).ljust(nbytes, b"\x00"), "<i4").copy()

    # -- snapshot / compaction (DESIGN.md §9) --------------------------------
    def _require_snapshots(self) -> SnapshotStore:
        if self.snapshots is None:
            raise ValueError(
                "snapshots are not enabled on this context "
                "(construct with snapshots=True)"
            )
        return self.snapshots

    def full_group_log(self, gid: int = 0) -> list[tuple[int, bytes]]:
        """The group's complete delivery history: compacted snapshot prefix
        (if any) stitched before the live ``group_log`` — the ONE read that
        is uniform in steady state, at retirement, and after restore."""
        if self.snapshots is None:
            return self.group_log[gid]
        return self.snapshots.log_prefix(gid) + self.group_log[gid]

    def snapshot_group(
        self, gid: int = 0, upto: int | None = None
    ) -> GroupSnapshot:
        """Drain group ``gid``'s decided ring prefix below ``upto`` (default:
        its sequencer watermark — everything) into the ``SnapshotStore``,
        seal it, move the host-log prefix into the store (compaction), and
        advance the reclamation watermark so the drained ring slots may be
        re-sequenced.  Returns the group's sealed ``GroupSnapshot``.
        """
        store = self._require_snapshots()
        self._check_group(gid)
        hw = self.hw
        if self.grouped:
            row = hw._slab_row(gid)
            seq_mark = hw.next_inst_host[gid]
            ld = np.asarray(hw.lstate.delivered[row])
            li = np.asarray(hw.lstate.inst[row])
            lv = np.asarray(hw.lstate.value[row])
        else:
            seq_mark = hw._next_inst_host
            ld = np.asarray(hw.lstate.delivered)
            li = np.asarray(hw.lstate.inst)
            lv = np.asarray(hw.lstate.value)
        upto = seq_mark if upto is None else upto
        wm = store.watermark(gid)
        if not wm <= upto <= seq_mark:
            raise ValueError(
                f"snapshot upto={upto} outside [{wm}, {seq_mark}] "
                f"(group {gid})"
            )
        # decided entries in [wm, upto), ascending by instance — the raw
        # ring words (NOP fillers included: the seal covers device history)
        slots = np.nonzero((ld != 0) & (li >= wm) & (li < upto))[0]
        order = slots[np.argsort(li[slots], kind="stable")]
        store.absorb(gid, li[order], lv[order], upto)
        # compaction: move the host log's leading run below the watermark
        # into the store (list order preserved exactly — stitched reads are
        # bit-identical to the unsplit log)
        log = self.group_log[gid]
        cut = 0
        while cut < len(log) and log[cut][0] < upto:
            cut += 1
        store.absorb_log(gid, log[:cut])
        self.group_log[gid] = log[cut:]
        if self.grouped:
            hw.set_reclaimed(gid, upto)
        else:
            hw.set_reclaimed(upto)
        return store.snapshot(gid)

    def crash_acceptor(self, aid: int, group: int = 0) -> None:
        """Crash one group member WITH state loss: liveness drops AND its
        acceptor register file (BRAM) is zeroed — unlike ``kill_acceptor``,
        which models a frozen-but-intact switch.  Revive with
        ``restore_acceptor`` (snapshot + live ring suffix bootstrap)."""
        self._check_group(group)
        if self.grouped:
            self.hw.kill_acceptor(group, aid)
            self.hw.wipe_acceptor(group, aid)
        else:
            self.hw.kill_acceptor(aid)
            self.hw.wipe_acceptor(aid)

    def restore_acceptor(self, aid: int, group: int = 0) -> int:
        """Revive a crashed group member by state transfer (DESIGN.md §9):
        instances below the snapshot watermark are covered by the sealed
        snapshot (never re-proposed), and the live ring suffix's decided
        instances are adopted from the learner ring — they are decided, so
        claiming votes for them at the current round is safe (the vertical-
        Paxos transfer NetChain motivates).  Returns the number of adopted
        ring slots."""
        from .failover import restore_acceptor as _restore

        self._check_group(group)
        wm = self.snapshots.watermark(group) if self.snapshots else 0
        if self.grouped:
            return _restore(self.hw, aid, gid=group, watermark=wm)
        return _restore(self.hw, aid, watermark=wm)

    def adopt_group(
        self,
        snap: GroupSnapshot,
        log_prefix: list[tuple[int, bytes]] | None = None,
    ) -> int:
        """Admit a tenant bootstrapping from a transferred snapshot: claims
        a free slot whose sequencer and reclamation watermarks start at
        ``snap.watermark``, and seeds the ``SnapshotStore`` from the
        transfer — verifying its seal (divergence/corruption check) before
        trusting it.  ``log_prefix`` seeds the stitched ``delivered()``
        history.  Returns the new group id."""
        self._require_grouped()
        store = self._require_snapshots()
        gid = self.hw.adopt_group(int(snap.watermark))
        self.learned_g[gid] = {}
        self._partial_g[gid] = {}
        self.group_log[gid] = []
        self._next_client_seq_g[gid] = 0
        store.reset_group(gid)
        store.seed(gid, snap, log_prefix)
        return gid

    def migrate_group(
        self, gid: int, dst_shard: int, max_rounds: int = 64
    ) -> GroupSnapshot:
        """Live slab migration (DESIGN.md §13): move tenant ``gid`` to
        ``dst_shard`` between waves, no stop-the-world.

        The protocol composes machinery this context already trusts:
        pump until the group's in-flight submissions drain (other tenants
        keep deciding during these waves), ``snapshot_group`` the full
        prefix (ring drained into the ``SnapshotStore``, reclamation
        watermark advanced to the sequencer watermark), seal it, let the
        sharded dataplane swap slots, then re-derive the store's seal and
        verify it against the pre-move snapshot — the same
        divergence/corruption check ``adopt_group`` applies to transferred
        state.  Returns the sealed snapshot the move was verified against.
        Callers routing by placement must bump their routing epoch
        (``serve.ConsensusService.migrate_group`` does)."""
        self._require_grouped()
        store = self._require_snapshots()
        self._check_group(gid)
        hw = self.hw
        if not hasattr(hw, "migrate_group"):
            raise ValueError(
                "migrate_group requires the groups-sharded dataplane "
                "(construct the context with mesh=...)"
            )
        for _ in range(max_rounds):
            if not any(
                isinstance(k, tuple) and k[0] == gid for k in self._pending
            ):
                break
            self.pump()
        else:
            raise RuntimeError(
                f"group {gid} did not drain within {max_rounds} pump rounds"
            )
        snap = self.snapshot_group(gid)
        hw.migrate_group(gid, dst_shard)
        after = store.snapshot(gid)
        if after.seal != snap.seal or after.watermark != snap.watermark:
            raise RuntimeError(
                f"group {gid} snapshot seal changed across migration: "
                f"{snap.seal!r} -> {after.seal!r}"
            )
        return snap

    # -- dynamic membership (DESIGN.md §7) -----------------------------------
    def _require_grouped(self) -> None:
        if not self.grouped:
            raise ValueError(
                "dynamic membership requires a group-keyed context "
                "(n_groups > 1 or mesh=...)"
            )

    def live_groups(self) -> list[int]:
        """Currently live group ids (ascending) — the routing domain."""
        if not self.grouped:
            return [0]
        return self.hw.live_groups()

    def create_group(self) -> int:
        """Admit a tenant: claim a free slot on the group axis (zeroed
        rings, fresh watermark/round and client-sequence space, empty
        logs).  Returns the new group id — deterministic, lowest free slot
        first."""
        self._require_grouped()
        gid = self.hw.create_group()
        self.learned_g[gid] = {}
        self._partial_g[gid] = {}
        self.group_log[gid] = []
        self._next_client_seq_g[gid] = 0
        if self.snapshots is not None:
            self.snapshots.reset_group(gid)
        return gid

    def retire_group(self, gid: int) -> list[tuple[int, bytes]]:
        """Reclaim a tenant's slot: the group's delivery log is drained
        (returned to the caller — the serving tier archives it for routing-
        epoch stitching), its round parks at ``NO_ROUND`` and the slot joins
        the free-list.  Undelivered submissions to the group are dropped —
        with the tenant gone there is no group to decide them — and their
        dedup keys are purged so a future tenant reusing the slot starts
        from a clean (group, seq) space.  Host scalars only: no other
        group's state is touched.  With snapshots enabled the returned log
        is the STITCHED history (compacted prefix + live log) — retirement
        and steady state read the same way."""
        self._require_grouped()
        self.hw.retire_group(gid)          # raises unless live
        self._softco_g.pop(gid, None)
        # flush the tenant's in-flight coordinator traffic NOW, not at the
        # next pump: if the slot is recreated before a pump runs, the
        # pump-time liveness filter would see the recycled slot live again
        # and sequence the old tenant's stale submit into the new tenant's
        # log (and poison its fresh (group, seq) dedup space)
        self.net.purge(
            "coordinator", lambda m: (m[3] if len(m) > 3 else 0) == gid
        )
        for key in [
            k
            for k in self._pending
            if isinstance(k, tuple) and k[0] == gid
        ]:
            del self._pending[key]
        self._delivered_seqs = {
            k
            for k in self._delivered_seqs
            if not (isinstance(k, tuple) and k[0] == gid)
        }
        return self.full_group_log(gid)

    # -- failover ------------------------------------------------------------
    def fail_coordinator(
        self, est_next_inst: int | None = None, group: int = 0
    ) -> None:
        """Hardware coordinator dies; a software coordinator takes over.

        Runs the *safe* takeover (core.failover): claims a globally unique
        higher round, Phase-1-scans the uncertainty window around the
        (possibly stale) sequencer estimate, re-proposes any voted values it
        finds, and resumes sequencing past them — the paper's §3.1/§6.4
        procedure with the catch-up made explicit.

        On a multi-group context this is a *per-group* event: only ``group``
        moves to software coordination (its hardware round parks at NO_ROUND,
        making it inert in the shared fused dispatch); every other group keeps
        hardware-sequencing undisturbed.
        """
        self._check_group(group)
        if self.grouped:
            return self._fail_coordinator_group(group, est_next_inst)

        from .failover import takeover

        est = (
            est_next_inst
            if est_next_inst is not None
            else int(jax.device_get(self.hw.cstate.next_inst))
        )
        epoch = self._next_epoch
        self._next_epoch += 1
        res = takeover(
            self.hw,
            coordinator_id=1,
            epoch=epoch,
            est_next_inst=est,
            window=self.cfg.batch * 2,
            quorum=self.cfg.quorum,
        )
        self._softco = SoftCoordinator(
            cid=1, crnd=res.crnd, next_inst=res.next_inst
        )
        return res

    def _fail_coordinator_group(
        self, gid: int, est_next_inst: int | None
    ) -> None:
        from .failover import takeover_group

        est = (
            est_next_inst
            if est_next_inst is not None
            else int(jax.device_get(self.hw.cstate.next_inst[gid]))
        )
        epoch = self._next_epoch
        self._next_epoch += 1
        res = takeover_group(
            self.hw,
            gid,
            coordinator_id=1,
            epoch=epoch,
            est_next_inst=est,
            window=self.cfg.batch * 2,
            quorum=self.cfg.quorum,
        )
        self._softco_g[gid] = SoftCoordinator(
            cid=1, crnd=res.crnd, next_inst=res.next_inst
        )
        self.hw.freeze_group(gid)
        return res

    @mirror_guard
    def restore_hardware_coordinator(self, group: int = 0) -> None:
        self._check_group(group)
        if self.grouped:
            co = self._softco_g.pop(group, None)
            if co is not None:
                # per-group realignment: only this group's watermark/round
                # move; the kernel path's block realignment happens inside
                # restore_group (same §3.1 gap-fill rationale as below)
                self.hw.restore_group(group, int(co.next_inst), int(co.crnd))
            return
        if self._softco is None:
            return
        nxt = int(self._softco.next_inst)
        if self.hw.use_kernels:
            # An arbitrary takeover watermark can break the kernel path's
            # block-alignment invariant — and since bursts advance in block
            # multiples it would never realign on its own, silently pinning
            # the dataplane to the jnp fallback forever.  Burn forward to the
            # next block boundary instead: the skipped instances are never
            # proposed and are recoverable as no-ops (paper §3.1 gap fill).
            bb = self.hw._block(self.cfg.batch)
            nxt = -(-nxt // bb) * bb
        self.hw.cstate = CoordinatorState(
            next_inst=jnp.int32(nxt),
            crnd=jnp.int32(self._softco.crnd),
        )
        self.hw._next_inst_host = nxt  # resync the host watermark mirror
        self._softco = None

    def _soft_sequence(self, vals: np.ndarray, active: np.ndarray) -> MsgBatch:
        assert self._softco is not None
        return self._soft_p2a(self._softco, vals, active)

    def _soft_p2a(
        self, co: SoftCoordinator, vals: np.ndarray, active: np.ndarray,
        gid: int | None = None,
    ) -> MsgBatch:
        """Software-coordinator sequencing: bind a burst to the coordinator's
        next window (shared by the single-group and per-group failover
        paths; ``gid`` tags the batch with its consensus group)."""
        b = vals.shape[0]
        inst = np.arange(co.next_inst, co.next_inst + b, dtype=np.int32)
        co.next_inst += b
        return MsgBatch(
            msgtype=jnp.where(jnp.asarray(active), MSG_P2A, MSG_NOP).astype(jnp.int32),
            inst=jnp.asarray(inst),
            rnd=jnp.full((b,), co.crnd, jnp.int32),
            vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
            swid=jnp.full((b,), co.cid, jnp.int32),
            value=jnp.asarray(vals),
            gid=None if gid is None else jnp.int32(gid),
        )

    def _run_recover(self, inst: int, nop: bytes) -> None:
        """Phase 1 + Phase 2 for one instance with a no-op value (paper §3.1);
        decided votes fan out to the software learners over SimNet."""
        votes = self._recover_votes(self.hw, inst, nop)
        for aid, v in enumerate(votes or []):
            if v is None:
                continue
            for lid in range(self.n_learners):
                self.net.send(("learner", lid), ("votes", aid, _to_host(v)))

    def _recover_votes(
        self, surface, inst: int, nop: bytes, gid: int | None = None
    ) -> list[MsgBatch | None] | None:
        """The shared recovery engine: Phase-1 scan one instance, choose the
        required value (discovered vote, else the no-op), Phase-2 it, and
        return the per-acceptor vote batches (None = no quorum of promises).
        ``surface`` is any staged dataplane surface — the hardware dataplane
        or one group's view; ``gid`` tags the batches with their group.
        """
        from .failover import allocate_round

        epoch = self._next_epoch
        self._next_epoch += 1
        crnd = allocate_round(epoch, coordinator_id=2)
        b = self.cfg.batch
        gtag = None if gid is None else jnp.int32(gid)
        # Filler slots carry a contiguous inst window starting at the target:
        # the vectorized acceptor scatter requires distinct ring slots per
        # batch, and all-zero filler insts would collide with the recovered
        # instance whenever inst % n_instances == 0 (slot-0 clobber).  The
        # fillers' rnd stays NO_ROUND, so they never accept/promise anything.
        window = jnp.arange(inst, inst + b, dtype=jnp.int32)
        p1a = MsgBatch.nop(b, self.cfg.value_words)
        p1a = p1a.replace(
            msgtype=p1a.msgtype.at[0].set(MSG_P1A),
            inst=window,
            rnd=p1a.rnd.at[0].set(crnd),
            gid=gtag,
        )
        promises = surface.prepare(p1a)
        best: tuple[int, bytes | None] = (NO_ROUND, None)
        got = 0
        for v in promises:
            if v is None:
                continue
            host = _to_host(v)
            if host["msgtype"][0] != 2:  # MSG_P1B
                continue
            got += 1
            vr = int(host["vrnd"][0])
            if vr > best[0]:
                best = (vr, host["value"][0].tobytes())
        if got < self.cfg.quorum:
            return None  # cannot recover without a quorum
        if best[1] is not None and best[0] != NO_ROUND:
            value_words = np.frombuffer(best[1], "<i4").copy()
        else:
            value_words = self._encode(-1, nop)
            value_words[0] = NOP_SENTINEL
        p2a = MsgBatch.nop(b, self.cfg.value_words)
        p2a = p2a.replace(
            msgtype=p2a.msgtype.at[0].set(MSG_P2A),
            inst=window,  # distinct slots; fillers at NO_ROUND never accept
            rnd=p2a.rnd.at[0].set(crnd),
            value=p2a.value.at[0].set(jnp.asarray(value_words)),
            gid=gtag,
        )
        return surface.vote(p2a)


def _to_host(m: MsgBatch) -> dict:
    return {
        "msgtype": np.asarray(m.msgtype),
        "inst": np.asarray(m.inst),
        "rnd": np.asarray(m.rnd),
        "vrnd": np.asarray(m.vrnd),
        "swid": np.asarray(m.swid),
        "value": np.asarray(m.value),
    }
