"""Model / shape configuration dataclasses for the assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qk_norm: bool = False
    # attention pattern: every `global_every`-th layer is global, others use
    # a sliding window of `local_window` (0 = all layers global/full)
    local_window: int = 0
    global_every: int = 0           # e.g. 6 -> pattern LLLLLG (5:1)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # RWKV6
    rwkv_head_dim: int = 64
    # RecurrentGemma / Griffin
    d_rnn: int = 0                  # RG-LRU recurrence width (0 = d_model)
    conv_width: int = 4
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    # encoder-decoder (whisper): n_layers = decoder layers
    n_enc_layers: int = 0
    src_len: int = 1500             # stub frontend (frames / patches) length
    # vlm
    n_patches: int = 0
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: bool = True
    # ---- §Perf hillclimb levers (see EXPERIMENTS.md) ----
    remat_policy: str = "full"     # full | dots (save matmul outputs)
    dispatch_groups: int = 1       # MoE: shard-local dispatch groups (EP a2a)
    ring_local_cache: bool = False # decode: window-length cache for local layers

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        d, nl = self.d_model, self.n_layers
        emb = self.vocab * d
        attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * d
        if self.family == "ssm":  # rwkv6: time-mix (r,k,v,g,o) + channel-mix
            attn = 5 * d * d
        mlp = 3 * d * self.d_ff
        if self.n_experts:
            mlp = self.n_experts * 3 * d * self.d_ff_expert
            if self.shared_expert:
                mlp += 3 * d * self.d_ff
        core = nl * (attn + mlp)
        if self.family == "hybrid" and self.block_pattern:
            # recurrent blocks replace attention with RG-LRU (~4 d*d_rnn)
            rnn = self.d_rnn or d
            frac_rec = self.block_pattern.count("rec") / len(self.block_pattern)
            rec_blk = 4 * d * rnn + mlp
            attn_blk = attn + mlp
            core = int(nl * (frac_rec * rec_blk + (1 - frac_rec) * attn_blk))
        if self.family == "encdec":
            # GELU MLPs (2 matrices); decoder = self+cross attn, encoder = self
            mlp_e = 2 * d * self.d_ff
            core = nl * (2 * attn + mlp_e) + self.n_enc_layers * (attn + mlp_e)
        return emb + core

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.n_params
        d, nl = self.d_model, self.n_layers
        dense = self.n_params - nl * self.n_experts * 3 * d * self.d_ff_expert
        active_mlp = nl * self.top_k * 3 * d * self.d_ff_expert
        return dense + active_mlp

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        pattern = self.block_pattern[: 3] if self.block_pattern else ()
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if not pattern else 2 * len(pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=128,
            d_ff_expert=96 if self.n_experts else 0,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            local_window=min(self.local_window, 8) if self.local_window else 0,
            d_rnn=32 if self.d_rnn else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            src_len=16 if self.n_enc_layers or self.n_patches else self.src_len,
            n_patches=8 if self.n_patches else 0,
            rwkv_head_dim=16,
            dtype="float32",
            block_pattern=pattern,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic sequence mixing; only these families run it
# (see DESIGN.md §5 for the skip rationale per arch).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")
