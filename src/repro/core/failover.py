"""Coordinator failover (paper §3.1 + §6.4).

When the hardware coordinator fails, a software coordinator takes over.  The
paper's procedure: the replacement needs only an *estimate* of the last
instance; if the estimate is low, acceptors reject until it catches up; if it
is high, learners see gaps and fill them via ``recover``.

We implement the *safe* variant of that procedure: the takeover coordinator
claims a fresh, strictly higher round (rounds are partitioned by coordinator
id so concurrent coordinators can never share one) and runs batched Phase 1
over the uncertainty window.  Any instance found voted is re-proposed with
its discovered value (Paxos's value-choice rule); untouched instances become
available for fresh proposals.  This both "catches up" the sequencer and
preserves agreement for already-decided instances.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from .types import MSG_NOP, MSG_P1A, MSG_P2A, MsgBatch

NO_ROUND = -1


def allocate_round(epoch: int, coordinator_id: int, n_coordinators: int = 16) -> int:
    """Globally unique, monotonically increasing round for a coordinator.

    rounds ≡ coordinator_id (mod n_coordinators): two coordinators can never
    issue the same round, the invariant that makes >= acceptance safe.
    """
    return epoch * n_coordinators + coordinator_id


@dataclasses.dataclass
class TakeoverResult:
    crnd: int
    next_inst: int
    reproposed: List[Tuple[int, bytes]]   # (inst, value) re-proposed values
    scanned: int


def takeover(
    hw,                      # HardwareDataplane
    *,
    coordinator_id: int,
    epoch: int,
    est_next_inst: int,
    window: int,
    quorum: int,
) -> TakeoverResult:
    """Run the safe takeover procedure against the (hardware) acceptors.

    Scans ``[max(0, est_next_inst - window), est_next_inst + window)`` with
    batched Phase 1, collects promises, and re-proposes discovered values
    with the new round.  Returns the state the new coordinator starts from.
    """
    crnd = allocate_round(epoch, coordinator_id)
    lo = max(0, est_next_inst - window)
    hi = est_next_inst + window
    b = hw.cfg.batch
    vwords = hw.cfg.value_words

    reproposed: List[Tuple[int, bytes]] = []
    highest_voted = -1
    scanned = 0

    for base in range(lo, hi, b):
        insts = np.arange(base, base + b, dtype=np.int32)
        # The final batch may overhang the window when (hi - lo) % b != 0.
        # Out-of-window positions are masked inert (msgtype NOP at NO_ROUND):
        # a P1A there would bump promised rounds beyond the window, and the
        # Phase-2 re-propose below would vote values into instances the
        # takeover has no business touching.
        in_win = insts < hi
        scanned += int(in_win.sum())
        p1a = MsgBatch(
            msgtype=jnp.where(
                jnp.asarray(in_win), MSG_P1A, MSG_NOP
            ).astype(jnp.int32),
            inst=jnp.asarray(insts),
            rnd=jnp.where(jnp.asarray(in_win), crnd, NO_ROUND).astype(
                jnp.int32
            ),
            vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
            swid=jnp.full((b,), coordinator_id, jnp.int32),
            value=jnp.zeros((b, vwords), jnp.int32),
        )
        promises = hw.prepare(p1a)
        # aggregate promises: per position, need quorum of P1B; track best vrnd
        got = np.zeros((b,), np.int32)
        best_vrnd = np.full((b,), NO_ROUND, np.int32)
        best_val = np.zeros((b, vwords), np.int32)
        for v in promises:
            if v is None:
                continue
            host_t = np.asarray(v.msgtype)
            host_vr = np.asarray(v.vrnd)
            host_val = np.asarray(v.value)
            is_p1b = host_t == 2  # MSG_P1B
            got += is_p1b.astype(np.int32)
            better = is_p1b & (host_vr > best_vrnd)
            best_vrnd = np.where(better, host_vr, best_vrnd)
            best_val = np.where(better[:, None], host_val, best_val)
        quorate = got >= quorum
        voted = quorate & (best_vrnd != NO_ROUND) & in_win
        if voted.any():
            # Re-propose discovered values at the new round (value-choice
            # rule).  NOP slots at ``crnd`` vote like P2As (the wire-path
            # filler semantics), which is the designed in-window catch-up —
            # but out-of-window slots must stay inert, so their round is
            # NO_ROUND (below any promise).
            p2a = MsgBatch(
                msgtype=jnp.where(jnp.asarray(voted), MSG_P2A, 0).astype(jnp.int32),
                inst=jnp.asarray(insts),
                rnd=jnp.where(jnp.asarray(in_win), crnd, NO_ROUND).astype(
                    jnp.int32
                ),
                vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
                swid=jnp.full((b,), coordinator_id, jnp.int32),
                value=jnp.asarray(best_val),
            )
            hw.vote(p2a)
            for i in np.nonzero(voted)[0]:
                reproposed.append((int(insts[i]), best_val[i].tobytes()))
                highest_voted = max(highest_voted, int(insts[i]))

    next_inst = max(est_next_inst, highest_voted + 1)
    return TakeoverResult(
        crnd=crnd, next_inst=next_inst, reproposed=reproposed, scanned=scanned
    )


def takeover_group(
    mg,                      # MultiGroupDataplane
    gid: int,
    *,
    coordinator_id: int,
    epoch: int,
    est_next_inst: int,
    window: int,
    quorum: int,
) -> TakeoverResult:
    """Per-group coordinator takeover against a multi-group dataplane.

    Runs the exact same safe procedure as :func:`takeover`, but scoped to one
    group's acceptor rings via ``mg.group_view(gid)`` — the Phase-1 scan, the
    re-proposals, and the sequencer catch-up touch only that group's slice of
    the stacked ``(G, A, N)`` state.  Every other group's registers, watermark
    and round are untouched, which is what makes failover a per-tenant event
    in the shared-service model (DESIGN.md §5).
    """
    return takeover(
        mg.group_view(gid),
        coordinator_id=coordinator_id,
        epoch=epoch,
        est_next_inst=est_next_inst,
        window=window,
        quorum=quorum,
    )
