"""Pallas TPU kernel: the CAANS acceptor dataplane (Phase 2A vote).

The paper's acceptor is a P4 match-action stage holding the instance history
in switch BRAM and rewriting Paxos headers at line rate.  The TPU-native
reformulation (DESIGN.md §2): the monotonic sequencer guarantees that a batch
of B messages addresses a *contiguous window* ``[base, base+B)`` of the
instance ring, so the per-packet random BRAM access becomes a contiguous
block load → VREG compare/select → block store:

    HBM (instance ring, the "BRAM")  --BlockSpec-->  VMEM tile
    msg batch fields (SoA)           --BlockSpec-->  VMEM tiles
    vote batch fields (SoA)          <--             VMEM tiles

Grid iterates over batch blocks; the ring block index is derived from the
scalar-prefetched window base (``(base//BB + i) % (N//BB)``), which also
handles ring wraparound for free as long as ``BB | N`` and ``BB | base`` —
invariants the sequencer maintains (batches are BB-aligned).

State update is in-place via ``input_output_aliases`` — the history never
round-trips through host memory, mirroring the stateful register semantics of
the P4 targets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import MSG_NOP, MSG_P2A, MSG_P2B, MSG_REJECT

NO_ROUND = -1

# Batch block (messages per grid step).  8x128 is the float32/int32 VREG tile;
# value words ride along the lane dimension.
DEFAULT_BLOCK_B = 128


def _acceptor_kernel(
    # scalar prefetch
    base_ref,          # int32[1]  window base slot (BB-aligned)
    aid_ref,           # int32[1]  acceptor id
    # inputs (VMEM tiles)
    msgtype_ref,       # int32[BB]
    msg_rnd_ref,       # int32[BB]
    msg_val_ref,       # int32[BB, V]
    st_rnd_ref,        # int32[BB]      ring block (aliased out)
    st_vrnd_ref,       # int32[BB]      ring block (aliased out)
    st_val_ref,        # int32[BB, V]   ring block (aliased out)
    # outputs
    out_st_rnd_ref,    # int32[BB]
    out_st_vrnd_ref,   # int32[BB]
    out_st_val_ref,    # int32[BB, V]
    vote_type_ref,     # int32[BB]
    vote_rnd_ref,      # int32[BB]
    vote_vrnd_ref,     # int32[BB]
    vote_swid_ref,     # int32[BB]
    vote_val_ref,      # int32[BB, V]
):
    msgtype = msgtype_ref[...]
    mrnd = msg_rnd_ref[...]
    mval = msg_val_ref[...]
    cur_rnd = st_rnd_ref[...]
    cur_vrnd = st_vrnd_ref[...]
    cur_val = st_val_ref[...]

    # vote rule: P2A (or sequenced NOP filler) with rnd >= promised
    is_p2 = (msgtype == MSG_P2A) | (msgtype == MSG_NOP)
    accept = is_p2 & (mrnd >= cur_rnd)

    new_rnd = jnp.where(accept, mrnd, cur_rnd)
    new_vrnd = jnp.where(accept, mrnd, cur_vrnd)
    new_val = jnp.where(accept[:, None], mval, cur_val)

    out_st_rnd_ref[...] = new_rnd
    out_st_vrnd_ref[...] = new_vrnd
    out_st_val_ref[...] = new_val

    vote_type_ref[...] = jnp.where(accept, MSG_P2B, MSG_REJECT).astype(jnp.int32)
    vote_rnd_ref[...] = new_rnd
    vote_vrnd_ref[...] = new_vrnd
    vote_swid_ref[...] = jnp.full_like(msgtype, aid_ref[0])
    vote_val_ref[...] = jnp.where(accept[:, None], mval, 0)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "interpret"),
)
def acceptor_phase2_window(
    st_rnd: jax.Array,     # int32[N]
    st_vrnd: jax.Array,    # int32[N]
    st_val: jax.Array,     # int32[N, V]
    base: jax.Array,       # int32[]  window base slot, BB-aligned, BB | N
    aid: jax.Array,        # int32[]
    msgtype: jax.Array,    # int32[B]
    msg_rnd: jax.Array,    # int32[B]
    msg_val: jax.Array,    # int32[B, V]
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Vote on a contiguous window batch.  Returns
    (st_rnd', st_vrnd', st_val', vote_type, vote_rnd, vote_vrnd, vote_swid,
    vote_val)."""
    n = st_rnd.shape[0]
    b, v = msg_val.shape
    bb = min(block_b, b)
    assert b % bb == 0, (b, bb)
    assert n % bb == 0, (n, bb)
    grid = (b // bb,)
    n_blocks = n // bb

    def ring_map(i, base_ref, aid_ref):
        # block index into the ring, wrapping modulo N/BB
        return ((base_ref[0] // bb + i) % n_blocks,)

    def ring_map2(i, base_ref, aid_ref):
        return ((base_ref[0] // bb + i) % n_blocks, 0)

    def batch_map(i, base_ref, aid_ref):
        return (i,)

    def batch_map2(i, base_ref, aid_ref):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), batch_map),        # msgtype
            pl.BlockSpec((bb,), batch_map),        # msg_rnd
            pl.BlockSpec((bb, v), batch_map2),     # msg_val
            pl.BlockSpec((bb,), ring_map),         # st_rnd
            pl.BlockSpec((bb,), ring_map),         # st_vrnd
            pl.BlockSpec((bb, v), ring_map2),      # st_val
        ],
        out_specs=[
            pl.BlockSpec((bb,), ring_map),         # st_rnd'
            pl.BlockSpec((bb,), ring_map),         # st_vrnd'
            pl.BlockSpec((bb, v), ring_map2),      # st_val'
            pl.BlockSpec((bb,), batch_map),        # vote_type
            pl.BlockSpec((bb,), batch_map),        # vote_rnd
            pl.BlockSpec((bb,), batch_map),        # vote_vrnd
            pl.BlockSpec((bb,), batch_map),        # vote_swid
            pl.BlockSpec((bb, v), batch_map2),     # vote_val
        ],
    )

    out_shapes = [
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n, v), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b, v), jnp.int32),
    ]

    fn = pl.pallas_call(
        _acceptor_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        # ring state updated in place: inputs 5,6,7 (after the 2 scalar
        # prefetch args) alias outputs 0,1,2
        input_output_aliases={5: 0, 6: 1, 7: 2},
        interpret=interpret,
    )
    base = jnp.asarray(base, jnp.int32).reshape((1,))
    aid = jnp.asarray(aid, jnp.int32).reshape((1,))
    return tuple(
        fn(base, aid, msgtype, msg_rnd, msg_val, st_rnd, st_vrnd, st_val)
    )
