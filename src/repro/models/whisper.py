"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

``input_specs()`` supplies precomputed frame embeddings (B, F, D) — the conv
frontend is a stub per the assignment.  Encoder: non-causal self-attention
with sinusoidal positions.  Decoder: causal self-attention + cross-attention
over the encoder output, GELU MLPs, tied embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import PSpec


def _stack(spec: PSpec, n: int) -> PSpec:
    return PSpec((n,) + spec.shape, ("layers",) + spec.axes, spec.init, spec.scale)


def _gelu_mlp_specs(cfg) -> dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi": PSpec((d, f), ("embed", "mlp")),
        "wo": PSpec((f, d), ("mlp", "embed")),
    }


def _gelu_mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]))
    h = L.shard(h, ("batch", None, "mlp_act"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def _enc_block_specs(cfg) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": PSpec((d,), ("embed",), init="zeros"),
        "ln2": PSpec((d,), ("embed",), init="zeros"),
        "attn": L.attention_specs(cfg),
        "mlp": _gelu_mlp_specs(cfg),
    }


def _dec_block_specs(cfg) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": PSpec((d,), ("embed",), init="zeros"),
        "lnx": PSpec((d,), ("embed",), init="zeros"),
        "ln2": PSpec((d,), ("embed",), init="zeros"),
        "attn": L.attention_specs(cfg),
        "cross": L.attention_specs(cfg),
        "mlp": _gelu_mlp_specs(cfg),
    }


def specs(cfg) -> dict[str, Any]:
    enc = jax.tree_util.tree_map(
        lambda s: _stack(s, cfg.n_enc_layers),
        _enc_block_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )
    dec = jax.tree_util.tree_map(
        lambda s: _stack(s, cfg.n_layers),
        _dec_block_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )
    return {
        "embed": PSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "enc": enc,
        "dec": dec,
        "ln_enc": PSpec((cfg.d_model,), ("embed",), init="zeros"),
        "ln_f": PSpec((cfg.d_model,), ("embed",), init="zeros"),
    }


def encode(cfg, params, frames: jax.Array) -> jax.Array:
    """frames: (B, F, D) stub embeddings -> encoder output (B, F, D)."""
    b, f, d = frames.shape
    h = frames.astype(params["ln_enc"].dtype) + L.sinusoidal_pos(f, d).astype(
        frames.dtype
    )
    h = L.shard(h, ("batch", None, None))

    def body(carry, blk):
        x = carry
        a, _ = L.attention_fwd(
            blk["attn"], L.rms_norm(x, blk["ln1"], cfg.norm_eps), cfg,
            causal=False, use_rope=False,
        )
        x = x + a
        x = x + _gelu_mlp(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps))
        return x, None

    body_fn = L.checkpoint_fn(body, cfg)
    h, _ = jax.lax.scan(body_fn, h, params["enc"])
    return L.rms_norm(h, params["ln_enc"], cfg.norm_eps)


def forward(cfg, params, batch, *, collect_cache: bool = False):
    """batch = {frames: (B,F,D), tokens: (B,S)}."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    d = cfg.d_model
    h = params["embed"][tokens].astype(params["embed"].dtype)
    h = h + L.sinusoidal_pos(s, d).astype(h.dtype)
    h = L.shard(h, ("batch", "act_seq", None))

    def body(carry, blk):
        x = carry
        a, (kk, vv) = L.attention_fwd(
            blk["attn"], L.rms_norm(x, blk["ln1"], cfg.norm_eps), cfg,
            causal=True, use_rope=False,
        )
        x = x + a
        # cross-attention: kv from encoder output
        xq = L.rms_norm(x, blk["lnx"], cfg.norm_eps)
        ck = jnp.einsum("bfd,dhk->bfhk", enc_out, blk["cross"]["wk"])
        cv = jnp.einsum("bfd,dhk->bfhk", enc_out, blk["cross"]["wv"])
        c, _ = L.attention_fwd(
            blk["cross"], xq, cfg, causal=False, use_rope=False,
            kv_override=(ck, cv),
        )
        x = x + c
        x = x + _gelu_mlp(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps))
        ys = (kk, vv, ck, cv) if collect_cache else None
        return x, ys

    body_fn = L.checkpoint_fn(body, cfg)
    h, sc = jax.lax.scan(body_fn, h, params["dec"])
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["embed"].T.astype(h.dtype))
    logits = L.shard(logits, ("batch", "act_seq", "vocab"))

    cache = None
    if collect_cache:
        kk, vv, ck, cv = sc
        kpos = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None, :], (cfg.n_layers, b, s)
        )
        cache = {"k": kk, "v": vv, "kpos": kpos, "cross_k": ck, "cross_v": cv}
    return logits, cache


def prefill(cfg, params, batch):
    return forward(cfg, params, batch, collect_cache=True)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda s: jnp.full(s.shape, -1, jnp.int32)
        if s.dtype == jnp.int32
        else jnp.zeros(s.shape, s.dtype),
        cache_specs(cfg, batch, max_len, dtype),
    )


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    f = cfg.src_len
    return {
        "k": jax.ShapeDtypeStruct((l, batch, max_len, kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((l, batch, max_len, kv, hd), dtype),
        "kpos": jax.ShapeDtypeStruct((l, batch, max_len), jnp.int32),
        "cross_k": jax.ShapeDtypeStruct((l, batch, f, kv, hd), dtype),
        "cross_v": jax.ShapeDtypeStruct((l, batch, f, kv, hd), dtype),
    }


CACHE_AXES = {
    "k": ("layers", "batch", "cache_seq", "kv_heads", None),
    "v": ("layers", "batch", "cache_seq", "kv_heads", None),
    "kpos": ("layers", "batch", "cache_seq"),
    "cross_k": ("layers", "batch", None, "kv_heads", None),
    "cross_v": ("layers", "batch", None, "kv_heads", None),
}


def decode_step(cfg, params, tokens, cache, pos):
    b = tokens.shape[0]
    kvh, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    d = cfg.d_model
    h = params["embed"][tokens].astype(params["embed"].dtype)
    h = h + _pos_embed_at(pos, d).astype(h.dtype)
    c = cache["k"].shape[2]
    slot = pos % c

    def body(carry, xs):
        blk, kc, vc, kp, ck, cv = xs
        x = carry
        xn = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
        p = blk["attn"]
        q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
        kk = jnp.einsum("bsd,dhk->bshk", xn, p["wk"])
        vv = jnp.einsum("bsd,dhk->bshk", xn, p["wv"])
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kk.astype(kc.dtype), slot, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vv.astype(vc.dtype), slot, 1)
        kp = jax.lax.dynamic_update_slice_in_dim(
            kp, jnp.full((b, 1), pos, jnp.int32), slot, 1
        )
        out = L.decode_attention(q.reshape(b, 1, kvh, g, hd), kc, vc, kp, pos)
        x = x + jnp.einsum(
            "bshk,hkd->bsd", out.reshape(b, 1, cfg.n_heads, hd), p["wo"]
        )
        # cross-attention over the fixed encoder cache
        xq = L.rms_norm(x, blk["lnx"], cfg.norm_eps)
        pc = blk["cross"]
        qx = jnp.einsum("bsd,dhk->bshk", xq, pc["wq"])
        f = ck.shape[1]
        fpos = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))
        outx = L.decode_attention(
            qx.reshape(b, 1, kvh, g, hd), ck, cv, fpos, jnp.int32(f),
        )
        x = x + jnp.einsum(
            "bshk,hkd->bsd", outx.reshape(b, 1, cfg.n_heads, hd), pc["wo"]
        )
        x = x + _gelu_mlp(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps))
        return x, (kc, vc, kp)

    h, (kc, vc, kp) = jax.lax.scan(
        body,
        h,
        (
            params["dec"],
            cache["k"],
            cache["v"],
            cache["kpos"],
            cache["cross_k"],
            cache["cross_v"],
        ),
    )
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["embed"].T.astype(h.dtype))
    new_cache = dict(cache)
    new_cache.update({"k": kc, "v": vc, "kpos": kp})
    return logits, new_cache


def _pos_embed_at(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal position embedding for one (traced) position."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d][None]
