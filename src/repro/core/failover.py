"""Coordinator failover (paper §3.1 + §6.4) and acceptor state restore.

When the hardware coordinator fails, a software coordinator takes over.  The
paper's procedure: the replacement needs only an *estimate* of the last
instance; if the estimate is low, acceptors reject until it catches up; if it
is high, learners see gaps and fill them via ``recover``.

We implement the *safe* variant of that procedure: the takeover coordinator
claims a fresh, strictly higher round (rounds are partitioned by coordinator
id so concurrent coordinators can never share one) and runs batched Phase 1
over the uncertainty window.  Any instance found voted is re-proposed with
its discovered value (Paxos's value-choice rule); untouched instances become
available for fresh proposals.  This both "catches up" the sequencer and
preserves agreement for already-decided instances.

``restore_acceptor`` is the complementary *acceptor*-side recovery
(DESIGN.md §9): a group member that crashed WITH state loss (its register
file / BRAM wiped) is rebuilt from snapshot + live ring suffix before
rejoining the quorum — the vertical-Paxos-style state transfer NetChain
pairs with in-network consensus.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .types import MSG_NOP, MSG_P1A, MSG_P2A, AcceptorState, MsgBatch

NO_ROUND = -1


def allocate_round(epoch: int, coordinator_id: int, n_coordinators: int = 16) -> int:
    """Globally unique, monotonically increasing round for a coordinator.

    rounds ≡ coordinator_id (mod n_coordinators): two coordinators can never
    issue the same round, the invariant that makes >= acceptance safe.
    """
    return epoch * n_coordinators + coordinator_id


@dataclasses.dataclass
class TakeoverResult:
    crnd: int
    next_inst: int
    reproposed: list[tuple[int, bytes]]   # (inst, value) re-proposed values
    scanned: int


def takeover(
    hw,                      # HardwareDataplane
    *,
    coordinator_id: int,
    epoch: int,
    est_next_inst: int,
    window: int,
    quorum: int,
) -> TakeoverResult:
    """Run the safe takeover procedure against the (hardware) acceptors.

    Scans ``[max(0, est_next_inst - window), est_next_inst + window)`` with
    batched Phase 1, collects promises, and re-proposes discovered values
    with the new round.  Returns the state the new coordinator starts from.
    """
    crnd = allocate_round(epoch, coordinator_id)
    lo = max(0, est_next_inst - window)
    hi = est_next_inst + window
    b = hw.cfg.batch
    vwords = hw.cfg.value_words

    reproposed: list[tuple[int, bytes]] = []
    highest_voted = -1
    scanned = 0

    for base in range(lo, hi, b):
        insts = np.arange(base, base + b, dtype=np.int32)
        # The final batch may overhang the window when (hi - lo) % b != 0.
        # Out-of-window positions are masked inert (msgtype NOP at NO_ROUND):
        # a P1A there would bump promised rounds beyond the window, and the
        # Phase-2 re-propose below would vote values into instances the
        # takeover has no business touching.
        in_win = insts < hi
        scanned += int(in_win.sum())
        p1a = MsgBatch(
            msgtype=jnp.where(
                jnp.asarray(in_win), MSG_P1A, MSG_NOP
            ).astype(jnp.int32),
            inst=jnp.asarray(insts),
            rnd=jnp.where(jnp.asarray(in_win), crnd, NO_ROUND).astype(
                jnp.int32
            ),
            vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
            swid=jnp.full((b,), coordinator_id, jnp.int32),
            value=jnp.zeros((b, vwords), jnp.int32),
        )
        promises = hw.prepare(p1a)
        # aggregate promises: per position, need quorum of P1B; track best vrnd
        got = np.zeros((b,), np.int32)
        best_vrnd = np.full((b,), NO_ROUND, np.int32)
        best_val = np.zeros((b, vwords), np.int32)
        for v in promises:
            if v is None:
                continue
            host_t = np.asarray(v.msgtype)
            host_vr = np.asarray(v.vrnd)
            host_val = np.asarray(v.value)
            is_p1b = host_t == 2  # MSG_P1B
            got += is_p1b.astype(np.int32)
            better = is_p1b & (host_vr > best_vrnd)
            best_vrnd = np.where(better, host_vr, best_vrnd)
            best_val = np.where(better[:, None], host_val, best_val)
        quorate = got >= quorum
        voted = quorate & (best_vrnd != NO_ROUND) & in_win
        if voted.any():
            # Re-propose discovered values at the new round (value-choice
            # rule).  NOP slots at ``crnd`` vote like P2As (the wire-path
            # filler semantics), which is the designed in-window catch-up —
            # but out-of-window slots must stay inert, so their round is
            # NO_ROUND (below any promise).
            p2a = MsgBatch(
                msgtype=jnp.where(jnp.asarray(voted), MSG_P2A, 0).astype(jnp.int32),
                inst=jnp.asarray(insts),
                rnd=jnp.where(jnp.asarray(in_win), crnd, NO_ROUND).astype(
                    jnp.int32
                ),
                vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
                swid=jnp.full((b,), coordinator_id, jnp.int32),
                value=jnp.asarray(best_val),
            )
            hw.vote(p2a)
            for i in np.nonzero(voted)[0]:
                reproposed.append((int(insts[i]), best_val[i].tobytes()))
                highest_voted = max(highest_voted, int(insts[i]))

    next_inst = max(est_next_inst, highest_voted + 1)
    return TakeoverResult(
        crnd=crnd, next_inst=next_inst, reproposed=reproposed, scanned=scanned
    )


def takeover_group(
    mg,                      # MultiGroupDataplane
    gid: int,
    *,
    coordinator_id: int,
    epoch: int,
    est_next_inst: int,
    window: int,
    quorum: int,
) -> TakeoverResult:
    """Per-group coordinator takeover against a multi-group dataplane.

    Runs the exact same safe procedure as :func:`takeover`, but scoped to one
    group's acceptor rings via ``mg.group_view(gid)`` — the Phase-1 scan, the
    re-proposals, and the sequencer catch-up touch only that group's slice of
    the stacked ``(G, A, N)`` state.  Every other group's registers, watermark
    and round are untouched, which is what makes failover a per-tenant event
    in the shared-service model (DESIGN.md §5).
    """
    return takeover(
        mg.group_view(gid),
        coordinator_id=coordinator_id,
        epoch=epoch,
        est_next_inst=est_next_inst,
        window=window,
        quorum=quorum,
    )


# -- acceptor state restore (snapshot + live ring suffix, DESIGN.md §9) ------

def rebuild_acceptor_rows(
    ld: np.ndarray,
    li: np.ndarray,
    lv: np.ndarray,
    crnd: int,
    lo: int,
    hi: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Reconstruct one acceptor's ``(rnd, vrnd, value)`` register rows from
    the learner ring's decided live suffix.

    Every decided instance in ``[lo, hi)`` is adopted as a vote at the
    current round (decided values are frozen by quorum, so re-voting them at
    any round is safe — the vertical-Paxos state-transfer argument); every
    other slot is reborn fresh-zero.  Instances below ``lo`` live in the
    snapshot and their ring slots are reclaimable, so the rebuilt acceptor
    never needs them.
    """
    n = ld.shape[0]
    vwords = lv.shape[1]
    adopt_rnd = max(int(crnd), 0)
    rnd = np.zeros((n,), np.int32)
    vrnd = np.full((n,), NO_ROUND, np.int32)
    val = np.zeros((n, vwords), np.int32)
    sel = (ld != 0) & (li >= lo) & (li < hi)
    slots = np.nonzero(sel)[0]
    rnd[slots] = adopt_rnd
    vrnd[slots] = adopt_rnd
    val[slots] = lv[slots]
    return rnd, vrnd, val


def restore_acceptor(
    hw,                      # HardwareDataplane or MultiGroupDataplane
    aid: int,
    *,
    gid: int | None = None,
    watermark: int = 0,
) -> int:
    """Rebuild a wiped acceptor from snapshot watermark + live ring suffix
    and rejoin it to the quorum.

    The snapshot covers everything below ``watermark`` (those ring slots are
    reclaimed and must stay untouched on the rebuilt acceptor too — fresh
    zeros, exactly like a new ring generation).  The live suffix
    ``[watermark, next_inst)`` is adopted from the *learner* ring: only
    decided instances are transferred, undecided in-flight slots come back
    fresh and are re-decided by the surviving quorum's normal protocol.
    Returns the number of adopted (decided) instances.
    """
    if gid is not None:
        srow = hw._slab_row(gid)
        ld = np.asarray(hw.lstate.delivered[srow])
        li = np.asarray(hw.lstate.inst[srow])
        lv = np.asarray(hw.lstate.value[srow])
        crnd = int(hw.crnd_host[gid])
        hi = int(hw.next_inst_host[gid])
        rnd, vrnd, val = rebuild_acceptor_rows(ld, li, lv, crnd, watermark, hi)
        row = AcceptorState(
            rnd=jnp.asarray(rnd), vrnd=jnp.asarray(vrnd), value=jnp.asarray(val)
        )
        hw.stack = jax.tree_util.tree_map(
            lambda s, r: s.at[srow, aid].set(r), hw.stack, row
        )
        hw.revive_acceptor(gid, aid)
    else:
        ld = np.asarray(hw.lstate.delivered)
        li = np.asarray(hw.lstate.inst)
        lv = np.asarray(hw.lstate.value)
        crnd = int(jax.device_get(jnp.asarray(hw.cstate.crnd)))
        hi = int(hw._next_inst_host)
        rnd, vrnd, val = rebuild_acceptor_rows(ld, li, lv, crnd, watermark, hi)
        row = AcceptorState(
            rnd=jnp.asarray(rnd), vrnd=jnp.asarray(vrnd), value=jnp.asarray(val)
        )
        hw.stack = jax.tree_util.tree_map(
            lambda s, r: s.at[aid].set(r), hw.stack, row
        )
        hw.revive_acceptor(aid)
    return int((vrnd != NO_ROUND).sum())
