"""Deterministic simulated message fabric with UDP-like fault injection.

The paper's deployment carries Paxos headers in UDP datagrams: messages can
be dropped, duplicated, and reordered.  ICI collectives are reliable, so in
the TPU adaptation loss lives at the host/DCN boundary — which is exactly
where this simulator sits (between host-side role steps).  Faults are driven
by a seeded RNG so every adversarial schedule is reproducible.
"""
from __future__ import annotations

import dataclasses
import random
from collections import defaultdict, deque
from typing import Any, Deque, Dict, Hashable, List


@dataclasses.dataclass
class FaultSpec:
    drop: float = 0.0       # probability a message is dropped
    dup: float = 0.0        # probability a message is duplicated
    reorder: float = 0.0    # probability a message is queued out of order


class SimNet:
    """Point-to-point queues between named endpoints with fault injection."""

    def __init__(self, faults: FaultSpec | None = None, seed: int = 0):
        self.faults = faults or FaultSpec()
        self.rng = random.Random(seed)
        self.queues: Dict[Hashable, Deque[Any]] = defaultdict(deque)
        self.sent = 0
        self.dropped = 0
        self.partitioned: set = set()   # endpoints cut off from the fabric

    def partition(self, endpoint: Hashable, cut: bool = True) -> None:
        if cut:
            self.partitioned.add(endpoint)
        else:
            self.partitioned.discard(endpoint)

    def send(self, dst: Hashable, msg: Any) -> None:
        self.sent += 1
        if dst in self.partitioned:
            self.dropped += 1
            return
        if self.rng.random() < self.faults.drop:
            self.dropped += 1
            return
        copies = 2 if self.rng.random() < self.faults.dup else 1
        q = self.queues[dst]
        for _ in range(copies):
            if q and self.rng.random() < self.faults.reorder:
                pos = self.rng.randrange(len(q) + 1)
                q.insert(pos, msg)
            else:
                q.append(msg)

    def purge(self, dst: Hashable, predicate) -> int:
        """Drop every queued message at ``dst`` matching ``predicate``;
        returns the number dropped.  Models an endpoint flushing traffic
        that became undeliverable (e.g. addressed to a retired consensus
        group) without disturbing queue order for the survivors."""
        q = self.queues[dst]
        keep = [m for m in q if not predicate(m)]
        n = len(q) - len(keep)
        q.clear()
        q.extend(keep)
        self.dropped += n
        return n

    def recv(self, dst: Hashable) -> Any | None:
        q = self.queues[dst]
        return q.popleft() if q else None

    def recv_all(self, dst: Hashable) -> List[Any]:
        q = self.queues[dst]
        out = list(q)
        q.clear()
        return out

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())
