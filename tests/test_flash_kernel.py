"""Pallas flash-attention kernel vs direct-softmax oracle (interpret mode)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

RNG = np.random.default_rng(7)


def _mk(b, h, kvh, s, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32).astype(dtype)
    k = jnp.asarray(RNG.standard_normal((b, kvh, s, d)), jnp.float32).astype(dtype)
    v = jnp.asarray(RNG.standard_normal((b, kvh, s, d)), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,h,kvh,s,d",
    [(1, 4, 2, 256, 64), (2, 4, 4, 128, 128), (1, 8, 1, 256, 64), (1, 2, 2, 384, 128)],
)
def test_flash_causal_sweep(b, h, kvh, s, d):
    q, k, v = _mk(b, h, kvh, s, d, jnp.float32)
    got = flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("window", [64, 128, 1024])
def test_flash_sliding_window(window):
    q, k, v = _mk(1, 4, 2, 256, 64, jnp.float32)
    got = flash_attention(q, k, v, window=window, interpret=True)
    want = ref.flash_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_non_causal():
    q, k, v = _mk(1, 2, 1, 128, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_bf16():
    q, k, v = _mk(1, 4, 2, 128, 128, jnp.bfloat16)
    got = flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2
    )


def test_flash_matches_model_layer_attention():
    """Cross-check against the pure-JAX chunked attention used by the models."""
    from repro.models.layers import flash_attention as jnp_flash

    b, kvh, g, s, d = 1, 2, 2, 256, 64
    q, k, v = _mk(b, kvh * g, kvh, s, d, jnp.float32)
    got = flash_attention(q, k, v, window=64, interpret=True)
    # models layout: q (B, S, KV, G, D); k/v (B, S, KV, D)
    qm = q.reshape(b, kvh, g, s, d).transpose(0, 3, 1, 2, 4)
    km = k.transpose(0, 2, 1, 3)
    vm = v.transpose(0, 2, 1, 3)
    want = jnp_flash(qm, km, vm, causal=True, window=64, chunk_q=128, chunk_k=128)
    want = want.transpose(0, 2, 3, 1, 4).reshape(b, kvh * g, s, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
