"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes and finiteness."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train import train_loop

TINY = ShapeConfig("tiny", 16, 2, "train")
ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    batch = registry.make_inputs(cfg, TINY, key)
    mod = registry.family_module(cfg)
    logits, _ = mod.forward(
        cfg, params, {k: v for k, v in batch.items() if k != "labels"}
    )
    assert logits.shape == (TINY.global_batch, TINY.seq_len, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_no_nans(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    state = train_loop.init_state(cfg, key)
    ocfg = opt_mod.OptConfig(lr=5e-3, warmup_steps=0, total_steps=10)
    step = jax.jit(train_loop.make_train_step(cfg, ocfg))
    batch = registry.make_inputs(cfg, TINY, key)

    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), arch
        assert np.isfinite(float(metrics["grad_norm"])), arch
        losses.append(loss)
    # same batch thrice -> loss must drop
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_matches_family_estimate(arch):
    """Exact param count (from specs) within 12% of the 6ND-model estimate."""
    cfg = get_config(arch)
    exact = registry.count_params(cfg)
    est = cfg.n_params
    assert abs(exact - est) / est < 0.12, (arch, exact, est)


def test_named_sizes_sanity():
    """Spot-check full-size parameter counts against the model names."""
    expected = {
        "gemma3-27b": 27e9,
        "yi-9b": 9e9,
        "mistral-nemo-12b": 12e9,
        "dbrx-132b": 132e9,
    }
    for arch, approx in expected.items():
        exact = registry.count_params(get_config(arch))
        assert 0.7 * approx < exact < 1.45 * approx, (arch, exact)


def test_grad_accum_equivalence():
    """grad_accum=2 must match grad_accum=1 on the same global batch."""
    cfg = get_config("qwen3-4b").reduced()
    cfg = dataclasses.replace(cfg, remat=False)
    key = jax.random.PRNGKey(3)
    state0 = train_loop.init_state(cfg, key)
    batch = registry.make_inputs(cfg, TINY, key)
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1, m1 = jax.jit(train_loop.make_train_step(cfg, ocfg, grad_accum=1))(state0, batch)
    s2, m2 = jax.jit(train_loop.make_train_step(cfg, ocfg, grad_accum=2))(state0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(s1.params)
    l2 = jax.tree_util.tree_leaves(s2.params)
    for a, b in zip(l1, l2, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
