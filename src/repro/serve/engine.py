"""Serving engine: batched prefill + decode over any registry architecture,
plus the consensus-as-a-service front door.

``prefill_step`` and ``serve_step`` are the two lowered entry points of the
inference shapes (``prefill_32k`` lowers prefill; ``decode_32k`` /
``long_500k`` lower one ``serve_step`` against a seq_len-deep cache).  The
host-side ``ServeLoop`` runs continuous batching over them for the examples
and benchmarks.

``ConsensusService`` is the serving tier of the multi-group dataplane
(DESIGN.md §5): client *sessions* hash-route onto the G device-resident
Paxos groups of a multi-group ``PaxosContext``, so millions of independent
session streams share one fused dispatch while each session keeps a total
order within its group.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


def make_prefill_step(cfg) -> Callable:
    mod = registry.family_module(cfg)

    def prefill_step(params, batch: dict[str, jax.Array]):
        logits, cache = mod.prefill(cfg, params, batch)
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg) -> Callable:
    mod = registry.family_module(cfg)

    def serve_step(params, tokens, cache, pos):
        logits, cache = mod.decode_step(cfg, params, tokens, cache, pos)
        return logits.reshape(tokens.shape[0], -1), cache

    return serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray         # (S,) int32
    max_new: int = 16
    generated: list[int] | None = None


# ---------------------------------------------------------------------------
# Consensus as a service: session -> group routing over the fused dataplane
# ---------------------------------------------------------------------------
_FNV_OFFSET = 0x811C9DC5
_FNV_PRIME = 0x01000193


def session_hash(session_id) -> int:
    """32-bit FNV-1a of a session id (bytes / str / arbitrary-width int).

    Stable across processes and runs (unlike Python's salted ``hash``), cheap
    enough for the submit path, and uniform enough that G groups see balanced
    load from arbitrary session-id distributions.
    """
    if isinstance(session_id, bytes):
        data = session_id
    elif isinstance(session_id, str):
        data = session_id.encode()
    else:
        # variable-length encoding: arbitrary-width ints (uuid4().int is
        # 128-bit) must not overflow a fixed 8-byte window
        sid = int(session_id)
        data = sid.to_bytes(
            max(1, (sid.bit_length() + 8) // 8), "little", signed=True
        )
    h = _FNV_OFFSET
    for byte in data:
        h = ((h ^ byte) * _FNV_PRIME) & 0xFFFFFFFF
    return h


def session_group(session_id, n_groups: int) -> int:
    """Deterministic session -> consensus-group routing over a full group
    axis: ``session_hash % n_groups``."""
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    return session_hash(session_id) % n_groups


def session_group_live(session_id, live_groups: list[int], capacity: int) -> int:
    """Epoch-aware routing: primary slot with deterministic fallback.

    The session's *primary* slot is the capacity routing
    (``session_hash % capacity`` — exactly :func:`session_group`, and
    placement-independent).  While the primary is live the session stays
    pinned to it, so a membership event never moves sessions of surviving
    groups; only sessions whose slot retired re-route, deterministically,
    over the live set (``live_groups[hash % len]``) — and return to their
    primary when the slot is recreated."""
    if not live_groups:
        raise ValueError("no live consensus groups to route onto")
    h = session_hash(session_id)
    primary = h % capacity
    if primary in live_groups:
        return primary
    return live_groups[h % len(live_groups)]


class Ticket(NamedTuple):
    """Structured submit receipt: the group that sequences the value and
    the client sequence within that group's space.  A ``NamedTuple`` so the
    historical ``gid, seq = service.submit(...)`` unpacking keeps working
    while new code reads ``ticket.group`` / ``ticket.seq``."""

    group: int
    seq: int


class Session:
    """Typed per-session client handle — the session-scoped surface of
    ``ConsensusService``, replacing the loose ``(session_id, payload)``
    calling convention.

    Handles are stateless and constructed on demand (``service.session(id)``):
    routing is re-resolved per call, so a handle is always epoch-aware, and
    no per-session host memory accretes in the serving tier — a session
    universe of millions costs nothing here.  Stateful clients (leases,
    counters) layer above; see ``serve.kv.KVSession``.
    """

    __slots__ = ("service", "id")

    def __init__(self, service: "ConsensusService", session_id):
        self.service = service
        self.id = session_id

    @property
    def group(self) -> int:
        """The session's current group (epoch-aware routing)."""
        return self.service.group_of(self.id)

    def submit(self, payload: bytes) -> Ticket:
        """Route one value to the session's group; returns a :class:`Ticket`.

        The value-width door guard runs here as well as in
        ``PaxosContext.submit``: an oversized payload must fail at whichever
        front door the client used, with the limit named."""
        svc = self.service
        limit = svc.ctx.cfg.max_payload_bytes
        if len(payload) > limit:
            raise ValueError(
                f"payload is {len(payload)} bytes; this service carries at "
                f"most {limit} payload bytes per value "
                f"(PaxosConfig.value_words={svc.ctx.cfg.value_words})"
            )
        gid = svc.group_of(self.id)
        seq = svc.ctx.submit(payload, group=gid)
        svc.stats["submitted"] += 1
        svc.submits_per_group[gid] += 1
        return Ticket(gid, seq)

    def delivered(self) -> list[tuple[int, bytes]]:
        """The stitched ``(inst, payload)`` log this session observes."""
        return self.service._delivered(self.id)

    def read(self) -> list[bytes]:
        """Delivered payloads only, in decided order — the common
        application-level read."""
        return [p for _inst, p in self.service._delivered(self.id)]


class ConsensusService:
    """Front door of the multi-group consensus dataplane.

    Wraps a (multi-group) ``PaxosContext``: ``session(id)`` hands out the
    typed per-session handle (submit hash-routes the session's values to
    its group), ``pump``/``run_until_quiescent`` drive the shared fused
    dispatch, and ``Session.delivered`` reads the session's group log — the
    per-group total order every session in that group observes.

    **Routing epochs (dynamic membership, DESIGN.md §7).**  ``cfg.n_groups``
    is a capacity; the routing domain is the *live* group set.  Every
    membership event driven through ``create_group``/``retire_group`` bumps
    the routing epoch: sessions re-resolve via
    :func:`session_group_live` (primary capacity slot with deterministic
    fallback over the live set — placement-independent, and stable for
    sessions of surviving groups), a retiring group's log is archived under
    its ``(gid, generation)``, and ``delivered`` stitches a session's
    pre-retirement logs in front of its current group's log.  Membership
    must flow through this service (not the raw context) for the archive to
    stay complete.
    """

    def __init__(self, ctx):
        self.ctx = ctx
        self.n_groups = ctx.cfg.n_groups
        self.stats = {"submitted": 0}
        # bounded introspection state: G counters, not a per-session map —
        # the hash is pure and cheap, and a session universe of millions
        # must not accrete host memory in the routing tier
        self.submits_per_group = [0] * self.n_groups
        # routing epochs: per-epoch (live gid list, per-slot generation)
        # snapshots; archived logs keyed by (gid, generation)
        self._gen = [0] * self.n_groups
        self._epochs: list[tuple[list[int], list[int]]] = [
            (self._live_now(), list(self._gen))
        ]
        self._archived: dict[tuple[int, int], list[tuple[int, bytes]]] = {}

    # -- membership (drives the context, keeps the epoch history) ------------
    def _live_now(self) -> list[int]:
        live = getattr(self.ctx.hw, "live_host", None)
        if live is None:
            return list(range(self.n_groups))
        return [g for g in range(self.n_groups) if live[g]]

    @property
    def routing_epoch(self) -> int:
        return len(self._epochs) - 1

    def _bump_epoch(self) -> None:
        self._epochs.append((self._live_now(), list(self._gen)))

    def create_group(self) -> int:
        """Admit a tenant: claim a slot on the group axis and bump the
        routing epoch — sessions re-resolve over the grown live set."""
        gid = self.ctx.create_group()
        self._gen[gid] += 1
        self._bump_epoch()
        return gid

    def retire_group(self, gid: int) -> None:
        """Reclaim a tenant's slot: the group's log is archived under its
        (gid, generation) for ``delivered`` stitching, and the routing
        epoch bumps — sessions pinned to the slot re-route
        deterministically over the survivors."""
        log = self.ctx.retire_group(gid)
        self._archived[(gid, self._gen[gid])] = list(log)
        self._bump_epoch()

    def adopt_group(self, snap, log_prefix=None) -> int:
        """Admit a tenant bootstrapping from a transferred snapshot
        (vertical-Paxos state transfer, DESIGN.md §9) *through the serving
        tier*: generation and routing-epoch bookkeeping exactly as
        ``create_group``, with the context seeding its ``SnapshotStore``
        from the sealed transfer.  Returns the new group id."""
        gid = self.ctx.adopt_group(snap, log_prefix)
        self._gen[gid] += 1
        self._bump_epoch()
        return gid

    def migrate_group(self, gid: int, dst_shard: int):
        """Live slab migration through the serving tier (DESIGN.md §13):
        drain -> sealed snapshot -> seal-verified slot swap on the sharded
        dataplane, then a routing-epoch bump so placement-aware routers
        (``shard_of``) re-resolve.  The group's *identity* is untouched —
        no generation bump, session -> group routing and ``delivered``
        stitching are placement-blind.  Returns the sealed snapshot the
        transfer was verified against."""
        snap = self.ctx.migrate_group(gid, dst_shard)
        self._bump_epoch()
        return snap

    def plan_placement(self):
        """The load-weighted ``PlacementMap`` the sharded dataplane would
        adopt for the current ``group_loads()`` snapshot (LPT greedy,
        deterministic) — pure planning; adopt it group-by-group with
        ``migrate_group``."""
        hw = self.ctx.hw
        if not hasattr(hw, "plan_placement"):
            raise ValueError("plan_placement requires the sharded dataplane")
        return hw.plan_placement(self.group_loads())

    def group_of(self, session_id) -> int:
        """Epoch-aware session -> group routing over the live set."""
        live, _gens = self._epochs[-1]
        return session_group_live(session_id, live, self.n_groups)

    # -- group -> shard placement (the sharded dataplane, DESIGN.md §6) ------
    def group_placement(self) -> list[int]:
        """group id -> owning mesh shard.  Routing composes as session ->
        group (FNV-1a, placement-independent) -> shard (dataplane
        placement); an unsharded dataplane is the degenerate one-shard
        placement.  Re-placing groups over a different mesh therefore never
        moves a session between groups — only the group's *shard* changes."""
        hw = self.ctx.hw
        if hasattr(hw, "group_placement"):
            return hw.group_placement()
        return [0] * self.n_groups

    def shard_of(self, session_id) -> int:
        """Mesh shard that serves the session's group (O(1): indexes the
        dataplane's placement directly — no per-request list rebuild)."""
        gid = self.group_of(session_id)
        hw = self.ctx.hw
        if hasattr(hw, "shard_of_group"):
            return hw.shard_of_group(gid)
        return 0

    # -- the typed session surface -------------------------------------------
    def session(self, session_id) -> Session:
        """The typed per-session handle (see :class:`Session`)."""
        return Session(self, session_id)

    def submit(self, session_id, payload: bytes) -> Ticket:
        """Deprecated: use ``service.session(session_id).submit(payload)``.

        Thin shim over the typed surface; the ``Ticket`` it returns unpacks
        exactly like the historical ``(group, client_seq)`` tuple."""
        warnings.warn(
            "ConsensusService.submit(session_id, payload) is deprecated; "
            "use service.session(session_id).submit(payload)",
            DeprecationWarning,
            stacklevel=2,
        )
        return Session(self, session_id).submit(payload)

    def pump(self, rounds: int = 1) -> None:
        """Drive the shared dispatch.  The serving tier feeds the dispatch
        planner its cumulative per-group load snapshot first
        (``group_loads``) — introspection the planner surfaces through
        ``plan_report`` alongside its own per-wave tiering decisions."""
        planner = getattr(self.ctx, "planner", None)
        if planner is not None:
            planner.observe_service_loads(self.group_loads())
        self.ctx.pump(rounds)

    def run_until_quiescent(self, max_rounds: int = 64) -> None:
        """Pump until nothing is pending (or ``max_rounds``), refreshing
        the planner's serving-tier load snapshot *per pumped round* — the
        historical single pre-loop observation left multi-round quiescence
        runs reporting stale load introspection (delivery callbacks can
        change per-group loads between rounds)."""
        for _ in range(max_rounds):
            if self.ctx.quiescent():
                return
            self.pump()

    def plan_report(self) -> dict:
        """The dispatch planner's introspection report (burst-shape
        vocabulary, cohort dispatch counts, full-fold rounds, realignment
        sweeps) — the serving-tier view of DESIGN.md §8."""
        planner = getattr(self.ctx, "planner", None)
        if planner is None:
            return {}
        return planner.report()

    def delivered(self, session_id) -> list[tuple[int, bytes]]:
        """Deprecated: use ``service.session(session_id).delivered()``."""
        warnings.warn(
            "ConsensusService.delivered(session_id) is deprecated; "
            "use service.session(session_id).delivered()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self._delivered(session_id)

    def session_chain(self, session_id) -> list[tuple[int, int]]:
        """The distinct ``(group, generation)`` segments a session's history
        spans, in epoch order — the stitching skeleton ``Session.delivered``
        reads through, exposed so state-machine tiers (``serve.kv``) can
        keep one incremental replica per segment instead of re-reading
        concatenated logs."""
        seen: set = set()
        chain: list[tuple[int, int]] = []
        for live, gens in self._epochs:
            if not live:
                continue
            gid = session_group_live(session_id, live, self.n_groups)
            key = (gid, gens[gid])
            if key not in seen:
                seen.add(key)
                chain.append(key)
        return chain

    def group_generation(self, gid: int) -> int:
        """Current generation (``create_group`` count) of capacity slot
        ``gid`` — the second half of a segment key."""
        return self._gen[gid]

    def log_segment(self, gid: int, gen: int) -> list[tuple[int, bytes]]:
        """One ``(group, generation)`` segment of the stitched history: the
        archived log for retired generations, the live stitched log
        (snapshot prefix + group log, ``PaxosContext.full_group_log``) for
        the current one, empty for a generation this service never saw
        decide."""
        key = (gid, gen)
        if key in self._archived:
            return self._archived[key]
        if gen == self._gen[gid]:
            return self.ctx.full_group_log(gid)
        return []

    def archived_segments(self) -> dict[tuple[int, int], list[tuple[int, bytes]]]:
        """Read-only view of the retirement archive: ``(gid, generation) ->
        drained log``.  Apply loops use it to finalize retired segments."""
        return dict(self._archived)

    def _delivered(self, session_id) -> list[tuple[int, bytes]]:
        """The (inst, payload) log the session observes, in decided order.

        Uniform group-log read — no G == 1 special case (a service can pass
        through G == 1 transiently under dynamic membership, and an
        ungrouped context logs into ``group_log[0]``).  Under routing
        epochs the view is *stitched*: for every distinct (group,
        generation) the session was routed to, the archived pre-retirement
        log (retired generations) or the live group log (the current one),
        concatenated in epoch order.  With snapshots enabled the live read
        is itself stitched — compacted snapshot prefix + live log
        (``PaxosContext.full_group_log``) — so compaction is invisible to
        sessions in steady state, not just at retirement.
        """
        out: list[tuple[int, bytes]] = []
        for key in self.session_chain(session_id):
            out.extend(self.log_segment(*key))
        return out

    def group_loads(self) -> list[int]:
        """Values submitted per group (load-balance introspection)."""
        return list(self.submits_per_group)


class ServeLoop:
    """Greedy continuous-batching loop (host side, CPU-scale)."""

    def __init__(self, cfg, params, batch_size: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.mod = registry.family_module(cfg)
        self._decode = jax.jit(make_serve_step(cfg))
        self.steps = 0

    def run(self, requests: list[Request]) -> dict[int, list[int]]:
        """Teacher-forced prefill via decode steps, then greedy generation.

        Mixed prompt lengths never see padding: every row feeds a *real*
        token at every step — its prompt while the shared position counter
        is inside the prompt, its own greedy continuation afterwards.  Each
        row therefore crosses from teacher-forcing to generation at its own
        boundary, and since row ``i`` has consumed exactly ``t`` of its own
        tokens by step ``t``, the shared position counter is per-row exact.
        Generations match per-request decode bit-for-bit (cache rows only
        ever hold the row's own tokens); rows that finish early idle on
        their last token, which touches no other row.
        """
        out: dict[int, list[int]] = {}
        for chunk_start in range(0, len(requests), self.batch):
            chunk = requests[chunk_start : chunk_start + self.batch]
            b = len(chunk)
            # an empty prompt seeds token 0 as an implicit BOS (the row must
            # feed something at step 0) and generates from it
            lens = [max(1, len(r.prompt)) for r in chunk]
            cache = self.mod.init_cache(
                self.cfg, self.batch, self.max_len, jnp.dtype(self.cfg.dtype)
            )
            gen: list[list[int]] = [[] for _ in range(b)]
            cur = np.zeros((self.batch, 1), np.int32)
            for i, r in enumerate(chunk):
                if len(r.prompt):
                    cur[i, 0] = r.prompt[0]
            total = max(ln + r.max_new for ln, r in zip(lens, chunk, strict=True))
            for t in range(total - 1):
                last, cache = self._decode(
                    self.params, jnp.asarray(cur), cache, jnp.int32(t)
                )
                self.steps += 1
                nxt = np.asarray(jnp.argmax(last, axis=-1), np.int32)
                for i, r in enumerate(chunk):
                    k = t + 1 - lens[i]         # generation index this step
                    if k < 0:
                        cur[i, 0] = r.prompt[t + 1]   # still teacher-forcing
                    elif k < r.max_new:
                        gen[i].append(int(nxt[i]))
                        cur[i, 0] = nxt[i]
            for i, r in enumerate(chunk):
                out[r.rid] = gen[i]
        return out
