"""Paper Fig. 7c: with CAANS the bottleneck moves to the learner.

We measure per-role host time in the CAANS deployment: coordinator+acceptor
work is inside the compiled dataplane (device time), while the learner's
quorum bookkeeping and the application callback run on the host.  The paper's
claim — hardware roles ~idle on host, learner saturated — falls out as the
host-share of the learner dominating.
"""
from __future__ import annotations

import time

from repro.core import PaxosConfig, PaxosContext

from .common import emit

CFG = PaxosConfig(n_acceptors=3, n_instances=1 << 14, batch=64)
N = 2000


def run() -> None:
    ctx = PaxosContext(CFG)
    t_dataplane = 0.0
    t_learner = 0.0

    # instrument by wrapping the role pumps
    orig_coord = ctx._pump_coordinator
    orig_learn = ctx._pump_learners

    def timed_coord():
        nonlocal t_dataplane
        t0 = time.perf_counter()
        orig_coord()
        t_dataplane += time.perf_counter() - t0

    def timed_learn():
        nonlocal t_learner
        t0 = time.perf_counter()
        orig_learn()
        t_learner += time.perf_counter() - t0

    # warm dispatch shapes before instrumentation
    for k in range(256):
        ctx.submit(b"w" * 48)
        if k % 64 == 63:
            ctx.pump()
    ctx.run_until_quiescent(max_rounds=200)

    ctx._pump_coordinator = timed_coord
    ctx._pump_learners = timed_learn

    for k in range(N):
        ctx.submit(b"y" * 48)
        if k % 64 == 63:
            ctx.pump()
    ctx.run_until_quiescent(max_rounds=300)

    total = t_dataplane + t_learner
    emit(
        "fig7c/caans_host_share/learner",
        t_learner / N * 1e6,
        f"learner={t_learner/total:.2f} dataplane={t_dataplane/total:.2f} "
        f"(paper: learner ~100% CPU, coord/acc in hardware)",
    )
