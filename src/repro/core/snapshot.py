"""Host-side snapshot / log-compaction store (DESIGN.md §9).

The fixed-N instance rings of the CAANS dataplane wrap: instance ``i`` lives
in ring slot ``i % N``, so a service that runs forever re-uses every slot once
per N instances.  Historically nothing reclaimed slots — sequencing past an
undrained slot silently overwrote the learner's dedup state, corrupting the
log.  This module is the host half of the fix:

* ``SnapshotStore`` drains each group's *delivered* ring prefix below a
  watermark into host memory and seals it with
  ``kernels.digest.tree_digest`` so replicas can compare snapshots by one
  integer instead of trusting a transfer (the BFT-motivated divergence
  check).  The sealed prefix is also the compaction substrate: the context
  moves its host ``group_log`` prefix here and ``delivered()`` stitches
  ``snapshot prefix + live log`` uniformly in steady state.

* ``RingOverflowError`` is the device half's host surface: the reclamation
  mask threaded through ``kernels/wirepath.py`` refuses to sequence lanes at
  or past ``watermark + N``, and the dataplane door raises this *before*
  dispatch, naming the boundary instance, so callers schedule a snapshot
  instead of corrupting state.

A snapshot's seal is computed over the **full** drained prefix (instances and
raw value words), never incrementally per drain chunk — replicas that
snapshot at different cadences still agree bit-for-bit once their watermarks
match, which is what makes the seal a divergence check rather than a
drain-schedule fingerprint.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


class RingOverflowError(RuntimeError):
    """A burst would sequence into ring slots whose decisions have not been
    drained below the snapshot watermark — explicit backpressure at the
    dataplane door instead of the historical silent dedup-state overwrite.

    ``boundary`` is the first instance the ring cannot hold
    (``reclaimed + N``); ``attempted`` is one past the last instance of the
    refused burst.  ``context`` carries the same facts as a machine-readable
    dict so schedulers can react (snapshot-and-retry, shed the group, alert)
    without parsing the message.
    """

    def __init__(
        self, group: int, base: int, burst: int, boundary: int
    ) -> None:
        self.group = group
        self.base = base
        self.burst = burst
        self.boundary = boundary
        self.attempted = base + burst
        self.context = {
            "group": group,
            "base": base,
            "burst": burst,
            "boundary": boundary,
            "attempted": base + burst,
        }
        super().__init__(
            f"ring overflow: group {group} burst [{base}, {base + burst}) "
            f"passes the reclaim boundary {boundary} — snapshot the "
            f"delivered prefix to advance the watermark"
        )


class RingReclamationMixin:
    """Watermark-gated ring reclamation: the ONE door-guard contract every
    dataplane shares (DESIGN.md §9).

    Contract:

    * Disabled by default (``_reclaim_marks is None``): rings silently
      overwrite on wrap — the legacy mode unbounded-twin oracles rely on.
    * ``enable_reclamation()`` arms one watermark per group at 0.  From
      then on only instances in ``[mark, mark + N)`` may sequence; a burst
      whose window crosses ``mark + N`` raises :class:`RingOverflowError`
      at the host door *before* any device dispatch, and the reclamation-
      limit vector threaded through the kernels refuses the same lanes
      (defense in depth).
    * ``_reclaim_set`` advances a group's mark after a snapshot drain.
      Marks are monotone and can never pass the group's sequencer
      watermark; both violations raise ``ValueError``.

    A single-group dataplane is the G == 1 degenerate case (group id 0)
    whose public scalar surface adapts onto this vector core.  Subclasses
    provide ``cfg`` and ``_seq_marks()`` — the per-group sequencer
    watermark host mirrors the window validation reads.
    """

    _reclaim_marks: list[int] | None = None
    # provided by the concrete dataplane (PaxosConfig); declared loose so
    # the mixin stays independent of the host class hierarchy
    cfg: Any

    def _seq_marks(self) -> list[int]:
        raise NotImplementedError

    @property
    def reclamation_enabled(self) -> bool:
        return self._reclaim_marks is not None

    def enable_reclamation(self) -> None:
        """Switch from silent overwrite-on-wrap to watermark-gated rings."""
        if self._reclaim_marks is None:
            self._reclaim_marks = [0] * len(self._seq_marks())

    def _reclaim_set(self, gid: int, upto: int) -> None:
        if self._reclaim_marks is None:
            raise ValueError("reclamation is not enabled on this dataplane")
        lo, hi = self._reclaim_marks[gid], self._seq_marks()[gid]
        if not lo <= upto <= hi:
            raise ValueError(
                f"reclaim watermark {upto} outside [{lo}, {hi}] (group {gid})"
            )
        self._reclaim_marks[gid] = upto

    def _reclaim_guard(self, gid: int, base: int, burst: int) -> None:
        if self._reclaim_marks is None:
            return
        boundary = self._reclaim_marks[gid] + self.cfg.n_instances
        if base + burst > boundary:
            raise RingOverflowError(gid, base, burst, boundary)

    def _reclaim_limits_np(self) -> np.ndarray | None:
        """int32[G] first-refused-instance vector, or None when disabled —
        the host-authoritative form every dispatch threads to its engine."""
        if self._reclaim_marks is None:
            return None
        return np.asarray(self._reclaim_marks, np.int32) + self.cfg.n_instances


@dataclasses.dataclass
class GroupSnapshot:
    """One group's sealed snapshot: every decided instance below the
    watermark (including NOP fillers — the seal covers the raw ring words)
    plus the ``tree_digest`` seal over the full prefix."""

    watermark: int
    insts: np.ndarray    # int32[K]     absolute instances, ascending
    values: np.ndarray   # int32[K, V]  raw decided value words
    seal: int


def _seal(insts: np.ndarray, values: np.ndarray) -> int:
    # lazy import: kernels.ops pulls in jax; keep the store importable cheap
    from repro.kernels import ops as kops

    if insts.size == 0:
        return 0
    return int(kops.tree_digest((insts, values)))


class SnapshotStore:
    """Per-group sealed snapshot prefixes + compacted host log prefixes.

    Two parallel stores per group id:

    * ``entries`` — the raw drained ring prefix ``(insts, values)``: every
      decided instance below the watermark with its raw value words, NOP
      fillers included.  This is what the seal covers and what a reborn
      group member bootstraps from (it is exactly the device-visible
      history).
    * ``log_prefix`` — the application-level ``(inst, payload)`` list moved
      out of the context's ``group_log``: the compacted half of the stitched
      ``delivered()`` view.
    """

    def __init__(self) -> None:
        self._insts: dict[int, np.ndarray] = {}
        self._values: dict[int, np.ndarray] = {}
        self._watermark: dict[int, int] = {}
        self._log: dict[int, list[tuple[int, bytes]]] = {}

    # -- watermarks ---------------------------------------------------------
    def watermark(self, gid: int = 0) -> int:
        """First instance NOT covered by this group's snapshot."""
        return self._watermark.get(gid, 0)

    # -- drain --------------------------------------------------------------
    def absorb(
        self, gid: int, insts: np.ndarray, values: np.ndarray, upto: int
    ) -> None:
        """Append a drained ring chunk ``[watermark, upto)`` and advance the
        watermark.  ``insts`` must be ascending and inside the window; gaps
        are legal (undecided instances below the watermark are permanent
        holes — they can never be proposed again)."""
        wm = self.watermark(gid)
        if upto < wm:
            raise ValueError(f"snapshot watermark may not move back: "
                             f"{upto} < {wm} (group {gid})")
        insts = np.asarray(insts, np.int32).reshape((-1,))
        values = np.asarray(values, np.int32)
        if insts.size:
            values = values.reshape((insts.size, -1))
            if np.any(np.diff(insts) <= 0):
                raise ValueError("drained instances must be ascending")
            if int(insts[0]) < wm or int(insts[-1]) >= upto:
                raise ValueError(
                    f"drained instances [{int(insts[0])}, {int(insts[-1])}] "
                    f"outside the window [{wm}, {upto}) (group {gid})"
                )
            if gid in self._insts:
                self._insts[gid] = np.concatenate([self._insts[gid], insts])
                self._values[gid] = np.concatenate(
                    [self._values[gid], values]
                )
            else:
                self._insts[gid] = insts
                self._values[gid] = values
        self._watermark[gid] = upto

    def absorb_log(
        self, gid: int, entries: list[tuple[int, bytes]]
    ) -> None:
        """Append compacted ``(inst, payload)`` host-log entries."""
        self._log.setdefault(gid, []).extend(entries)

    # -- reads --------------------------------------------------------------
    def entries(self, gid: int = 0) -> tuple[np.ndarray, np.ndarray]:
        """The full drained prefix ``(insts, values)`` below the watermark."""
        if gid not in self._insts:
            return (np.zeros((0,), np.int32), np.zeros((0, 0), np.int32))
        return (self._insts[gid], self._values[gid])

    def log_prefix(self, gid: int = 0) -> list[tuple[int, bytes]]:
        """The compacted host-log prefix (for ``delivered()`` stitching)."""
        return self._log.get(gid, [])

    def seal(self, gid: int = 0) -> int:
        """``tree_digest`` over the FULL prefix — chunking-invariant, so two
        replicas agree iff their drained histories agree bit-for-bit."""
        insts, values = self.entries(gid)
        return _seal(insts, values)

    def snapshot(self, gid: int = 0) -> GroupSnapshot:
        """Sealed, self-contained snapshot of this group (transfer unit)."""
        insts, values = self.entries(gid)
        return GroupSnapshot(
            watermark=self.watermark(gid),
            insts=insts.copy(),
            values=values.copy(),
            seal=_seal(insts, values),
        )

    # -- transfer / lifecycle ----------------------------------------------
    def seed(
        self,
        gid: int,
        snap: GroupSnapshot,
        log_prefix: list[tuple[int, bytes]] | None = None,
    ) -> None:
        """Install a transferred snapshot under ``gid``, verifying its seal
        (the divergence check: a corrupted or diverged transfer is rejected,
        not trusted).  Used when a freshly created group member bootstraps
        from a peer's snapshot (vertical-Paxos state transfer)."""
        if gid in self._insts or self.watermark(gid):
            raise ValueError(f"group {gid} already has snapshot state")
        insts = np.asarray(snap.insts, np.int32).reshape((-1,))
        values = np.asarray(snap.values, np.int32)
        if insts.size:
            values = values.reshape((insts.size, -1))
        if _seal(insts, values) != snap.seal:
            raise ValueError(
                f"snapshot seal mismatch for group {gid}: transfer is "
                f"corrupt or replicas diverged"
            )
        if insts.size:
            self._insts[gid] = insts
            self._values[gid] = values
        self._watermark[gid] = int(snap.watermark)
        if log_prefix:
            self._log[gid] = list(log_prefix)

    def reset_group(self, gid: int) -> None:
        """Forget a group's snapshot state (slot retired / recreated)."""
        self._insts.pop(gid, None)
        self._values.pop(gid, None)
        self._watermark.pop(gid, None)
        self._log.pop(gid, None)
