"""Fast-lane smoke runs of the headline examples.

The examples are executable documentation; CI runs them in the fast lane so
an API change that breaks the documented surface fails before the slow
matrix.  ``runpy`` executes each file exactly as ``python examples/x.py``
would (the scripts assert their own invariants and raise on violation).
"""
import pathlib
import runpy

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"


@pytest.mark.parametrize("name", ["quickstart.py", "replicated_kv.py"])
def test_example_runs(name, capsys):
    runpy.run_path(str(EXAMPLES / name), run_name="__main__")
    assert capsys.readouterr().out.strip()    # each example reports progress
