"""Roofline-term derivation from dry-run artifacts.

Hardware constants (per spec; TPU v5-class):
    peak bf16:   197 TFLOP/s per chip
    HBM bw:      819 GB/s per chip
    ICI link bw: ~50 GB/s per link per chip

Terms (seconds per step, per chip — cost_analysis of the GSPMD-partitioned
executable is per-device, so no further division by chip count):

    compute    = HLO_FLOPs_dev / peak
    memory     = HLO_bytes_dev / hbm_bw
    collective = collective_bytes_dev / link_bw

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) with D = tokens processed
by the step; the ratio MODEL_FLOPS / (HLO_FLOPs_dev × chips) flags remat /
redundant-compute waste.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_dev: float
    hbm_bytes_dev: float
    coll_bytes_dev: float
    model_flops: float

    @property
    def t_compute(self) -> float:
        return self.flops_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (remat / redundancy waste)."""
        total = self.flops_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput achieved at the bound, vs pure-compute peak.

        = (MODEL_FLOPS / chips / t_bound) / PEAK — i.e. the MFU the step would
        achieve if it ran exactly at its dominant roofline term.
        """
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.chips / self.t_bound) / PEAK_FLOPS

    def row(self) -> dict[str, object]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N_active·D with D = tokens processed by the lowered step."""
    n = cfg.n_active_params
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d          # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def from_record(rec: dict) -> Roofline:
    return Roofline(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        chips=rec["chips"],
        flops_dev=rec.get("flops", 0.0),
        hbm_bytes_dev=rec.get("bytes_accessed", 0.0),
        coll_bytes_dev=rec.get("collective_bytes", 0.0),
        model_flops=rec.get("model_flops", 0.0),
    )
