"""Batched serving driver.

    python -m repro.launch.serve --arch qwen3-4b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.serve.engine import Request, ServeLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(cfg, key)
    rng = np.random.default_rng(args.seed)

    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    loop = ServeLoop(cfg, params, batch_size=args.batch,
                     max_len=args.prompt_len + args.max_new)
    t0 = time.time()
    out = loop.run(reqs)
    dt = time.time() - t0
    tok = sum(len(v) for v in out.values())
    print(
        f"served {len(reqs)} requests, {tok} tokens in {dt:.2f}s "
        f"({tok/max(dt,1e-9):.1f} tok/s, {loop.steps} decode steps)"
    )
    for rid in sorted(out)[:4]:
        print(f"  req {rid}: {out[rid]}")


if __name__ == "__main__":
    main()
