"""Dataplane contract checker: static alias/donation/prefetch/oracle-parity
verification of the wire-path kernel stack (DESIGN.md §12).

CAANS-style dataplanes are only trustworthy when the compiled artifact
provably matches the protocol layout — the paper leans on P4's static
pipeline typing for this.  Our equivalent hazards are hand-maintained
Python conventions that no single test names:

  * every ``pallas_call``'s ``input_output_aliases`` map must stay a
    bijection onto the leading (state) outputs, with input indices offset
    by ``num_scalar_prefetch`` — a silent off-by-one after the next
    prefetch vector lands corrupts aliased device state;
  * every ``jax.jit`` dispatch of a kernel wrapper must donate exactly
    the aliased state operands, and the host must never read a donated
    array after the call site;
  * every kernel wrapper in ``kernels/ops.py`` must keep signature parity
    (names, arity, keyword defaults) with its jnp oracle in
    ``core/batched.py``;
  * every entry point's scalar-prefetch vector must keep ONE canonical
    relative order, declared once as data below;
  * kernel bodies must stay trace-pure, and host watermark/round/
    reclamation mirrors in ``core/api.py`` may only move inside
    dispatch-/guard-annotated methods.

This module enforces all of that mechanically, from source (``ast``) and
from live signatures (``inspect``):

    PYTHONPATH=src python -m repro.analysis.contracts   # exit 0 when clean
    python tools/check_contracts.py                     # same, path-free

Violations print as ``file:line: RULE-ID: message`` and the process exits
non-zero on any non-advisory finding.  Rule catalogue in ``RULES``.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import importlib
import inspect
import os
import re
import sys
from collections.abc import Callable, Iterable, Sequence
from typing import Any

RULES: dict[str, str] = {
    "ALIAS-BIJECTION": (
        "input_output_aliases must map distinct inputs onto exactly the "
        "leading outputs 0..m-1 (a bijection onto the state outputs)"
    ),
    "ALIAS-OFFSET": (
        "an aliased input index must equal num_scalar_prefetch + the "
        "positional offset of a state operand whose BlockSpec (shape and "
        "index map) is identical to the aliased output's"
    ),
    "ALIAS-ARITY": (
        "pallas_call arity drift: call-site args, in/out specs, out_shape "
        "and kernel parameters must all agree with num_scalar_prefetch"
    ),
    "PREFETCH-ORDER": (
        "scalar-prefetch vectors must follow the canonical class order "
        "declared in CANONICAL_PREFETCH_ORDER"
    ),
    "DONATE-STATE": (
        "donate_argnums must name only aliased state operands "
        "(stack/lstate/astate)"
    ),
    "DONATE-MISSING": (
        "a jax.jit dispatch of a kernel wrapper must donate exactly the "
        "wrapper's registered state operands"
    ),
    "DONATE-USE": (
        "host read of a donated state attribute after the donating "
        "dispatch and before reassignment (use-after-donate)"
    ),
    "ORACLE-PARITY": (
        "kernel wrapper and jnp oracle signatures (names, arity, keyword "
        "defaults) must match, modulo declared extras"
    ),
    "ORACLE-MISSING": (
        "every public entry in kernels/ops.py must be registered with "
        "@dataplane_contract"
    ),
    "KERNEL-PURITY": (
        "_*_kernel bodies must not Python-branch on Ref-derived values or "
        "mutate captured globals"
    ),
    "KERNEL-HOST": (
        "host-level idiom (numpy/.item()/device_get/print) inside a kernel "
        "body (advisory)"
    ),
    "MIRROR-GUARD": (
        "host watermark/round/reclamation mirrors may only be mutated in "
        "__init__ or @mirror_guard-annotated methods of core/api.py"
    ),
}


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    file: str
    line: int
    message: str
    advisory: bool = False

    def __str__(self) -> str:
        tag = " (advisory)" if self.advisory else ""
        return f"{self.file}:{self.line}: {self.rule}{tag}: {self.message}"


# ---------------------------------------------------------------------------
# Contract registry: @dataplane_contract links wrappers to their oracles
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ContractEntry:
    """One kernel wrapper's declared contract (see DESIGN.md §12).

    ``state_args`` are the wrapper parameters that alias device state in
    the underlying ``pallas_call`` — exactly the set a ``jax.jit``
    dispatch must donate.  ``extra``/``oracle_extra`` name parameters that
    intentionally exist on only one side of the wrapper/oracle pair;
    everything else must match.  ``strict_order=False`` relaxes the
    comparison to name-set + default equality for pairs whose parameter
    layouts legitimately differ (e.g. coordinator-stateless wrappers).
    """

    name: str
    fn: Callable[..., Any]
    oracle: Callable[..., Any] | None
    state_args: tuple[str, ...]
    extra: tuple[str, ...]
    oracle_extra: tuple[str, ...]
    strict_order: bool
    reason: str | None


CONTRACT_REGISTRY: dict[str, ContractEntry] = {}


def dataplane_contract(
    oracle: Callable[..., Any] | None = None,
    *,
    state_args: Sequence[str] = (),
    extra: Sequence[str] = (),
    oracle_extra: Sequence[str] = (),
    strict_order: bool = True,
    reason: str | None = None,
) -> Callable[[Callable[..., Any]], Callable[..., Any]]:
    """Register a ``kernels/ops.py`` wrapper against its jnp oracle.

    Returns the function unchanged (zero runtime cost; positional layouts
    seen by ``jax.jit(..., donate_argnums=...)`` are untouched).  A
    wrapper with no standalone oracle passes ``oracle=None`` with a
    ``reason`` documenting how it is verified instead.
    """

    def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
        CONTRACT_REGISTRY[fn.__name__] = ContractEntry(
            name=fn.__name__,
            fn=fn,
            oracle=oracle,
            state_args=tuple(state_args),
            extra=tuple(extra),
            oracle_extra=tuple(oracle_extra),
            strict_order=strict_order,
            reason=reason,
        )
        return fn

    return deco


def mirror_guard(fn: Callable[..., Any]) -> Callable[..., Any]:
    """Marks a ``core/api.py`` method as an authorized mutation site for
    the host watermark/round/reclamation mirrors (dispatch methods that
    advance mirrors in lockstep with a device round, and guard/restore
    methods that re-seed them).  The mirror-pairing lint flags mirror
    writes anywhere else."""
    fn.__mirror_guard__ = True
    return fn


# ---------------------------------------------------------------------------
# Canonical dataplane layout — THE single source of truth (DESIGN.md §12)
# ---------------------------------------------------------------------------
# Relative order of scalar-prefetch classes on the wire.  Every prefetch
# vector (and every host entry-point's per-group scalar args) must list
# its scalars as an order-preserving subsequence of this tuple.
CANONICAL_PREFETCH_ORDER = (
    "gsel",       # selected group-block indices (grid compaction)
    "watermark",  # window base: next_inst / wni wave table / base slot
    "round",      # coordinator round (crnd)
    "quorum",     # f+1
    "alive",      # per-acceptor runtime liveness mask
    "limit",      # ring reclamation limit (first refused instance)
    "wen",        # persistent-wave per-round participation table
    "segids",     # per-lane local slab-row table (packed shard dispatch)
)

# ``enabled`` is deliberately NOT in the wire order: it is a host-side
# membership mask folded into ``round``/``watermark`` before prefetch
# (disabled groups ride at NO_ROUND with substituted lockstep bases), so
# host signatures may place it among trailing optionals.
_HOST_FOLDED = frozenset({"enabled"})

# Scalar-operand spelling -> class.  Kernel params are matched after
# stripping a trailing ``_ref``.
SCALAR_CLASSES: dict[str, str] = {
    "gs": "gsel", "gsel": "gsel", "blocks": "gsel",
    "ni": "watermark", "wni": "watermark", "wnik": "watermark",
    "base": "watermark", "next_inst": "watermark", "marks": "watermark",
    "cr": "round", "crnd": "round",
    "q": "quorum", "quorum": "quorum",
    "al": "alive", "alive": "alive",
    "lim": "limit", "limit": "limit", "reclaim_limit": "limit",
    "wen": "wen", "wenk": "wen",
    "en": "enabled", "enabled": "enabled",
    "seg": "segids", "segids": "segids",
}

# Per-entry expected prefetch vectors (class sequences), keyed by the
# wrapper function that owns the ``pallas_call``.  Each must be a
# subsequence of CANONICAL_PREFETCH_ORDER (asserted below).
EXPECTED_PREFETCH: dict[str, tuple[str, ...]] = {
    "cohort_wirepath_round": (
        "gsel", "watermark", "round", "quorum", "alive", "limit",
    ),
    "persistent_wirepath_round": (
        "gsel", "watermark", "round", "quorum", "alive", "limit", "wen",
    ),
    "acceptor_vote_all_window": ("watermark", "alive"),
    "packed_shard_round": (
        "watermark", "round", "quorum", "alive", "limit", "segids",
    ),
}

# Host entry points that delegate to another wire-path entry; the scalar
# args of the delegated call must stay in canonical relative order.
DELEGATING_ENTRY_POINTS: dict[str, str] = {
    "wirepath_round": "multigroup_wirepath_round",
    "multigroup_wirepath_round": "cohort_wirepath_round",
    "shard_slab_round": "multigroup_wirepath_round",
}

# core/fabric.py: the shard_map-replicated control scalars, leading params
# of the per-shard ``local`` body, in declared order.
FABRIC_REPLICATED_SCALARS = ("watermark", "round", "enabled", "alive", "limit")

# Wrapper params that may legally be donated by a jax.jit dispatch.
STATE_PARAM_NAMES = frozenset({"stack", "lstate", "astate"})

# Host mirrors paired with device watermark/round/reclamation state.
MIRROR_ATTRS = frozenset(
    {
        "next_inst_host",
        "_next_inst_host",
        "crnd_host",
        "reclaimed_host",
        "_reclaim_marks",
    }
)

# Files whose jax.jit sites are kernel-wrapper dispatches (donation audit
# scope); training/launch jits donate model state and are out of scope.
DONATION_FILES = ("core/api.py", "core/fabric.py")


def _is_subsequence(seq: Sequence[str], canon: Sequence[str]) -> bool:
    it = iter(canon)
    return all(c in it for c in seq)


def _self_check() -> None:
    for entry, classes in EXPECTED_PREFETCH.items():
        assert _is_subsequence(classes, CANONICAL_PREFETCH_ORDER), entry
    fab = [c for c in FABRIC_REPLICATED_SCALARS if c not in _HOST_FOLDED]
    assert _is_subsequence(fab, CANONICAL_PREFETCH_ORDER), "fabric scalars"


_self_check()


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------
def _dotted(node: ast.expr) -> str | None:
    """'pl.pallas_call' for Attribute chains, 'name' for Names."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _const_int(node: ast.expr | None) -> int | None:
    if (
        isinstance(node, ast.Constant)
        and isinstance(node.value, int)
        and not isinstance(node.value, bool)
    ):
        return node.value
    return None


def _kwarg(call: ast.Call, name: str) -> ast.expr | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _funcdefs(tree: ast.AST) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]


def _assign_env(fdef: ast.FunctionDef) -> dict[str, ast.expr]:
    """name -> last assigned value expression, for simple Name targets."""
    env: dict[str, ast.expr] = {}
    for node in ast.walk(fdef):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                env[tgt.id] = node.value
    return env


def _scalar_class_of_name(name: str) -> str | None:
    stripped = name[:-4] if name.endswith("_ref") else name
    return SCALAR_CLASSES.get(stripped)


def _scalar_class_of_expr(node: ast.expr) -> str | None:
    """First recognizable scalar operand inside an expression, in source
    order — resilient to ``jnp.asarray(ni, jnp.int32).reshape(...)``
    wrapping (module names like ``jnp`` are not in the table)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            cls = _scalar_class_of_name(sub.id)
            if cls is not None:
                return cls
    return None


def _spec_fingerprint(spec: ast.expr) -> tuple[str, str] | None:
    """(block-shape dump, index-map identity) of a pl.BlockSpec call."""
    if not isinstance(spec, ast.Call) or len(spec.args) < 1:
        return None
    shape = ast.dump(spec.args[0])
    if len(spec.args) >= 2:
        idx = spec.args[1]
        index = idx.id if isinstance(idx, ast.Name) else ast.dump(idx)
    else:
        index = "<default>"
    return shape, index


def _spec_list(node: ast.expr | None) -> list[ast.expr] | None:
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]  # single BlockSpec (e.g. one output)


def _out_shape_count(node: ast.expr | None, env: dict[str, ast.expr]) -> int | None:
    if isinstance(node, ast.Name):
        node = env.get(node.id)
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return len(node.elts)
    if isinstance(node, ast.ListComp) and len(node.generators) == 1:
        gen = node.generators[0]
        if (
            isinstance(gen.iter, ast.Call)
            and _dotted(gen.iter.func) == "range"
            and len(gen.iter.args) == 1
        ):
            return _const_int(gen.iter.args[0])
        return None
    if isinstance(node, ast.Call):
        return 1
    return None


@dataclasses.dataclass(frozen=True)
class PallasSite:
    """One audited ``pallas_call`` (exhaustiveness record)."""

    file: str
    line: int
    entry: str            # enclosing wrapper function
    kernel: str | None
    num_scalar_prefetch: int | None
    aliases: tuple[tuple[int, int], ...]


# ---------------------------------------------------------------------------
# Check family 1+3+4: pallas alias/arity audit, prefetch order, purity
# ---------------------------------------------------------------------------
def check_kernel_source(
    src: str,
    filename: str,
    expected_prefetch: dict[str, tuple[str, ...]] | None = None,
    delegations: dict[str, str] | None = None,
) -> tuple[list[Violation], list[PallasSite]]:
    """Audit every ``pallas_call`` in ``src`` plus kernel-body purity.

    Returns ``(violations, sites)`` where ``sites`` records each audited
    call site — the exhaustiveness test pins this list for
    ``kernels/wirepath.py``.
    """
    if expected_prefetch is None:
        expected_prefetch = EXPECTED_PREFETCH
    if delegations is None:
        delegations = DELEGATING_ENTRY_POINTS
    tree = ast.parse(src, filename=filename)
    out: list[Violation] = []
    sites: list[PallasSite] = []
    module_defs = {f.name: f for f in _funcdefs(tree)}

    for fdef in _funcdefs(tree):
        env = _assign_env(fdef)
        for node in ast.walk(fdef):
            if not isinstance(node, ast.Call):
                continue
            dn = _dotted(node.func)
            if dn is None or dn.split(".")[-1] != "pallas_call":
                continue
            out_v, site = _audit_pallas_site(
                node, fdef, env, module_defs, filename, expected_prefetch
            )
            out.extend(out_v)
            sites.append(site)
        if fdef.name in delegations:
            out.extend(
                _audit_delegation(fdef, delegations[fdef.name], filename)
            )

    out.extend(_check_kernel_purity(tree, filename))
    return out, sites


def _resolve_grid_spec(
    call: ast.Call, env: dict[str, ast.expr]
) -> tuple[int | None, list[ast.expr] | None, list[ast.expr] | None, int]:
    """(num_scalar_prefetch, in_specs, out_specs, n_scratch)."""
    gs = _kwarg(call, "grid_spec")
    if isinstance(gs, ast.Name):
        gs = env.get(gs.id)
    if isinstance(gs, ast.Call):
        n = _const_int(_kwarg(gs, "num_scalar_prefetch"))
        if n is None and _kwarg(gs, "num_scalar_prefetch") is None:
            n = 0
        in_specs = _spec_list(_kwarg(gs, "in_specs"))
        out_specs = _spec_list(_kwarg(gs, "out_specs"))
        scr = _kwarg(gs, "scratch_shapes")
        n_scratch = (
            len(scr.elts) if isinstance(scr, (ast.List, ast.Tuple)) else 0
        )
        return n, in_specs, out_specs, n_scratch
    # plain pallas_call(grid=..., in_specs=..., out_specs=...)
    in_specs = _spec_list(_kwarg(call, "in_specs"))
    out_specs = _spec_list(_kwarg(call, "out_specs"))
    return 0, in_specs, out_specs, 0


def _find_dispatch(
    pallas_call: ast.Call, fdef: ast.FunctionDef
) -> ast.Call | None:
    """The call applying the pallas-built function to its operands: either
    ``fn = pl.pallas_call(...)`` later invoked as ``fn(...)``, or the
    immediate ``pl.pallas_call(...)(...)`` form."""
    bound: str | None = None
    for node in ast.walk(fdef):
        if (
            isinstance(node, ast.Assign)
            and node.value is pallas_call
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
        ):
            bound = node.targets[0].id
    for node in ast.walk(fdef):
        if not isinstance(node, ast.Call):
            continue
        if node.func is pallas_call:
            return node
        if (
            bound is not None
            and isinstance(node.func, ast.Name)
            and node.func.id == bound
        ):
            return node
    return None


def _kernel_def(
    pallas_call: ast.Call,
    fdef: ast.FunctionDef,
    module_defs: dict[str, ast.FunctionDef],
) -> ast.FunctionDef | None:
    if not pallas_call.args:
        return None
    kn = pallas_call.args[0]
    if not isinstance(kn, ast.Name):
        return None
    for nested in _funcdefs(fdef):
        if nested.name == kn.id and nested is not fdef:
            return nested
    return module_defs.get(kn.id)


def _audit_pallas_site(
    call: ast.Call,
    fdef: ast.FunctionDef,
    env: dict[str, ast.expr],
    module_defs: dict[str, ast.FunctionDef],
    filename: str,
    expected_prefetch: dict[str, tuple[str, ...]],
) -> tuple[list[Violation], PallasSite]:
    out: list[Violation] = []
    line = call.lineno
    n, in_specs, out_specs, n_scratch = _resolve_grid_spec(call, env)
    kdef = _kernel_def(call, fdef, module_defs)

    aliases: list[tuple[int, int]] = []
    adict = _kwarg(call, "input_output_aliases")
    if isinstance(adict, ast.Dict):
        keys = [_const_int(k) for k in adict.keys]
        vals = [_const_int(v) for v in adict.values]
        if None in keys or None in vals:
            out.append(
                Violation(
                    "ALIAS-BIJECTION", filename, line,
                    "input_output_aliases must be a literal int->int map",
                )
            )
        else:
            aliases = list(zip(keys, vals, strict=True))  # type: ignore[arg-type]
            out.extend(
                _check_alias_map(
                    aliases, n, in_specs, out_specs, filename, line
                )
            )

    # arity cross-checks (skipped where unresolvable)
    dispatch = _find_dispatch(call, fdef)
    if (
        dispatch is not None
        and n is not None
        and in_specs is not None
        and not any(isinstance(a, ast.Starred) for a in dispatch.args)
    ):
        want = n + len(in_specs)
        if len(dispatch.args) != want:
            out.append(
                Violation(
                    "ALIAS-ARITY", filename, dispatch.lineno,
                    f"dispatch passes {len(dispatch.args)} operands but "
                    f"num_scalar_prefetch({n}) + in_specs({len(in_specs)}) "
                    f"= {want}",
                )
            )
    n_out = _out_shape_count(_kwarg(call, "out_shape"), env)
    if n_out is not None and out_specs is not None and n_out != len(out_specs):
        out.append(
            Violation(
                "ALIAS-ARITY", filename, line,
                f"out_shape has {n_out} entries but out_specs has "
                f"{len(out_specs)}",
            )
        )
    if (
        kdef is not None
        and kdef.args.vararg is None
        and n is not None
        and in_specs is not None
        and out_specs is not None
    ):
        want = n + len(in_specs) + len(out_specs) + n_scratch
        got = len(kdef.args.args)
        if got != want:
            out.append(
                Violation(
                    "ALIAS-ARITY", filename, kdef.lineno,
                    f"kernel {kdef.name} has {got} params but prefetch({n}) "
                    f"+ inputs({len(in_specs)}) + outputs({len(out_specs)}) "
                    f"+ scratch({n_scratch}) = {want}",
                )
            )

    # prefetch-vector order for declared wire-path entries
    if fdef.name in expected_prefetch and n is not None:
        expect = expected_prefetch[fdef.name]
        if n != len(expect):
            out.append(
                Violation(
                    "PREFETCH-ORDER", filename, line,
                    f"{fdef.name}: num_scalar_prefetch is {n}, canonical "
                    f"vector is {expect} (len {len(expect)})",
                )
            )
        if dispatch is not None and len(dispatch.args) >= n:
            got_classes = tuple(
                _scalar_class_of_expr(a) for a in dispatch.args[:n]
            )
            if got_classes != expect:
                out.append(
                    Violation(
                        "PREFETCH-ORDER", filename, dispatch.lineno,
                        f"{fdef.name}: prefetch vector classes "
                        f"{got_classes} != canonical {expect}",
                    )
                )
        if kdef is not None:
            named = [a.arg for a in kdef.args.args]
            limit = len(named) if kdef.args.vararg is not None else n
            kc = tuple(
                _scalar_class_of_name(p) for p in named[: min(n, limit)]
            )
            if kc != expect[: len(kc)]:
                out.append(
                    Violation(
                        "PREFETCH-ORDER", filename, kdef.lineno,
                        f"kernel {kdef.name}: leading params map to {kc}, "
                        f"canonical prefix is {expect[: len(kc)]}",
                    )
                )

    site = PallasSite(
        file=filename,
        line=line,
        entry=fdef.name,
        kernel=kdef.name if kdef is not None else None,
        num_scalar_prefetch=n,
        aliases=tuple(aliases),
    )
    return out, site


def _check_alias_map(
    aliases: list[tuple[int, int]],
    n: int | None,
    in_specs: list[ast.expr] | None,
    out_specs: list[ast.expr] | None,
    filename: str,
    line: int,
) -> list[Violation]:
    out: list[Violation] = []
    keys = [k for k, _ in aliases]
    vals = [v for _, v in aliases]
    if len(set(keys)) != len(keys):
        out.append(
            Violation(
                "ALIAS-BIJECTION", filename, line,
                f"duplicate aliased inputs {sorted(keys)}",
            )
        )
    if sorted(vals) != list(range(len(vals))):
        out.append(
            Violation(
                "ALIAS-BIJECTION", filename, line,
                f"alias outputs {sorted(vals)} are not the contiguous "
                f"leading range 0..{len(vals) - 1}",
            )
        )
    if n is None or in_specs is None or out_specs is None:
        return out
    for k, v in aliases:
        if k < n:
            out.append(
                Violation(
                    "ALIAS-OFFSET", filename, line,
                    f"aliased input {k} lies inside the scalar-prefetch "
                    f"window (num_scalar_prefetch={n}) — off-by-one from "
                    f"a prefetch vector change",
                )
            )
            continue
        idx = k - n
        if idx >= len(in_specs) or v >= len(out_specs):
            out.append(
                Violation(
                    "ALIAS-OFFSET", filename, line,
                    f"alias {k}->{v} is out of range for in_specs"
                    f"[{len(in_specs)}]/out_specs[{len(out_specs)}] with "
                    f"num_scalar_prefetch={n}",
                )
            )
            continue
        fin = _spec_fingerprint(in_specs[idx])
        fout = _spec_fingerprint(out_specs[v])
        if fin is not None and fout is not None and fin != fout:
            out.append(
                Violation(
                    "ALIAS-OFFSET", filename, line,
                    f"alias {k}->{v}: input spec (shape {fin[0]}, index "
                    f"map {fin[1]}) != output spec (shape {fout[0]}, "
                    f"index map {fout[1]}) — the aliased operand is not "
                    f"the state operand at prefetch offset {idx}",
                )
            )
    return out


def _audit_delegation(
    fdef: ast.FunctionDef, target: str, filename: str
) -> list[Violation]:
    """Scalar args of a delegated wire-path call must stay in canonical
    relative order (``enabled`` excluded: host-folded, see above)."""
    out: list[Violation] = []
    for node in ast.walk(fdef):
        if not isinstance(node, ast.Call):
            continue
        dn = _dotted(node.func)
        if dn is None or dn.split(".")[-1] != target:
            continue
        classes = [
            c
            for c in (_scalar_class_of_expr(a) for a in node.args)
            if c is not None and c not in _HOST_FOLDED
        ]
        if not _is_subsequence(classes, CANONICAL_PREFETCH_ORDER):
            out.append(
                Violation(
                    "PREFETCH-ORDER", filename, node.lineno,
                    f"{fdef.name} -> {target}: scalar args in order "
                    f"{tuple(classes)} are not a subsequence of canonical "
                    f"{CANONICAL_PREFETCH_ORDER}",
                )
            )
    return out


# ---------------------------------------------------------------------------
# Check family 4: kernel-body purity
# ---------------------------------------------------------------------------
_KERNEL_NAME = re.compile(r"^_\w+_kernel$")
_STATIC_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "at"})


def _dynamic_ref_use(node: ast.AST, params: frozenset[str]) -> bool:
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Name):
        return node.id in params
    return any(
        _dynamic_ref_use(c, params) for c in ast.iter_child_nodes(node)
    )


def _check_kernel_purity(tree: ast.AST, filename: str) -> list[Violation]:
    out: list[Violation] = []
    for fdef in _funcdefs(tree):
        if not _KERNEL_NAME.match(fdef.name):
            continue
        params = frozenset(
            a.arg for a in fdef.args.args + fdef.args.kwonlyargs
        )
        for node in ast.walk(fdef):
            if isinstance(node, (ast.If, ast.While)) and _dynamic_ref_use(
                node.test, params
            ):
                out.append(
                    Violation(
                        "KERNEL-PURITY", filename, node.lineno,
                        f"{fdef.name}: Python {type(node).__name__} on a "
                        f"Ref-derived value — branch decisions must be "
                        f"jnp.where/pl.when so they trace",
                    )
                )
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                out.append(
                    Violation(
                        "KERNEL-PURITY", filename, node.lineno,
                        f"{fdef.name}: {type(node).__name__.lower()} "
                        f"mutation of captured state inside a kernel body",
                    )
                )
            elif isinstance(node, ast.Call):
                dn = _dotted(node.func) or ""
                leaf = dn.split(".")[-1]
                if (
                    dn.startswith("np.")
                    or leaf in {"item", "device_get"}
                    or dn == "print"
                ):
                    out.append(
                        Violation(
                            "KERNEL-HOST", filename, node.lineno,
                            f"{fdef.name}: host-level idiom `{dn}` inside "
                            f"a kernel body",
                            advisory=True,
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# Check family 1b: donation audit + use-after-donate (dispatch files)
# ---------------------------------------------------------------------------
class _ImportResolver:
    """Resolves ``kops.fused_round`` / ``batched.acceptor_phase2_all`` /
    local function names to positional parameter lists (and, for
    ``kernels/ops.py`` targets, their registry entries) by importing the
    real modules — the checker runs with ``src`` importable."""

    def __init__(self, tree: ast.AST):
        self.aliases: dict[str, str] = {}
        self.local_defs: dict[str, ast.FunctionDef] = {
            f.name: f for f in _funcdefs(tree)
        }
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}"
                    )
            elif isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = a.name

    def resolve(
        self, target: ast.expr
    ) -> tuple[list[str], ContractEntry | None] | None:
        """Positional param names of the jitted callable, or None."""
        dn = _dotted(target)
        if dn is None:
            return None
        if dn in self.local_defs:
            fdef = self.local_defs[dn]
            return [a.arg for a in fdef.args.args], None
        head, _, attr = dn.partition(".")
        mod_path = self.aliases.get(head)
        if mod_path is None or not attr:
            return None
        try:
            mod = importlib.import_module(mod_path)
            fn = getattr(mod, attr)
            sig = inspect.signature(fn)
        except Exception:
            return None
        params = [
            p.name
            for p in sig.parameters.values()
            if p.kind
            in (
                inspect.Parameter.POSITIONAL_ONLY,
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
            )
        ]
        entry = None
        if mod_path.endswith("kernels.ops"):
            _load_ops_registry()
            entry = CONTRACT_REGISTRY.get(attr)
        return params, entry


def _donate_positions(call: ast.Call) -> list[int] | None:
    node = _kwarg(call, "donate_argnums")
    if node is None:
        return None
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = [_const_int(e) for e in node.elts]
        return None if None in vals else vals  # type: ignore[return-value]
    v = _const_int(node)
    return None if v is None else [v]


def _jit_calls(tree: ast.AST) -> list[ast.Call]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dn = _dotted(node.func)
            if dn in ("jax.jit", "jit") and node.args:
                out.append(node)
    return out


def check_dispatch_source(
    src: str,
    filename: str,
    resolver: _ImportResolver | None = None,
) -> list[Violation]:
    """Donation audit over every ``jax.jit(..., donate_argnums=...)`` in a
    dispatch file, plus the per-class use-after-donate lint."""
    tree = ast.parse(src, filename=filename)
    if resolver is None:
        resolver = _ImportResolver(tree)
    out: list[Violation] = []
    for call in _jit_calls(tree):
        resolved = resolver.resolve(call.args[0])
        positions = _donate_positions(call)
        if resolved is None:
            continue
        params, entry = resolved
        donated: set[str] = set()
        if positions is not None:
            for p in positions:
                if p >= len(params):
                    out.append(
                        Violation(
                            "DONATE-STATE", filename, call.lineno,
                            f"donate_argnums position {p} is out of range "
                            f"for {_dotted(call.args[0])} "
                            f"({len(params)} positional params)",
                        )
                    )
                    continue
                donated.add(params[p])
            bad = donated - STATE_PARAM_NAMES
            if bad:
                out.append(
                    Violation(
                        "DONATE-STATE", filename, call.lineno,
                        f"{_dotted(call.args[0])} donates non-state "
                        f"operand(s) {sorted(bad)} — only aliased state "
                        f"({sorted(STATE_PARAM_NAMES)}) may be donated",
                    )
                )
        if entry is not None:
            want = set(entry.state_args)
            if donated != want:
                missing = sorted(want - donated)
                extra = sorted((donated - want) & STATE_PARAM_NAMES)
                parts = []
                if missing:
                    parts.append(f"missing {missing}")
                if extra:
                    parts.append(f"extraneous {extra}")
                if parts:
                    out.append(
                        Violation(
                            "DONATE-MISSING", filename, call.lineno,
                            f"jit of kernel wrapper {entry.name} must "
                            f"donate exactly its aliased state operands "
                            f"{sorted(want)}: " + ", ".join(parts),
                        )
                    )
    out.extend(_check_use_after_donate(tree, filename, resolver))
    return out


def _self_attr(node: ast.expr) -> str | None:
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _donating_attrs_of_class(
    cdef: ast.ClassDef, resolver: _ImportResolver
) -> dict[str, frozenset[str]]:
    """attr name -> donated param names, from ``self.X = jax.jit(...,
    donate_argnums=...)`` statements anywhere in ``__init__``."""
    out: dict[str, frozenset[str]] = {}
    for fdef in cdef.body:
        if not (isinstance(fdef, ast.FunctionDef) and fdef.name == "__init__"):
            continue
        for node in ast.walk(fdef):
            if not (
                isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.value, ast.Call)
            ):
                continue
            attr = _self_attr(node.targets[0])
            if attr is None:
                continue
            call = node.value
            if _dotted(call.func) not in ("jax.jit", "jit") or not call.args:
                continue
            positions = _donate_positions(call)
            resolved = resolver.resolve(call.args[0])
            if positions is None or resolved is None:
                continue
            params, _entry = resolved
            names = frozenset(
                params[p] for p in positions if p < len(params)
            )
            if names:
                out[attr] = names
    return out


def _check_use_after_donate(
    tree: ast.AST, filename: str, resolver: _ImportResolver
) -> list[Violation]:
    out: list[Violation] = []
    for cdef in (n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)):
        donating = _donating_attrs_of_class(cdef, resolver)
        if not donating:
            continue
        for fdef in cdef.body:
            if not isinstance(fdef, ast.FunctionDef) or fdef.name == "__init__":
                continue
            out.extend(
                _scan_method_for_use_after_donate(
                    fdef, donating, filename
                )
            )
    return out


def _scan_method_for_use_after_donate(
    fdef: ast.FunctionDef,
    donating: dict[str, frozenset[str]],
    filename: str,
) -> list[Violation]:
    out: list[Violation] = []
    # local aliases of donating dispatchers: fn = self._x / IfExp / partial
    local_fns: dict[str, frozenset[str]] = {}
    # list vars whose elements we can enumerate (args = [...]; args.append)
    list_vars: dict[str, list[ast.expr]] = {}

    def donated_params_of(expr: ast.expr) -> frozenset[str] | None:
        attr = _self_attr(expr)
        if attr is not None:
            return donating.get(attr)
        if isinstance(expr, ast.Name):
            return local_fns.get(expr.id)
        if isinstance(expr, ast.IfExp):
            a = donated_params_of(expr.body)
            b = donated_params_of(expr.orelse)
            if a is None and b is None:
                return None
            return (a or frozenset()) | (b or frozenset())
        if isinstance(expr, ast.Call):
            dn = _dotted(expr.func)
            if dn in ("functools.partial", "partial") and expr.args:
                return donated_params_of(expr.args[0])
        return None

    def arg_state_attrs(call: ast.Call) -> set[str]:
        found: set[str] = set()
        exprs: list[ast.expr] = []
        for a in call.args:
            if isinstance(a, ast.Starred) and isinstance(a.value, ast.Name):
                exprs.extend(list_vars.get(a.value.id, []))
            else:
                exprs.append(a)
        exprs.extend(kw.value for kw in call.keywords)
        for e in exprs:
            attr = _self_attr(e)
            if attr is not None:
                found.add(attr)
        return found

    def stmt_donating_calls(stmt: ast.stmt) -> list[tuple[ast.Call, frozenset[str]]]:
        calls = []
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                dp = donated_params_of(node.func)
                if dp:
                    calls.append((node, dp))
        return calls

    def assigned_self_attrs(stmt: ast.stmt) -> set[str]:
        attrs: set[str] = set()
        targets: list[ast.expr] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        flat: list[ast.expr] = []
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                flat.extend(t.elts)
            else:
                flat.append(t)
        for t in flat:
            a = _self_attr(t)
            if a is not None:
                attrs.add(a)
        return attrs

    def track_locals(stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                dp = donated_params_of(stmt.value)
                if dp:
                    local_fns[tgt.id] = dp
                if isinstance(stmt.value, ast.List):
                    list_vars[tgt.id] = list(stmt.value.elts)
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
            dn = _dotted(call.func)
            if dn is not None and dn.endswith(".append"):
                base = dn.rsplit(".", 1)[0]
                if base in list_vars and len(call.args) == 1:
                    list_vars[base].append(call.args[0])

    def process(stmts: Iterable[ast.stmt], dead: set[str]) -> set[str]:
        for stmt in stmts:
            if isinstance(stmt, ast.If):
                d1 = process(stmt.body, set(dead))
                d2 = process(stmt.orelse, set(dead))
                dead = d1 | d2
                continue
            if isinstance(stmt, (ast.For, ast.While)):
                dead |= process(stmt.body, set(dead))
                dead |= process(stmt.orelse, set(dead))
                continue
            if isinstance(stmt, (ast.With, ast.Try)):
                dead = process(getattr(stmt, "body", []), dead)
                for h in getattr(stmt, "handlers", []):
                    dead |= process(h.body, set(dead))
                continue
            track_locals(stmt)
            dcalls = stmt_donating_calls(stmt)
            if not dcalls:
                # plain statement: any read of a dead attr is a
                # use-after-donate
                for node in ast.walk(stmt):
                    attr = _self_attr(node)
                    if (
                        attr in dead
                        and isinstance(node.ctx, ast.Load)  # type: ignore[attr-defined]
                    ):
                        out.append(
                            Violation(
                                "DONATE-USE", filename, node.lineno,
                                f"{fdef.name}: reads self.{attr} after it "
                                f"was donated to a dispatch and before "
                                f"reassignment",
                            )
                        )
                        dead.discard(attr)  # report once
            else:
                for call, dparams in dcalls:
                    dead |= arg_state_attrs(call) & dparams
            dead -= assigned_self_attrs(stmt)
        return dead

    process(fdef.body, set())
    return out


# ---------------------------------------------------------------------------
# Check family 3b: fabric replicated-scalar order
# ---------------------------------------------------------------------------
def check_fabric_source(src: str, filename: str) -> list[Violation]:
    tree = ast.parse(src, filename=filename)
    out: list[Violation] = []
    for fdef in _funcdefs(tree):
        if fdef.name != "local":
            continue
        want = FABRIC_REPLICATED_SCALARS
        names = [a.arg for a in fdef.args.args[: len(want)]]
        got = tuple(_scalar_class_of_name(p) for p in names)
        if got != want:
            out.append(
                Violation(
                    "PREFETCH-ORDER", filename, fdef.lineno,
                    f"shard_map body `local`: leading replicated scalars "
                    f"{got} != declared {want}",
                )
            )
    return out


# ---------------------------------------------------------------------------
# Check family 2: oracle-parity registry
# ---------------------------------------------------------------------------
def _positional_params(fn: Callable[..., Any]) -> list[inspect.Parameter]:
    return [
        p
        for p in inspect.signature(fn).parameters.values()
        if p.kind
        not in (inspect.Parameter.VAR_POSITIONAL, inspect.Parameter.VAR_KEYWORD)
    ]


def _srcinfo(fn: Callable[..., Any], root: str | None) -> tuple[str, int]:
    try:
        f = inspect.getsourcefile(fn) or "<unknown>"
        line = inspect.getsourcelines(fn)[1]
    except (OSError, TypeError):
        return "<unknown>", 0
    if root:
        try:
            f = os.path.relpath(f, root)
        except ValueError:
            pass
    return f, line


def signature_violations(
    entry: ContractEntry, root: str | None = None
) -> list[Violation]:
    """Compare a registered wrapper against its oracle (names, order,
    keyword defaults), modulo the entry's declared extras."""
    file, line = _srcinfo(entry.fn, root)
    out: list[Violation] = []
    wparams = _positional_params(entry.fn)
    wnames = {p.name for p in wparams}
    for x in entry.extra:
        if x not in wnames:
            out.append(
                Violation(
                    "ORACLE-PARITY", file, line,
                    f"{entry.name}: declared extra param `{x}` does not "
                    f"exist on the wrapper (stale registration)",
                )
            )
    if entry.oracle is None:
        if not entry.reason:
            out.append(
                Violation(
                    "ORACLE-PARITY", file, line,
                    f"{entry.name}: registered without an oracle and "
                    f"without a reason",
                )
            )
        return out
    oparams = _positional_params(entry.oracle)
    onames = {p.name for p in oparams}
    for x in entry.oracle_extra:
        if x not in onames:
            out.append(
                Violation(
                    "ORACLE-PARITY", file, line,
                    f"{entry.name}: declared oracle_extra param `{x}` does "
                    f"not exist on the oracle (stale registration)",
                )
            )
    ws = [p for p in wparams if p.name not in entry.extra]
    os_ = [p for p in oparams if p.name not in entry.oracle_extra]
    oracle_name = getattr(entry.oracle, "__name__", "<oracle>")
    if entry.strict_order:
        if [p.name for p in ws] != [p.name for p in os_]:
            out.append(
                Violation(
                    "ORACLE-PARITY", file, line,
                    f"{entry.name}: wrapper params "
                    f"{[p.name for p in ws]} != oracle {oracle_name} "
                    f"params {[p.name for p in os_]} (modulo declared "
                    f"extras)",
                )
            )
            return out
        pairs = list(zip(ws, os_, strict=True))
    else:
        if {p.name for p in ws} != {p.name for p in os_}:
            out.append(
                Violation(
                    "ORACLE-PARITY", file, line,
                    f"{entry.name}: shared param name sets differ from "
                    f"oracle {oracle_name}: "
                    f"{sorted(p.name for p in ws)} vs "
                    f"{sorted(p.name for p in os_)}",
                )
            )
            return out
        by_name = {p.name: p for p in os_}
        pairs = [(p, by_name[p.name]) for p in ws]
    for wp, op in pairs:
        wd, od = wp.default, op.default
        if (wd is inspect.Parameter.empty) != (od is inspect.Parameter.empty):
            out.append(
                Violation(
                    "ORACLE-PARITY", file, line,
                    f"{entry.name}: param `{wp.name}` required on one side "
                    f"but defaulted on the other",
                )
            )
        elif wd is not inspect.Parameter.empty and wd != od:
            out.append(
                Violation(
                    "ORACLE-PARITY", file, line,
                    f"{entry.name}: param `{wp.name}` default {wd!r} != "
                    f"oracle default {od!r}",
                )
            )
    return out


_OPS_MODULE = "repro.kernels.ops"


def _load_ops_registry() -> Any:
    return importlib.import_module(_OPS_MODULE)


def check_registry(root: str) -> list[Violation]:
    """Parity for every registered wrapper + exhaustiveness over the
    public surface of ``kernels/ops.py``."""
    out: list[Violation] = []
    _load_ops_registry()
    ops_path = os.path.join(root, "src", "repro", "kernels", "ops.py")
    rel = os.path.relpath(ops_path, root)
    with open(ops_path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=rel)
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and not node.name.startswith("_"):
            if node.name not in CONTRACT_REGISTRY:
                out.append(
                    Violation(
                        "ORACLE-MISSING", rel, node.lineno,
                        f"public kernel entry `{node.name}` has no "
                        f"@dataplane_contract registration",
                    )
                )
    for entry in CONTRACT_REGISTRY.values():
        out.extend(signature_violations(entry, root))
    return out


# ---------------------------------------------------------------------------
# Check family 5: host-mirror pairing lint
# ---------------------------------------------------------------------------
def _terminal_attr(node: ast.expr) -> tuple[str, int] | None:
    """Attribute name + line for stores through ``x.attr`` or
    ``x.attr[...]`` target shapes (any base object, so ``self.hw._x``
    and ``self.x[gid]`` both match)."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr, node.lineno
    return None


def check_mirror_source(src: str, filename: str) -> list[Violation]:
    tree = ast.parse(src, filename=filename)
    out: list[Violation] = []
    for cdef in (n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)):
        for fdef in cdef.body:
            if not isinstance(fdef, ast.FunctionDef):
                continue
            guarded = fdef.name == "__init__" or any(
                (_dotted(d) or "").split(".")[-1] == "mirror_guard"
                for d in fdef.decorator_list
            )
            if guarded:
                continue
            for node in ast.walk(fdef):
                targets: list[ast.expr] = []
                if isinstance(node, ast.Assign):
                    targets = list(node.targets)
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                for t in targets:
                    flat = (
                        list(t.elts)
                        if isinstance(t, (ast.Tuple, ast.List))
                        else [t]
                    )
                    for leaf in flat:
                        hit = _terminal_attr(leaf)
                        if hit is not None and hit[0] in MIRROR_ATTRS:
                            out.append(
                                Violation(
                                    "MIRROR-GUARD", filename, hit[1],
                                    f"{cdef.name}.{fdef.name} mutates host "
                                    f"mirror `{hit[0]}` outside a "
                                    f"@mirror_guard-annotated method",
                                )
                            )
    return out


# ---------------------------------------------------------------------------
# Repo driver
# ---------------------------------------------------------------------------
def _default_root() -> str:
    # src/repro/analysis/contracts.py -> repo root
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", ".."))


def _read(root: str, rel: str) -> str:
    with open(os.path.join(root, rel), encoding="utf-8") as f:
        return f.read()


def _ensure_importable(root: str) -> None:
    src = os.path.join(root, "src")
    if src not in sys.path:
        sys.path.insert(0, src)


def pallas_sites(root: str | None = None) -> list[PallasSite]:
    """Every audited ``pallas_call`` site under ``src/repro/kernels`` —
    the exhaustiveness surface (tests pin the wirepath.py subset)."""
    root = root or _default_root()
    sites: list[PallasSite] = []
    kdir = os.path.join(root, "src", "repro", "kernels")
    for name in sorted(os.listdir(kdir)):
        if not name.endswith(".py"):
            continue
        rel = os.path.join("src", "repro", "kernels", name)
        _, s = check_kernel_source(_read(root, rel), rel)
        sites.extend(s)
    return sites


def check_repo(root: str | None = None) -> list[Violation]:
    """Run every contract family over the repository."""
    root = root or _default_root()
    _ensure_importable(root)
    out: list[Violation] = []

    kdir = os.path.join(root, "src", "repro", "kernels")
    for name in sorted(os.listdir(kdir)):
        if not name.endswith(".py"):
            continue
        rel = os.path.join("src", "repro", "kernels", name)
        v, _sites = check_kernel_source(_read(root, rel), rel)
        out.extend(v)

    for tail in DONATION_FILES:
        rel = os.path.join("src", "repro", tail)
        out.extend(check_dispatch_source(_read(root, rel), rel))

    rel = os.path.join("src", "repro", "core", "fabric.py")
    out.extend(check_fabric_source(_read(root, rel), rel))

    rel = os.path.join("src", "repro", "core", "api.py")
    out.extend(check_mirror_source(_read(root, rel), rel))

    out.extend(check_registry(root))
    return sorted(out, key=lambda v: (v.file, v.line, v.rule))


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.contracts",
        description="Static dataplane contract checker (DESIGN.md §12).",
    )
    ap.add_argument(
        "--root", default=None, help="repository root (default: inferred)"
    )
    ap.add_argument(
        "--strict-advisory",
        action="store_true",
        help="treat advisory findings as errors",
    )
    ns = ap.parse_args(argv)
    violations = check_repo(ns.root)
    errors = 0
    for v in violations:
        print(v, file=sys.stderr)
        if not v.advisory or ns.strict_advisory:
            errors += 1
    if errors:
        print(
            f"contracts: {errors} violation(s) "
            f"({len(violations) - errors} advisory)",
            file=sys.stderr,
        )
        return 1
    n_sites = len(pallas_sites(ns.root))
    print(
        f"contracts OK: {len(CONTRACT_REGISTRY)} registered kernel entries, "
        f"{n_sites} pallas_call sites audited"
        + (f", {len(violations)} advisory note(s)" if violations else "")
    )
    return 0


if __name__ == "__main__":
    # ``python -m`` executes this file as ``__main__``; delegate to the
    # canonical module instance so the registry populated by importing
    # ``repro.kernels.ops`` is the one we read.
    from repro.analysis.contracts import main as _main

    sys.exit(_main())
