"""Schema / sanity check of a committed wire-path bench artifact.

``BENCH_wirepath.json`` is both the perf-trajectory record and the baseline
the CI regression gate diffs against — a malformed commit (truncated sweep,
NaN ratio, missing headline row) would otherwise only surface after CI has
spent a full bench run, or worse, silently disable a gate.  This check is
pure JSON validation: it runs in milliseconds, before any bench, and it is
also exercised as a fast-lane unit test (``tests/test_bench_schema.py``)
so a bad artifact fails the cheapest job first.

    PYTHONPATH=src python -m benchmarks.check_bench_schema BENCH_wirepath.json
"""
from __future__ import annotations

import json
import math
import sys
from typing import List

# Headline rows the regression gate keys on: committing an artifact without
# them would silently skip (or permanently fail) a gate.
REQUIRED_HEADLINES = (
    "wirepath/speedup_pallas_vs_per_acceptor/",
    "wirepath/multigroup_scaling_pallas/",
    "wirepath/sharded_scaling_pallas/",
    "wirepath/skew_speedup_twotier/",
    "wirepath/sustained_ratio/",
    "wirepath/kv_read_write_ratio/",
    "wirepath/persistent_speedup/",
    "wirepath/trickle_persistent_ratio/",
)
RATIO_FIELDS = (
    "speedup", "scaling", "skew_speedup", "sustained_ratio", "kv_ratio",
    "persistent_speedup", "trickle_persistent_ratio",
    "persistent_amortization",
)


def _finite_positive(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def validate(doc: dict) -> List[str]:
    """Returns a list of human-readable schema violations (empty = valid)."""
    errors: List[str] = []
    meta = doc.get("meta")
    if not isinstance(meta, dict) or "backend" not in meta:
        errors.append("meta missing or has no 'backend' key")
    elif meta.get("partial"):
        errors.append(
            "artifact is a partial sweep (meta.partial) — the committed "
            "baseline must come from the full sweep"
        )
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return errors + ["rows missing or empty"]
    for i, row in enumerate(rows):
        name = row.get("name")
        if not isinstance(name, str) or not name.startswith("wirepath/"):
            errors.append(f"row {i}: bad name {name!r}")
            continue
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or not math.isfinite(us) or us < 0:
            errors.append(f"{name}: bad us_per_call {us!r}")
        if "msgs_per_s" in row and not _finite_positive(row["msgs_per_s"]):
            if not row.get("skipped"):
                errors.append(f"{name}: bad msgs_per_s {row['msgs_per_s']!r}")
        for field in RATIO_FIELDS:
            if field in row and not _finite_positive(row[field]):
                errors.append(f"{name}: bad {field} {row[field]!r}")
    names = [r.get("name", "") for r in rows]
    for prefix in REQUIRED_HEADLINES:
        if not any(
            n.startswith(prefix)
            and any(f in r for f in RATIO_FIELDS)
            for n, r in zip(names, rows)
        ):
            errors.append(f"missing headline row {prefix}* (gate would skip)")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        doc = json.load(f)
    errors = validate(doc)
    if errors:
        for e in errors:
            print(f"SCHEMA: {e}", file=sys.stderr)
        return 1
    print(
        f"bench schema OK: {len(doc['rows'])} rows, "
        f"backend={doc['meta'].get('backend')}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
