"""Replicated key-value tier over the consensus service (DESIGN.md §10).

NetChain's thesis (PAPERS.md, arXiv 1802.08236), applied to this dataplane:
the consensus fabric IS the storage system.  Mutations ride the fused wire
path exactly once; reads never touch it while a session's lease holds.

Three layers:

* **Op codec** — versioned binary frames (put / delete / cas / get) small
  enough to ride one consensus value (``PaxosConfig.max_payload_bytes``).
  Every frame carries the issuing session's tag and a per-session op
  counter: the counter is the read-your-writes token the lease machinery
  keys on.
* **GroupReplica** — the deterministic apply loop.  One replica per
  ``(group, generation)`` segment consumes that segment's delivered log
  past its ``applied_len`` watermark; identical logs produce bit-identical
  state on every backend, which the linearizability chaos suite pins
  against unbounded twin oracles.
* **ReplicatedKV / KVSession** — the facade.  Writes submit frames through
  the typed :class:`~repro.serve.engine.Session` API; ``get`` is
  **consensus-free** while the session's lease holds (no unapplied writes
  + segment unchanged since validation): it applies already-delivered
  entries host-side and answers from replica state, dispatching nothing to
  the wire path.  A stale lease escalates to ONE serialized read-index op,
  which orders behind every surviving earlier op of the session.

Snapshot integration: a replica's apply cursor runs over
``full_group_log`` — snapshot-store prefix + live log, whose concatenation
is append-only stable under compaction — and ``ConsensusService.
adopt_group`` seeds transferred prefixes into that read.  State transfer
is therefore *applied* host-side, never replayed through the dataplane
(the dispatch-count tests pin this).
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any

from .engine import ConsensusService, Ticket, session_hash

# ---------------------------------------------------------------------------
# Op codec: versioned frames packed into MsgBatch value payloads
# ---------------------------------------------------------------------------
KV_MAGIC = 0xC5
KV_VERSION = 1
OP_PUT = 1
OP_DELETE = 2
OP_CAS = 3
OP_GET = 4           # serialized read-index marker: applies no state
OP_NAMES = {OP_PUT: "put", OP_DELETE: "delete", OP_CAS: "cas", OP_GET: "get"}
_FLAG_EXPECT = 1     # cas frame carries an expected value (else expect-absent)
# magic, version, opcode, flags, sid_tag, counter, klen, vlen, elen
_HEADER = struct.Struct("<BBBBIIHHH")


class KvCodecError(ValueError):
    """Malformed, truncated, or unsupported KV op frame."""


@dataclasses.dataclass(frozen=True)
class KvOp:
    """One decoded KV operation — the unit the apply loop consumes.

    ``sid_tag`` is the FNV-1a tag of the issuing session and ``counter``
    its per-session op counter: together they make every frame a
    read-your-writes token the lease machinery can look up in replica
    state."""

    op: int
    key: bytes
    value: bytes = b""
    expect: bytes | None = None   # cas only; None = "expect absent"
    sid_tag: int = 0
    counter: int = 0


def encode_op(op: KvOp) -> bytes:
    """Pack one op into its wire frame (raises ``KvCodecError`` on an
    unencodable op, e.g. ``expect`` on a non-cas frame)."""
    if op.op not in OP_NAMES:
        raise KvCodecError(f"unknown opcode {op.op}")
    flags = 0
    expect = b""
    if op.expect is not None:
        if op.op != OP_CAS:
            raise KvCodecError("expect is only meaningful on cas frames")
        flags |= _FLAG_EXPECT
        expect = op.expect
    for name, blob in (("key", op.key), ("value", op.value),
                       ("expect", expect)):
        if len(blob) > 0xFFFF:
            raise KvCodecError(f"{name} is {len(blob)} bytes (u16 max)")
    return (
        _HEADER.pack(
            KV_MAGIC,
            KV_VERSION,
            op.op,
            flags,
            op.sid_tag & 0xFFFFFFFF,
            op.counter & 0xFFFFFFFF,
            len(op.key),
            len(op.value),
            len(expect),
        )
        + op.key
        + op.value
        + expect
    )


def decode_op(buf: bytes) -> KvOp:
    """Decode one wire frame, rejecting anything malformed: wrong magic or
    version, unknown opcode or flags, and any length mismatch (truncation
    AND trailing garbage) — a replica must never guess at a frame."""
    if len(buf) < _HEADER.size:
        raise KvCodecError(
            f"frame truncated: {len(buf)} < header {_HEADER.size}"
        )
    magic, ver, opcode, flags, sid_tag, counter, klen, vlen, elen = (
        _HEADER.unpack_from(buf)
    )
    if magic != KV_MAGIC:
        raise KvCodecError(f"bad magic 0x{magic:02X}")
    if ver != KV_VERSION:
        raise KvCodecError(f"unsupported frame version {ver}")
    if opcode not in OP_NAMES:
        raise KvCodecError(f"unknown opcode {opcode}")
    if flags & ~_FLAG_EXPECT:
        raise KvCodecError(f"unknown flags 0x{flags:02X}")
    if len(buf) != _HEADER.size + klen + vlen + elen:
        raise KvCodecError(
            f"frame length {len(buf)} != header + key {klen} + value {vlen} "
            f"+ expect {elen}"
        )
    ofs = _HEADER.size
    key = buf[ofs : ofs + klen]
    ofs += klen
    value = buf[ofs : ofs + vlen]
    ofs += vlen
    expect_bytes = buf[ofs : ofs + elen]
    if flags & _FLAG_EXPECT:
        if opcode != OP_CAS:
            raise KvCodecError("expect flag on a non-cas frame")
        expect: bytes | None = expect_bytes
    else:
        if elen:
            raise KvCodecError("expect bytes without the expect flag")
        expect = None
    return KvOp(opcode, key, value, expect, sid_tag, counter)


# ---------------------------------------------------------------------------
# Deterministic apply loop, one replica per (group, generation) segment
# ---------------------------------------------------------------------------
class GroupReplica:
    """Deterministic apply loop over one ``(group, generation)`` segment.

    ``state`` maps key -> (value, version); a deleted key stays behind as a
    ``(None, version)`` tombstone so a newer segment's delete masks an older
    segment's value under stitched lookup.  ``applied_len`` is the segment's
    read watermark — the monotone count of log entries applied — and
    ``applied_counter`` the highest per-session op counter applied so far,
    the lease machinery's "has my write landed" oracle.
    """

    def __init__(self) -> None:
        self.state: dict[bytes, tuple[bytes | None, int]] = {}
        self.applied_len = 0
        self.applied_counter: dict[int, int] = {}
        self.final = False           # archived segment, fully applied

    def apply_log(self, log: list[tuple[int, bytes]]) -> int:
        """Apply the suffix past the watermark; returns ops consumed.

        Safe against any later view of the same segment: ``full_group_log``
        is append-only stable (compaction migrates entries into the
        snapshot prefix without reordering), so the cursor never re-applies
        an entry."""
        if len(log) < self.applied_len:
            raise ValueError(
                f"segment log shrank: {len(log)} < applied {self.applied_len}"
            )
        new = log[self.applied_len :]
        for _inst, payload in new:
            self._apply_one(decode_op(payload))
        self.applied_len = len(log)
        return len(new)

    def _apply_one(self, op: KvOp) -> None:
        prev = self.applied_counter.get(op.sid_tag, 0)
        if op.counter > prev:
            self.applied_counter[op.sid_tag] = op.counter
        if op.op == OP_GET:
            return                    # read-index marker: no state change
        if op.op == OP_CAS:
            cur = self.state.get(op.key)
            cur_val = None if cur is None else cur[0]
            if cur_val != op.expect:
                return                # failed cas: committed no-op
        cur = self.state.get(op.key)
        version = (0 if cur is None else cur[1]) + 1
        if op.op == OP_DELETE:
            self.state[op.key] = (None, version)   # tombstone
        else:                         # put, or a cas that matched
            self.state[op.key] = (op.value, version)

    def signature(self) -> tuple[dict[bytes, tuple[bytes | None, int]], int]:
        """Canonical (state, applied_len) for bit-equality across twins."""
        return (dict(self.state), self.applied_len)


# ---------------------------------------------------------------------------
# The facade: ReplicatedKV over a ConsensusService, leased sessions
# ---------------------------------------------------------------------------
class ReplicatedKV:
    """Replicated KV facade over a :class:`ConsensusService`.

    Maintains one :class:`GroupReplica` per ``(group, generation)`` segment
    and hands out stateful :class:`KVSession` clients.  ``refresh()`` is
    the host-side apply pump: archived segments finalize once, live
    segments consume their stitched log's new suffix.  Nothing in this
    class dispatches to the wire path — only session mutations (and
    read-index fallbacks) do, through the service."""

    def __init__(
        self, service: ConsensusService, max_read_rounds: int = 64
    ) -> None:
        self.service = service
        self.max_read_rounds = max_read_rounds
        self._replicas: dict[tuple[int, int], GroupReplica] = {}
        self._sessions: dict[Any, "KVSession"] = {}
        self.stats: dict[str, int] = {"leased_gets": 0, "read_index_gets": 0,
                                      "ops_submitted": 0}
        # per-epoch caches: the live set, current generations, and the
        # retirement archive only change at membership events, which all
        # flow through the service and bump its routing epoch — refresh()
        # is on the leased-get path and must stay O(live groups), not
        # O(history)
        self._snaps = getattr(service.ctx, "snapshots", None)
        self._epoch_seen = -1
        self._live_reps: list[tuple[int, GroupReplica]] = []

    def session(self, session_id: Any) -> "KVSession":
        """The stateful KV client for one session id (cached: unlike the
        stateless routing handles, a KV session owns lease state)."""
        s = self._sessions.get(session_id)
        if s is None:
            s = self._sessions[session_id] = KVSession(self, session_id)
        return s

    def replica(self, gid: int, gen: int | None = None) -> GroupReplica:
        """The segment replica for ``(gid, gen)`` (current generation when
        ``gen`` is omitted), created empty on first touch."""
        if gen is None:
            gen = self.service.group_generation(gid)
        key = (gid, gen)
        rep = self._replicas.get(key)
        if rep is None:
            rep = self._replicas[key] = GroupReplica()
        return rep

    def refresh(self) -> None:
        """Apply everything already delivered — host-side only.

        Snapshot and adopted prefixes are *applied* here exactly like live
        entries (they arrive through the same stitched ``full_group_log``
        read), never replayed through the dataplane."""
        svc = self.service
        ctx = svc.ctx
        if svc.routing_epoch != self._epoch_seen:
            for key, log in svc.archived_segments().items():
                rep = self.replica(*key)
                if not rep.final:
                    rep.apply_log(log)
                    rep.final = True
            self._live_reps = [
                (gid, self.replica(gid)) for gid in ctx.live_groups()
            ]
            self._epoch_seen = svc.routing_epoch
        snaps = self._snaps
        for gid, rep in self._live_reps:
            # cheap steady-state exit: the stitched log is append-only
            # stable, so an unchanged length means no new suffix — skip
            # materializing the prefix+live concatenation (this is what
            # keeps a leased get O(1) in the history length)
            total = len(ctx.group_log[gid])
            if snaps is not None:
                total += len(snaps.log_prefix(gid))
            if total != rep.applied_len:
                rep.apply_log(ctx.full_group_log(gid))

    def read_watermark(self, gid: int) -> int:
        """Applied-entry count of the group's current-generation segment —
        the monotone per-group read watermark leased gets answer behind."""
        return self.replica(gid).applied_len

    def lookup(self, session_id: Any, key: bytes) -> bytes | None:
        """Stitched lookup over the session's segment chain, newest segment
        first; a tombstone in a newer segment masks older values."""
        for seg in reversed(self.service.session_chain(session_id)):
            rep = self._replicas.get(seg)
            if rep is not None and key in rep.state:
                return rep.state[key][0]
        return None


class KVSession:
    """Stateful KV client bound to one session id.

    Tracks the per-session op counter (the RYW token every frame carries),
    the set of unapplied tokens, and the segment/epoch of the last lease
    validation.  The lease rule (DESIGN.md §10): a host-side get is
    read-your-writes safe iff

    * every op this session issued has been applied somewhere on its
      segment chain (no pending tokens), and
    * the session's ``(group, generation)`` segment is unchanged since the
      lease was last validated — a membership event that re-routes the
      session invalidates it (in-flight writes may have died with a
      retired generation).  An epoch bump that did NOT move the session
      (another tenant's membership event) re-validates host-side.

    A stale lease escalates to ONE read-index op through consensus: the op
    serializes behind every surviving earlier op of the session, so once
    it applies the session's writes have too, and the lease re-validates
    at the current epoch."""

    def __init__(self, kv: ReplicatedKV, session_id: Any) -> None:
        self.kv = kv
        self.id = session_id
        self.tag = session_hash(session_id)
        self._counter = 0
        self._pending: dict[int, int] = {}   # counter -> group submitted to
        self._epoch = kv.service.routing_epoch
        self._seg = self._current_seg()
        # segment chain cached per routing epoch: the chain only grows at
        # membership events, and recomputing it hashes the session id per
        # epoch — too hot for a per-get path meant to be O(1)
        self._chain: list[tuple[int, int]] | None = None
        self._chain_epoch = -1

    # -- write path (consensus) ---------------------------------------------
    def put(self, key: bytes, value: bytes) -> Ticket:
        return self._submit(KvOp(OP_PUT, key, value, None, self.tag))

    def delete(self, key: bytes) -> Ticket:
        return self._submit(KvOp(OP_DELETE, key, b"", None, self.tag))

    def cas(self, key: bytes, expect: bytes | None, value: bytes) -> Ticket:
        """Compare-and-set: applies iff the segment's current value equals
        ``expect`` (``None`` = create iff absent).  A failed cas is a
        committed no-op — it still advances the session's RYW token."""
        return self._submit(KvOp(OP_CAS, key, value, expect, self.tag))

    def _submit(self, op: KvOp) -> Ticket:
        self._counter += 1
        op = dataclasses.replace(op, counter=self._counter)
        ticket = self.kv.service.session(self.id).submit(encode_op(op))
        self._pending[self._counter] = ticket.group
        self.kv.stats["ops_submitted"] += 1
        return ticket

    # -- consensus-free read path -------------------------------------------
    def _current_seg(self) -> tuple[int, int]:
        svc = self.kv.service
        gid = svc.group_of(self.id)
        return (gid, svc.group_generation(gid))

    def _segments(self) -> list[tuple[int, int]]:
        svc = self.kv.service
        ep = svc.routing_epoch
        chain = self._chain
        if chain is None or self._chain_epoch != ep:
            chain = svc.session_chain(self.id)
            self._chain = chain
            self._chain_epoch = ep
        return chain

    def _applied_token(self) -> int:
        """Highest op counter of this session applied anywhere on its
        chain (counters are issued in one monotone stream, so the max is
        exactly "everything up to here has landed or died")."""
        best = 0
        for seg in self._segments():
            rep = self.kv._replicas.get(seg)
            if rep is not None:
                c = rep.applied_counter.get(self.tag, 0)
                if c > best:
                    best = c
        return best

    def _revalidate(self) -> None:
        """Cheap host-side lease upkeep: prune tokens at or below the
        applied high-water mark, and absorb epoch bumps that left this
        session's segment in place."""
        if not self._pending and self._epoch == self.kv.service.routing_epoch:
            return                    # lease already valid: nothing to do
        applied = self._applied_token()
        for c in [c for c in self._pending if c <= applied]:
            del self._pending[c]
        svc = self.kv.service
        if self._epoch != svc.routing_epoch:
            seg = self._current_seg()
            if seg == self._seg:
                self._epoch = svc.routing_epoch
            # else: stale until the read-index round re-validates

    @property
    def lease_valid(self) -> bool:
        return not self._pending and self._epoch == self.kv.service.routing_epoch

    def get(self, key: bytes) -> bytes | None:
        """Read one key.

        Leased: host-side only — apply already-delivered entries, answer
        from replica state, ZERO wire-path dispatches (pinned by the
        dispatch-count tests).  Stale: one serialized read-index op (see
        class docstring), then the same replica read."""
        kv = self.kv
        kv.refresh()
        self._revalidate()
        if self.lease_valid:
            kv.stats["leased_gets"] += 1
        else:
            self._read_index()
            kv.stats["read_index_gets"] += 1
        for seg in reversed(self._segments()):
            rep = kv._replicas.get(seg)
            if rep is not None and key in rep.state:
                return rep.state[key][0]
        return None

    def _read_index(self) -> None:
        svc = self.kv.service
        ticket = self._submit(KvOp(OP_GET, b"", b"", None, self.tag))
        target = self._counter
        seg = (ticket.group, svc.group_generation(ticket.group))
        for _ in range(self.kv.max_read_rounds):
            self.kv.refresh()
            rep = self.kv._replicas.get(seg)
            if (
                rep is not None
                and rep.applied_counter.get(self.tag, 0) >= target
            ):
                break
            svc.pump()
        else:
            raise RuntimeError(
                f"read-index op for session {self.id!r} did not apply "
                f"within {self.kv.max_read_rounds} pump rounds"
            )
        # every op this session issued before the read either applied (it
        # sequences ahead of the read in the same group) or died with a
        # retired generation — nothing is still outstanding
        self._pending.clear()
        self._seg = self._current_seg()
        self._epoch = svc.routing_epoch
