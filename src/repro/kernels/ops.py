"""Jit'd wrappers exposing the Pallas kernels with the ``core.batched``
signatures, so the hardware dataplane (``core.api.HardwareDataplane``) can be
switched between the jnp engine and the kernels with one flag.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python for correctness validation; on a real TPU
backend they compile to Mosaic.  ``INTERPRET`` auto-detects.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.analysis.contracts import dataplane_contract
from repro.core import batched as _batched
from repro.core.batched import LearnerState
from repro.core.types import AcceptorState, CoordinatorState, MsgBatch

from . import acceptor as _acceptor
from . import coordinator as _coordinator
from . import digest as _digest
from . import learner as _learner
from . import ref as _ref
from . import wirepath as _wirepath

NO_ROUND = -1
INTERPRET = jax.default_backend() == "cpu"


@dataplane_contract(oracle=_batched.coordinator_sequence)
def coordinator_sequence(
    cstate: CoordinatorState, values: jax.Array, active: jax.Array
) -> tuple[CoordinatorState, MsgBatch]:
    """Kernel-backed drop-in for ``batched.coordinator_sequence``."""
    b = values.shape[0]
    msgtype, inst, rnd, vrnd, new_next = _coordinator.coordinator_sequence_window(
        cstate.next_inst, cstate.crnd, jnp.asarray(active), interpret=INTERPRET
    )
    out = MsgBatch(
        msgtype=msgtype,
        inst=inst,
        rnd=rnd,
        vrnd=vrnd,
        swid=jnp.zeros((b,), jnp.int32),
        value=values,
    )
    return CoordinatorState(next_inst=new_next, crnd=cstate.crnd), out


@dataplane_contract(oracle=_batched.acceptor_phase2, state_args=("astate",))
def acceptor_phase2(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> tuple[AcceptorState, MsgBatch]:
    """Kernel-backed drop-in for ``batched.acceptor_phase2``.

    Requires the contiguous-window invariant maintained by the sequencer:
    ``msgs.inst == base + iota(B)`` with ``base`` a multiple of the kernel
    batch block.  (The API layer always produces such batches.)
    """
    base = msgs.inst[0]
    (st_rnd, st_vrnd, st_val, vt, vr, vv, vs, vval) = (
        _acceptor.acceptor_phase2_window(
            astate.rnd,
            astate.vrnd,
            astate.value,
            base,
            jnp.asarray(aid, jnp.int32),
            msgs.msgtype,
            msgs.rnd,
            msgs.value,
            interpret=INTERPRET,
        )
    )
    votes = MsgBatch(
        msgtype=vt, inst=msgs.inst, rnd=vr, vrnd=vv, swid=vs, value=vval
    )
    return AcceptorState(st_rnd, st_vrnd, st_val), votes


@dataplane_contract(oracle=_batched.learner_quorum)
def learner_quorum(
    vote_msgtype: jax.Array,
    vote_inst: jax.Array,
    vote_vrnd: jax.Array,
    vote_value: jax.Array,
    quorum: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Kernel-backed drop-in for ``batched.learner_quorum``."""
    deliver, win, value = _learner.learner_quorum_window(
        jnp.int32(quorum),
        vote_msgtype,
        vote_vrnd,
        vote_value,
        interpret=INTERPRET,
    )
    inst = vote_inst[0]  # position-aligned batches: inst identical across A
    return deliver.astype(bool), inst, win, value


@dataplane_contract(
    oracle=_batched.fused_round, state_args=("stack", "lstate")
)
def fused_round(
    cstate: CoordinatorState,
    stack: AcceptorState,
    lstate: LearnerState,
    values: jax.Array,
    active: jax.Array,
    alive: jax.Array,
    quorum: int | jax.Array,
    reclaim_limit: jax.Array | None = None,
) -> tuple[CoordinatorState, AcceptorState, LearnerState,
           jax.Array, jax.Array, jax.Array, jax.Array]:
    """Kernel-backed drop-in for ``batched.fused_round`` — the whole Phase-2
    round in one ``pallas_call`` (DESIGN.md §3).

    ``active`` is accepted for signature parity but never reaches the device:
    sequenced NOP fillers vote identically to P2As, so on the wire path the
    active mask only matters to the application layer (which discards fillers
    by value).  Precondition: ``cstate.next_inst`` is block-aligned — the
    invariant ``HardwareDataplane`` maintains (and checks host-side).
    ``reclaim_limit`` is the first instance the ring may NOT sequence into
    (snapshot watermark + N, DESIGN.md §9); ``None`` = no reclamation.
    """
    del active  # sequenced fillers vote like P2As; see docstring
    b = values.shape[0]
    (st_rnd, st_vrnd, st_val, ldel, linst, lval, fresh, win, value) = (
        _wirepath.wirepath_round(
            cstate.next_inst,
            cstate.crnd,
            jnp.asarray(quorum, jnp.int32),
            jnp.asarray(alive, jnp.int32),
            stack.rnd,
            stack.vrnd,
            stack.value,
            lstate.delivered,
            lstate.inst,
            lstate.value,
            values,
            reclaim_limit,
            interpret=INTERPRET,
        )
    )
    inst = cstate.next_inst + jnp.arange(b, dtype=jnp.int32)
    new_c = CoordinatorState(
        next_inst=cstate.next_inst + b, crnd=cstate.crnd
    )
    return (
        new_c,
        AcceptorState(st_rnd, st_vrnd, st_val),
        LearnerState(ldel, linst, lval),
        fresh != 0,
        inst,
        win,
        value,
    )


@dataplane_contract(
    oracle=_batched.multigroup_fused_round,
    state_args=("stack", "lstate"),
    extra=("group_block",),
)
def multigroup_fused_round(
    cstate: CoordinatorState,   # leaves shaped (G,)
    stack: AcceptorState,       # leaves shaped (G, A, N[, V])
    lstate: LearnerState,       # leaves shaped (G, N[, V])
    values: jax.Array,          # int32[G, B, V]
    active: jax.Array,          # bool[G, B]
    alive: jax.Array,           # bool[G, A]
    quorum: int | jax.Array,
    enabled: jax.Array | None = None,
    reclaim_limit: jax.Array | None = None,  # int32[G]; None = no reclamation
    *,
    group_block: int = 1,
) -> tuple[CoordinatorState, AcceptorState, LearnerState,
           jax.Array, jax.Array, jax.Array, jax.Array]:
    """Kernel-backed drop-in for ``batched.multigroup_fused_round`` — G
    device-resident Paxos groups, one ``pallas_call`` (DESIGN.md §5).

    ``active`` never reaches the device for the same reason as in
    ``fused_round``.  ``group_block > 1`` folds groups into one grid step —
    legal only when the folded *enabled* groups' watermarks are in lockstep,
    which the ``MultiGroupDataplane`` checks against its host watermark
    mirrors; ``enabled`` (0/1 per group) marks frozen/vacant/idle groups so
    the kernel can hold them inert and fold over their divergent watermarks
    (DESIGN.md §7).  Precondition: every enabled group's ``next_inst`` is
    block-aligned.
    """
    del active  # sequenced fillers vote like P2As; see fused_round
    b = values.shape[1]
    (st_rnd, st_vrnd, st_val, ldel, linst, lval, fresh, win, value) = (
        _wirepath.multigroup_wirepath_round(
            cstate.next_inst,
            cstate.crnd,
            jnp.asarray(quorum, jnp.int32),
            jnp.asarray(alive, jnp.int32),
            stack.rnd,
            stack.vrnd,
            stack.value,
            lstate.delivered,
            lstate.inst,
            lstate.value,
            values,
            None if enabled is None else jnp.asarray(enabled, jnp.int32),
            reclaim_limit,
            group_block=group_block,
            interpret=INTERPRET,
        )
    )
    inst = cstate.next_inst[:, None] + jnp.arange(b, dtype=jnp.int32)[None, :]
    new_c = CoordinatorState(
        next_inst=cstate.next_inst + b, crnd=cstate.crnd
    )
    return (
        new_c,
        AcceptorState(st_rnd, st_vrnd, st_val),
        LearnerState(ldel, linst, lval),
        fresh != 0,
        inst,
        win,
        value,
    )


@dataplane_contract(
    oracle=None,
    state_args=("stack", "lstate"),
    reason=(
        "compositional entry with no standalone oracle: the jnp parity "
        "path is full-width batched.multigroup_fused_round over "
        "scatter-expanded cohort rows (tests/test_wirepath_parity.py)"
    ),
)
def cohort_fused_round(
    stack: AcceptorState,       # leaves shaped (G, A, N[, V])
    lstate: LearnerState,       # leaves shaped (G, N[, V])
    gsel: jax.Array,            # int32[NB]  selected group-block indices
    next_inst: jax.Array,       # int32[G]
    crnd: jax.Array,            # int32[G]
    alive: jax.Array,           # int32[G, A]
    quorum: int | jax.Array,
    values: jax.Array,          # int32[NB*GB, B, V]  compact cohort burst
    enabled: jax.Array,         # int32[G]  cohort membership mask
    reclaim_limit: jax.Array | None = None,  # int32[G]; None = no reclamation
    *,
    group_block: int = 1,
) -> tuple[AcceptorState, LearnerState, jax.Array, jax.Array, jax.Array]:
    """Cohort-compacted fused round (DESIGN.md §8): the grid visits only the
    group blocks named by ``gsel``, so a dispatch costs what its cohort
    costs — not the full capacity G.  Stateless with respect to the
    coordinator: the dataplane advances its own watermark mirrors for the
    cohort members (it must mask non-members anyway).

    Returns ``(stack', lstate', fresh[C, B], win[C, B], value[C, B, V])``
    with ``C = NB * group_block`` compact rows in ``gsel``-block order.
    """
    (st_rnd, st_vrnd, st_val, ldel, linst, lval, fresh, win, value) = (
        _wirepath.cohort_wirepath_round(
            jnp.asarray(gsel, jnp.int32),
            next_inst,
            crnd,
            jnp.asarray(quorum, jnp.int32),
            jnp.asarray(alive, jnp.int32),
            stack.rnd,
            stack.vrnd,
            stack.value,
            lstate.delivered,
            lstate.inst,
            lstate.value,
            values,
            jnp.asarray(enabled, jnp.int32),
            reclaim_limit,
            group_block=group_block,
            interpret=INTERPRET,
        )
    )
    return (
        AcceptorState(st_rnd, st_vrnd, st_val),
        LearnerState(ldel, linst, lval),
        fresh != 0,
        win,
        value,
    )


@dataplane_contract(
    oracle=_batched.packed_multigroup_round,
    state_args=("stack", "lstate"),
    extra=("block_b",),
)
def packed_shard_round(
    stack: AcceptorState,       # leaves shaped (Gl, A, N[, V])
    lstate: LearnerState,       # leaves shaped (Gl, N[, V])
    segids: jax.Array,          # int32[C]  per-lane slab row (0..Gl)
    next_inst: jax.Array,       # int32[C]  per-lane window base
    crnd: jax.Array,            # int32[C]  per-lane coordinator round
    alive: jax.Array,           # int32[C, A]  per-lane liveness row
    quorum: int | jax.Array,
    values: jax.Array,          # int32[C, B, V]  packed burst values
    enabled: jax.Array,         # int32[C]  0 marks a pad lane
    reclaim_limit: jax.Array | None = None,  # int32[C]; None = no reclamation
    *,
    block_b: int | None = None,
) -> tuple[AcceptorState, LearnerState, jax.Array, jax.Array, jax.Array]:
    """Packed ragged-shard round (DESIGN.md §13): ``C`` uniform lanes, each
    routed to its resident slab row by the ``segids`` prefetch table, so a
    shard's dispatch costs what its enabled lanes cost — not the full
    ``Gl``-row slab.  Coordinator-stateless like ``cohort_fused_round``
    (the dataplane advances its own watermark mirrors per lane).

    Returns ``(stack', lstate', fresh[C, B], win[C, B], value[C, B, V])``
    in packed lane order; pads return all-inert rows.
    """
    if block_b is None:
        block_b = _wirepath.DEFAULT_BLOCK_B
    (st_rnd, st_vrnd, st_val, ldel, linst, lval, fresh, win, value) = (
        _wirepath.packed_shard_round(
            jnp.asarray(segids, jnp.int32),
            next_inst,
            crnd,
            jnp.asarray(quorum, jnp.int32),
            jnp.asarray(alive, jnp.int32),
            stack.rnd,
            stack.vrnd,
            stack.value,
            lstate.delivered,
            lstate.inst,
            lstate.value,
            values,
            jnp.asarray(enabled, jnp.int32),
            reclaim_limit,
            block_b=block_b,
            interpret=INTERPRET,
        )
    )
    return (
        AcceptorState(st_rnd, st_vrnd, st_val),
        LearnerState(ldel, linst, lval),
        fresh != 0,
        win,
        value,
    )


@dataplane_contract(
    oracle=_batched.persistent_multigroup_rounds,
    state_args=("stack", "lstate"),
    extra=("gsel", "wni", "wen", "crnd", "group_block", "block_b"),
    oracle_extra=("cstate", "active", "enabled_rounds"),
    strict_order=False,
)
def persistent_cohort_rounds(
    stack: AcceptorState,       # leaves shaped (G, A, N[, V])
    lstate: LearnerState,       # leaves shaped (G, N[, V])
    gsel: jax.Array,            # int32[NB]  selected group-block indices
    wni: jax.Array,             # int32[K, G]  per-round window bases
    wen: jax.Array,             # int32[K, G]  per-round participation
    crnd: jax.Array,            # int32[G]
    alive: jax.Array,           # int32[G, A]
    quorum: int | jax.Array,
    values: jax.Array,          # int32[K, NB*GB, B, V]  compact wave values
    reclaim_limit: jax.Array | None = None,  # int32[G]; None = no reclamation
    *,
    group_block: int = 1,
    block_b: int | None = None,
) -> tuple[AcceptorState, LearnerState, jax.Array, jax.Array, jax.Array]:
    """Persistent K-round wave dispatch (DESIGN.md §11): the whole chunk
    wave stays device-resident and syncs back to host once per K rounds.
    Coordinator-stateless like ``cohort_fused_round`` — the dataplane walks
    its own watermark mirrors from the same ``wni``/``wen`` descriptor.

    Returns ``(stack', lstate', fresh[K, C, B], win[K, C, B],
    value[K, C, B, V])`` with ``C = NB * group_block`` compact rows.
    """
    if block_b is None:
        block_b = _wirepath.DEFAULT_BLOCK_B
    (st_rnd, st_vrnd, st_val, ldel, linst, lval, fresh, win, value) = (
        _wirepath.persistent_wirepath_round(
            jnp.asarray(gsel, jnp.int32),
            jnp.asarray(wni, jnp.int32),
            jnp.asarray(wen, jnp.int32),
            crnd,
            jnp.asarray(quorum, jnp.int32),
            jnp.asarray(alive, jnp.int32),
            stack.rnd,
            stack.vrnd,
            stack.value,
            lstate.delivered,
            lstate.inst,
            lstate.value,
            values,
            reclaim_limit,
            block_b=block_b,
            group_block=group_block,
            interpret=INTERPRET,
        )
    )
    return (
        AcceptorState(st_rnd, st_vrnd, st_val),
        LearnerState(ldel, linst, lval),
        fresh != 0,
        win,
        value,
    )


@dataplane_contract(oracle=_batched.acceptor_phase2_all, state_args=("stack",))
def acceptor_phase2_all(
    stack: AcceptorState, msgs: MsgBatch, alive: jax.Array
) -> tuple[AcceptorState, MsgBatch]:
    """Kernel-backed drop-in for ``batched.acceptor_phase2_all``.

    Requires the contiguous-window invariant (``msgs.inst == base + iota(B)``
    with block-aligned ``base``); the API layer falls back to the jnp scatter
    path when it cannot guarantee it.
    """
    base = msgs.inst[0]
    (st_rnd, st_vrnd, st_val, vt, vr, vv, vs, vval) = (
        _wirepath.acceptor_vote_all_window(
            stack.rnd,
            stack.vrnd,
            stack.value,
            base,
            jnp.asarray(alive, jnp.int32),
            msgs.msgtype,
            msgs.rnd,
            msgs.value,
            interpret=INTERPRET,
        )
    )
    votes = MsgBatch(
        msgtype=vt,
        inst=jnp.broadcast_to(msgs.inst[None, :], vt.shape),
        rnd=vr,
        vrnd=vv,
        swid=vs,
        value=vval,
    )
    return AcceptorState(st_rnd, st_vrnd, st_val), votes


@dataplane_contract(oracle=_ref.digest)
def digest(x: jax.Array) -> jax.Array:
    return _digest.digest(x, interpret=INTERPRET)


@dataplane_contract(
    oracle=None,
    reason=(
        "leaf-wise composition of ``digest``: the jnp oracle is "
        "kernels.ref.digest applied per flattened leaf, folded with the "
        "same mixing constant (tests/test_digest.py pins parity)"
    ),
)
def tree_digest(tree) -> jax.Array:
    return _digest.tree_digest(tree, interpret=INTERPRET)
