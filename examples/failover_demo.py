"""Failure-handling walkthrough (paper §6.4 / Fig. 8) in one script:

  1. steady state through the hardware dataplane,
  2. acceptor failure (f of 2f+1): throughput holds,
  3. hardware-coordinator failure -> safe software takeover with Phase-1
     re-scan (re-proposing voted instances),
  4. learner gap + recover(),
  5. elastic membership view change decided through the log.

    PYTHONPATH=src python examples/failover_demo.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core import PaxosConfig, PaxosContext
from repro.train import elastic


def main() -> None:
    cfg = PaxosConfig(n_acceptors=3, n_instances=4096, batch=16)
    got = {}
    ctx = PaxosContext(cfg, deliver=lambda v, s, i: got.__setitem__(i, v))

    print("1) steady state: 10 values")
    for k in range(10):
        ctx.submit(f"steady-{k}".encode())
    ctx.run_until_quiescent()
    assert len(got) == 10

    print("2) acceptor 1 dies (tolerated: quorum 2 of 3 remains)")
    ctx.hw.kill_acceptor(1)
    for k in range(5):
        ctx.submit(f"degraded-{k}".encode())
    ctx.run_until_quiescent()
    assert len(got) == 15

    print("3) hardware coordinator dies -> software takeover w/ Phase-1 scan")
    # stale estimate on purpose: the scan catches the sequencer up safely
    res = ctx.fail_coordinator(est_next_inst=8)
    print(f"   scanned {res.scanned} instances, re-proposed "
          f"{len(res.reproposed)}, next_inst={res.next_inst}, crnd={res.crnd}")
    for k in range(5):
        ctx.submit(f"takeover-{k}".encode())
    ctx.run_until_quiescent()
    assert len(got) == 20

    print("4) learner misses instance -> recover() refetches decided value")
    inst = sorted(got)[3]
    lost = ctx.learned[0].pop(inst)
    ctx.recover(inst)
    ctx.run_until_quiescent()
    assert ctx.learned[0][inst] == lost
    print(f"   instance {inst} recovered: {lost!r}")

    print("5) membership view change decided through the consensus log")
    view_ctx = PaxosContext(dataclasses.replace(cfg, value_words=64))
    v0 = elastic.MembershipView(0, ("h0", "h1", "h2", "h3"), (4, 1),
                                ("data", "model"))
    vm = elastic.ViewManager(view_ctx, v0)
    new = vm.propose_view(["h0", "h1", "h3"], model_parallel=1)
    print(f"   epoch {new.epoch}: hosts={new.hosts} mesh={new.mesh_shape}")
    assert new.epoch == 1

    print("\nall failure paths exercised; no value lost, no double delivery")


if __name__ == "__main__":
    main()
