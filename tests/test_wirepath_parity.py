"""Adversarial parity: the fused wire path vs the scalar ``paxos.py`` oracle.

Drives randomized multi-round schedules through BOTH fused wire-path
implementations — the jnp ``batched.fused_round`` and the Pallas megakernel
``kernels.wirepath.wirepath_round`` (interpret mode) — and checks them
bit-for-bit against the scalar role state machines of ``core.paxos``:
``Coordinator.on_submit`` -> ``Acceptor.on_p2a`` per live acceptor ->
``Learner.on_p2b`` quorum, plus a ring-dedup mirror of ``LearnerState``.

Schedules include dead/revived acceptors mid-stream (frozen register files),
coordinator round bumps (takeover-style re-proposal over already-voted
slots, i.e. duplicate instances at the slot level), and enough rounds to
wrap the instance ring several times at the ``n_instances`` boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched
from repro.core.paxos import Acceptor, Coordinator, Learner, Msg
from repro.core.types import MSG_P2A, MSG_P2B, AcceptorState, CoordinatorState

from repro.kernels import wirepath

NO_ROUND = -1


class _ScalarWirePath:
    """The scalar-oracle mirror of one fused Phase-2 round.

    Sequencing, voting and quorum counting are the unmodified ``core.paxos``
    roles; only the bounded dedup memory (the ring) is modelled here, since
    the scalar Learner's dict is unbounded by construction.
    """

    def __init__(self, n_acceptors: int, n_instances: int):
        self.n = n_instances
        self.co = Coordinator(cid=0, n_instances=n_instances)
        self.acceptors = [
            Acceptor(aid=i, n_instances=n_instances) for i in range(n_acceptors)
        ]
        self.learner = Learner(lid=0, n_acceptors=n_acceptors)
        # LearnerState ring mirror: slot -> (inst, value)
        self.ring: dict = {}

    def round(self, values: np.ndarray, alive: np.ndarray):
        b, v = values.shape
        fresh = np.zeros((b,), bool)
        win = np.full((b,), NO_ROUND, np.int32)
        out_val = np.zeros((b, v), np.int32)
        for j in range(b):
            p2a = self.co.on_submit(Msg(5, value=values[j]))
            votes = []
            for aid, acc in enumerate(self.acceptors):
                if not alive[aid]:
                    continue  # crashed switch: BRAM frozen, emits nothing
                out = acc.on_p2a(
                    Msg(MSG_P2A, inst=p2a.inst, rnd=p2a.rnd, value=values[j])
                )
                if out.msgtype == MSG_P2B:
                    votes.append((aid, out))
            decided = None
            for aid, out in votes:
                d = self.learner.on_p2b(
                    Msg(MSG_P2B, inst=out.inst, rnd=out.rnd, vrnd=out.vrnd,
                        swid=aid, value=out.value)
                )
                if d is not None:
                    decided = d
            if decided is not None:
                win[j] = decided.rnd
                out_val[j] = decided.value
                slot = decided.inst % self.n
                prev = self.ring.get(slot)
                if prev is None or prev[0] != decided.inst:
                    fresh[j] = True
                    self.ring[slot] = (decided.inst, decided.value.copy())
        return fresh, win, out_val


def _mk_device_state(a: int, n: int, v: int):
    one = AcceptorState.init(n, v)
    stack = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (a,) + x.shape).copy(), one
    )
    return (
        CoordinatorState.init(),
        stack,
        batched.LearnerState.init(n, v),
    )


def _schedule(seed: int, rounds: int, a: int):
    """Random alive masks + round bumps; at least quorum alive most rounds."""
    rng = np.random.default_rng(seed)
    sched = []
    crnd = 0
    for _ in range(rounds):
        alive = rng.random(a) > 0.25
        if rng.random() < 0.2:
            crnd += int(rng.integers(1, 3))
        sched.append((alive, crnd))
    return sched


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n,b,v,a", [(256, 32, 4, 3), (128, 64, 2, 5)])
def test_fused_round_matches_scalar_oracle(seed, n, b, v, a):
    """Multi-round randomized schedule, ring wraps several times."""
    rng = np.random.default_rng(seed)
    rounds = 2 * n // b + 3  # guarantees ring wraparound at the N boundary
    quorum = a // 2 + 1

    cstate, stack, lstate = _mk_device_state(a, n, v)
    cstate_k, stack_k, lstate_k = _mk_device_state(a, n, v)
    oracle = _ScalarWirePath(a, n)

    # pre-seed promised rounds above the initial crnd so the schedule
    # exercises the reject path (recovery-touched slots) until crnd catches up
    seed_rnd = rng.integers(0, 4, (a, n)).astype(np.int32)
    stack = AcceptorState(jnp.asarray(seed_rnd), stack.vrnd, stack.value)
    stack_k = AcceptorState(jnp.asarray(seed_rnd), stack_k.vrnd, stack_k.value)
    for aid in range(a):
        for slot in np.nonzero(seed_rnd[aid])[0]:
            oracle.acceptors[aid].slots[int(slot)] = (
                int(seed_rnd[aid, slot]), NO_ROUND, np.zeros((v,), np.int32)
            )

    for alive, crnd in _schedule(seed, rounds, a):
        values = rng.integers(-99, 99, (b, v)).astype(np.int32)
        active = jnp.ones((b,), bool)
        cstate = CoordinatorState(next_inst=cstate.next_inst, crnd=jnp.int32(crnd))
        cstate_k = CoordinatorState(
            next_inst=cstate_k.next_inst, crnd=jnp.int32(crnd)
        )
        oracle.co.crnd = crnd

        cstate, stack, lstate, fresh, inst, win, value = batched.fused_round(
            cstate, stack, lstate, jnp.asarray(values), active,
            jnp.asarray(alive), quorum,
        )
        outs = wirepath.wirepath_round(
            cstate_k.next_inst, cstate_k.crnd, jnp.int32(quorum),
            jnp.asarray(alive, jnp.int32),
            stack_k.rnd, stack_k.vrnd, stack_k.value,
            lstate_k.delivered, lstate_k.inst, lstate_k.value,
            jnp.asarray(values), interpret=True,
        )
        (k_rnd, k_vrnd, k_val, k_ldel, k_linst, k_lval,
         k_fresh, k_win, k_value) = outs
        stack_k = AcceptorState(k_rnd, k_vrnd, k_val)
        lstate_k = batched.LearnerState(k_ldel, k_linst, k_lval)
        cstate_k = CoordinatorState(
            next_inst=cstate_k.next_inst + b, crnd=cstate_k.crnd
        )

        o_fresh, o_win, o_value = oracle.round(values, alive)

        # Pallas megakernel == jnp fused round, bit for bit, ALL positions
        np.testing.assert_array_equal(np.asarray(fresh), np.asarray(k_fresh) != 0)
        np.testing.assert_array_equal(np.asarray(win), np.asarray(k_win))
        np.testing.assert_array_equal(np.asarray(value), np.asarray(k_value))
        for x, y in zip(jax.tree_util.tree_leaves((stack, lstate)),
                        jax.tree_util.tree_leaves((stack_k, lstate_k)), strict=True):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

        # fused round == scalar oracle
        np.testing.assert_array_equal(np.asarray(fresh), o_fresh)
        np.testing.assert_array_equal(
            np.asarray(win)[o_fresh], o_win[o_fresh]
        )
        np.testing.assert_array_equal(
            np.asarray(value)[o_fresh], o_value[o_fresh]
        )

    # final acceptor register files agree with the scalar acceptors
    h_rnd = np.asarray(stack.rnd)
    h_vrnd = np.asarray(stack.vrnd)
    h_val = np.asarray(stack.value)
    for aid, acc in enumerate(oracle.acceptors):
        for slot, (rnd, vrnd, val) in acc.slots.items():
            assert h_rnd[aid, slot] == rnd, (aid, slot)
            assert h_vrnd[aid, slot] == vrnd, (aid, slot)
            np.testing.assert_array_equal(h_val[aid, slot], val)


def test_fused_round_ring_wraparound_boundary():
    """A window crossing the N boundary wraps block indices and redelivers
    fresh instances into previously-used slots."""
    n, b, v, a = 128, 32, 2, 3
    rng = np.random.default_rng(9)
    cstate, stack, lstate = _mk_device_state(a, n, v)
    alive = jnp.ones((a,), bool)
    seen_vals = []
    # 5 rounds of 32 = 160 instances: wraps at round 5 (inst 128..159 reuse
    # slots 0..31, which already hold delivered instances 0..31)
    for r in range(5):
        values = rng.integers(0, 100, (b, v)).astype(np.int32)
        seen_vals.append(values)
        cstate, stack, lstate, fresh, inst, win, value = batched.fused_round(
            cstate, stack, lstate, jnp.asarray(values),
            jnp.ones((b,), bool), alive, 2,
        )
        # wraparound must NOT suppress fresh instances reusing a slot
        assert np.asarray(fresh).all(), f"round {r}"
        np.testing.assert_array_equal(np.asarray(value), values)
    # slots 0..31 now hold the round-4 instances (128..159), not 0..31
    np.testing.assert_array_equal(
        np.asarray(lstate.inst)[:b], np.arange(4 * b, 5 * b)
    )
    np.testing.assert_array_equal(np.asarray(lstate.value)[:b], seen_vals[4])


def test_fused_round_duplicate_instance_suppressed():
    """Re-running the sequencer over the same window (stale watermark after a
    failover rollback) re-decides the same instances; dedup must mark them
    stale, not fresh."""
    n, b, v, a = 128, 16, 2, 3
    rng = np.random.default_rng(3)
    values = rng.integers(0, 50, (b, v)).astype(np.int32)
    cstate, stack, lstate = _mk_device_state(a, n, v)
    alive = jnp.ones((a,), bool)
    _, stack, lstate, fresh, _, _, _ = batched.fused_round(
        cstate, stack, lstate, jnp.asarray(values), jnp.ones((b,), bool),
        alive, 2,
    )
    assert np.asarray(fresh).all()
    # replay the SAME window (cstate was not advanced) at a higher round
    cstate2 = CoordinatorState(next_inst=jnp.int32(0), crnd=jnp.int32(5))
    _, stack, lstate, fresh2, _, win2, val2 = batched.fused_round(
        cstate2, stack, lstate, jnp.asarray(values), jnp.ones((b,), bool),
        alive, 2,
    )
    # decided again (Paxos re-decides the same value at the higher round)...
    assert (np.asarray(win2) == 5).all()
    np.testing.assert_array_equal(np.asarray(val2), values)
    # ...but delivery is suppressed as a duplicate
    assert not np.asarray(fresh2).any()


# ---------------------------------------------------------------------------
# Multi-group parity: G fused groups == G independent single-group runs
# ---------------------------------------------------------------------------
def _mk_mg_state(g: int, a: int, n: int, v: int):
    return batched.init_multigroup_state(g, a, n, v)


@pytest.mark.parametrize("g", [1, 4, 8])
def test_multigroup_fused_matches_independent_runs(g):
    """The G-group fused round (Pallas kernel, both group->grid mappings, and
    the vmapped jnp oracle) is bit-identical to G *independent* single-group
    ``fused_round`` executions and to G independent scalar oracles — through
    per-group dead acceptors, a mid-schedule coordinator failover in one
    group (round bump + watermark jump), and ring wraparound."""
    a, n, b, v = 3, 256, 32, 4
    quorum = a // 2 + 1
    rounds = 2 * n // b + 3  # wraps each group's ring
    fail_group = g - 1       # the group that loses its coordinator
    fail_round = rounds // 2
    rng = np.random.default_rng(g)

    cstate, stack, lstate = _mk_mg_state(g, a, n, v)
    cstate_k, stack_k, lstate_k = _mk_mg_state(g, a, n, v)
    # independent single-group references + scalar oracles, one per group
    ind = [_mk_device_state(a, n, v) for _ in range(g)]
    oracles = [_ScalarWirePath(a, n) for _ in range(g)]

    crnd_host = np.zeros((g,), np.int32)
    ni_host = np.zeros((g,), np.int32)
    lockstep = True
    for r in range(rounds):
        # per-group liveness: quorum always alive, the rest random
        alive = rng.random((g, a)) > 0.3
        alive[:, :quorum] = True
        if r == fail_round:
            # takeover in ONE group: strictly higher unique round, watermark
            # jumps forward past the uncertainty window (block-aligned)
            crnd_host[fail_group] += 7
            ni_host[fail_group] += 2 * b
            lockstep = False
            for gid in range(g):
                oracles[gid].co.crnd = int(crnd_host[gid])
            oracles[fail_group].co.next_inst = int(ni_host[fail_group])
        values = rng.integers(-99, 99, (g, b, v)).astype(np.int32)

        cstate = CoordinatorState(
            next_inst=jnp.asarray(ni_host), crnd=jnp.asarray(crnd_host)
        )
        cstate_k = CoordinatorState(
            next_inst=jnp.asarray(ni_host), crnd=jnp.asarray(crnd_host)
        )

        # jnp multigroup oracle
        cstate, stack, lstate, fresh, inst, win, value = (
            batched.multigroup_fused_round(
                cstate, stack, lstate, jnp.asarray(values),
                jnp.ones((g, b), bool), jnp.asarray(alive), quorum,
            )
        )
        # Pallas megakernel, one group per grid step (general mapping) and —
        # while the watermarks are in lockstep — all groups folded per step.
        # EVERY mapping must match the jnp oracle bit for bit (both calls see
        # the same pre-round state; no donation at this call level).
        group_blocks = (1, g) if lockstep else (1,)
        for gb in group_blocks:
            outs = wirepath.multigroup_wirepath_round(
                cstate_k.next_inst, cstate_k.crnd, jnp.int32(quorum),
                jnp.asarray(alive, jnp.int32),
                stack_k.rnd, stack_k.vrnd, stack_k.value,
                lstate_k.delivered, lstate_k.inst, lstate_k.value,
                jnp.asarray(values), group_block=gb, interpret=True,
            )
            np.testing.assert_array_equal(
                np.asarray(fresh), np.asarray(outs[6]) != 0, err_msg=f"gb={gb}"
            )
            np.testing.assert_array_equal(
                np.asarray(win), np.asarray(outs[7]), err_msg=f"gb={gb}"
            )
            np.testing.assert_array_equal(
                np.asarray(value), np.asarray(outs[8]), err_msg=f"gb={gb}"
            )
            for x, y in zip(jax.tree_util.tree_leaves((stack, lstate)),
                            outs[:6], strict=True):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y), err_msg=f"gb={gb}"
                )
        (k_rnd, k_vrnd, k_val, k_ldel, k_linst, k_lval,
         k_fresh, k_win, k_value) = outs
        stack_k = AcceptorState(k_rnd, k_vrnd, k_val)
        lstate_k = batched.LearnerState(k_ldel, k_linst, k_lval)

        for gid in range(g):
            # fused group slice == independent single-group fused_round
            c_g, st_g, ls_g = ind[gid]
            c_g = CoordinatorState(
                next_inst=jnp.int32(ni_host[gid]), crnd=jnp.int32(crnd_host[gid])
            )
            c_g, st_g, ls_g, f_g, i_g, w_g, v_g = batched.fused_round(
                c_g, st_g, ls_g, jnp.asarray(values[gid]),
                jnp.ones((b,), bool), jnp.asarray(alive[gid]), quorum,
            )
            ind[gid] = (c_g, st_g, ls_g)
            np.testing.assert_array_equal(np.asarray(fresh[gid]), np.asarray(f_g))
            np.testing.assert_array_equal(np.asarray(inst[gid]), np.asarray(i_g))
            np.testing.assert_array_equal(np.asarray(win[gid]), np.asarray(w_g))
            np.testing.assert_array_equal(np.asarray(value[gid]), np.asarray(v_g))
            for x, y in zip(
                jax.tree_util.tree_leaves(
                    jax.tree_util.tree_map(lambda s, gid=gid: s[gid], (stack, lstate))
                ),
                jax.tree_util.tree_leaves((st_g, ls_g)), strict=True,
            ):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

            # fused group slice == the group's independent scalar oracle
            o_fresh, o_win, o_value = oracles[gid].round(values[gid], alive[gid])
            np.testing.assert_array_equal(np.asarray(fresh[gid]), o_fresh)
            np.testing.assert_array_equal(
                np.asarray(win[gid])[o_fresh], o_win[o_fresh]
            )
            np.testing.assert_array_equal(
                np.asarray(value[gid])[o_fresh], o_value[o_fresh]
            )

        ni_host += b

    # final per-group register files agree with each group's scalar acceptors
    h_rnd = np.asarray(stack.rnd)
    h_vrnd = np.asarray(stack.vrnd)
    for gid in range(g):
        for aid, acc in enumerate(oracles[gid].acceptors):
            for slot, (rnd, vrnd, _val) in acc.slots.items():
                assert h_rnd[gid, aid, slot] == rnd, (gid, aid, slot)
                assert h_vrnd[gid, aid, slot] == vrnd, (gid, aid, slot)


def test_multigroup_dead_acceptor_isolated_to_group():
    """Killing an acceptor in one group changes nothing in any other group:
    the others' outputs and register files stay bit-identical to an all-alive
    run, and the victim group still delivers through its quorum."""
    g, a, n, b, v = 4, 3, 128, 32, 2
    rng = np.random.default_rng(7)
    values = jnp.asarray(rng.integers(0, 99, (g, b, v)).astype(np.int32))
    active = jnp.ones((g, b), bool)

    alive_all = jnp.ones((g, a), bool)
    alive_dead = alive_all.at[1, 2].set(False)  # kill acceptor 2 of group 1

    outs = {}
    for key, alive in (("all", alive_all), ("dead", alive_dead)):
        cstate, stack, lstate = _mk_mg_state(g, a, n, v)
        outs[key] = batched.multigroup_fused_round(
            cstate, stack, lstate, values, active, alive, 2
        )
    for x, y in zip(jax.tree_util.tree_leaves(outs["all"]),
                    jax.tree_util.tree_leaves(outs["dead"]), strict=True):
        x, y = np.asarray(x), np.asarray(y)
        mask = np.ones(x.shape[0], bool)
        mask[1] = False  # every group but the victim is untouched
        np.testing.assert_array_equal(x[mask], y[mask])
    # the victim still has quorum (2 of 3) and delivers everything
    fresh_dead = np.asarray(outs["dead"][3])
    assert fresh_dead[1].all()


def test_vote_all_window_kernel_matches_jnp():
    """Staged all-acceptor vote kernel vs the vmapped scatter path."""
    from repro.kernels import ref

    rng = np.random.default_rng(11)
    a, n, b, v = 3, 256, 128, 4
    st_rnd = jnp.asarray(rng.integers(0, 3, (a, n)).astype(np.int32))
    st_vrnd = jnp.asarray(rng.integers(-1, 2, (a, n)).astype(np.int32))
    st_val = jnp.asarray(rng.integers(-9, 9, (a, n, v)).astype(np.int32))
    base = 128  # window [128, 256): block-aligned, wraps on next call
    alive = jnp.asarray([1, 0, 1], jnp.int32)
    mt = jnp.asarray(rng.choice([3, 0], size=b).astype(np.int32))
    mr = jnp.asarray(rng.integers(0, 4, b).astype(np.int32))
    mv = jnp.asarray(rng.integers(-9, 9, (b, v)).astype(np.int32))
    k = wirepath.acceptor_vote_all_window(
        st_rnd, st_vrnd, st_val, base, alive, mt, mr, mv, interpret=True
    )
    r = ref.acceptor_vote_all_window(
        st_rnd, st_vrnd, st_val, base, alive, mt, mr, mv
    )
    for x, y in zip(k, r, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # wrapped follow-up window [256, 384) -> slots [0, 128)
    k2 = wirepath.acceptor_vote_all_window(
        k[0], k[1], k[2], 256, alive, mt, mr, mv, interpret=True
    )
    r2 = ref.acceptor_vote_all_window(
        r[0], r[1], r[2], 256, alive, mt, mr, mv
    )
    for x, y in zip(k2, r2, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Cohort-compacted dispatch (DESIGN.md §8)
# ---------------------------------------------------------------------------
def test_cohort_round_matches_full_width_oracle():
    """``cohort_wirepath_round`` — the group-axis-compacted kernel entry —
    is bit-identical to the full-width jnp oracle with non-members held
    inert, across: a compact single-group hot tier, a folded block carrying
    a disabled member, divergent per-block watermark bases, and multiple
    rounds of watermark advance (the cold tier wrapping its ring slower
    than the hot tier).  Unselected groups' slabs must ride through the
    aliased state outputs bit-unchanged."""
    g, a, n, v = 8, 3, 256, 4
    quorum = a // 2 + 1
    rng = np.random.default_rng(11)
    _cs, stack, ls = _mk_mg_state(g, a, n, v)
    _cso, stack_o, ls_o = _mk_mg_state(g, a, n, v)
    alive = jnp.ones((g, a), jnp.int32)
    marks = np.zeros((g,), np.int32)
    hot = 0
    hot_b, cold_b = 64, 8
    for _ in range(2 * n // hot_b + 2):          # hot ring wraps twice
        # -- hot tier: compact single-group block ---------------------------
        vals_h = rng.integers(-99, 99, (1, hot_b, v)).astype(np.int32)
        en_h = np.zeros((g,), np.int32)
        en_h[hot] = 1
        outs = wirepath.cohort_wirepath_round(
            jnp.asarray([hot], jnp.int32),
            jnp.asarray(marks), jnp.zeros((g,), jnp.int32),
            jnp.int32(quorum), alive,
            stack.rnd, stack.vrnd, stack.value,
            ls.delivered, ls.inst, ls.value,
            jnp.asarray(vals_h), jnp.asarray(en_h),
            group_block=1, interpret=True,
        )
        stack = AcceptorState(*outs[:3])
        ls = batched.LearnerState(*outs[3:6])
        # oracle: full-width with non-members' rounds at NO_ROUND
        vals_f = np.zeros((g, hot_b, v), np.int32)
        vals_f[hot] = vals_h[0]
        eff = CoordinatorState(
            next_inst=jnp.asarray(marks),
            crnd=jnp.where(jnp.asarray(en_h) != 0, 0, NO_ROUND),
        )
        _c, stack_o, ls_o, fresh_o, _i, _w, val_o = (
            batched.multigroup_fused_round(
                eff, stack_o, ls_o, jnp.asarray(vals_f),
                jnp.ones((g, hot_b), bool), alive != 0, quorum,
            )
        )
        np.testing.assert_array_equal(
            np.asarray(outs[6] != 0), np.asarray(fresh_o)[[hot]]
        )
        np.testing.assert_array_equal(
            np.asarray(outs[8]), np.asarray(val_o)[[hot]]
        )
        marks[hot] += hot_b
        # -- cold tier: groups 1..7 folded into one full-width block --------
        vals_c = rng.integers(-99, 99, (g, cold_b, v)).astype(np.int32)
        en_c = np.ones((g,), np.int32)
        en_c[hot] = 0
        outs = wirepath.cohort_wirepath_round(
            jnp.asarray([0], jnp.int32),
            jnp.asarray(marks), jnp.zeros((g,), jnp.int32),
            jnp.int32(quorum), alive,
            stack.rnd, stack.vrnd, stack.value,
            ls.delivered, ls.inst, ls.value,
            jnp.asarray(vals_c), jnp.asarray(en_c),
            group_block=g, interpret=True,
        )
        stack = AcceptorState(*outs[:3])
        ls = batched.LearnerState(*outs[3:6])
        eff = CoordinatorState(
            next_inst=jnp.asarray(marks),
            crnd=jnp.where(jnp.asarray(en_c) != 0, 0, NO_ROUND),
        )
        _c, stack_o, ls_o, fresh_o, _i, _w, val_o = (
            batched.multigroup_fused_round(
                eff, stack_o, ls_o, jnp.asarray(vals_c),
                jnp.ones((g, cold_b), bool), alive != 0, quorum,
            )
        )
        np.testing.assert_array_equal(
            np.asarray(outs[6] != 0), np.asarray(fresh_o)
        )
        cold = [i for i in range(g) if i != hot]
        np.testing.assert_array_equal(
            np.asarray(outs[8])[cold], np.asarray(val_o)[cold]
        )
        marks[[i for i in range(g) if i != hot]] += cold_b
        # full state parity every round: compaction, folding over the
        # disabled hot slot, and untouched-slab aliasing are all state-exact
        for x, y in zip(
            jax.tree_util.tree_leaves((stack, ls)),
            jax.tree_util.tree_leaves((stack_o, ls_o)), strict=True,
        ):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_cohort_round_per_block_bases():
    """Two divergent lockstep halves fold at width G/2, each block deriving
    its ring offset from its own base — bit-identical to the oracle."""
    g, a, n, v, b = 8, 3, 128, 4, 16
    quorum = a // 2 + 1
    rng = np.random.default_rng(5)
    _cs, stack, ls = _mk_mg_state(g, a, n, v)
    _cso, stack_o, ls_o = _mk_mg_state(g, a, n, v)
    alive = jnp.ones((g, a), jnp.int32)
    marks = np.asarray([32, 32, 32, 32, 96, 96, 96, 96], np.int32)
    vals = rng.integers(-99, 99, (g, b, v)).astype(np.int32)
    outs = wirepath.cohort_wirepath_round(
        jnp.asarray([0, 1], jnp.int32),
        jnp.asarray(marks), jnp.zeros((g,), jnp.int32),
        jnp.int32(quorum), alive,
        stack.rnd, stack.vrnd, stack.value,
        ls.delivered, ls.inst, ls.value,
        jnp.asarray(vals), jnp.ones((g,), jnp.int32),
        group_block=4, interpret=True,
    )
    cs_o = CoordinatorState(
        next_inst=jnp.asarray(marks), crnd=jnp.zeros((g,), jnp.int32)
    )
    _c, stack_o, ls_o, fresh_o, _i, _w, val_o = (
        batched.multigroup_fused_round(
            cs_o, stack_o, ls_o, jnp.asarray(vals),
            jnp.ones((g, b), bool), alive != 0, quorum,
        )
    )
    np.testing.assert_array_equal(np.asarray(outs[6] != 0), np.asarray(fresh_o))
    np.testing.assert_array_equal(np.asarray(outs[8]), np.asarray(val_o))
    for x, y in zip(
        jax.tree_util.tree_leaves((AcceptorState(*outs[:3]),
                                   batched.LearnerState(*outs[3:6]))),
        jax.tree_util.tree_leaves((stack_o, ls_o)), strict=True,
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
