"""Shared benchmark utilities: timing, CSV emission, JSON perf trajectory."""
from __future__ import annotations

import json
import platform
import time
from collections.abc import Callable

ROWS: list[tuple[str, float, str]] = []
RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = "", **extra) -> None:
    """Print one CSV row and append a machine-readable record.

    ``extra`` keys (burst, path, msgs_per_s, ...) land verbatim in the JSON
    record so later PRs can diff perf trajectories (see ``write_json``).
    """
    ROWS.append((name, us_per_call, derived))
    RECORDS.append({"name": name, "us_per_call": us_per_call,
                    "derived": derived, **extra})
    print(f"{name},{us_per_call:.3f},{derived}")


def write_json(path: str, meta: dict | None = None,
               prefix: str | None = None) -> None:
    """Dump emitted records (optionally filtered by name prefix) as JSON.

    The file is the perf trajectory artifact (e.g. ``BENCH_wirepath.json``):
    subsequent PRs diff msgs/s against it, and ``make_report`` renders it.
    """
    rows = [r for r in RECORDS if prefix is None or r["name"].startswith(prefix)]
    doc = {
        "meta": {
            "python": platform.python_version(),
            "machine": platform.machine(),
            **(meta or {}),
        },
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)")


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            stat: str = "median") -> float:
    """Wall-time per call in microseconds.

    ``stat="median"`` is the default reporting estimator; ``stat="min"`` is
    the noise-robust choice for *gated* metrics (CI regression checks) on
    shared/noisy runners — the minimum over iterations converges on the
    uncontended cost of the call.
    """
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    pick = times[0] if stat == "min" else times[len(times) // 2]
    return pick * 1e6


def block(x):
    import jax

    return jax.block_until_ready(x)
