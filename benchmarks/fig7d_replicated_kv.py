"""Paper Fig. 7d: replicated key-value store (the LevelDB case study).

Three replicas each apply the decided log to their own in-memory KV store
(LevelDB stand-in); clients submit serialized get/put ops through the
unchanged submit/deliver API.  Reports end-to-end op throughput (application
overhead included) vs the raw echo numbers, and checks replica consistency —
the CAANS guarantee the paper's case study leans on.
"""
from __future__ import annotations

import time

from repro.core import PaxosConfig, PaxosContext

from .common import emit

N_OPS = 2000
CFG = PaxosConfig(n_acceptors=3, n_instances=1 << 14, batch=64)


class KVReplica:
    def __init__(self):
        self.store = {}
        self.applied = 0

    def apply(self, op: bytes):
        kind, _, rest = op.partition(b":")
        self.applied += 1
        if kind == b"put":
            k, _, v = rest.partition(b"=")
            self.store[k] = v
        elif kind == b"get":
            self.store.get(rest)


def run() -> None:
    replicas = [KVReplica() for _ in range(3)]
    ctx = PaxosContext(CFG, n_learners=3, fused=True)

    def deliver(value, size, inst):
        # learner 0 callback; apply to all 3 replicas from their learned maps
        for r in replicas:
            r.apply(bytes(value))

    ctx.deliver_cb = deliver

    # warm every dispatch shape (64-burst, 16-tail, singletons): jit compiles
    # are not steady-state op latency
    for burst in (64, 64, 16, 8, 1):
        for i in range(burst):
            ctx.submit(b"put:warm=%d" % i)
        ctx.pump()
    ctx.run_until_quiescent(max_rounds=100)
    for r in replicas:
        r.store.clear()
        r.applied = 0

    t0 = time.perf_counter()
    for i in range(N_OPS):
        if i % 2 == 0:
            ctx.submit(b"put:k%d=v%d" % (i % 97, i))
        else:
            ctx.submit(b"get:k%d" % (i % 97))
        if i % 64 == 63:
            ctx.pump()
    ctx.run_until_quiescent(max_rounds=300)
    dt = time.perf_counter() - t0

    assert replicas[0].applied == N_OPS, replicas[0].applied
    # replica consistency: identical final stores
    s0 = replicas[0].store
    consistent = all(r.store == s0 for r in replicas)
    emit(
        "fig7d/replicated_kv",
        dt / N_OPS * 1e6,
        f"tput={N_OPS/dt:.0f} op/s consistent={consistent} "
        f"(paper: 75,825 op/s w/ LevelDB vs 134,094 echo)",
    )
