"""Serving-engine tests: prefill/decode consistency and the batching loop."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry
from repro.serve.engine import Request, ServeLoop, make_prefill_step, make_serve_step

DECODE_FAMS = [
    "qwen3-4b",          # dense + qk_norm
    "gemma3-27b",        # local:global sliding window
    "rwkv6-3b",          # ssm: O(1) state
    "recurrentgemma-2b", # hybrid superblocks
    "whisper-base",      # enc-dec w/ cross cache
]


@pytest.mark.parametrize("arch", DECODE_FAMS)
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), remat=False,
                              capacity_factor=8.0)
    mod = registry.family_module(cfg)
    key = jax.random.PRNGKey(7)
    params = registry.init_params(cfg, key)
    B, T = 2, 10
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.src_len, cfg.d_model))
    ref_logits, _ = mod.forward(cfg, params, batch)

    cache = mod.init_cache(cfg, B, T, jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        _, pc = mod.prefill(cfg, params, {"tokens": tokens[:, :1],
                                          "frames": batch["frames"]})
        cache["cross_k"], cache["cross_v"] = pc["cross_k"], pc["cross_v"]
    outs = []
    step = jax.jit(make_serve_step(cfg))
    for t in range(T):
        logits, cache = step(params, tokens[:, t : t + 1], cache, jnp.int32(t))
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)
    err = np.abs(dec - np.asarray(ref_logits)).max()
    assert err < 5e-3, (arch, err)


def test_prefill_step_returns_last_logits_and_cache():
    cfg = dataclasses.replace(get_config("yi-9b").reduced(), remat=False)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    B, T = 2, 8
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    last, cache = jax.jit(make_prefill_step(cfg))(params, {"tokens": tokens})
    assert last.shape == (B, cfg.vocab)
    assert cache["k"].shape == (cfg.n_layers, B, T, cfg.n_kv_heads, cfg.hd)
    # prefill cache must continue identically to decode-built cache
    full, _ = registry.family_module(cfg).forward(cfg, params, {"tokens": tokens})
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1]), atol=2e-4
    )


def test_serve_loop_batched_requests():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(), remat=False)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32), max_new=4)
        for i in range(5)
    ]
    loop = ServeLoop(cfg, params, batch_size=3, max_len=16)
    out = loop.run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    assert all(len(v) == 4 for v in out.values())
    # determinism: same request set -> same generations
    out2 = ServeLoop(cfg, params, batch_size=3, max_len=16).run(reqs)
    assert out == out2


def test_ring_cache_sliding_window_decode():
    """Window-limited cache (ring) must agree with full-window attention for
    positions within the window."""
    cfg = dataclasses.replace(
        get_config("recurrentgemma-2b").reduced(), remat=False
    )
    mod = registry.family_module(cfg)
    key = jax.random.PRNGKey(2)
    params = registry.init_params(cfg, key)
    B, T = 1, 12
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    ref_logits, _ = mod.forward(cfg, params, {"tokens": tokens})
    # cache smaller than T but >= window: ring wrap must still be exact
    c = max(cfg.local_window, 8)
    cache = mod.init_cache(cfg, B, c, jnp.dtype(cfg.dtype))
    outs = []
    for t in range(T):
        logits, cache = mod.decode_step(cfg, params, tokens[:, t : t + 1], cache,
                                        jnp.int32(t))
        outs.append(np.asarray(logits).reshape(B, -1))
    err = np.abs(np.stack(outs, 1) - np.asarray(ref_logits)).max()
    assert err < 5e-3, err
