"""Training substrate: optimizer, data, checkpointing, elastic, train loop."""
from . import checkpoint, data, elastic, optimizer, train_loop  # noqa: F401
