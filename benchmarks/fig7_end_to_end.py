"""Paper Fig. 7a/7b + Table 4: end-to-end echo throughput/latency + tails.

The paper's echo experiment: clients submit timestamped values, servers echo
on deliver; latency = client round-trip, throughput = deliveries/s.  We run
the identical workload against (a) the libpaxos-like software baseline and
(b) the CAANS hardware dataplane, at increasing offered load (threads ->
submit burst size), and report p50/p99 + std at 25/50/75% of each system's
max throughput (Table 4's predictability comparison).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PaxosConfig, PaxosContext, SoftwarePaxos

from .common import emit

CFG = PaxosConfig(n_acceptors=3, n_instances=1 << 14, batch=256)
N_MSG = 4000


def _drive(system, submit, pump, n: int, burst: int) -> tuple[float, np.ndarray]:
    """Returns (throughput msg/s, latencies_us)."""
    lat: list[float] = []
    t_submit = {}
    delivered = {0: 0}

    def on_deliver(value, size, inst):
        k = bytes(value)
        if k in t_submit:
            lat.append(time.perf_counter() - t_submit.pop(k))
        delivered[0] += 1

    system.deliver_cb = lambda *a: None
    # warm every dispatch shape (jit compiles are not steady-state latency)
    for _ in range(3):
        for _ in range(burst):
            submit(b"warmup")
        pump()
    for _ in range(50):
        pump()
    system.deliver_cb = on_deliver
    t0 = time.perf_counter()
    i = 0
    while i < n:
        for _ in range(min(burst, n - i)):
            payload = f"m{i:08d}".encode()
            t_submit[payload] = time.perf_counter()
            submit(payload)
            i += 1
        pump()
    # drain
    for _ in range(200):
        if not t_submit:
            break
        pump()
    dt = time.perf_counter() - t0
    return delivered[0] / dt, np.asarray(lat) * 1e6


def run() -> None:
    results = {}
    for name, make in (
        ("libpaxos_sw", lambda: SoftwarePaxos(CFG)),
        ("caans_hw_staged", lambda: PaxosContext(CFG)),
        ("caans_hw", lambda: PaxosContext(CFG, fused=True)),
    ):
        best = 0.0
        for burst in (1, 8, 32, 64, 256):
            sysm = make()
            tput, lat = _drive(
                sysm, sysm.submit, lambda s=sysm: s.pump(), N_MSG, burst
            )
            best = max(best, tput)
            emit(
                f"fig7a/{name}/burst={burst}",
                float(np.median(lat)) if len(lat) else 0.0,
                f"tput={tput:.0f}/s p99={np.percentile(lat,99):.0f}us"
                if len(lat)
                else f"tput={tput:.0f}/s",
            )
            results.setdefault(name, []).append((burst, tput, lat))
        emit(f"fig7a/{name}/max_throughput", 1e6 / best, f"{best:.0f} msg/s")

    # Table 4: predictability at fractional load (approximated by the burst
    # closest to that fraction of max throughput)
    for name, rows in results.items():
        maxt = max(t for _, t, _ in rows)
        for frac in (0.25, 0.5, 0.75):
            burst, tput, lat = min(rows, key=lambda r, frac=frac: abs(r[1] - frac * maxt))
            if len(lat):
                emit(
                    f"table4/{name}/load={int(frac*100)}%",
                    float(np.mean(lat)),
                    f"std={np.std(lat):.1f}us (burst={burst})",
                )
    # paper's headline: CAANS/libpaxos throughput ratio (paper: 2.24x)
    r = max(t for _, t, _ in results["caans_hw"]) / max(
        t for _, t, _ in results["libpaxos_sw"]
    )
    emit("fig7a/throughput_ratio_caans_vs_sw", 0.0, f"{r:.2f}x (paper: 2.24x)")
