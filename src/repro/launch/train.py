"""End-to-end training driver.

    python -m repro.launch.train --arch qwen3-4b --smoke --steps 50
    python -m repro.launch.train --arch gemma3-27b --steps 100 \
        --batch 8 --seq 512 --ckpt-dir /tmp/ckpt

On this CPU container only ``--smoke`` (reduced config) is practical; the
same driver drives the production mesh on real hardware (``--mesh prod``).
Fault-tolerance path: consensus-committed checkpoints, quorum step-commit,
restart from the latest committed step.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.core import PaxosConfig, PaxosContext
from repro.launch import sharding as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import checkpoint as ckpt_mod
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--mesh", choices=["host", "prod", "prod-multi"], default="host")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()

    if args.mesh == "host":
        mesh = make_host_mesh()
        rules = sh.BASE_RULES
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multi")
        rules = sh.BASE_RULES
    sh.install(mesh, rules)

    key = jax.random.PRNGKey(args.seed)
    state = train_loop.init_state(cfg, key)
    opt_cfg = opt_mod.OptConfig(lr=args.lr, total_steps=max(args.steps, 10))
    step_fn = jax.jit(
        train_loop.make_train_step(cfg, opt_cfg, grad_accum=args.grad_accum),
        donate_argnums=(0,),
    )

    dcfg = data_mod.DataConfig(
        vocab=cfg.vocab,
        global_batch=args.batch,
        seq_len=args.seq,
        seed=args.seed,
        n_patches=cfg.n_patches,
        src_len=cfg.src_len if cfg.family == "encdec" else 0,
        d_model=cfg.d_model,
    )
    stream = data_mod.SyntheticStream(dcfg)

    paxos = PaxosContext(PaxosConfig(n_acceptors=3, n_instances=4096, batch=16))
    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(args.ckpt_dir, paxos_ctx=paxos)
        if args.resume and mgr.latest_committed():
            state, start_step = mgr.restore(state)
            print(f"resumed from committed step {start_step}")

    loop_cfg = train_loop.LoopConfig(
        steps=args.steps,
        checkpoint_every=args.ckpt_every,
        straggler_prob=args.straggler_prob,
    )
    t0 = time.time()
    state, hist = train_loop.run_loop(
        cfg,
        state,
        iter(stream),
        loop=loop_cfg,
        train_step=step_fn,
        paxos_ctx=paxos,
        checkpoint_mgr=mgr,
        rng_seed=args.seed,
    )
    dt = time.time() - t0
    committed = sum(hist["committed"])
    print(
        f"{args.steps} steps in {dt:.1f}s ({dt / max(args.steps,1) * 1e3:.1f} ms/step) "
        f"loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f} "
        f"committed={committed}/{args.steps} "
        f"consensus_delivered={paxos.stats['delivered']}"
    )
    sh.uninstall()


if __name__ == "__main__":
    main()
