"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run             # all
    PYTHONPATH=src python -m benchmarks.run fig7        # substring filter
"""
from __future__ import annotations

import sys
import time

from . import (
    bench_wirepath,
    fig2_utilization,
    fig7_end_to_end,
    fig7c_bottleneck_shift,
    fig7d_replicated_kv,
    fig8_failure,
    roofline_report,
    table1_component_latency,
    table2_throughput,
)

SUITES = [
    ("fig2", fig2_utilization),
    ("table1", table1_component_latency),
    ("table2", table2_throughput),
    ("fig7a", fig7_end_to_end),
    ("fig7c", fig7c_bottleneck_shift),
    ("fig7d", fig7d_replicated_kv),
    ("fig8", fig8_failure),
    ("wirepath", bench_wirepath),
    ("roofline", roofline_report),
]


def main() -> None:
    pat = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, mod in SUITES:
        if pat and pat not in name:
            continue
        try:
            mod.run()
        except Exception as e:  # keep the harness robust
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
