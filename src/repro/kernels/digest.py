"""Pallas TPU kernel: gradient digest for quorum step-commit.

Beyond-paper integration (DESIGN.md §3): each data-parallel replica group
votes for a training step with the *digest* of its gradient contribution; the
step commits when f+1 of 2f+1 groups agree.  The digest must be (a) cheap —
it runs every step over every gradient byte — and (b) order-deterministic.

We use a weighted modular fold over the int32 bit pattern:

    digest = sum_i  bits(x_i) * (2*i + 1)   (mod 2^32)

(odd weights make the fold position-sensitive: permuted or shifted gradients
collide with probability ~2^-32, unlike a plain sum).  The kernel is a
bandwidth-bound grid reduction: HBM-stream blocks into VMEM, fold in VREGs,
accumulate into a single scalar tile across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 32 * 1024  # elements per grid step (128 KiB of f32)


def _digest_kernel(x_ref, out_ref):
    i = pl.program_id(0)
    nb = x_ref.shape[0]
    bits = x_ref[...].view(jnp.int32) if x_ref.dtype != jnp.int32 else x_ref[...]
    # use 2D iota for TPU compatibility
    idx = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0)[:, 0] + i * nb
    w = idx * 2 + 1
    partial = jnp.sum(bits * w)  # int32 wraparound == mod 2^32

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = 0

    out_ref[0, 0] += partial


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def digest(
    x: jax.Array, *, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """Fold a flat array into an int32 digest."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = min(block, n)
    pad = (-n) % nb
    if pad:
        flat = jnp.pad(flat, (0, pad))
        n += pad
    grid = (n // nb,)
    out = pl.pallas_call(
        _digest_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((nb,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(flat)
    return out[0, 0]


def tree_digest(tree, *, interpret: bool = False) -> jax.Array:
    """Digest a whole gradient pytree (combines leaf digests order-sensitively)."""
    leaves = jax.tree_util.tree_leaves(tree)
    acc = jnp.int32(0)
    for leaf in leaves:
        d = digest(leaf, interpret=interpret)
        acc = acc * jnp.int32(1000003) + d  # polynomial combine
    return acc
