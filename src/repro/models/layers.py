"""Layer library: param specs, sharding hooks, attention, MLP, MoE.

Params are nested dicts of arrays built from ``PSpec`` trees; every param
carries *logical axis names* (a parallel tree) that ``launch/sharding.py``
maps onto mesh axes (DP/FSDP/TP/SP/EP).  Model code annotates activations
with ``shard`` calls; outside a mesh context these are no-ops, so the same
code runs on a single CPU device and under the 512-way dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any
from collections.abc import Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Activation sharding hook (installed by launch/sharding.py)
# ---------------------------------------------------------------------------
_ACTIVATION_SHARDER: Callable[[jax.Array, tuple], jax.Array] | None = None


def set_activation_sharder(fn: Callable | None) -> None:
    global _ACTIVATION_SHARDER
    _ACTIVATION_SHARDER = fn


def shard(x: jax.Array, axes: tuple) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a mesh)."""
    if _ACTIVATION_SHARDER is None:
        return x
    return _ACTIVATION_SHARDER(x, axes)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones
    scale: float = 1.0                # stddev multiplier (fan-in applied below)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(spec: PSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def materialize(spec_tree, key: jax.Array, dtype) -> Any:
    """Turn a PSpec tree into a param tree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, PSpec)
    )
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys, strict=True)]
    return jax.tree_util.tree_unflatten(treedef, out)


def axes_tree(spec_tree) -> Any:
    """Extract the logical-axes tree (same structure as params)."""
    return jax.tree_util.tree_map(
        lambda s: s.axes, spec_tree, is_leaf=lambda x: isinstance(x, PSpec)
    )


def spec_shapes(spec_tree, dtype) -> Any:
    """ShapeDtypeStruct tree (for eval_shape / dry-run, no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


# ---------------------------------------------------------------------------
# Remat policy selection (§Perf lever)
# ---------------------------------------------------------------------------
def checkpoint_fn(body, cfg):
    """Wrap a scan body with the configured rematerialization policy."""
    if not cfg.remat:
        return body
    policy = getattr(cfg, "remat_policy", "full")
    if policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots
        )
    return jax.checkpoint(body)


# ---------------------------------------------------------------------------
# Normalization / rotary
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embeddings.  x: (..., S, H, D), pos: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    if pos.ndim == 1:
        ang = pos[:, None].astype(jnp.float32) * freqs[None, :]       # (S, half)
        ang = ang[None, :, None, :]                                    # (1,S,1,half)
    else:
        ang = pos[..., None].astype(jnp.float32) * freqs               # (B,S,half)
        ang = ang[:, :, None, :]                                       # (B,S,1,half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


# ---------------------------------------------------------------------------
# Flash-style attention (double-chunked online softmax, pure JAX)
# ---------------------------------------------------------------------------
def flash_attention(
    q: jax.Array,              # (B, Sq, KV, G, D)  G = heads per kv group
    k: jax.Array,              # (B, Sk, KV, D)
    v: jax.Array,              # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: jax.Array | int = 0,      # 0 = unbounded; may be traced (per-layer)
    q_offset: jax.Array | int = 0,    # absolute position of q[0]
    k_positions: jax.Array | None = None,   # (Sk,) absolute key positions
    chunk_q: int = 512,
    chunk_k: int = 512,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Online-softmax attention that never materializes (Sq, Sk).

    The (q-chunk x k-chunk) score tile is the only quadratic intermediate;
    both chunk sizes bound the transient VMEM/HBM footprint, which is what
    makes prefill_32k lowerable and train_4k fit per-device.
    """
    b, sq, kvh, g, d = q.shape
    sk = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    # pad to multiples
    pq, pk = (-sq) % cq, (-sk) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (sq + pq) // cq, (sk + pk) // ck

    if k_positions is None:
        kpos_all = jnp.arange(sk + pk, dtype=jnp.int32)
        kvalid_all = kpos_all < sk
    else:
        kpos_all = jnp.pad(k_positions, (0, pk), constant_values=-1)
        kvalid_all = kpos_all >= 0
    window = jnp.asarray(window, jnp.int32)

    qr = q.reshape(b, nq, cq, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, ck, kvh, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, ck, kvh, d).transpose(1, 0, 2, 3, 4)
    kposr = kpos_all.reshape(nk, ck)
    kvalidr = kvalid_all.reshape(nk, ck)

    def per_q_chunk(qi, q_blk):
        qpos = (
            jnp.asarray(q_offset, jnp.int32) + qi * cq + jnp.arange(cq, dtype=jnp.int32)
        )

        def per_k_chunk(carry, inputs):
            acc, m, lse = carry
            k_blk, v_blk, kpos, kvalid = inputs
            s = jnp.einsum(
                "bqkgd,bskd->bqkgs", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale                                   # (B, cq, KV, G, ck)
            mask = kvalid[None, :]                      # (1, ck)
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            mask = mask & jnp.where(
                window > 0, kpos[None, :] > qpos[:, None] - window, True
            )
            s = jnp.where(mask[None, :, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            lse = lse * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bqkgs,bskd->bqkgd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc = acc * alpha[..., None] + pv
            return (acc, m_new, lse), None

        acc0 = jnp.zeros((b, cq, kvh, g, d), jnp.float32)
        m0 = jnp.full((b, cq, kvh, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, cq, kvh, g), jnp.float32)
        (acc, m, lse), _ = jax.lax.scan(
            per_k_chunk, (acc0, m0, l0), (kr, vr, kposr, kvalidr)
        )
        return acc / jnp.maximum(lse[..., None], 1e-30)

    out = jax.lax.map(
        lambda args: per_q_chunk(*args),
        (jnp.arange(nq, dtype=jnp.int32), qr),
    )                                                   # (nq, B, cq, KV, G, D)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq + pq, kvh, g, d)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,               # (B, 1, KV, G, D)
    k_cache: jax.Array,         # (B, L_cache, KV, D)
    v_cache: jax.Array,         # (B, L_cache, KV, D)
    k_pos: jax.Array,           # (B, L_cache) absolute positions (-1 = empty)
    pos: jax.Array,             # int32[] current absolute position
    *,
    window: jax.Array | int = 0,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Single-token attention over a (possibly ring) KV cache."""
    d = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum(
        "bqkgd,bskd->bqkgs", q, k_cache, preferred_element_type=jnp.float32
    ) * scale                                            # (B,1,KV,G,S)
    window = jnp.asarray(window, jnp.int32)
    valid = (k_pos >= 0) & (k_pos <= pos)
    valid = valid & jnp.where(window > 0, k_pos > pos - window, True)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bqkgs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (GQA + optional qk_norm + rope)
# ---------------------------------------------------------------------------
def attention_specs(cfg, d_model: int | None = None) -> dict[str, PSpec]:
    d = d_model or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sp = {
        "wq": PSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        sp["q_norm"] = PSpec((hd,), ("head_dim",), init="zeros")
        sp["k_norm"] = PSpec((hd,), ("head_dim",), init="zeros")
    return sp


def attention_fwd(
    p: dict[str, jax.Array],
    x: jax.Array,              # (B, S, D)
    cfg,
    *,
    causal: bool = True,
    window: jax.Array | int = 0,
    positions: jax.Array | None = None,   # (S,) absolute positions
    use_rope: bool = True,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attn
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill).  Returns (out, (k, v))."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // kv
    pos = positions if positions is not None else jnp.arange(s, dtype=jnp.int32)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        kk = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        vv = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    else:
        kk, vv = kv_override
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        kk = rms_norm(kk, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        if kv_override is None:
            kk = rope(kk, pos, cfg.rope_theta)
    q = shard(q, ("batch", None, "heads", None))
    kk = shard(kk, ("batch", None, "kv_heads", None))
    vv = shard(vv, ("batch", None, "kv_heads", None))

    qg = q.reshape(b, s, kv, g, hd)
    out = flash_attention(
        qg, kk, vv, causal=causal, window=window,
        q_offset=pos[0] if positions is not None else 0,
    )
    out = out.reshape(b, s, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard(out, ("batch", None, "embed_act")), (kk, vv)


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and MoE
# ---------------------------------------------------------------------------
def mlp_specs(cfg, d_ff: int | None = None) -> dict[str, PSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": PSpec((d, f), ("embed", "mlp")),
        "wg": PSpec((d, f), ("embed", "mlp")),
        "wo": PSpec((f, d), ("mlp", "embed")),
    }


def mlp_fwd(p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * jnp.einsum(
        "bsd,df->bsf", x, p["wi"]
    )
    h = shard(h, ("batch", None, "mlp_act"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def moe_specs(cfg) -> dict[str, PSpec]:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    sp = {
        "router": PSpec((d, e), ("embed", None)),
        "wi": PSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "wg": PSpec((e, d, f), ("expert", "embed", "expert_mlp")),
        "wo": PSpec((e, f, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.shared_expert:
        sp["shared"] = mlp_specs(cfg)
    return sp


def moe_fwd(p: dict[str, jax.Array], x: jax.Array, cfg) -> jax.Array:
    """Capacity-based sort-free MoE dispatch (one-hot position ranking).

    Tokens above expert capacity are dropped (standard Switch semantics);
    capacity = T * top_k / E * capacity_factor.

    ``cfg.dispatch_groups`` (§Perf lever): with G > 1, tokens are split into
    G groups, each with capacity/G, and ranks are computed *within* a group.
    When G equals the batch-sharding degree and the group dim is constrained
    to the batch axes, the rank cumsum and the dispatch scatter become fully
    shard-local — no cross-device prefix sums, the expert buffers meet the
    tokens in one all-to-all-shaped reshard instead of the baseline's
    replicate-and-repartition storm.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = max(cfg.dispatch_groups, 1)
    assert t % g == 0, (t, g)
    tg = t // g
    cap = max(int(tg * k / e * cfg.capacity_factor), 4)
    xt = x.reshape(g, tg, d)
    xt = shard(xt, ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                    # (g, tg, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its group-local expert queue
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)       # (g, tg, k, e)
    flat = onehot.reshape(g, tg * k, e)
    rank = jnp.cumsum(flat, axis=1) - flat                 # exclusive prefix
    rank = jnp.sum(rank * flat, axis=-1).reshape(g, tg, k)
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)                      # overflow -> pad slot

    # scatter tokens into (g, e, cap+1, d) buffers (pad slot absorbs
    # overflow).  The scatter/gather are vmapped over the group dim so the
    # partitioner sees g as a batch dim and keeps the dispatch shard-local.
    eidx = idx.reshape(g, tg * k)
    sidx = slot.reshape(g, tg * k)
    tokens_rep = jnp.repeat(xt.reshape(g * tg, d), k, axis=0).reshape(g, tg * k, d)

    def scatter_group(xg, eg, sg):
        return jnp.zeros((e, cap + 1, d), x.dtype).at[eg, sg].add(xg)

    buf = jax.vmap(scatter_group)(tokens_rep, eidx, sidx)   # (g, e, cap+1, d)
    buf = shard(buf[:, :, :cap], ("batch", "expert", None, None))

    hg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
    hi = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    hh = shard(hg * hi, ("batch", "expert", None, "expert_mlp"))
    out_buf = jnp.einsum("gecf,efd->gecd", hh, p["wo"])     # (g, e, cap, d)

    def gather_group(ob, eg, sg):
        return ob[eg, jnp.minimum(sg, cap - 1)]

    out_tok = jax.vmap(gather_group)(out_buf, eidx, sidx)   # (g, tg*k, d)
    w = (gate.reshape(g, tg * k) * keep.reshape(g, tg * k)).astype(out_tok.dtype)
    out = jnp.sum((out_tok * w[..., None]).reshape(g, tg, k, d), axis=2)

    out = out.reshape(t, d)
    if cfg.shared_expert:
        out = out + mlp_fwd(p["shared"], x).reshape(t, d)
    return out.reshape(b, s, d)
