"""Snapshot / compaction / reclamation subsystem (DESIGN.md §9) — fast lane.

Covers the host store (chunk-invariant seals, seal-verified transfer), the
ring-overflow door guard on both dataplanes (explicit backpressure with the
boundary instance pinned — the regression test for the historical silent
overwrite-on-wrap), the context lifecycle (snapshot → crash → restore,
ring-wrap vs. an unbounded twin, stitched ``delivered()`` through the
serving tier), and snapshot-seeded group adoption.  The long multi-
generation wrap schedules live in the slow chaos suite
(``test_chaos_schedules.py``).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import PaxosConfig, PaxosContext
from repro.core.api import HardwareDataplane, MultiGroupDataplane
from repro.core.network import FaultSpec
from repro.core.snapshot import GroupSnapshot, RingOverflowError, SnapshotStore

A = 3


def _ctx(n_instances=16, snapshots=True, **kw):
    cfg = PaxosConfig(n_acceptors=A, n_instances=n_instances, batch=8)
    return PaxosContext(cfg, fused=True, snapshots=snapshots, **kw)


def _feed(ctx, lo, hi, group=None):
    for i in range(lo, hi):
        if group is None:
            ctx.submit(f"m{i}".encode())
        else:
            ctx.submit(f"m{i}g{group}".encode(), group=group)
    ctx.run_until_quiescent()


# ---------------------------------------------------------------------------
# FaultSpec validation (satellite: reject nonsense probabilities on entry)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "bad", [{"drop": -0.1}, {"dup": 1.0001}, {"reorder": 17}, {"drop": -1e-9}]
)
def test_faultspec_rejects_out_of_range(bad):
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(**bad)


def test_faultspec_accepts_boundaries():
    FaultSpec(drop=0.0, dup=1.0, reorder=0.5)   # endpoints are legal


# ---------------------------------------------------------------------------
# SnapshotStore: chunk-invariant seals, watermark discipline, sealed transfer
# ---------------------------------------------------------------------------
def test_store_seal_is_chunk_invariant():
    insts = np.arange(12, dtype=np.int32)
    values = np.arange(24, dtype=np.int32).reshape(12, 2)
    one = SnapshotStore()
    one.absorb(0, insts, values, 12)
    two = SnapshotStore()
    two.absorb(0, insts[:5], values[:5], 5)
    two.absorb(0, insts[5:], values[5:], 12)
    assert one.seal(0) == two.seal(0) != 0
    assert one.watermark(0) == two.watermark(0) == 12
    np.testing.assert_array_equal(one.entries(0)[0], two.entries(0)[0])
    np.testing.assert_array_equal(one.entries(0)[1], two.entries(0)[1])


def test_store_watermark_discipline():
    s = SnapshotStore()
    s.absorb(0, np.array([0, 1], np.int32), np.zeros((2, 1), np.int32), 4)
    with pytest.raises(ValueError, match="move back"):
        s.absorb(0, np.zeros((0,), np.int32), np.zeros((0, 1), np.int32), 2)
    with pytest.raises(ValueError, match="ascending"):
        s.absorb(0, np.array([6, 5], np.int32), np.zeros((2, 1), np.int32), 8)
    with pytest.raises(ValueError, match="outside the window"):
        s.absorb(0, np.array([2], np.int32), np.zeros((1, 1), np.int32), 8)
    # gaps are legal: undecided instances below the watermark are holes
    s.absorb(0, np.array([5, 7], np.int32), np.zeros((2, 1), np.int32), 8)
    assert s.watermark(0) == 8


def test_store_seed_verifies_seal():
    src = SnapshotStore()
    src.absorb(0, np.arange(4, dtype=np.int32), np.ones((4, 2), np.int32), 4)
    snap = src.snapshot(0)
    dst = SnapshotStore()
    dst.seed(1, snap, log_prefix=[(0, b"x")])
    assert dst.seal(1) == snap.seal
    assert dst.log_prefix(1) == [(0, b"x")]
    tampered = GroupSnapshot(
        watermark=snap.watermark,
        insts=snap.insts,
        values=snap.values + 1,       # corrupt the transfer
        seal=snap.seal,
    )
    with pytest.raises(ValueError, match="seal mismatch"):
        SnapshotStore().seed(2, tampered)
    with pytest.raises(ValueError, match="already has"):
        dst.seed(1, snap)


# ---------------------------------------------------------------------------
# Ring-overflow door guard (the silent-overwrite regression test)
# ---------------------------------------------------------------------------
def test_overflow_guard_single_dataplane():
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8)
    hw = HardwareDataplane(cfg)
    hw.enable_reclamation()
    vals = np.zeros((8, cfg.value_words), np.int32)
    act = np.ones((8,), np.int32)
    hw.pipeline(vals, act)
    hw.pipeline(vals, act)            # exact fit: instances [0, 16)
    with pytest.raises(RingOverflowError) as ei:
        hw.pipeline(vals, act)
    e = ei.value
    # the boundary instance is pinned: with nothing reclaimed the first
    # un-holdable instance is exactly N
    assert (e.base, e.burst, e.boundary) == (16, 8, 16)
    assert e.attempted == 24
    hw.set_reclaimed(8)               # snapshot advanced the watermark
    hw.pipeline(vals, act)            # [16, 24) now fits
    with pytest.raises(RingOverflowError):
        hw.pipeline(vals, act)        # [24, 32) passes boundary 8 + 16


def test_overflow_guard_multigroup_names_group():
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8, n_groups=2)
    hw = MultiGroupDataplane(cfg)
    hw.enable_reclamation()
    vals = np.zeros((2, 8, cfg.value_words), np.int32)
    act = np.ones((2, 8), np.int32)
    hw.pipeline(vals, act)
    hw.pipeline(vals, act)
    with pytest.raises(RingOverflowError) as ei:
        hw.pipeline(vals, act)
    assert ei.value.group == 0
    assert ei.value.boundary == 16
    hw.set_reclaimed(0, 16)           # group 0 drained, group 1 not
    with pytest.raises(RingOverflowError) as ei:
        hw.pipeline(vals, act)
    assert ei.value.group == 1
    hw.set_reclaimed(1, 16)
    hw.pipeline(vals, act)


def test_set_reclaimed_validates_window():
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8)
    hw = HardwareDataplane(cfg)
    hw.enable_reclamation()
    with pytest.raises(ValueError):
        hw.set_reclaimed(4)           # beyond the sequencer watermark
    vals = np.zeros((8, cfg.value_words), np.int32)
    hw.pipeline(vals, np.ones((8,), np.int32))
    hw.set_reclaimed(8)
    with pytest.raises(ValueError):
        hw.set_reclaimed(4)           # watermark may not move back


# ---------------------------------------------------------------------------
# Context lifecycle: wrap vs unbounded twin, crash/restore, stitching
# ---------------------------------------------------------------------------
def test_wrap_smoke_matches_unbounded_twin():
    """Three ring generations with periodic snapshots deliver the same
    stitched log as a twin whose ring never wraps (the unbounded oracle),
    and equal watermarks give equal seals."""
    ctx = _ctx(n_instances=16)
    twin = _ctx(n_instances=256)      # never wraps
    for wave in range(6):
        lo, hi = wave * 8, wave * 8 + 8
        _feed(ctx, lo, hi)
        _feed(twin, lo, hi)
        ctx.snapshot_group()          # drain every generation boundary
        twin.snapshot_group()
    assert ctx.hw._next_inst_host == 48 > 2 * 16
    assert ctx.full_group_log() == twin.full_group_log()
    assert [p for _i, p in ctx.full_group_log()] == [
        f"m{i}".encode() for i in range(48)
    ]
    assert ctx.snapshots.seal(0) == twin.snapshots.seal(0) != 0


def test_unsnapshotted_wrap_is_refused_at_the_door():
    ctx = _ctx(n_instances=16)
    _feed(ctx, 0, 16)
    ctx.submit(b"overflow")
    with pytest.raises(RingOverflowError):
        ctx.pump()
    ctx.snapshot_group()              # drain → the same submit now lands
    ctx.run_until_quiescent()
    assert [p for _i, p in ctx.full_group_log()][-1] == b"overflow"


def test_crash_restore_acceptor_single():
    """Crash WITH state loss mid-run; restore rebuilds from snapshot
    watermark + live ring suffix and the restored member then carries a
    quorum (a different acceptor is killed afterwards)."""
    ctx = _ctx(n_instances=64)
    _feed(ctx, 0, 16)
    ctx.snapshot_group(upto=8)
    ctx.crash_acceptor(2)
    _feed(ctx, 16, 24)                # decided by the surviving quorum
    adopted = ctx.restore_acceptor(2)
    assert adopted == 16              # decided suffix [8, 24)
    ctx.hw.kill_acceptor(0)           # quorum now NEEDS the restored member
    _feed(ctx, 24, 32)
    got = [p for _i, p in ctx.full_group_log()]
    assert got == [f"m{i}".encode() for i in range(32)]


def test_crash_restore_acceptor_grouped():
    cfg = PaxosConfig(n_acceptors=A, n_instances=64, batch=8, n_groups=2)
    ctx = PaxosContext(cfg, snapshots=True)
    _feed(ctx, 0, 8, group=0)
    _feed(ctx, 0, 8, group=1)
    ctx.snapshot_group(1, upto=4)
    ctx.crash_acceptor(1, group=1)
    _feed(ctx, 8, 16, group=1)
    assert ctx.restore_acceptor(1, group=1) == 12   # decided [4, 16)
    ctx.hw.kill_acceptor(1, 0)
    _feed(ctx, 16, 24, group=1)
    got = [p for _i, p in ctx.full_group_log(1)]
    assert got == [f"m{i}g1".encode() for i in range(24)]
    # group 0 never snapshotted: its log is untouched by group 1's lifecycle
    assert [p for _i, p in ctx.full_group_log(0)] == [
        f"m{i}g0".encode() for i in range(8)
    ]


def test_delivered_stitches_through_the_service():
    """ConsensusService.delivered() is compaction-blind: the session's view
    is identical before and after the prefix moves into the store."""
    from repro.serve.engine import ConsensusService

    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8, n_groups=1)
    ctx = PaxosContext(cfg, fused=True, snapshots=True)
    svc = ConsensusService(ctx)
    sess = svc.session("session-0")
    for i in range(16):
        sess.submit(f"v{i}".encode())
    svc.run_until_quiescent()
    before = sess.delivered()
    assert [p for _i, p in before] == [f"v{i}".encode() for i in range(16)]
    ctx.snapshot_group(0)
    assert ctx.group_log[0] == []     # live log fully compacted away
    assert sess.delivered() == before
    for i in range(16, 24):           # ring wraps into reclaimed slots
        sess.submit(f"v{i}".encode())
    svc.run_until_quiescent()
    assert sess.read() == [f"v{i}".encode() for i in range(24)]


def test_adopt_group_bootstraps_from_snapshot():
    """Retire a tenant, move its sealed snapshot + compacted log to a fresh
    slot via ``adopt_group``: the adopted group's stitched history equals
    the original's, and it keeps deciding from the watermark."""
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8, n_groups=2)
    ctx = PaxosContext(cfg, snapshots=True)
    _feed(ctx, 0, 16, group=1)
    snap = ctx.snapshot_group(1)
    prefix = ctx.snapshots.log_prefix(1)
    history = ctx.full_group_log(1)
    assert ctx.retire_group(1) == history    # stitched return at retirement
    gid = ctx.adopt_group(snap, log_prefix=list(prefix))
    assert gid == 1                          # lowest free slot
    assert ctx.full_group_log(gid) == history
    assert ctx.snapshots.seal(gid) == snap.seal
    # the adopted group continues at the watermark: new decisions append
    _feed(ctx, 16, 24, group=gid)
    got = [p for _i, p in ctx.full_group_log(gid)]
    assert got == [f"m{i}g1".encode() for i in range(24)]
    # and its ring is watermark-gated like any other group's
    inst = ctx.hw.next_inst_host[gid]
    assert inst >= snap.watermark


def test_adopt_group_rejects_diverged_snapshot():
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8, n_groups=2)
    ctx = PaxosContext(cfg, snapshots=True)
    _feed(ctx, 0, 8, group=1)
    snap = ctx.snapshot_group(1)
    ctx.retire_group(1)
    bad = GroupSnapshot(
        watermark=snap.watermark,
        insts=snap.insts,
        values=snap.values ^ 1,
        seal=snap.seal,
    )
    with pytest.raises(ValueError, match="seal mismatch"):
        ctx.adopt_group(bad)


def test_snapshots_require_the_fused_wire_path():
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8)
    with pytest.raises(ValueError, match="fused wire path"):
        PaxosContext(cfg, fused=False, snapshots=True)
