"""Replicated log on top of the consensus layer.

The paper's memory-limitation discussion (§3.1): acceptors keep a bounded
instance ring; applications checkpoint and then ``trim`` the log once ``f+1``
learners acknowledge an instance watermark.  This module provides the ordered
log view a state-machine-replication application consumes, gap detection
(feeding ``recover``), and the trim protocol.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable


@dataclasses.dataclass
class LogEntry:
    inst: int
    payload: bytes


class ReplicatedLog:
    """In-order delivery + gap tracking + quorum trim."""

    def __init__(self, n_learners: int = 1, quorum: int = 2):
        self.entries: dict[int, bytes] = {}
        self.apply_watermark = 0          # next instance to apply, in order
        self.trim_watermark = 0           # everything below is trimmed
        self.quorum = quorum
        self._trim_acks: dict[int, set] = {}
        self.applied: list[LogEntry] = []
        self.on_apply: Callable[[int, bytes], None] | None = None

    def offer(self, inst: int, payload: bytes) -> None:
        """A learner delivered (inst, payload)."""
        if inst < self.trim_watermark or inst in self.entries:
            return
        self.entries[inst] = payload
        self._drain()

    def _drain(self) -> None:
        while self.apply_watermark in self.entries:
            inst = self.apply_watermark
            payload = self.entries[inst]
            self.applied.append(LogEntry(inst, payload))
            if self.on_apply:
                self.on_apply(inst, payload)
            self.apply_watermark += 1

    def gaps(self, horizon: int) -> list[int]:
        """Instances < horizon not yet offered — candidates for recover()."""
        return [
            i
            for i in range(self.apply_watermark, horizon)
            if i not in self.entries and i >= self.trim_watermark
        ]

    # -- trim protocol (paper: f+1 learners ack a checkpointed watermark) ----
    def ack_trim(self, learner_id: int, upto: int) -> bool:
        """Record a learner's checkpoint ack; trims once quorum is reached."""
        acks = self._trim_acks.setdefault(upto, set())
        acks.add(learner_id)
        if len(acks) >= self.quorum and upto <= self.apply_watermark:
            self._trim(upto)
            return True
        return False

    def _trim(self, upto: int) -> None:
        for i in range(self.trim_watermark, upto):
            self.entries.pop(i, None)
        self.trim_watermark = max(self.trim_watermark, upto)
        self._trim_acks = {k: v for k, v in self._trim_acks.items() if k > upto}
