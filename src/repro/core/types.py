"""Paxos message / state types, as structure-of-arrays for dataplane batching.

The paper's Paxos header (Fig. 5)::

    struct paxos_t {
      uint8_t msgtype;
      uint8_t inst[INST_SIZE];
      uint8_t rnd;
      uint8_t vrnd;
      uint8_t swid[8];
      uint8_t value[VALUE_SIZE];
    };

On TPU the unit of traffic is a *batch* of headers, stored SoA so each field
is a vector register-friendly array.  ``value`` is a fixed number of 32-bit
words (the paper uses fixed 64B values; we default to 16 words = 64B).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Message types (paper: phase 1A/1B/2A/2B + housekeeping)
# ---------------------------------------------------------------------------
MSG_NOP = 0         # no-op filler slot in a batch
MSG_P1A = 1         # prepare            (coordinator -> acceptor)
MSG_P1B = 2         # promise            (acceptor -> coordinator)
MSG_P2A = 3         # accept request     (coordinator -> acceptor)
MSG_P2B = 4         # vote               (acceptor -> learner/coordinator)
MSG_SUBMIT = 5      # proposer -> coordinator
MSG_DELIVER = 6     # learner decision (synthesized at quorum)
MSG_REJECT = 7      # acceptor NACK (promised higher round)

# Default sizing (paper: 65,535 instances in BRAM, 64B values).
DEFAULT_INSTANCES = 1 << 16
DEFAULT_VALUE_WORDS = 16  # 16 x int32 = 64 bytes

NO_ROUND = jnp.int32(-1)


@dataclasses.dataclass(frozen=True)
class PaxosConfig:
    """Static protocol configuration."""

    n_acceptors: int = 3              # 2f+1
    n_instances: int = DEFAULT_INSTANCES
    value_words: int = DEFAULT_VALUE_WORDS
    batch: int = 128                  # dataplane batch ("packets per burst")
    n_groups: int = 1                 # device-resident Paxos groups (G)
    # consecutive fragmented rounds (enabled groups spread over >1 watermark
    # class) after which the dispatch planner burns divergent groups forward
    # to a common block boundary so the full-width fold re-engages
    # (DESIGN.md §8).  None = never realign: instance numbering then stays
    # bit-identical to independent per-group deployments.
    realign_after: "int | None" = None
    # Persistent-wave depth cap (DESIGN.md §11): a cohort with K full
    # batch-sized chunks queued for every member runs up to K Phase-2
    # rounds in ONE device dispatch, syncing results back once per wave.
    # 1 = every round is its own dispatch (the pre-§11 behavior).  Delivery
    # and numbering are bit-identical either way; only dispatch_count and
    # latency differ.
    persistent_rounds: int = 8
    # Double-buffered pump (DESIGN.md §11): plan and pack wave N+1 on host
    # while wave N executes, deferring each wave's host read-back by one
    # wave.  pump() stays externally synchronous (all waves resolved before
    # it returns) and delivery order is unchanged.
    async_pump: bool = True

    @property
    def f(self) -> int:
        return (self.n_acceptors - 1) // 2

    @property
    def quorum(self) -> int:
        return self.f + 1

    @property
    def max_payload_bytes(self) -> int:
        """Widest application payload one consensus value can carry: the
        ``value_words * 4``-byte value minus the 8-byte (seq, len) framing
        header ``PaxosContext`` packs in front of every payload."""
        return self.value_words * 4 - 8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MsgBatch:
    """A batch of Paxos headers, structure-of-arrays.

    Shapes: all fields ``[B]`` except ``value`` which is ``[B, V]``.

    ``gid`` is the consensus-group id the batch belongs to when the dataplane
    serves multiple device-resident groups (the multi-group analogue of the
    paper's single switch pipeline serving one group).  ``None`` — the
    default, and the only value on the single-group fast path — means "group
    0 / untagged"; group routing happens before batching, so a batch is
    always homogeneous and one scalar-per-batch id suffices.
    """

    msgtype: jax.Array   # int32[B]
    inst: jax.Array      # int32[B]
    rnd: jax.Array       # int32[B]
    vrnd: jax.Array      # int32[B]
    swid: jax.Array      # int32[B]  sender id
    value: jax.Array     # int32[B, V]
    gid: Any = None      # optional scalar int32: consensus group id

    def tree_flatten(self) -> tuple[tuple[Any, ...], None]:
        return (
            (self.msgtype, self.inst, self.rnd, self.vrnd, self.swid,
             self.value, self.gid),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux: None, children: tuple[Any, ...]) -> "MsgBatch":
        return cls(*children)

    @property
    def batch(self) -> int:
        return self.msgtype.shape[0]

    @classmethod
    def nop(cls, batch: int, value_words: int = DEFAULT_VALUE_WORDS) -> "MsgBatch":
        z = jnp.zeros((batch,), jnp.int32)
        return cls(
            msgtype=z,
            inst=z,
            rnd=jnp.full((batch,), NO_ROUND, jnp.int32),
            vrnd=jnp.full((batch,), NO_ROUND, jnp.int32),
            swid=z,
            value=jnp.zeros((batch, value_words), jnp.int32),
        )

    def replace(self, **kw: Any) -> "MsgBatch":
        return dataclasses.replace(self, **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AcceptorState:
    """The acceptor's bounded instance history — the paper's BRAM register file.

    ``inst`` maps onto slot ``inst % n_instances`` (a ring).  ``rnd`` is the
    promised round, ``vrnd`` the round of the vote cast (-1 = none), ``value``
    the voted value.  Under the single-coordinator (multi-Paxos) optimization
    the state is pre-initialized to round 0 promises, eliding Phase 1.
    """

    rnd: jax.Array    # int32[N]
    vrnd: jax.Array   # int32[N]
    value: jax.Array  # int32[N, V]

    def tree_flatten(self) -> tuple[tuple[jax.Array, ...], None]:
        return ((self.rnd, self.vrnd, self.value), None)

    @classmethod
    def tree_unflatten(
        cls, aux: None, children: tuple[jax.Array, ...]
    ) -> "AcceptorState":
        return cls(*children)

    @property
    def n_instances(self) -> int:
        return self.rnd.shape[0]

    @classmethod
    def init(
        cls,
        n_instances: int = DEFAULT_INSTANCES,
        value_words: int = DEFAULT_VALUE_WORDS,
    ) -> "AcceptorState":
        return cls(
            rnd=jnp.zeros((n_instances,), jnp.int32),
            vrnd=jnp.full((n_instances,), NO_ROUND, jnp.int32),
            value=jnp.zeros((n_instances, value_words), jnp.int32),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CoordinatorState:
    """Coordinator sequencer state: next instance + current round."""

    next_inst: jax.Array  # int32[]    monotonically increasing sequence number
    crnd: jax.Array       # int32[]    the coordinator's round

    def tree_flatten(self) -> tuple[tuple[jax.Array, ...], None]:
        return ((self.next_inst, self.crnd), None)

    @classmethod
    def tree_unflatten(
        cls, aux: None, children: tuple[jax.Array, ...]
    ) -> "CoordinatorState":
        return cls(*children)

    @classmethod
    def init(cls, crnd: int = 0, next_inst: int = 0) -> "CoordinatorState":
        return cls(next_inst=jnp.int32(next_inst), crnd=jnp.int32(crnd))


def encode_value(payload: bytes, value_words: int = DEFAULT_VALUE_WORDS) -> np.ndarray:
    """Pack an application byte buffer into int32 value words (host side)."""
    nbytes = value_words * 4
    if len(payload) > nbytes:
        raise ValueError(f"value too large: {len(payload)} > {nbytes}")
    buf = payload.ljust(nbytes, b"\x00")
    return np.frombuffer(buf, dtype="<i4").copy()


def decode_value(words: np.ndarray) -> bytes:
    """Unpack int32 value words back to a byte buffer (host side)."""
    return np.asarray(words, dtype="<i4").tobytes()
