"""Groups-sharded dataplane parity (DESIGN.md §6).

The contract under test: ``ShardedMultiGroupDataplane`` — the multi-group
wire path with its ``(G, A, N)`` slabs partitioned over a ``groups`` mesh
axis via ``shard_map`` — is *bit-identical* to the single-device
``MultiGroupDataplane`` and to G independent scalar ``core.paxos`` oracles,
on both the jnp and Pallas-kernel backends, through frozen groups, dead
acceptors, and ring wraparound.  On the in-process host mesh (1 CPU device)
that pins the degenerate reduction; ``test_sharded_multidevice`` re-runs
the parity on a real 8-shard mesh in a subprocess, with the frozen group
and the dead acceptor living on *distinct shards*.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import (
    MultiGroupDataplane,
    PaxosConfig,
    PaxosContext,
    ShardedMultiGroupDataplane,
)
from repro.core.paxos import Acceptor, Coordinator, Learner, Msg
from repro.core.types import MSG_P2A, MSG_P2B
from repro.launch.mesh import make_group_mesh

N_DEV = len(jax.devices())


class _ScalarGroup:
    """One group's scalar-oracle mirror of the fused Phase-2 round
    (sequencing, per-acceptor votes, learner quorum — unmodified
    ``core.paxos`` roles)."""

    def __init__(self, n_acceptors: int, n_instances: int):
        self.co = Coordinator(cid=0, n_instances=n_instances)
        self.acceptors = [
            Acceptor(aid=i, n_instances=n_instances) for i in range(n_acceptors)
        ]
        self.learner = Learner(lid=0, n_acceptors=n_acceptors)

    def round(self, values: np.ndarray, alive) -> list:
        decided = []
        for j in range(values.shape[0]):
            p2a = self.co.on_submit(Msg(5, value=values[j]))
            d = None
            for aid, acc in enumerate(self.acceptors):
                if not alive[aid]:
                    continue
                out = acc.on_p2a(
                    Msg(MSG_P2A, inst=p2a.inst, rnd=p2a.rnd, value=values[j])
                )
                if out.msgtype == MSG_P2B:
                    got = self.learner.on_p2b(
                        Msg(MSG_P2B, inst=out.inst, rnd=out.rnd,
                            vrnd=out.vrnd, swid=aid, value=out.value)
                    )
                    if got is not None:
                        d = got
            decided.append(d)
        return decided


def _state_leaves(hw):
    return [
        np.asarray(x)
        for x in jax.tree_util.tree_leaves((hw.stack, hw.lstate))
    ]


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("mult", [1, 2])
def test_sharded_matches_unsharded_and_scalar_oracle(mult, use_kernels):
    """Sharded == unsharded == G scalar oracles, bit for bit, through a
    frozen group, a dead acceptor, and a full ring wrap."""
    g = N_DEV * mult
    cfg = PaxosConfig(n_acceptors=3, n_instances=128, batch=16, n_groups=g)
    mg = MultiGroupDataplane(cfg, use_kernels=use_kernels)
    sh = ShardedMultiGroupDataplane(
        cfg, mesh=make_group_mesh(), use_kernels=use_kernels
    )
    oracles = [_ScalarGroup(cfg.n_acceptors, cfg.n_instances) for _ in range(g)]
    alive = np.ones((g, cfg.n_acceptors), bool)
    if g > 1:
        mg.kill_acceptor(g - 1, 2)
        sh.kill_acceptor(g - 1, 2)
        alive[g - 1, 2] = False
    rng = np.random.default_rng(7)
    frozen = None
    rounds = 2 * cfg.n_instances // cfg.batch + 2   # wraps the ring twice
    for r in range(rounds):
        if g > 1 and r == 2:
            frozen = 0
            mg.freeze_group(frozen)
            sh.freeze_group(frozen)
        if frozen is not None and r == rounds - 3:
            back = sh.next_inst_host[frozen]
            mg.restore_group(frozen, back, 0)
            sh.restore_group(frozen, back, 0)
            frozen = None
        vals = rng.integers(-99, 99, (g, cfg.batch, cfg.value_words))
        vals = vals.astype(np.int32)
        act = np.ones((g, cfg.batch), bool)
        fresh_a, inst_a, val_a = mg.pipeline(vals, act)
        fresh_b, inst_b, val_b = sh.pipeline(vals, act)
        np.testing.assert_array_equal(fresh_a, fresh_b)
        np.testing.assert_array_equal(inst_a, inst_b)
        np.testing.assert_array_equal(val_a, val_b)
        for gid in range(g):
            if gid == frozen:
                assert not fresh_b[gid].any()   # inert: decides nothing
                continue
            decided = oracles[gid].round(vals[gid], alive[gid])
            for j, d in enumerate(decided):
                assert (d is not None) == bool(fresh_b[gid, j]), (gid, j)
                if d is not None:
                    assert d.inst == inst_b[gid, j]
                    np.testing.assert_array_equal(d.value, val_b[gid, j])
    for a, b in zip(_state_leaves(mg), _state_leaves(sh), strict=True):
        np.testing.assert_array_equal(a, b)
    # final register files agree with the scalar acceptors, per group
    h_rnd, h_vrnd = np.asarray(sh.stack.rnd), np.asarray(sh.stack.vrnd)
    for gid, oracle in enumerate(oracles):
        for aid, acc in enumerate(oracle.acceptors):
            for slot, (rnd, vrnd, _val) in acc.slots.items():
                assert h_rnd[gid, aid, slot] == rnd, (gid, aid, slot)
                assert h_vrnd[gid, aid, slot] == vrnd, (gid, aid, slot)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_sharded_context_parity_with_failover(use_kernels):
    """A sharded context == the unsharded multi-group context (logs AND
    device registers) == G independent single-group contexts (logs), through
    a per-group coordinator failover and a dead acceptor elsewhere."""
    g = max(2, 2 * N_DEV)
    cfg = PaxosConfig(n_acceptors=3, n_instances=512, batch=16, n_groups=g)
    cfg1 = PaxosConfig(n_acceptors=3, n_instances=512, batch=16)
    mg = PaxosContext(cfg, use_kernels=use_kernels)
    sh = PaxosContext(cfg, use_kernels=use_kernels, mesh=make_group_mesh())
    singles = [
        PaxosContext(cfg1, use_kernels=use_kernels, fused=True)
        for _ in range(g)
    ]
    victim, casualty = 1, g - 1
    for ctx in (mg, sh):
        ctx.hw.kill_acceptor(casualty, 0)
    singles[casualty].hw.kill_acceptor(0)

    def wave(w):
        for gid in range(g):
            p = f"w{w}g{gid}".encode()
            mg.submit(p, group=gid)
            sh.submit(p, group=gid)
            singles[gid].submit(p)
        for ctx in (mg, sh, *singles):
            ctx.run_until_quiescent()

    for w in range(2):
        wave(w)
    mg.fail_coordinator(group=victim)
    sh.fail_coordinator(group=victim)
    singles[victim].fail_coordinator()
    for w in range(2, 4):
        wave(w)
    mg.restore_hardware_coordinator(group=victim)
    sh.restore_hardware_coordinator(group=victim)
    singles[victim].restore_hardware_coordinator()
    for w in range(4, 6):
        wave(w)

    assert sh.group_log == mg.group_log
    for gid in range(g):
        assert sh.group_log[gid] == singles[gid].delivered_log, gid
    for a, b in zip(_state_leaves(mg.hw), _state_leaves(sh.hw), strict=True):
        np.testing.assert_array_equal(a, b)
    assert all(len(log) == 6 for log in sh.group_log)


def test_placement_and_validation():
    cfg = PaxosConfig(n_acceptors=3, n_instances=128, batch=16, n_groups=4)
    sh = ShardedMultiGroupDataplane(cfg, mesh=make_group_mesh())
    gl = 4 // sh.n_shards
    assert sh.group_placement() == [gid // gl for gid in range(4)]
    assert [sh.shard_of_group(gid) for gid in range(4)] == sh.group_placement()
    with pytest.raises(ValueError):
        sh.shard_of_group(4)
    # G must tile the mesh axis exactly
    mesh = make_group_mesh()
    bad = PaxosConfig(n_groups=3 * mesh.shape["groups"] + 1)
    if bad.n_groups % mesh.shape["groups"]:
        with pytest.raises(ValueError):
            ShardedMultiGroupDataplane(bad, mesh=mesh)
    # a mesh without a groups axis is rejected
    with pytest.raises(ValueError):
        ShardedMultiGroupDataplane(cfg, mesh=jax.make_mesh((1,), ("data",)))


def test_sharded_g1_context_serves():
    """A sharded single-group context engages the group-keyed surface."""
    ctx = PaxosContext(
        PaxosConfig(n_acceptors=3, n_instances=128, batch=16),
        mesh=make_group_mesh(),
    )
    assert isinstance(ctx.hw, ShardedMultiGroupDataplane)
    for k in range(5):
        ctx.submit(f"x{k}".encode())
    ctx.run_until_quiescent()
    assert [p for _i, p in ctx.group_log[0]] == [
        f"x{k}".encode() for k in range(5)
    ]


def _run(code: str, devices: int = 8) -> str:
    env_code = (
        f"import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"\n'
    )
    proc = subprocess.run(
        [sys.executable, "-c", env_code + textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


@pytest.mark.slow
def test_sharded_multidevice():
    """8-shard mesh: G ∈ {8, 16} sharded == single-device unsharded, with
    the frozen group and the dead acceptor on distinct shards."""
    out = _run(
        """
        import numpy as np, jax
        from repro.core import MultiGroupDataplane, PaxosConfig, \\
            ShardedMultiGroupDataplane
        from repro.launch.mesh import make_group_mesh

        assert len(jax.devices()) == 8
        for use_k, g in ((False, 8), (False, 16), (True, 8)):
            cfg = PaxosConfig(n_acceptors=3, n_instances=128, batch=16,
                              n_groups=g)
            mg = MultiGroupDataplane(cfg, use_kernels=use_k)
            sh = ShardedMultiGroupDataplane(cfg, mesh=make_group_mesh(),
                                            use_kernels=use_k)
            assert sh.n_shards == 8
            frozen, casualty = 2, g - 1
            assert sh.shard_of_group(frozen) != sh.shard_of_group(casualty)
            rng = np.random.default_rng(3)
            mg.kill_acceptor(casualty, 1); sh.kill_acceptor(casualty, 1)
            mg.freeze_group(frozen); sh.freeze_group(frozen)
            for r in range(3):
                vals = rng.integers(-50, 50, (g, 16, cfg.value_words))
                vals = vals.astype(np.int32)
                act = np.ones((g, 16), bool)
                for x, y in zip(mg.pipeline(vals, act),
                                sh.pipeline(vals, act)):
                    np.testing.assert_array_equal(x, y)
            mg.restore_group(frozen, 0, 1); sh.restore_group(frozen, 0, 1)
            vals = rng.integers(-50, 50, (g, 16, cfg.value_words))
            vals = vals.astype(np.int32)
            act = np.ones((g, 16), bool)
            for x, y in zip(mg.pipeline(vals, act), sh.pipeline(vals, act)):
                np.testing.assert_array_equal(x, y)
            for x, y in zip(
                jax.tree_util.tree_leaves((mg.stack, mg.lstate)),
                jax.tree_util.tree_leaves((sh.stack, sh.lstate)),
            ):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            print("OK", use_k, g)
        print("SHARDED_OK")
        """
    )
    assert "SHARDED_OK" in out
