"""Paper Fig. 2: per-role processing share in the software baseline.

The paper measures CPU utilization per Paxos role at peak throughput and
finds coordinator ~100%, acceptors scaling with replication.  We reproduce
the *shape* of that result with per-role busy-time shares in the
libpaxos-like software deployment, including the learner-scaling sweep
(Fig. 2b): acceptor work grows with the number of learners (one vote fan-out
per learner), learner share falls.
"""
from __future__ import annotations

from repro.core import PaxosConfig, SoftwarePaxos

from .common import emit


def run() -> None:
    cfg = PaxosConfig(n_acceptors=3, n_instances=4096, batch=32)

    for n_learners in (1, 2, 4, 8):
        sw = SoftwarePaxos(cfg, n_learners=n_learners)
        n = 2000
        for k in range(n):
            sw.submit(b"x" * 32)
            if k % 64 == 0:
                sw.pump()
        sw.run_until_quiescent(max_rounds=500)
        total = sum(sw.busy.values()) or 1e-12
        shares = {r: sw.busy[r] / total for r in ("proposer", "coordinator",
                                                  "acceptor", "learner")}
        us_coord = sw.busy["coordinator"] / n * 1e6
        emit(
            f"fig2/software_roles/learners={n_learners}",
            us_coord,
            f"shares coord={shares['coordinator']:.2f} "
            f"acc={shares['acceptor']:.2f} "
            f"learn={shares['learner']:.2f} prop={shares['proposer']:.2f}",
        )
