"""Quickstart: the drop-in CAANS API (paper Fig. 4) in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

An application that wants replicated, totally-ordered operations:
  1. builds a PaxosContext (the consensus service),
  2. registers a deliver callback,
  3. calls submit() — exactly the libpaxos API the paper preserves.
The coordinator/acceptor dataplane runs as one compiled JAX program (the
"network hardware"); on a TPU deployment the same code runs on the ICI
fabric via core.fabric.
"""
import sys

sys.path.insert(0, "src")

from repro.core import PaxosConfig, PaxosContext


def main() -> None:
    decided = []

    def deliver(value: bytes, size: int, instance: int) -> None:
        """Application callback: called exactly once per decided instance."""
        decided.append((instance, value))
        print(f"  deliver(inst={instance}): {value!r}")

    ctx = PaxosContext(
        PaxosConfig(n_acceptors=3, n_instances=4096, batch=16),
        deliver=deliver,
        fused=True,          # whole Phase-2 round in one compiled dispatch
    )

    print("submitting 5 commands...")
    for i in range(5):
        ctx.submit(f"command-{i}".encode())
    ctx.run_until_quiescent()

    print("\nkilling acceptor 2 (f=1 of 2f+1=3 may fail)...")
    ctx.hw.kill_acceptor(2)
    ctx.submit(b"still-works")
    ctx.run_until_quiescent()

    print("\nhardware coordinator fails -> software takeover (paper §6.4)...")
    ctx.fail_coordinator()
    ctx.submit(b"after-failover")
    ctx.run_until_quiescent()

    assert [v for _, v in decided] == [
        b"command-0", b"command-1", b"command-2", b"command-3", b"command-4",
        b"still-works", b"after-failover",
    ]
    insts = [i for i, _ in decided]
    assert len(insts) == len(set(insts)), "agreement: one value per instance"
    print(f"\nOK: {len(decided)} values decided in order, none lost.")


if __name__ == "__main__":
    main()
