"""The software baseline — a "libpaxos-like" all-software deployment.

The paper benchmarks CAANS against libpaxos (Fig. 2, Fig. 7): every role runs
as a software process exchanging UDP messages.  Here, every role runs as a
scalar-Python state machine (``core.paxos``) exchanging messages over the same
``SimNet``.  Per-role processing time is instrumented so the benchmark suite
can reproduce the paper's CPU-utilization plots (coordinator/acceptor as the
software bottleneck) and the end-to-end comparison.
"""
from __future__ import annotations

import time
from collections import defaultdict
from collections.abc import Callable

from .network import SimNet
from .paxos import Acceptor, Coordinator, Learner, Proposer
from .types import MSG_P1B, MSG_P2A, MSG_P2B, MSG_SUBMIT, PaxosConfig


class SoftwarePaxos:
    """A full software deployment: 1 proposer, 1 coordinator, 2f+1 acceptors,
    n learners, wired through SimNet.  The comparison baseline."""

    def __init__(
        self,
        cfg: PaxosConfig | None = None,
        deliver: Callable[[bytes, int, int], None] | None = None,
        net: SimNet | None = None,
        n_learners: int = 1,
    ):
        self.cfg = cfg or PaxosConfig()
        self.net = net or SimNet()
        self.proposer = Proposer(pid=0)
        self.coordinator = Coordinator(cid=0, n_instances=self.cfg.n_instances)
        self.acceptors = [
            Acceptor(aid=i, n_instances=self.cfg.n_instances)
            for i in range(self.cfg.n_acceptors)
        ]
        self.alive = [True] * self.cfg.n_acceptors
        self.deliver_cb = deliver
        self.learners = [
            Learner(lid=i, n_acceptors=self.cfg.n_acceptors)
            for i in range(n_learners)
        ]
        self.learners[0].deliver_cb = self._on_deliver
        self.delivered: list[tuple[int, bytes]] = []
        # per-role busy seconds — reproduces the paper's Fig. 2 methodology
        self.busy: dict[str, float] = defaultdict(float)

    def _on_deliver(self, inst: int, value: bytes) -> None:
        self.delivered.append((inst, value))
        if self.deliver_cb:
            self.deliver_cb(value, len(value), inst)

    # -- API ------------------------------------------------------------------
    def submit(self, payload: bytes) -> None:
        t0 = time.perf_counter()
        msg = self.proposer.submit(payload)
        self.busy["proposer"] += time.perf_counter() - t0
        self.net.send("coordinator", msg)

    def pump(self, rounds: int = 1) -> None:
        for _ in range(rounds):
            self._pump_coordinator()
            self._pump_acceptors()
            self._pump_learners()

    def run_until_quiescent(self, max_rounds: int = 64) -> None:
        for _ in range(max_rounds):
            if self.net.pending() == 0:
                return
            self.pump()

    # -- role pumps ------------------------------------------------------------
    def _pump_coordinator(self) -> None:
        for msg in self.net.recv_all("coordinator"):
            t0 = time.perf_counter()
            out = None
            if msg.msgtype == MSG_SUBMIT:
                out = self.coordinator.on_submit(msg)
            elif msg.msgtype == MSG_P1B:
                out = self.coordinator.on_p1b(msg, self.cfg.quorum)
            self.busy["coordinator"] += time.perf_counter() - t0
            if out is not None and out.msgtype == MSG_P2A:
                for aid in range(self.cfg.n_acceptors):
                    self.net.send(("acceptor", aid), out)

    def _pump_acceptors(self) -> None:
        for aid, acc in enumerate(self.acceptors):
            msgs = self.net.recv_all(("acceptor", aid))
            if not self.alive[aid]:
                continue
            for msg in msgs:
                t0 = time.perf_counter()
                if msg.msgtype == MSG_P2A:
                    out = acc.on_p2a(msg)
                else:
                    out = acc.on_p1a(msg)
                self.busy["acceptor"] += time.perf_counter() - t0
                if out.msgtype == MSG_P2B:
                    for lid in range(len(self.learners)):
                        self.net.send(("learner", lid), out)
                elif out.msgtype == MSG_P1B:
                    self.net.send("coordinator", out)

    def _pump_learners(self) -> None:
        for lid, ln in enumerate(self.learners):
            for msg in self.net.recv_all(("learner", lid)):
                t0 = time.perf_counter()
                ln.on_p2b(msg)
                self.busy["learner"] += time.perf_counter() - t0

    # -- fault injection ---------------------------------------------------------
    def kill_acceptor(self, aid: int) -> None:
        self.alive[aid] = False

    def revive_acceptor(self, aid: int) -> None:
        self.alive[aid] = True
