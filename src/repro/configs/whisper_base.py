"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec,
conv frontend (stub: input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,              # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    head_dim=64,
    src_len=1500,            # 30 s of 10 ms frames after conv stride 2 (stub)
)
