"""Pallas TPU megakernel: the fused CAANS wire path, for G resident groups.

One ``pallas_call`` executes a *complete* Phase-2 round — coordinator
sequencing, the Phase-2 vote of all ``A = 2f+1`` acceptors against the
stacked instance rings, the learner quorum count, and the ``LearnerState``
ring-dedup update — for **G independent Paxos groups at once**.  This is the
TPU analogue of the paper's core claim (a consensus round costs barely more
than forwarding the packets) combined with NetChain's scale-free observation:
a device pipeline serves *many* replicated groups as one shared service
(PAPER.md; DESIGN.md §3, §5).

Layout (DESIGN.md §5):

    grid = (G // GB, B // BB)       # group axis x batch axis
    stacked rings  (G, A, N)[, V]   --BlockSpec (GB, A, BB)-->  VMEM, in-place
    learner rings  (G, N)[, V]      --BlockSpec (GB, BB)  -->   VMEM, in-place
    burst values   (G, B, V)        --BlockSpec (GB, BB, V)-->  VMEM
    fresh/win/value outputs         <--                         VMEM

Groups never interact: each has its own coordinator watermark/round (the
``next_inst``/``crnd`` scalar-prefetch vectors are per-group), its own
acceptor rings, its own learner ring, and its own liveness row in the
``(G, A)`` alive mask.  The quorum reduction runs down the acceptor axis
*within* each group block.

``group_block`` picks the group→grid mapping:

  * ``group_block=1`` (default): one group per grid step, each group's ring
    window derived from its own watermark — fully general, including groups
    whose watermarks diverged after a per-group coordinator failover.
  * ``group_block=GB>1``: GB groups ride the leading block dimension of a
    single grid step (the batch analogue of the acceptor-in-block decision).
    Requires the GB groups of a block to share one BB-aligned watermark
    ("lockstep"), since a block has a single ring offset.  This is the
    highest-amortization mapping for the common case of a service pumping
    all groups together.

Invariants (maintained by ``core.api.MultiGroupDataplane``, asserted where
shapes are static): ``BB | B``, ``BB | N``, ``B <= N``, ``GB | G``, and every
*enabled* group's window base is BB-aligned.  Liveness is a *runtime* input —
the ``(G, A)`` alive mask rides in scalar-prefetch SMEM, so killing/reviving
an acceptor in any group never recompiles the kernel.

**Enabled mask (dynamic membership, DESIGN.md §7).**  ``enabled`` marks which
groups advance this round; a disabled group — frozen under a software
coordinator, vacant (retired from the free-list), or simply idle — rides
along *inert*: its round is presented as NO_ROUND (acceptors reject every
slot) and, under ``group_block > 1``, its watermark is substituted with the
block's enabled-lockstep base so a folded block keeps a single well-defined
ring offset even when disabled members' watermarks diverged.  The disabled
group's ring windows are loaded and stored back bit-unchanged, so folding
over vacant slots is state-exact.

**Cohort selection (DESIGN.md §8).**  ``cohort_wirepath_round`` is the
general entry: a ``gsel`` scalar-prefetch vector names which GB-aligned
group blocks the grid visits, so a dispatch costs what its cohort costs —
the group-axis analogue of the ring blocking.  Unselected groups' slabs
are never loaded; their rows of the aliased state outputs retain the input
data, exactly like unvisited ring blocks along the batch axis.  Each
selected block derives its ring offset from its own (substituted)
watermark base, which is what lets cohorts that diverged after per-group
failovers fold block-wise instead of collapsing to ``group_block = 1``.
``multigroup_wirepath_round`` is its every-block-selected slice; the host
side of the policy (burst tiers, fold widths, block selection) lives in
``core.plan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import MSG_NOP, MSG_P2A, MSG_P2B, MSG_REJECT

NO_ROUND = -1

# Messages per grid step; 128 is the int32 lane width.
DEFAULT_BLOCK_B = 128


def _lane_iota(bb: int) -> jax.Array:
    # 1-D iota via 2-D broadcasted_iota (TPU requires >= 2D iota)
    return jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)[:, 0]


def _alive_col(alive_ref, a: int) -> jax.Array:
    # scalar-prefetch liveness -> (A, 1) vector mask (A is static)
    return jnp.stack([alive_ref[i] for i in range(a)])[:, None] != 0


# ---------------------------------------------------------------------------
# The fused multi-group round megakernel
# ---------------------------------------------------------------------------
def _phase2_block(
    inst,       # int32[GB, BB]  absolute instance numbers of this window
    crnd_g,     # int32[GB]      per-group coordinator round (NO_ROUND = inert)
    alive,      # bool[GB, A]
    lim_g,      # int32[GB]      per-group reclaim limit (first refused inst)
    quorum,     # int32[]
    mval,       # int32[GB, BB, V]  burst values
    cur_rnd,    # int32[GB, A, BB]  acceptor ring blocks
    cur_vrnd,   # int32[GB, A, BB]
    cur_val,    # int32[GB, A, BB, V]
    ldel,       # int32[GB, BB]     learner ring blocks
    linst,      # int32[GB, BB]
    lval,       # int32[GB, BB, V]
):
    """One Phase-2 round over one ``(GB, BB)`` window: sequence -> all-
    acceptor vote -> learner quorum -> ring dedup, as a pure function of the
    loaded blocks.  Shared by the single-round and persistent kernel bodies
    (identical arithmetic is what makes the K-round entry bit-exact against
    K single rounds by construction).  Returns
    ``(o_rnd, o_vrnd, o_val, o_ldel, o_linst, o_lval, fresh, win, value)``.
    """
    crnd = crnd_g[:, None, None]                                   # (GB, 1, 1)

    # Reclamation permit (DESIGN.md §9): a lane at or past the group's
    # reclaim limit (snapshot watermark + N) would land in a ring slot whose
    # decision has not been drained yet — acceptors refuse it wholesale, so
    # the slot survives bit-unchanged and the host sees backpressure instead
    # of a silent dedup-state overwrite.
    permit = inst < lim_g[:, None]                                 # (GB, BB)

    # -- every group's acceptor array votes (Phase 2A -> 2B), all at once ----
    accept = (
        alive[:, :, None] & (crnd >= cur_rnd) & permit[:, None, :]
    )                                                              # (GB, A, BB)

    o_rnd = jnp.where(accept, crnd, cur_rnd)
    o_vrnd = jnp.where(accept, crnd, cur_vrnd)
    o_val = jnp.where(accept[..., None], mval[:, None], cur_val)

    # -- learner quorum: reduce down the acceptor axis, per group ------------
    vote_vrnd = jnp.where(accept, crnd, NO_ROUND)                  # (GB, A, BB)
    win = jnp.max(vote_vrnd, axis=1)                               # (GB, BB)
    agree = accept & (vote_vrnd == win[:, None, :])                # (GB, A, BB)
    count = jnp.sum(agree.astype(jnp.int32), axis=1)               # (GB, BB)
    deliver = count >= quorum
    # decided value: first agreeing acceptor's vote, as a one-hot contraction
    first = agree & (jnp.cumsum(agree.astype(jnp.int32), axis=1) == 1)
    vote_val = jnp.where(accept[..., None], mval[:, None], 0)      # (GB,A,BB,V)
    value = jnp.sum(first.astype(jnp.int32)[..., None] * vote_val, axis=1)

    # -- ring dedup (LearnerState), in place, per group ----------------------
    dup = (ldel != 0) & (linst == inst)
    fresh = deliver & ~dup
    o_ldel = ldel | deliver.astype(jnp.int32)
    o_linst = jnp.where(fresh, inst, linst)
    o_lval = jnp.where(fresh[..., None], value, lval)
    return (
        o_rnd, o_vrnd, o_val, o_ldel, o_linst, o_lval,
        fresh.astype(jnp.int32), win, value,
    )


def _mg_wirepath_kernel(
    # scalar prefetch (SMEM) — consumed by the index maps; the kernel body
    # reads the same per-group values from the VMEM mirrors below, as vector
    # loads instead of G*A scalar gathers (the per-group marginal cost)
    ni_ref,         # int32[G]     per-group window base, BB-aligned
    crnd_ref,       # int32[G]     per-group coordinator round
    q_ref,          # int32[1]     quorum (f+1)
    alive_ref,      # int32[G, A]  per-group runtime liveness mask
    lim_ref,        # int32[G]     per-group reclaim limit (first refused inst)
    # inputs (VMEM tiles)
    values_ref,     # int32[GB, BB, V]     burst values
    st_rnd_ref,     # int32[GB, A, BB]     acceptor ring blocks (aliased out)
    st_vrnd_ref,    # int32[GB, A, BB]
    st_val_ref,     # int32[GB, A, BB, V]
    ldel_ref,       # int32[GB, BB]        learner ring blocks (aliased out)
    linst_ref,      # int32[GB, BB]
    lval_ref,       # int32[GB, BB, V]
    niv_ref,        # int32[GB]     VMEM mirror of ni_ref's block
    crndv_ref,      # int32[GB]     VMEM mirror of crnd_ref's block
    alivev_ref,     # int32[GB, A]  VMEM mirror of alive_ref's block
    limv_ref,       # int32[GB]     VMEM mirror of lim_ref's block
    # outputs
    o_rnd_ref,      # int32[GB, A, BB]
    o_vrnd_ref,     # int32[GB, A, BB]
    o_val_ref,      # int32[GB, A, BB, V]
    o_ldel_ref,     # int32[GB, BB]
    o_linst_ref,    # int32[GB, BB]
    o_lval_ref,     # int32[GB, BB, V]
    fresh_ref,      # int32[GB, BB]  out: fresh (non-duplicate) delivery mask
    win_ref,        # int32[GB, BB]  out: winning vrnd (NO_ROUND if none)
    value_ref,      # int32[GB, BB, V]  out: decided value
):
    # index-map inputs; body uses the mirrors
    del ni_ref, crnd_ref, alive_ref, lim_ref
    i = pl.program_id(1)
    _gb, _a, bb = st_rnd_ref.shape

    ni_g = niv_ref[...]                                            # (GB,)
    inst = ni_g[:, None] + i * bb + _lane_iota(bb)[None, :]        # (GB, BB)
    (
        o_rnd_ref[...], o_vrnd_ref[...], o_val_ref[...],
        o_ldel_ref[...], o_linst_ref[...], o_lval_ref[...],
        fresh_ref[...], win_ref[...], value_ref[...],
    ) = _phase2_block(
        inst,
        crndv_ref[...],
        alivev_ref[...] != 0,
        limv_ref[...],
        q_ref[0],
        values_ref[...],
        st_rnd_ref[...],
        st_vrnd_ref[...],
        st_val_ref[...],
        ldel_ref[...],
        linst_ref[...],
        lval_ref[...],
    )


def _cohort_wirepath_kernel(gsel_ref, *rest):
    # same body as the full-grid kernel; ``gsel_ref`` is consumed by the
    # index maps only (it selects which group blocks the grid visits)
    del gsel_ref
    _mg_wirepath_kernel(*rest)


@functools.partial(
    jax.jit, static_argnames=("block_b", "group_block", "interpret")
)
def cohort_wirepath_round(
    gsel: jax.Array,        # int32[NB]  selected group-block indices (÷ GB)
    next_inst: jax.Array,   # int32[G]  per-group window base (BB-aligned)
    crnd: jax.Array,        # int32[G]  per-group coordinator round
    quorum: jax.Array,      # int32[]
    alive: jax.Array,       # int32[G, A] (0/1)
    st_rnd: jax.Array,      # int32[G, A, N]   stacked acceptor rings
    st_vrnd: jax.Array,     # int32[G, A, N]
    st_val: jax.Array,      # int32[G, A, N, V]
    ldel: jax.Array,        # int32[G, N]      learner rings
    linst: jax.Array,       # int32[G, N]
    lval: jax.Array,        # int32[G, N, V]
    values: jax.Array,      # int32[NB*GB, B, V]  cohort burst values, compact
    enabled: jax.Array | None = None,  # int32[G] (0/1); None = all enabled
    limit: jax.Array | None = None,    # int32[G]; None = no reclamation
    *,
    block_b: int = DEFAULT_BLOCK_B,
    group_block: int = 1,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """One fused Phase-2 round for a *cohort* of groups: the grid visits
    only the ``GB``-aligned group blocks named by ``gsel`` (DESIGN.md §8).

    This is the group-axis analogue of the ring blocking: a dispatch's cost
    scales with the cohort it serves, not with the full capacity ``G``.
    Unselected groups' slabs are never loaded — their rows of the aliased
    state outputs retain their input data, exactly like the unvisited ring
    blocks along the batch axis.  ``values`` and the ``fresh``/``win``/
    ``value`` outputs are *compact*: row ``j*GB + k`` belongs to group
    ``gsel[j]*GB + k``.

    ``group_block > 1`` folds each selected block; the folded *enabled*
    members of a block must share one BB-aligned watermark (the per-cohort
    lockstep condition computed by ``core.plan.cohort_blocks``).
    ``enabled`` marks the cohort: non-members inside a selected block ride
    inert — round forced to NO_ROUND, watermark substituted with the
    block's enabled-lockstep base — and are written back bit-unchanged.

    ``limit`` is the per-group reclamation limit (DESIGN.md §9): the first
    instance the group may NOT sequence into — its snapshot watermark plus
    the ring capacity N.  Lanes at or past the limit are refused by every
    acceptor (state written back unchanged, no delivery), surfacing ring
    exhaustion as backpressure instead of silently overwriting undrained
    slots.  ``None`` grants a full permit (legacy overwrite-on-wrap mode).

    Returns ``(st_rnd', st_vrnd', st_val', ldel', linst', lval',
    fresh[NB*GB, B], win_vrnd[NB*GB, B], value[NB*GB, B, V])`` with the
    state outputs full-width ``(G, ...)`` (aliased in place).
    """
    g, a, n = st_rnd.shape
    c, b, v = values.shape
    bb = min(block_b, b)
    gb = group_block
    nb = gsel.shape[0]
    assert b % bb == 0, (b, bb)
    assert n % bb == 0, (n, bb)
    assert b <= n, "burst may not lap the instance ring"
    assert g % gb == 0, (g, gb)
    assert c == nb * gb, (c, nb, gb)
    nb_ring = n // bb
    grid = (nb, b // bb)

    # Ring offset of a selected block comes from its first group's watermark;
    # with group_block == 1 that IS the group's own watermark, with
    # group_block > 1 the caller guarantees the folded enabled members are in
    # lockstep (and disabled members' watermarks are substituted below).
    def ring2(gi, i, gsel_ref, ni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, (ni_ref[gs * gb] // bb + i) % nb_ring)

    def ring3(gi, i, gsel_ref, ni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, (ni_ref[gs * gb] // bb + i) % nb_ring, 0)

    def stack3(gi, i, gsel_ref, ni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, 0, (ni_ref[gs * gb] // bb + i) % nb_ring)

    def stack4(gi, i, gsel_ref, ni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, 0, (ni_ref[gs * gb] // bb + i) % nb_ring, 0)

    def batch2(gi, i, *_):
        return (gi, i)

    def batch3(gi, i, *_):
        return (gi, i, 0)

    def group1(gi, i, gsel_ref, *_):
        return (gsel_ref[gi],)

    def group2(gi, i, gsel_ref, *_):
        return (gsel_ref[gi], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, bb, v), batch3),       # values (compact)
            pl.BlockSpec((gb, a, bb), stack3),       # st_rnd
            pl.BlockSpec((gb, a, bb), stack3),       # st_vrnd
            pl.BlockSpec((gb, a, bb, v), stack4),    # st_val
            pl.BlockSpec((gb, bb), ring2),           # ldel
            pl.BlockSpec((gb, bb), ring2),           # linst
            pl.BlockSpec((gb, bb, v), ring3),        # lval
            pl.BlockSpec((gb,), group1),             # ni (VMEM mirror)
            pl.BlockSpec((gb,), group1),             # crnd (VMEM mirror)
            pl.BlockSpec((gb, a), group2),           # alive (VMEM mirror)
            pl.BlockSpec((gb,), group1),             # limit (VMEM mirror)
        ],
        out_specs=[
            pl.BlockSpec((gb, a, bb), stack3),       # st_rnd'
            pl.BlockSpec((gb, a, bb), stack3),       # st_vrnd'
            pl.BlockSpec((gb, a, bb, v), stack4),    # st_val'
            pl.BlockSpec((gb, bb), ring2),           # ldel'
            pl.BlockSpec((gb, bb), ring2),           # linst'
            pl.BlockSpec((gb, bb, v), ring3),        # lval'
            pl.BlockSpec((gb, bb), batch2),          # fresh (compact)
            pl.BlockSpec((gb, bb), batch2),          # win_vrnd (compact)
            pl.BlockSpec((gb, bb, v), batch3),       # value (compact)
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((g, a, n), jnp.int32),
        jax.ShapeDtypeStruct((g, a, n), jnp.int32),
        jax.ShapeDtypeStruct((g, a, n, v), jnp.int32),
        jax.ShapeDtypeStruct((g, n), jnp.int32),
        jax.ShapeDtypeStruct((g, n), jnp.int32),
        jax.ShapeDtypeStruct((g, n, v), jnp.int32),
        jax.ShapeDtypeStruct((c, b), jnp.int32),
        jax.ShapeDtypeStruct((c, b), jnp.int32),
        jax.ShapeDtypeStruct((c, b, v), jnp.int32),
    ]
    fn = pl.pallas_call(
        _cohort_wirepath_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        # all five state arrays update in place: inputs 7..12 (after the 6
        # scalar-prefetch args) alias outputs 0..5 — device-resident state
        input_output_aliases={7: 0, 8: 1, 9: 2, 10: 3, 11: 4, 12: 5},
        interpret=interpret,
    )
    ni = jnp.asarray(next_inst, jnp.int32).reshape((g,))
    cr = jnp.asarray(crnd, jnp.int32).reshape((g,))
    if enabled is not None:
        en = jnp.asarray(enabled, jnp.int32).reshape((g,)) != 0
        # a disabled group decides (and mutates) nothing: NO_ROUND rejects
        cr = jnp.where(en, cr, jnp.int32(NO_ROUND))
        if gb > 1:
            # a folded block has ONE ring offset (its first group's
            # watermark); substitute disabled members with the block's
            # enabled-lockstep base so their stray watermarks cannot skew
            # it — their windows are written back unchanged wherever they
            # land, so the substitution is state-exact
            enb = en.reshape(g // gb, gb)
            nib = ni.reshape(g // gb, gb)
            base = jnp.max(
                jnp.where(enb, nib, jnp.iinfo(jnp.int32).min), axis=1
            )
            base = jnp.where(jnp.any(enb, axis=1), base, 0)
            ni = jnp.where(enb, nib, base[:, None]).reshape((g,))
    q = jnp.asarray(quorum, jnp.int32).reshape((1,))
    al = jnp.asarray(alive, jnp.int32).reshape((g, a))
    gs = jnp.asarray(gsel, jnp.int32).reshape((nb,))
    if limit is None:
        # full permit: int32.max is an unreachable instance, so every lane
        # passes the gate (never add N to a watermark here — it overflows)
        lim = jnp.full((g,), jnp.iinfo(jnp.int32).max, jnp.int32)
    else:
        lim = jnp.asarray(limit, jnp.int32).reshape((g,))
    return tuple(
        fn(gs, ni, cr, q, al, lim, values, st_rnd, st_vrnd, st_val, ldel,
           linst, lval, ni, cr, al, lim)
    )


@functools.partial(
    jax.jit, static_argnames=("block_b", "group_block", "interpret")
)
def multigroup_wirepath_round(
    next_inst: jax.Array,   # int32[G]  per-group window base (BB-aligned)
    crnd: jax.Array,        # int32[G]  per-group coordinator round
    quorum: jax.Array,      # int32[]
    alive: jax.Array,       # int32[G, A] (0/1)
    st_rnd: jax.Array,      # int32[G, A, N]   stacked acceptor rings
    st_vrnd: jax.Array,     # int32[G, A, N]
    st_val: jax.Array,      # int32[G, A, N, V]
    ldel: jax.Array,        # int32[G, N]      learner rings
    linst: jax.Array,       # int32[G, N]
    lval: jax.Array,        # int32[G, N, V]
    values: jax.Array,      # int32[G, B, V]   per-group burst values
    enabled: jax.Array | None = None,  # int32[G] (0/1); None = all enabled
    limit: jax.Array | None = None,    # int32[G]; None = no reclamation
    *,
    block_b: int = DEFAULT_BLOCK_B,
    group_block: int = 1,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """One fused Phase-2 round for G device-resident groups; single dispatch.

    The full-width slice of ``cohort_wirepath_round``: every group block is
    selected, so the compact value/output layout coincides with the
    ``(G, ...)`` layout.  ``group_block > 1`` folds that many groups into
    each grid step (see the module docstring); the folded *enabled* groups
    of a block must share one BB-aligned watermark — the caller's
    responsibility (``core.plan.fold_width_full`` picks the widest legal
    fold from the host watermark mirrors).  ``enabled`` is the vacant/
    frozen mask: disabled groups get their round forced to NO_ROUND and,
    when folding, their watermark substituted with the block's
    enabled-lockstep base — they ride the dispatch inert and bit-unchanged.

    Returns ``(st_rnd', st_vrnd', st_val', ldel', linst', lval',
    fresh[G, B], win_vrnd[G, B], value[G, B, V])``.
    """
    g = st_rnd.shape[0]
    assert g % group_block == 0, (g, group_block)
    gsel = jnp.arange(g // group_block, dtype=jnp.int32)
    return cohort_wirepath_round(
        gsel, next_inst, crnd, quorum, alive,
        st_rnd, st_vrnd, st_val, ldel, linst, lval, values, enabled, limit,
        block_b=block_b, group_block=group_block, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Persistent K-round entry: a whole wave of Phase-2 rounds per pallas_call
# ---------------------------------------------------------------------------
def _persistent_wirepath_kernel(
    # scalar prefetch (SMEM) — consumed by the index maps; the body reads
    # the same per-(round, group) values from the VMEM mirrors below
    gsel_ref,       # int32[NB]    selected group-block indices (÷ GB)
    wni_ref,        # int32[K, G]  wave descriptor: per-round window bases
    crnd_ref,       # int32[G]     per-group coordinator round
    q_ref,          # int32[1]     quorum (f+1)
    alive_ref,      # int32[G, A]  per-group runtime liveness mask
    lim_ref,        # int32[G]     per-group reclaim limit
    wen_ref,        # int32[K, G]  wave descriptor: per-round enables
    # inputs (VMEM tiles)
    values_ref,     # int32[1, GB, BB, V]  round k's burst values
    st_rnd_ref,     # int32[GB, A, BB]     acceptor ring blocks (aliased out)
    st_vrnd_ref,    # int32[GB, A, BB]
    st_val_ref,     # int32[GB, A, BB, V]
    ldel_ref,       # int32[GB, BB]        learner ring blocks (aliased out)
    linst_ref,      # int32[GB, BB]
    lval_ref,       # int32[GB, BB, V]
    wniv_ref,       # int32[1, GB]  VMEM mirror of wni_ref's (round, block)
    wenv_ref,       # int32[1, GB]  VMEM mirror of wen_ref's (round, block)
    crndv_ref,      # int32[GB]     VMEM mirror of crnd_ref's block
    alivev_ref,     # int32[GB, A]  VMEM mirror of alive_ref's block
    limv_ref,       # int32[GB]     VMEM mirror of lim_ref's block
    # outputs
    o_rnd_ref,      # int32[GB, A, BB]
    o_vrnd_ref,     # int32[GB, A, BB]
    o_val_ref,      # int32[GB, A, BB, V]
    o_ldel_ref,     # int32[GB, BB]
    o_linst_ref,    # int32[GB, BB]
    o_lval_ref,     # int32[GB, BB, V]
    fresh_ref,      # int32[1, GB, BB]
    win_ref,        # int32[1, GB, BB]
    value_ref,      # int32[1, GB, BB, V]
):
    # index-map inputs; body uses the mirrors
    del gsel_ref, wni_ref, crnd_ref, alive_ref, lim_ref, wen_ref
    i = pl.program_id(2)
    _gb, _a, bb = st_rnd_ref.shape

    ni_g = wniv_ref[0]                                             # (GB,)
    # a group sitting out round k (wen == 0) rides the round inert: round
    # presented as NO_ROUND so its acceptors reject every slot, its window
    # (unchanged from its last enabled round) written back bit-identical
    en_g = wenv_ref[0] != 0                                        # (GB,)
    crnd_g = jnp.where(en_g, crndv_ref[...], jnp.int32(NO_ROUND))
    inst = ni_g[:, None] + i * bb + _lane_iota(bb)[None, :]        # (GB, BB)
    (
        o_rnd_ref[...], o_vrnd_ref[...], o_val_ref[...],
        o_ldel_ref[...], o_linst_ref[...], o_lval_ref[...],
        fresh_ref[0], win_ref[0], value_ref[0],
    ) = _phase2_block(
        inst,
        crnd_g,
        alivev_ref[...] != 0,
        limv_ref[...],
        q_ref[0],
        values_ref[0],
        st_rnd_ref[...],
        st_vrnd_ref[...],
        st_val_ref[...],
        ldel_ref[...],
        linst_ref[...],
        lval_ref[...],
    )


@functools.partial(
    jax.jit, static_argnames=("block_b", "group_block", "interpret")
)
def persistent_wirepath_round(
    gsel: jax.Array,        # int32[NB]    selected group-block indices (÷ GB)
    wni: jax.Array,         # int32[K, G]  per-round window bases (BB-aligned)
    wen: jax.Array,         # int32[K, G]  per-round participation (0/1)
    crnd: jax.Array,        # int32[G]     per-group coordinator round
    quorum: jax.Array,      # int32[]
    alive: jax.Array,       # int32[G, A] (0/1)
    st_rnd: jax.Array,      # int32[G, A, N]   stacked acceptor rings
    st_vrnd: jax.Array,     # int32[G, A, N]
    st_val: jax.Array,      # int32[G, A, N, V]
    ldel: jax.Array,        # int32[G, N]      learner rings
    linst: jax.Array,       # int32[G, N]
    lval: jax.Array,        # int32[G, N, V]
    values: jax.Array,      # int32[K, NB*GB, B, V]  wave values, compact rows
    limit: jax.Array | None = None,    # int32[G]; None = no reclamation
    *,
    block_b: int = DEFAULT_BLOCK_B,
    group_block: int = 1,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """K Phase-2 rounds in ONE ``pallas_call``: the persistent wire path.

    The single-round dispatch pays a host round-trip per round, and on small
    bursts that dispatch overhead — not consensus arithmetic — is the
    throughput ceiling (the paper's host-boundary argument; BENCH_wirepath
    rows ``trickle_*``).  Here the whole chunk *wave* is device-resident:
    the grid grows a leading sequential round axis ``K``, each round k
    re-runs sequence -> vote -> quorum -> learner dedup over its own ring
    window, and host sync (watermarks, the ``fresh``/``value`` read-back)
    happens once per K rounds instead of once per round.

    The **wave descriptor** generalizes the cohort scalar-prefetch vectors
    to a per-round table:

      * ``wni[k, g]`` — group ``g``'s window base at round ``k``.  The host
        precomputes the cumulative walk ``wni[k+1] = wni[k] + B·wen[k]``
        (and applies the folded-block base substitution per round), so the
        index maps stay pure lookups: block ``gi`` of round ``k`` maps its
        rings at ``(wni[k, gsel[gi]·GB] // BB + i) % (N // BB)``.
      * ``wen[k, g]`` — whether ``g`` participates in round ``k`` (the
        per-round burst length, quantized: a group either rides a full
        ``B``-slot window or sits the round out).  A non-participant is
        presented at NO_ROUND with its window frozen, so it is written back
        bit-unchanged — mid-wave freezes land exactly between rounds.
      * ``gsel`` — the cohort group-block selection, shared by all K rounds
        (one wave = one cohort).

    Rounds are *sequential by construction*: round k+1's windows are
    disjoint from round k's (enabled windows advance by B; ``K·B <= N``
    keeps a wave from lapping the ring), and revisited blocks belong only
    to non-participants whose writeback is bit-identical, so grid-step
    pipelining can never read a stale block that matters.

    Returns ``(st_rnd', st_vrnd', st_val', ldel', linst', lval',
    fresh[K, NB*GB, B], win_vrnd[K, NB*GB, B], value[K, NB*GB, B, V])`` —
    per-round compact outputs, state aliased in place.
    """
    g, a, n = st_rnd.shape
    k, c, b, v = values.shape
    bb = min(block_b, b)
    gb = group_block
    nb = gsel.shape[0]
    assert b % bb == 0, (b, bb)
    assert n % bb == 0, (n, bb)
    assert k * b <= n, "persistent wave may not lap the instance ring"
    assert g % gb == 0, (g, gb)
    assert c == nb * gb, (c, nb, gb)
    assert wni.shape == (k, g), (wni.shape, k, g)
    assert wen.shape == (k, g), (wen.shape, k, g)
    nb_ring = n // bb
    grid = (k, nb, b // bb)

    def ring2(kk, gi, i, gsel_ref, wni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, (wni_ref[kk, gs * gb] // bb + i) % nb_ring)

    def ring3(kk, gi, i, gsel_ref, wni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, (wni_ref[kk, gs * gb] // bb + i) % nb_ring, 0)

    def stack3(kk, gi, i, gsel_ref, wni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, 0, (wni_ref[kk, gs * gb] // bb + i) % nb_ring)

    def stack4(kk, gi, i, gsel_ref, wni_ref, *_):
        gs = gsel_ref[gi]
        return (gs, 0, (wni_ref[kk, gs * gb] // bb + i) % nb_ring, 0)

    def batch3(kk, gi, i, *_):
        return (kk, gi, i)

    def batch4(kk, gi, i, *_):
        return (kk, gi, i, 0)

    def wave2(kk, gi, i, gsel_ref, *_):
        return (kk, gsel_ref[gi])

    def group1(kk, gi, i, gsel_ref, *_):
        return (gsel_ref[gi],)

    def group2(kk, gi, i, gsel_ref, *_):
        return (gsel_ref[gi], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, gb, bb, v), batch4),    # values (compact, per-k)
            pl.BlockSpec((gb, a, bb), stack3),       # st_rnd
            pl.BlockSpec((gb, a, bb), stack3),       # st_vrnd
            pl.BlockSpec((gb, a, bb, v), stack4),    # st_val
            pl.BlockSpec((gb, bb), ring2),           # ldel
            pl.BlockSpec((gb, bb), ring2),           # linst
            pl.BlockSpec((gb, bb, v), ring3),        # lval
            pl.BlockSpec((1, gb), wave2),            # wni (VMEM mirror)
            pl.BlockSpec((1, gb), wave2),            # wen (VMEM mirror)
            pl.BlockSpec((gb,), group1),             # crnd (VMEM mirror)
            pl.BlockSpec((gb, a), group2),           # alive (VMEM mirror)
            pl.BlockSpec((gb,), group1),             # limit (VMEM mirror)
        ],
        out_specs=[
            pl.BlockSpec((gb, a, bb), stack3),       # st_rnd'
            pl.BlockSpec((gb, a, bb), stack3),       # st_vrnd'
            pl.BlockSpec((gb, a, bb, v), stack4),    # st_val'
            pl.BlockSpec((gb, bb), ring2),           # ldel'
            pl.BlockSpec((gb, bb), ring2),           # linst'
            pl.BlockSpec((gb, bb, v), ring3),        # lval'
            pl.BlockSpec((1, gb, bb), batch3),       # fresh (compact, per-k)
            pl.BlockSpec((1, gb, bb), batch3),       # win_vrnd
            pl.BlockSpec((1, gb, bb, v), batch4),    # value
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((g, a, n), jnp.int32),
        jax.ShapeDtypeStruct((g, a, n), jnp.int32),
        jax.ShapeDtypeStruct((g, a, n, v), jnp.int32),
        jax.ShapeDtypeStruct((g, n), jnp.int32),
        jax.ShapeDtypeStruct((g, n), jnp.int32),
        jax.ShapeDtypeStruct((g, n, v), jnp.int32),
        jax.ShapeDtypeStruct((k, c, b), jnp.int32),
        jax.ShapeDtypeStruct((k, c, b), jnp.int32),
        jax.ShapeDtypeStruct((k, c, b, v), jnp.int32),
    ]
    fn = pl.pallas_call(
        _persistent_wirepath_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        # state arrays update in place: inputs 8..13 (after the 7 scalar-
        # prefetch args) alias outputs 0..5 — device-resident across rounds
        input_output_aliases={8: 0, 9: 1, 10: 2, 11: 3, 12: 4, 13: 5},
        interpret=interpret,
    )
    cr = jnp.asarray(crnd, jnp.int32).reshape((g,))
    wenk = jnp.asarray(wen, jnp.int32).reshape((k, g)) != 0
    wnik = jnp.asarray(wni, jnp.int32).reshape((k, g))
    if gb > 1:
        # per round, a folded block has ONE ring offset (its first group's
        # window base); substitute that round's non-participants with the
        # block's participating-lockstep base, exactly as the single-round
        # cohort entry does — state-exact because non-participants are
        # written back unchanged wherever their window lands
        enb = wenk.reshape(k, g // gb, gb)
        nib = wnik.reshape(k, g // gb, gb)
        base = jnp.max(
            jnp.where(enb, nib, jnp.iinfo(jnp.int32).min), axis=2
        )
        base = jnp.where(jnp.any(enb, axis=2), base, 0)
        wnik = jnp.where(enb, nib, base[..., None]).reshape((k, g))
    q = jnp.asarray(quorum, jnp.int32).reshape((1,))
    al = jnp.asarray(alive, jnp.int32).reshape((g, a))
    gs = jnp.asarray(gsel, jnp.int32).reshape((nb,))
    wenk = wenk.astype(jnp.int32)
    if limit is None:
        lim = jnp.full((g,), jnp.iinfo(jnp.int32).max, jnp.int32)
    else:
        lim = jnp.asarray(limit, jnp.int32).reshape((g,))
    return tuple(
        fn(gs, wnik, cr, q, al, lim, wenk, values, st_rnd, st_vrnd, st_val,
           ldel, linst, lval, wnik, wenk, cr, al, lim)
    )


def shard_slab_round(
    group_offset: jax.Array,  # int32[]  first global group id of this slab
    next_inst: jax.Array,     # int32[G_global]  replicated watermark vector
    crnd: jax.Array,          # int32[G_global]  replicated round vector
    quorum: jax.Array,        # int32[]
    alive: jax.Array,         # int32[G_global, A]  replicated liveness
    st_rnd: jax.Array,        # int32[Gl, A, N]   this shard's acceptor slab
    st_vrnd: jax.Array,       # int32[Gl, A, N]
    st_val: jax.Array,        # int32[Gl, A, N, V]
    ldel: jax.Array,          # int32[Gl, N]      this shard's learner slab
    linst: jax.Array,         # int32[Gl, N]
    lval: jax.Array,          # int32[Gl, N, V]
    values: jax.Array,        # int32[Gl, B, V]   this shard's burst slab
    enabled: jax.Array | None = None,  # int32[G_global] (0/1) replicated
    limit: jax.Array | None = None,    # int32[G_global] replicated
    *,
    block_b: int = DEFAULT_BLOCK_B,
    group_block: int = 1,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Local-slab entry point for the groups-sharded dataplane (DESIGN.md §6).

    Runs ``multigroup_wirepath_round`` on ONE shard's contiguous slab of
    ``Gl = G_global / n_shards`` groups.  The per-group scalar vectors
    (watermarks, rounds, liveness, and the membership ``enabled`` mask) stay
    *global and replicated* — they are tiny, host-mutated metadata — and
    ``group_offset`` selects this shard's window so per-group scalars index
    correctly inside the shard.  Designed to be called inside ``shard_map``
    with the slab arrays partitioned over a ``groups`` mesh axis
    (``core.fabric.make_sharded_multigroup_round``).
    """
    gl, a = st_rnd.shape[0], st_rnd.shape[1]
    off = jnp.asarray(group_offset, jnp.int32).reshape(())
    ni = jax.lax.dynamic_slice(
        jnp.asarray(next_inst, jnp.int32).reshape((-1,)), (off,), (gl,)
    )
    cr = jax.lax.dynamic_slice(
        jnp.asarray(crnd, jnp.int32).reshape((-1,)), (off,), (gl,)
    )
    al = jax.lax.dynamic_slice(
        jnp.asarray(alive, jnp.int32).reshape((-1, a)),
        (off, jnp.int32(0)),
        (gl, a),
    )
    en = None
    if enabled is not None:
        en = jax.lax.dynamic_slice(
            jnp.asarray(enabled, jnp.int32).reshape((-1,)), (off,), (gl,)
        )
    lim = None
    if limit is not None:
        lim = jax.lax.dynamic_slice(
            jnp.asarray(limit, jnp.int32).reshape((-1,)), (off,), (gl,)
        )
    return multigroup_wirepath_round(
        ni, cr, quorum, al,
        st_rnd, st_vrnd, st_val, ldel, linst, lval, values, en, lim,
        block_b=block_b, group_block=group_block, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Packed ragged-shard entry: C resident lanes, slab rows routed by segment id
# ---------------------------------------------------------------------------
def _packed_shard_kernel(
    ni_ref, crnd_ref, q_ref, alive_ref, lim_ref, seg_ref, *rest
):
    # ``seg_ref`` is consumed by the index maps only — it routes each packed
    # lane to its resident slab row; the round body is the shared one
    del seg_ref
    _mg_wirepath_kernel(ni_ref, crnd_ref, q_ref, alive_ref, lim_ref, *rest)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def packed_shard_round(
    segids: jax.Array,      # int32[C]  per-lane local slab row (0..Gl)
    next_inst: jax.Array,   # int32[C]  per-lane window base (BB-aligned)
    crnd: jax.Array,        # int32[C]  per-lane coordinator round
    quorum: jax.Array,      # int32[]
    alive: jax.Array,       # int32[C, A] (0/1)
    st_rnd: jax.Array,      # int32[Gl, A, N]   this shard's acceptor slab
    st_vrnd: jax.Array,     # int32[Gl, A, N]
    st_val: jax.Array,      # int32[Gl, A, N, V]
    ldel: jax.Array,        # int32[Gl, N]      this shard's learner slab
    linst: jax.Array,       # int32[Gl, N]
    lval: jax.Array,        # int32[Gl, N, V]
    values: jax.Array,      # int32[C, B, V]  packed burst values, lane order
    enabled: jax.Array | None = None,  # int32[C] (0/1); None = all lanes real
    limit: jax.Array | None = None,    # int32[C]; None = no reclamation
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """One fused Phase-2 round over a shard's *packed* lane table: the grid
    visits ``C`` uniform lanes and a ``segids`` scalar-prefetch vector routes
    each lane to the slab row it serves — the GShard MoE input-packing idiom
    (ragged segments inside a fixed dispatch shape) applied to the group
    slabs (DESIGN.md §13).

    Where ``shard_slab_round`` always walks the shard's full ``Gl``-row slab
    (cold cohorts pay full-width slab cost), here the dispatch costs what
    its *resident, enabled* lanes cost: lane ``j`` processes slab row
    ``segids[j]`` with its own watermark/round/liveness/limit scalars — all
    per-LANE vectors, packed by the caller in lane order.  Slab rows not
    named by any lane are never loaded; their rows of the aliased state
    outputs retain the input data, exactly like unselected cohort blocks.

    Pad lanes (``enabled == 0``) make the lane count uniform across shards
    (shard_map shape uniformity).  A pad rides inert — round forced to
    NO_ROUND, so its row is loaded and stored back bit-identical — and its
    segment id is *redirected to a provably-unused slab row*: enabled lanes
    must name pairwise-distinct rows, so when any pad exists the enabled
    count is < C <= Gl and a free row exists.  That redirection is the
    safety argument under grid-step pipelining (the same argument as the
    persistent kernel's revisited blocks): every slab row is touched either
    by its single enabled lane, or only by pads whose writeback is
    bit-identical — no interleaving can publish a stale block.

    Returns ``(st_rnd', st_vrnd', st_val', ldel', linst', lval',
    fresh[C, B], win_vrnd[C, B], value[C, B, V])`` with the state outputs
    full-slab ``(Gl, ...)`` (aliased in place).
    """
    gl, a, n = st_rnd.shape
    c, b, v = values.shape
    bb = min(block_b, b)
    assert b % bb == 0, (b, bb)
    assert n % bb == 0, (n, bb)
    assert b <= n, "burst may not lap the instance ring"
    assert c <= gl, (
        "packed lane count may not exceed the slab height (pad redirection "
        "needs a free row whenever pads exist)", c, gl,
    )
    nb_ring = n // bb
    grid = (c, b // bb)

    # Each lane's ring offset comes from its OWN watermark; its slab row
    # from its segment id — both per-lane prefetch lookups.
    def ring2(gi, i, ni_ref, cr_ref, q_ref, al_ref, lim_ref, seg_ref):
        return (seg_ref[gi], (ni_ref[gi] // bb + i) % nb_ring)

    def ring3(gi, i, ni_ref, cr_ref, q_ref, al_ref, lim_ref, seg_ref):
        return (seg_ref[gi], (ni_ref[gi] // bb + i) % nb_ring, 0)

    def stack3(gi, i, ni_ref, cr_ref, q_ref, al_ref, lim_ref, seg_ref):
        return (seg_ref[gi], 0, (ni_ref[gi] // bb + i) % nb_ring)

    def stack4(gi, i, ni_ref, cr_ref, q_ref, al_ref, lim_ref, seg_ref):
        return (seg_ref[gi], 0, (ni_ref[gi] // bb + i) % nb_ring, 0)

    def batch2(gi, i, *_):
        return (gi, i)

    def batch3(gi, i, *_):
        return (gi, i, 0)

    def lane1(gi, i, *_):
        return (gi,)

    def lane2(gi, i, *_):
        return (gi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bb, v), batch3),        # values (packed)
            pl.BlockSpec((1, a, bb), stack3),        # st_rnd
            pl.BlockSpec((1, a, bb), stack3),        # st_vrnd
            pl.BlockSpec((1, a, bb, v), stack4),     # st_val
            pl.BlockSpec((1, bb), ring2),            # ldel
            pl.BlockSpec((1, bb), ring2),            # linst
            pl.BlockSpec((1, bb, v), ring3),         # lval
            pl.BlockSpec((1,), lane1),               # ni (VMEM mirror)
            pl.BlockSpec((1,), lane1),               # crnd (VMEM mirror)
            pl.BlockSpec((1, a), lane2),             # alive (VMEM mirror)
            pl.BlockSpec((1,), lane1),               # limit (VMEM mirror)
        ],
        out_specs=[
            pl.BlockSpec((1, a, bb), stack3),        # st_rnd'
            pl.BlockSpec((1, a, bb), stack3),        # st_vrnd'
            pl.BlockSpec((1, a, bb, v), stack4),     # st_val'
            pl.BlockSpec((1, bb), ring2),            # ldel'
            pl.BlockSpec((1, bb), ring2),            # linst'
            pl.BlockSpec((1, bb, v), ring3),         # lval'
            pl.BlockSpec((1, bb), batch2),           # fresh (packed)
            pl.BlockSpec((1, bb), batch2),           # win_vrnd (packed)
            pl.BlockSpec((1, bb, v), batch3),        # value (packed)
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((gl, a, n), jnp.int32),
        jax.ShapeDtypeStruct((gl, a, n), jnp.int32),
        jax.ShapeDtypeStruct((gl, a, n, v), jnp.int32),
        jax.ShapeDtypeStruct((gl, n), jnp.int32),
        jax.ShapeDtypeStruct((gl, n), jnp.int32),
        jax.ShapeDtypeStruct((gl, n, v), jnp.int32),
        jax.ShapeDtypeStruct((c, b), jnp.int32),
        jax.ShapeDtypeStruct((c, b), jnp.int32),
        jax.ShapeDtypeStruct((c, b, v), jnp.int32),
    ]
    fn = pl.pallas_call(
        _packed_shard_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        # all five state slabs update in place: inputs 7..12 (after the 6
        # scalar-prefetch args) alias outputs 0..5 — device-resident state
        input_output_aliases={7: 0, 8: 1, 9: 2, 10: 3, 11: 4, 12: 5},
        interpret=interpret,
    )
    ni = jnp.asarray(next_inst, jnp.int32).reshape((c,))
    cr = jnp.asarray(crnd, jnp.int32).reshape((c,))
    seg = jnp.asarray(segids, jnp.int32).reshape((c,))
    if enabled is not None:
        en = jnp.asarray(enabled, jnp.int32).reshape((c,)) != 0
        # a pad lane decides (and mutates) nothing: NO_ROUND rejects
        cr = jnp.where(en, cr, jnp.int32(NO_ROUND))
        # pad redirection: scatter enabled rows into a (Gl,) usage map (pads
        # dropped past the end), then point every pad at the first unused
        # row with an aligned window base — see the safety argument above
        used = (
            jnp.zeros((gl,), jnp.int32)
            .at[jnp.where(en, seg, gl)]
            .set(1, mode="drop")
        )
        pad_row = jnp.argmin(used).astype(jnp.int32)
        seg = jnp.where(en, seg, pad_row)
        ni = jnp.where(en, ni, 0)
    q = jnp.asarray(quorum, jnp.int32).reshape((1,))
    al = jnp.asarray(alive, jnp.int32).reshape((c, a))
    if limit is None:
        lim = jnp.full((c,), jnp.iinfo(jnp.int32).max, jnp.int32)
    else:
        lim = jnp.asarray(limit, jnp.int32).reshape((c,))
    return tuple(
        fn(ni, cr, q, al, lim, seg, values, st_rnd, st_vrnd, st_val, ldel,
           linst, lval, ni, cr, al, lim)
    )


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def wirepath_round(
    next_inst: jax.Array,   # int32[]  absolute window base (BB-aligned)
    crnd: jax.Array,        # int32[]
    quorum: jax.Array,      # int32[]
    alive: jax.Array,       # int32[A] (0/1)
    st_rnd: jax.Array,      # int32[A, N]   stacked acceptor rings
    st_vrnd: jax.Array,     # int32[A, N]
    st_val: jax.Array,      # int32[A, N, V]
    ldel: jax.Array,        # int32[N]      learner ring
    linst: jax.Array,       # int32[N]
    lval: jax.Array,        # int32[N, V]
    values: jax.Array,      # int32[B, V]   burst values
    limit: jax.Array | None = None,  # int32[]; None = no reclamation
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """One fused Phase-2 round for a single group: the G=1 slice of
    ``multigroup_wirepath_round`` (same kernel, one group on the grid).

    Returns ``(st_rnd', st_vrnd', st_val', ldel', linst', lval',
    fresh[B], win_vrnd[B], value[B, V])``.
    """
    outs = multigroup_wirepath_round(
        jnp.asarray(next_inst, jnp.int32).reshape((1,)),
        jnp.asarray(crnd, jnp.int32).reshape((1,)),
        quorum,
        jnp.asarray(alive, jnp.int32)[None],
        st_rnd[None],
        st_vrnd[None],
        st_val[None],
        ldel[None],
        linst[None],
        lval[None],
        values[None],
        None,
        None if limit is None else jnp.asarray(limit, jnp.int32).reshape((1,)),
        block_b=block_b,
        interpret=interpret,
    )
    return tuple(x[0] for x in outs)


# ---------------------------------------------------------------------------
# Staged variant: all-acceptor vote with per-acceptor vote output
# ---------------------------------------------------------------------------
def _vote_all_kernel(
    base_ref,       # int32[1]  window base slot (BB-aligned)
    alive_ref,      # int32[A]
    msgtype_ref,    # int32[BB]
    msg_rnd_ref,    # int32[BB]
    msg_val_ref,    # int32[BB, V]
    st_rnd_ref,     # int32[A, BB]  (aliased out)
    st_vrnd_ref,    # int32[A, BB]
    st_val_ref,     # int32[A, BB, V]
    o_rnd_ref,      # int32[A, BB]
    o_vrnd_ref,     # int32[A, BB]
    o_val_ref,      # int32[A, BB, V]
    vt_ref,         # int32[A, BB]  vote msgtype
    vr_ref,         # int32[A, BB]  vote rnd
    vv_ref,         # int32[A, BB]  vote vrnd
    vs_ref,         # int32[A, BB]  vote swid
    vval_ref,       # int32[A, BB, V]
):
    a, bb = st_rnd_ref.shape
    msgtype = msgtype_ref[...]
    mrnd = msg_rnd_ref[...]
    mval = msg_val_ref[...]
    cur_rnd = st_rnd_ref[...]
    cur_vrnd = st_vrnd_ref[...]
    cur_val = st_val_ref[...]

    alive = _alive_col(alive_ref, a)                             # (A, 1)
    is_p2 = (msgtype == MSG_P2A) | (msgtype == MSG_NOP)          # (BB,)
    accept = alive & is_p2[None, :] & (mrnd[None, :] >= cur_rnd)  # (A, BB)

    o_rnd_ref[...] = jnp.where(accept, mrnd[None, :], cur_rnd)
    o_vrnd_ref[...] = jnp.where(accept, mrnd[None, :], cur_vrnd)
    o_val_ref[...] = jnp.where(accept[:, :, None], mval[None], cur_val)

    vt_ref[...] = jnp.where(accept, MSG_P2B, MSG_REJECT).astype(jnp.int32)
    vr_ref[...] = jnp.where(accept, mrnd[None, :], cur_rnd)
    vv_ref[...] = jnp.where(accept, mrnd[None, :], cur_vrnd)
    vs_ref[...] = jax.lax.broadcasted_iota(jnp.int32, (a, bb), 0)
    vval_ref[...] = jnp.where(accept[:, :, None], mval[None], 0)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def acceptor_vote_all_window(
    st_rnd: jax.Array,      # int32[A, N]
    st_vrnd: jax.Array,     # int32[A, N]
    st_val: jax.Array,      # int32[A, N, V]
    base: jax.Array,        # int32[]  window base, BB-aligned
    alive: jax.Array,       # int32[A]
    msgtype: jax.Array,     # int32[B]
    msg_rnd: jax.Array,     # int32[B]
    msg_val: jax.Array,     # int32[B, V]
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """Whole-array Phase-2 vote on a contiguous window, one dispatch.

    The staged sibling of ``wirepath_round`` for when votes must surface as
    messages (per-learner fan-out over SimNet).  Returns
    ``(st_rnd', st_vrnd', st_val', vote_type[A,B], vote_rnd[A,B],
    vote_vrnd[A,B], vote_swid[A,B], vote_val[A,B,V])``.
    """
    a, n = st_rnd.shape
    b, v = msg_val.shape
    bb = min(block_b, b)
    assert b % bb == 0, (b, bb)
    assert n % bb == 0, (n, bb)
    assert b <= n, "burst may not lap the instance ring"
    nb_ring = n // bb
    grid = (b // bb,)

    def stack2(i, base_ref, *_):
        return (0, (base_ref[0] // bb + i) % nb_ring)

    def stack3(i, base_ref, *_):
        return (0, (base_ref[0] // bb + i) % nb_ring, 0)

    def vote2(i, *_):
        return (0, i)

    def vote3(i, *_):
        return (0, i, 0)

    def batch1(i, *_):
        return (i,)

    def batch2(i, *_):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), batch1),         # msgtype
            pl.BlockSpec((bb,), batch1),         # msg_rnd
            pl.BlockSpec((bb, v), batch2),       # msg_val
            pl.BlockSpec((a, bb), stack2),       # st_rnd
            pl.BlockSpec((a, bb), stack2),       # st_vrnd
            pl.BlockSpec((a, bb, v), stack3),    # st_val
        ],
        out_specs=[
            pl.BlockSpec((a, bb), stack2),       # st_rnd'
            pl.BlockSpec((a, bb), stack2),       # st_vrnd'
            pl.BlockSpec((a, bb, v), stack3),    # st_val'
            pl.BlockSpec((a, bb), vote2),        # vote_type
            pl.BlockSpec((a, bb), vote2),        # vote_rnd
            pl.BlockSpec((a, bb), vote2),        # vote_vrnd
            pl.BlockSpec((a, bb), vote2),        # vote_swid
            pl.BlockSpec((a, bb, v), vote3),     # vote_val
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((a, n), jnp.int32),
        jax.ShapeDtypeStruct((a, n), jnp.int32),
        jax.ShapeDtypeStruct((a, n, v), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b, v), jnp.int32),
    ]
    fn = pl.pallas_call(
        _vote_all_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        # stacked rings in place: inputs 5,6,7 alias outputs 0,1,2
        input_output_aliases={5: 0, 6: 1, 7: 2},
        interpret=interpret,
    )
    base = jnp.asarray(base, jnp.int32).reshape((1,))
    al = jnp.asarray(alive, jnp.int32)
    return tuple(fn(base, al, msgtype, msg_rnd, msg_val, st_rnd, st_vrnd, st_val))
