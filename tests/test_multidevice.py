"""Multi-device integration tests, run in subprocesses with
--xla_force_host_platform_device_count=8 (the main test process must keep the
default single device for the smoke tests)."""
from __future__ import annotations

import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow   # subprocess suite: skipped in the fast lane


def _run(code: str, devices: int = 8) -> str:
    env_code = (
        f"import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"\n'
    )
    proc = subprocess.run(
        [sys.executable, "-c", env_code + textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_fabric_consensus_round_all_devices_agree():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.fabric import make_fabric_consensus
        mesh = jax.make_mesh((8,), ("acc",))
        init_fn, step = make_fabric_consensus(mesh, axis="acc", n_instances=256,
                                              value_words=4)
        astate, cstate = init_fn()
        values = jnp.arange(8 * 2 * 4, dtype=jnp.int32).reshape(16, 4)
        active = jnp.ones((16,), bool)
        alive = jnp.ones((8,), bool)
        astate, cstate, decided, inst, value = step(astate, cstate, values, active, alive)
        assert np.asarray(decided).all(), decided
        np.testing.assert_array_equal(np.asarray(inst), np.arange(16))
        np.testing.assert_array_equal(np.asarray(value), np.asarray(values))
        assert int(cstate.next_inst) == 16
        # second round continues the instance window
        astate, cstate, decided, inst, _ = step(astate, cstate, values, active, alive)
        assert np.asarray(inst)[0] == 16
        print("FABRIC_OK")
        """
    )
    assert "FABRIC_OK" in out


def test_fabric_consensus_tolerates_f_failures():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.fabric import make_fabric_consensus
        mesh = jax.make_mesh((8,), ("acc",))
        # quorum 5 of 8 -> tolerate 3 dead acceptors
        init_fn, step = make_fabric_consensus(mesh, axis="acc", quorum=5,
                                              n_instances=128, value_words=2)
        astate, cstate = init_fn()
        values = jnp.ones((8, 2), jnp.int32)
        active = jnp.ones((8,), bool)
        alive = jnp.asarray([True]*5 + [False]*3)
        astate, cstate, decided, inst, value = step(astate, cstate, values, active, alive)
        assert np.asarray(decided).all()
        # 4 alive < quorum 5 -> no decision
        alive = jnp.asarray([True]*4 + [False]*4)
        astate, cstate, decided, *_ = step(astate, cstate, values, active, alive)
        assert not np.asarray(decided).any()
        print("QUORUM_OK")
        """
    )
    assert "QUORUM_OK" in out


def test_quorum_commit_digest_straggler():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.core.fabric import _shard_map, quorum_commit_digest
        mesh = jax.make_mesh((8,), ("data",))
        fn = _shard_map(
            functools.partial(quorum_commit_digest, axis="data", quorum=5),
            mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P(), P()))
        # all groups agree
        d = jnp.full((8,), 1234, jnp.int32)
        h = jnp.ones((8,), bool)
        commit, win = jax.jit(fn)(d, h)
        assert bool(commit) and int(win) == 8
        # 3 stragglers abstain -> still commits
        h = jnp.asarray([True]*5 + [False]*3)
        commit, win = jax.jit(fn)(d, h)
        assert bool(commit) and int(win) == 5
        # a diverging (corrupt) group never joins the quorum: with 3
        # stragglers + 1 corrupt, only 4 agree < quorum 5 -> no commit
        d2 = d.at[0].set(999)
        commit, win = jax.jit(fn)(d2, h)
        assert not bool(commit) and int(win) == 4
        # too many stragglers -> no commit
        h = jnp.asarray([True]*4 + [False]*4)
        commit, win = jax.jit(fn)(d, h)
        assert not bool(commit)
        print("COMMIT_OK")
        """
    )
    assert "COMMIT_OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch import sharding as sh
        from repro.launch.mesh import make_host_mesh
        from repro.models import registry
        from repro.train import train_loop
        from repro.configs.base import ShapeConfig

        cfg = get_config("qwen3-4b").reduced()
        mesh = make_host_mesh(8, model_parallel=2)     # (4, 2) data x model
        key = jax.random.PRNGKey(0)
        tiny = ShapeConfig("t", 16, 4, "train")
        batch = registry.make_inputs(cfg, tiny, key)

        # single-device reference
        state0 = train_loop.init_state(cfg, key)
        step0 = jax.jit(train_loop.make_train_step(cfg))
        _, m0 = step0(state0, batch)

        # sharded
        rules = sh.BASE_RULES
        sh.install(mesh, rules)
        state_sh = sh.tree_shardings(
            train_loop.state_shapes(cfg), train_loop.state_axes(cfg), rules, mesh)
        batch_specs = registry.input_specs(cfg, tiny)
        batch_sh = sh.batch_shardings(batch_specs, cfg, rules, mesh)
        state = jax.device_put(train_loop.init_state(cfg, key), state_sh)
        gbatch = {k: jax.device_put(v, batch_sh[k]) for k, v in batch.items()}
        step = jax.jit(train_loop.make_train_step(cfg),
                       in_shardings=(state_sh, batch_sh))
        _, m1 = step(state, gbatch)
        sh.uninstall()
        a, b = float(m0["loss"]), float(m1["loss"])
        assert abs(a - b) / abs(a) < 1e-3, (a, b)
        print("SHARDED_OK", a, b)
        """
    )
    assert "SHARDED_OK" in out


def test_sharded_moe_expert_parallel():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.launch import sharding as sh
        from repro.launch.mesh import make_host_mesh
        from repro.models import registry
        from repro.configs.base import ShapeConfig

        cfg = get_config("dbrx-132b").reduced()   # 4 experts
        mesh = make_host_mesh(8, model_parallel=4)  # experts 4-way EP
        key = jax.random.PRNGKey(0)
        tiny = ShapeConfig("t", 16, 4, "train")
        batch = registry.make_inputs(cfg, tiny, key)
        mod = registry.family_module(cfg)
        params = registry.init_params(cfg, key)
        ref, _ = mod.forward(cfg, params, {"tokens": batch["tokens"]})

        sh.install(mesh, sh.BASE_RULES)
        psh = sh.tree_shardings(registry.param_shapes(cfg),
                                registry.param_axes(cfg), sh.BASE_RULES, mesh)
        p = jax.device_put(params, psh)
        f = jax.jit(lambda p, t: mod.forward(cfg, p, {"tokens": t})[0],
                    in_shardings=(psh, None))
        got = f(p, batch["tokens"])
        sh.uninstall()
        err = np.abs(np.asarray(got) - np.asarray(ref)).max()
        assert err < 5e-4, err
        print("EP_OK", err)
        """
    )
    assert "EP_OK" in out


def test_packed_shard_dispatch_matches_full_width():
    """Packed segment-id cohort dispatch == full-width sharded dispatch,
    bit-for-bit (registers AND outputs), on a real 2-shard mesh with a
    ragged cohort (2 lanes on shard 0, 1 lane + 1 pad on shard 1) and a
    dead acceptor — both engines."""
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import batched, fabric
        from repro.core.plan import NO_ROUND, NOP_SENTINEL
        from repro.launch.mesh import make_group_mesh

        rng = np.random.default_rng(0)
        G, A, N, V, B = 8, 3, 256, 2, 16      # 2 shards x Gl = 4
        mesh = make_group_mesh()
        assert len(jax.devices()) == 2
        gl = G // 2
        _cs, stack, lstate = batched.init_multigroup_state(G, A, N, V)

        # prime every ring with 2 full-width rounds
        full = fabric.make_sharded_multigroup_round(
            mesh, n_groups=G, quorum=2, use_kernels=False)
        ni = jnp.zeros((G,), jnp.int32)
        cr = jnp.full((G,), 7, jnp.int32)
        en = jnp.ones((G,), jnp.int32)
        alive = jnp.ones((G, A), jnp.int32)
        for _ in range(2):
            vals = jnp.asarray(rng.integers(0, 100, (G, B, V)), jnp.int32)
            stack, lstate, *_ = full(ni, cr, en, alive, stack, lstate,
                                     vals, jnp.ones((G, B), bool))
            ni = ni + B
        stack0 = jax.tree_util.tree_map(np.asarray, stack)
        lstate0 = jax.tree_util.tree_map(np.asarray, lstate)
        ni0 = np.asarray(ni)

        # ragged cohort [1, 2, 6]: shard 0 lanes {1, 2}, shard 1 lane {6}+pad
        gids = [1, 2, 6]
        C = 2
        seg = np.zeros((2, C), np.int32); enp = np.zeros((2, C), np.int32)
        nip = np.zeros((2, C), np.int32)
        crp = np.full((2, C), NO_ROUND, np.int32)
        alp = np.ones((2, C, A), np.int32)
        valsp = np.full((2, C, B, V), NOP_SENTINEL, np.int32)
        cohort_vals = rng.integers(0, 100, (len(gids), B, V)).astype(np.int32)
        lanes = {0: [], 1: []}
        for i, g in enumerate(gids):
            s = g // gl
            j = len(lanes[s]); lanes[s].append(g)
            seg[s, j] = g % gl; enp[s, j] = 1
            nip[s, j] = ni0[g]; crp[s, j] = 7
            valsp[s, j] = cohort_vals[i]
        alp[0, 1, 0] = 0                      # dead acceptor on group 2
        alive_full = np.ones((G, A), np.int32); alive_full[2, 0] = 0

        # reference: full-width dispatch with only the cohort enabled
        en_r = np.zeros((G,), np.int32)
        cr_r = np.full((G,), NO_ROUND, np.int32)
        vals_r = np.full((G, B, V), NOP_SENTINEL, np.int32)
        for i, g in enumerate(gids):
            en_r[g] = 1; cr_r[g] = 7; vals_r[g] = cohort_vals[i]
        st = jax.tree_util.tree_map(jnp.asarray, stack0)
        ls = jax.tree_util.tree_map(jnp.asarray, lstate0)
        st, ls, fresh_r, _i, win_r, val_r = full(
            jnp.asarray(ni0), jnp.asarray(cr_r), jnp.asarray(en_r),
            jnp.asarray(alive_full), st, ls, jnp.asarray(vals_r),
            jnp.ones((G, B), bool))
        ref = (jax.tree_util.tree_map(np.asarray, st),
               jax.tree_util.tree_map(np.asarray, ls),
               np.asarray(fresh_r), np.asarray(win_r), np.asarray(val_r))

        for use_k in (False, True):
            packed = fabric.make_packed_sharded_round(
                mesh, quorum=2, use_kernels=use_k)
            st = jax.tree_util.tree_map(jnp.asarray, stack0)
            ls = jax.tree_util.tree_map(jnp.asarray, lstate0)
            st, ls, fresh, _i, win, val = packed(
                jnp.asarray(seg), jnp.asarray(nip), jnp.asarray(crp),
                jnp.asarray(enp), jnp.asarray(alp), st, ls,
                jnp.asarray(valsp))
            got_st = jax.tree_util.tree_map(np.asarray, st)
            got_ls = jax.tree_util.tree_map(np.asarray, ls)
            for a, b in zip(jax.tree_util.tree_leaves((got_st, got_ls)),
                            jax.tree_util.tree_leaves((ref[0], ref[1]))):
                np.testing.assert_array_equal(a, b)
            fresh = np.asarray(fresh).reshape(2, C, B)
            win = np.asarray(win).reshape(2, C, B)
            val = np.asarray(val).reshape(2, C, B, V)
            for g in gids:
                s, j = g // gl, lanes[g // gl].index(g)
                np.testing.assert_array_equal(fresh[s, j], ref[2][g])
                np.testing.assert_array_equal(win[s, j], ref[3][g])
                np.testing.assert_array_equal(val[s, j], ref[4][g])
        print("PACKED_OK")
        """,
        devices=2,
    )
    assert "PACKED_OK" in out


def test_live_migration_across_shards_matches_twins():
    """End-to-end live slab migration on a real 2-shard mesh: skewed load,
    a retire on the destination shard, then migrating the hot tenant from
    shard 0 to shard 1 without stopping the service — decided payload
    streams must keep matching per-group twins on both engines, and the
    placement map must record the move."""
    out = _run(
        """
        import numpy as np
        from repro.core.api import PaxosContext, ShardedMultiGroupDataplane
        from repro.core.types import PaxosConfig
        from repro.launch.mesh import make_group_mesh

        def run(use_kernels):
            cfg = PaxosConfig(n_groups=4, n_acceptors=3, n_instances=256,
                              batch=16, value_words=4)
            cfg1 = PaxosConfig(n_groups=1, n_acceptors=3, n_instances=256,
                               batch=16, value_words=4)
            ctx = PaxosContext(cfg, mesh=make_group_mesh(),
                               use_kernels=use_kernels, snapshots=True)
            twins = [PaxosContext(cfg1, use_kernels=use_kernels, fused=True,
                                  snapshots=True) for _ in range(4)]
            rng = np.random.default_rng(1)

            def waves(n, groups, hot=0):
                for w in range(n):
                    for g in groups:
                        k = 12 if g == hot else (2 if w % 2 == 0 else 1)
                        for _ in range(k):
                            p = bytes(rng.integers(0, 255, 6).astype(np.uint8))
                            ctx.submit(p, group=g)
                            twins[g].submit(p, group=0)
                    ctx.run_until_quiescent()
                    for g in groups:
                        twins[g].run_until_quiescent()

            waves(4, [0, 1, 2, 3])
            hw = ctx.hw
            assert isinstance(hw, ShardedMultiGroupDataplane)
            assert hw.placement.identity_map()
            ctx.retire_group(3)               # vacate a slot on shard 1
            assert hw.shard_of_group(0) == 0
            ctx.migrate_group(0, 1)           # live: drain/seal/swap/restore
            assert hw.shard_of_group(0) == 1, hw.group_placement()
            waves(3, [0, 1, 2])               # keep serving after the move
            for g in (0, 1, 2):
                a = [p for _i, p in ctx.full_group_log(g)]
                b = [p for _i, p in twins[g].full_group_log(0)]
                assert a == b, (use_kernels, g, len(a), len(b))
            print("MIGRATE_OK", use_kernels)

        run(False)
        run(True)
        """,
        devices=2,
    )
    assert out.count("MIGRATE_OK") == 2
