"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 —
Finch: data-dependent decay time-mix + channel-mix. [arXiv:2404.05892; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,              # 2560 / rwkv_head_dim(64)
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    rwkv_head_dim=64,
    tie_embeddings=False,    # rwkv uses separate head
)
