"""Sharding-rule resolver unit tests (no devices needed beyond CPU)."""
from __future__ import annotations

import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    # abstract mesh: no devices touched

    return sh.abstract_mesh((16, 16), ("data", "model"))


def test_param_fsdp_tp(mesh):
    spec = sh.resolve_spec((4096, 32, 128), ("embed", "heads", "head_dim"),
                           sh.BASE_RULES, mesh)
    assert spec == P("data", "model")


def test_kv_heads_fall_back_to_replication_when_indivisible(mesh):
    spec = sh.resolve_spec((4096, 4, 128), ("embed", "kv_heads", "head_dim"),
                           sh.BASE_RULES, mesh)
    assert spec == P("data")          # kv=4 not divisible by 16 -> replicated


def test_vocab_sharded_when_divisible(mesh):
    assert sh.resolve_spec((262144, 5376), ("vocab", "embed"),
                           sh.BASE_RULES, mesh) == P("model", "data")
    # whisper vocab 51865 is odd -> replicated
    assert sh.resolve_spec((51865, 512), ("vocab", "embed"),
                           sh.BASE_RULES, mesh) == P(None, "data")


def test_no_axis_reuse(mesh):
    # embed takes data; a second embed-like dim cannot reuse it
    spec = sh.resolve_spec((2560, 2560), ("embed", "embed"), sh.BASE_RULES, mesh)
    assert spec == P("data")


def test_batch_axis_prefers_pod_data():
    mesh3 = sh.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert sh.resolve_spec((256, 4096), ("batch", None), sh.BASE_RULES, mesh3) == P(
        ("pod", "data")
    )
    # batch=1 (long_500k): replicated
    assert sh.resolve_spec((1, 4096), ("batch", None), sh.BASE_RULES, mesh3) == P()


def test_opt_rules_enable_sp_and_cache_seq():
    mesh3 = sh.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    a = sh.resolve_spec((256, 4096, 5376), ("batch", "act_seq", None),
                        sh.OPT_RULES, mesh3)
    assert a == P(("pod", "data"), "model")
    # decode cache with kv_heads=8 (indivisible by 16): seq picks up model
    c = sh.resolve_spec((40, 128, 32768, 8, 128),
                        ("layers", "batch", "cache_seq", "kv_heads", None),
                        sh.OPT_RULES, mesh3)
    assert c == P(None, ("pod", "data"), "model")


def test_expert_parallel(mesh):
    spec = sh.resolve_spec((16, 6144, 10752), ("expert", "embed", "expert_mlp"),
                           sh.BASE_RULES, mesh)
    assert spec == P("model", "data")


def test_mesh_construction_contract():
    """make_production_mesh shapes/axes per the dry-run contract (needs the
    512-device env only when actually building; use spec check via source)."""
    import inspect

    from repro.launch import mesh as mesh_mod

    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
