"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors a kernel's *exact* contract (same inputs/outputs); the
kernel test suite sweeps shapes and dtypes asserting allclose/array_equal
against these.  Implementations delegate to ``repro.core.batched`` — the jnp
dataplane engine — so the oracle and the system share one source of protocol
truth.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import batched
from repro.core.types import (
    MSG_NOP,
    MSG_P2A,
    MSG_P2B,
    AcceptorState,
    MsgBatch,
)

NO_ROUND = -1


def acceptor_phase2_window(
    st_rnd, st_vrnd, st_val, base, aid, msgtype, msg_rnd, msg_val
) -> tuple[jax.Array, ...]:
    """Oracle for kernels.acceptor.acceptor_phase2_window."""
    n = st_rnd.shape[0]
    b = msgtype.shape[0]
    inst = (jnp.asarray(base, jnp.int32) + jnp.arange(b, dtype=jnp.int32)) % n
    msgs = MsgBatch(
        msgtype=msgtype,
        inst=inst,
        rnd=msg_rnd,
        vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
        swid=jnp.zeros((b,), jnp.int32),
        value=msg_val,
    )
    astate = AcceptorState(st_rnd, st_vrnd, st_val)
    astate, votes = batched.acceptor_phase2(astate, msgs, aid=aid)
    return (
        astate.rnd,
        astate.vrnd,
        astate.value,
        votes.msgtype,
        votes.rnd,
        votes.vrnd,
        votes.swid,
        votes.value,
    )


def coordinator_sequence_window(
    next_inst, crnd, active
) -> tuple[jax.Array, ...]:
    """Oracle for kernels.coordinator.coordinator_sequence_window."""
    b = active.shape[0]
    inst = jnp.asarray(next_inst, jnp.int32) + jnp.arange(b, dtype=jnp.int32)
    msgtype = jnp.where(active.astype(bool), MSG_P2A, MSG_NOP).astype(jnp.int32)
    rnd = jnp.full((b,), jnp.asarray(crnd, jnp.int32), jnp.int32)
    vrnd = jnp.full((b,), NO_ROUND, jnp.int32)
    return msgtype, inst, rnd, vrnd, (jnp.asarray(next_inst, jnp.int32) + b)


def learner_quorum_window(
    quorum, vote_type, vote_vrnd, vote_val
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for kernels.learner.learner_quorum_window."""
    is_vote = vote_type == MSG_P2B
    masked = jnp.where(is_vote, vote_vrnd, NO_ROUND)
    win = jnp.max(masked, axis=0)
    agree = is_vote & (vote_vrnd == win[None, :])
    count = jnp.sum(agree.astype(jnp.int32), axis=0)
    deliver = (count >= jnp.asarray(quorum, jnp.int32)).astype(jnp.int32)
    first = agree & (jnp.cumsum(agree.astype(jnp.int32), axis=0) == 1)
    value = jnp.sum(first.astype(jnp.int32)[:, :, None] * vote_val, axis=0)
    return deliver, win, value


def wirepath_round(
    next_inst, crnd, quorum, alive,
    st_rnd, st_vrnd, st_val, ldel, linst, lval, values,
) -> tuple[jax.Array, ...]:
    """Oracle for kernels.wirepath.wirepath_round — delegates to the jnp
    fused round so oracle and system share one source of protocol truth."""
    b = values.shape[0]
    cstate = batched.CoordinatorState(
        next_inst=jnp.asarray(next_inst, jnp.int32),
        crnd=jnp.asarray(crnd, jnp.int32),
    )
    stack = AcceptorState(st_rnd, st_vrnd, st_val)
    lstate = batched.LearnerState(ldel, linst, lval)
    active = jnp.ones((b,), bool)
    _, stack, lstate, fresh, _, win, value = batched.fused_round(
        cstate, stack, lstate, values, active,
        jnp.asarray(alive).astype(bool), jnp.asarray(quorum, jnp.int32),
    )
    return (
        stack.rnd, stack.vrnd, stack.value,
        lstate.delivered, lstate.inst, lstate.value,
        fresh.astype(jnp.int32), win, value,
    )


def acceptor_vote_all_window(
    st_rnd, st_vrnd, st_val, base, alive, msgtype, msg_rnd, msg_val
) -> tuple[jax.Array, ...]:
    """Oracle for kernels.wirepath.acceptor_vote_all_window."""
    n = st_rnd.shape[1]
    b = msgtype.shape[0]
    inst = (jnp.asarray(base, jnp.int32) + jnp.arange(b, dtype=jnp.int32)) % n
    msgs = MsgBatch(
        msgtype=msgtype,
        inst=inst,
        rnd=msg_rnd,
        vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
        swid=jnp.zeros((b,), jnp.int32),
        value=msg_val,
    )
    stack = AcceptorState(st_rnd, st_vrnd, st_val)
    stack, votes = batched.acceptor_phase2_all(
        stack, msgs, jnp.asarray(alive).astype(bool)
    )
    return (
        stack.rnd, stack.vrnd, stack.value,
        votes.msgtype, votes.rnd, votes.vrnd, votes.swid, votes.value,
    )


def digest(x: jax.Array) -> jax.Array:
    """Oracle for kernels.digest.digest (including padding semantics)."""
    flat = x.reshape(-1)
    bits = flat.view(jnp.int32) if flat.dtype != jnp.int32 else flat
    idx = jnp.arange(bits.shape[0], dtype=jnp.int32)
    return jnp.sum(bits * (idx * 2 + 1))


def flash_attention(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, KVH, Sk, D)
    v: jax.Array,
    *,
    window: int = 0,
    causal: bool = True,
    softmax_scale=None,
) -> jax.Array:
    """Oracle for kernels.flash_attention (direct softmax, no tiling)."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    qg = q.reshape(b, kvh, g, sq, d)
    s = jnp.einsum(
        "bkgqd,bksd->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgqs,bksd->bkgqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, h, sq, d).astype(q.dtype)
