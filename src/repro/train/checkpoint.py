"""Sharded checkpointing with consensus-committed manifests.

Layout per step::

    <dir>/step_<N>/
        manifest.json     {step, leaf paths, shapes, dtypes, digest}
        leaf_00000.npy ...
        COMMITTED         (written only after the manifest digest is decided
                           through the consensus log)

The two-phase structure is the paper's checkpoint/trim protocol applied to
training state: hosts write shards independently (phase: data), then the
manifest digest is proposed as a consensus value (phase: commit).  On
restart, only checkpoints whose manifest digest appears in the decided log —
or whose COMMITTED marker exists in the single-controller simulation — are
eligible, so a crash mid-write can never yield a half-restored model.

``restore`` reshards: leaves are loaded host-side and ``device_put`` against
the *current* mesh's shardings, so the same checkpoint restores onto a
different device count (elastic restart).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, paxos_ctx=None):
        self.dir = directory
        self.ctx = paxos_ctx
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, state: Any, step: int) -> str:
        path = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(path, exist_ok=True)
        leaves, treedef = jax.tree_util.tree_flatten(state)
        manifest = {"step": step, "n_leaves": len(leaves), "leaves": []}
        h = hashlib.sha256()
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(path, fn), arr)
            h.update(arr.tobytes()[:4096])  # sampled content hash
            manifest["leaves"].append(
                {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        manifest["digest"] = h.hexdigest()[:16]
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        self._commit(path, manifest)
        return path

    def _commit(self, path: str, manifest: dict) -> None:
        if self.ctx is not None:
            # propose the manifest digest through the consensus log
            payload = f"ckpt:{manifest['step']}:{manifest['digest']}".encode()
            self.ctx.submit(payload)
            self.ctx.run_until_quiescent()
            decided = any(
                p.startswith(b"ckpt:") and p == payload
                for _, p in self.ctx.delivered_log
            )
            if not decided:
                return  # not committed; leave checkpoint uncommitted
        with open(os.path.join(path, "COMMITTED"), "w") as f:
            f.write("ok")

    # -- restore ------------------------------------------------------------
    def latest_committed(self) -> str | None:
        if not os.path.isdir(self.dir):
            return None
        steps = sorted(
            d
            for d in os.listdir(self.dir)
            if d.startswith("step_")
            and os.path.exists(os.path.join(self.dir, d, "COMMITTED"))
        )
        return os.path.join(self.dir, steps[-1]) if steps else None

    def restore(
        self, like: Any, path: str | None = None, shardings: Any = None
    ) -> tuple[Any, int]:
        """Restore into the structure of ``like``; optionally reshard.

        ``shardings``: matching pytree of Shardings for the *current* mesh —
        arrays are device_put against it (elastic restart onto a new mesh).
        """
        path = path or self.latest_committed()
        if path is None:
            raise FileNotFoundError("no committed checkpoint")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(leaves_like) == manifest["n_leaves"], "structure mismatch"
        out = []
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        for i, meta in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(path, meta["file"]))
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
