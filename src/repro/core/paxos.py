"""Reference (scalar, host-side) Paxos role semantics.

This module is the *semantic oracle* for the whole system: plain-Python,
dictionary-based role state machines implementing exactly the protocol of the
paper (multi-Paxos with the Phase-1-elision optimization, §2.1/§3).  It is
used by:

  * the hypothesis property tests (adversarial message schedules), and
  * ``core/baseline.py`` — the "libpaxos-like" software baseline the paper
    compares against (Fig. 2 / Fig. 7).

The batched JAX engine (``core/batched.py``) and the Pallas kernels
(``kernels/``) must agree with these semantics; tests enforce it.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

from .types import (
    MSG_DELIVER,
    MSG_P1A,
    MSG_P1B,
    MSG_P2A,
    MSG_P2B,
    MSG_REJECT,
    MSG_SUBMIT,
)

NO_ROUND = -1


@dataclasses.dataclass
class Msg:
    """One Paxos header (paper Fig. 5), scalar form."""

    msgtype: int
    inst: int = 0
    rnd: int = NO_ROUND
    vrnd: int = NO_ROUND
    swid: int = 0
    value: bytes = b""

    def clone(self, **kw) -> "Msg":
        return dataclasses.replace(self, **kw)


class Proposer:
    """Software proposer: wraps values into SUBMIT headers (paper §3)."""

    def __init__(self, pid: int):
        self.pid = pid
        self.pending: dict[int, bytes] = {}   # seq -> payload (for retransmit)
        self._seq = 0

    def submit(self, payload: bytes) -> Msg:
        self._seq += 1
        self.pending[self._seq] = payload
        return Msg(MSG_SUBMIT, swid=self.pid, value=payload)


class Coordinator:
    """Sequencer: binds proposals to monotonically increasing instances.

    Under the single-coordinator optimization it never runs Phase 1 for fresh
    instances (acceptors are pre-initialized to promise round 0); Phase 1 is
    used only on takeover / recover.
    """

    def __init__(self, cid: int = 0, crnd: int = 0, next_inst: int = 0,
                 n_instances: int = 1 << 16):
        self.cid = cid
        self.crnd = crnd
        self.next_inst = next_inst
        self.n_instances = n_instances
        # Phase-1 bookkeeping for recover/takeover: inst -> {acceptor: (vrnd, value)}
        self.p1b: dict[tuple[int, int], dict[int, tuple[int, bytes]]] = {}

    # -- normal path (hardware fast path in CAANS) --------------------------
    def on_submit(self, msg: Msg) -> Msg:
        inst = self.next_inst
        self.next_inst += 1
        return Msg(MSG_P2A, inst=inst, rnd=self.crnd, swid=self.cid,
                   value=msg.value)

    # -- recovery path (phase 1 then 2) --------------------------------------
    def prepare(self, inst: int, rnd: int | None = None) -> Msg:
        if rnd is None:
            rnd = self.crnd
        return Msg(MSG_P1A, inst=inst, rnd=rnd, swid=self.cid)

    def on_p1b(self, msg: Msg, quorum: int) -> Msg | None:
        """Collect promises; at quorum, issue P2A with the required value.

        Returns the P2A to send once a quorum of promises for (inst, rnd) has
        been gathered, else None.  Chooses the value of the highest ``vrnd``
        among promises, or keeps the no-op the caller will supply.
        """
        key = (msg.inst, msg.rnd)
        acc = self.p1b.setdefault(key, {})
        acc[msg.swid] = (msg.vrnd, msg.value)
        if len(acc) < quorum:
            return None
        vrnd, value = max(acc.values(), key=lambda t: t[0])
        if vrnd == NO_ROUND:
            value = None  # caller substitutes the application no-op
        return Msg(MSG_P2A, inst=msg.inst, rnd=msg.rnd, swid=self.cid,
                   value=value if value is not None else b"")


class Acceptor:
    """The protocol's memory: a bounded ring of (rnd, vrnd, value) slots."""

    def __init__(self, aid: int, n_instances: int = 1 << 16):
        self.aid = aid
        self.n_instances = n_instances
        # slot -> (promised rnd, voted rnd, voted value).  Pre-initialized
        # (lazily) to (0, NO_ROUND, b"") == "promised round 0", eliding Phase 1.
        self.slots: dict[int, tuple[int, int, bytes]] = {}

    def _get(self, inst: int) -> tuple[int, int, bytes]:
        return self.slots.get(inst % self.n_instances, (0, NO_ROUND, b""))

    def _set(self, inst: int, v: tuple[int, int, bytes]) -> None:
        self.slots[inst % self.n_instances] = v

    def on_p1a(self, msg: Msg) -> Msg:
        rnd, vrnd, value = self._get(msg.inst)
        if msg.rnd > rnd:
            self._set(msg.inst, (msg.rnd, vrnd, value))
            return Msg(MSG_P1B, inst=msg.inst, rnd=msg.rnd, vrnd=vrnd,
                       swid=self.aid, value=value)
        return Msg(MSG_REJECT, inst=msg.inst, rnd=rnd, swid=self.aid)

    def on_p2a(self, msg: Msg) -> Msg:
        rnd, vrnd, value = self._get(msg.inst)
        if msg.rnd >= rnd:
            self._set(msg.inst, (msg.rnd, msg.rnd, msg.value))
            return Msg(MSG_P2B, inst=msg.inst, rnd=msg.rnd, vrnd=msg.rnd,
                       swid=self.aid, value=msg.value)
        return Msg(MSG_REJECT, inst=msg.inst, rnd=rnd, swid=self.aid)


class Learner:
    """Counts votes; delivers once a quorum votes the same round.

    Duplicate-safe: a (learner, instance) delivers at most once (paper §3.1,
    "learners detect and discard duplicated delivered values").
    """

    def __init__(self, lid: int, n_acceptors: int,
                 deliver_cb: Callable[[int, bytes], None] | None = None):
        self.lid = lid
        self.quorum = n_acceptors // 2 + 1
        self.votes: dict[int, dict[int, tuple[int, bytes]]] = {}
        self.delivered: dict[int, bytes] = {}
        self.deliver_cb = deliver_cb

    def on_p2b(self, msg: Msg) -> Msg | None:
        if msg.inst in self.delivered:
            return None
        votes = self.votes.setdefault(msg.inst, {})
        votes[msg.swid] = (msg.vrnd, msg.value)
        # quorum = f+1 votes with the same vrnd
        by_rnd: dict[int, int] = {}
        for vrnd, _ in votes.values():
            by_rnd[vrnd] = by_rnd.get(vrnd, 0) + 1
        for vrnd, count in by_rnd.items():
            if count >= self.quorum:
                value = next(v for r, v in votes.values() if r == vrnd)
                self.delivered[msg.inst] = value
                if self.deliver_cb:
                    self.deliver_cb(msg.inst, value)
                return Msg(MSG_DELIVER, inst=msg.inst, rnd=vrnd, value=value)
        return None

    def gaps(self, upto: int | None = None) -> list[int]:
        """Instances below the watermark that this learner has not delivered.

        With an explicit ``upto`` watermark the answer is defined even when
        nothing has been delivered yet: every instance in ``[0, upto]`` is a
        gap.  Only the implicit watermark (max delivered) needs deliveries.
        """
        if upto is None:
            if not self.delivered:
                return []
            hi = max(self.delivered)
        else:
            hi = upto
        return [i for i in range(hi + 1) if i not in self.delivered]
