"""Model registry: one uniform interface per architecture family.

Every family module exports:
  specs(cfg)                       -> PSpec tree
  forward(cfg, params, batch)      -> (logits, cache|None)
  prefill(cfg, params, batch)      -> (logits, cache)
  decode_step(cfg, params, tok, cache, pos) -> (logits, cache)
  init_cache / cache_specs / CACHE_AXES

``input_specs`` builds the ShapeDtypeStruct stand-ins for every model input
of an (arch x shape) cell — the dry-run lowers against these without any
device allocation.  Modality frontends ([audio]/[vlm]) are stubs: the specs
include precomputed frame/patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

from . import griffin, rwkv6, transformer, whisper
from . import layers as L

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": griffin,
    "encdec": whisper,
}


def family_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def model_specs(cfg: ModelConfig):
    return family_module(cfg).specs(cfg)


def param_axes(cfg: ModelConfig):
    return L.axes_tree(model_specs(cfg))


def param_shapes(cfg: ModelConfig, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    return L.spec_shapes(model_specs(cfg), dt)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    return L.materialize(model_specs(cfg), key, dt)


def count_params(cfg: ModelConfig) -> int:
    import numpy as np

    shapes = jax.tree_util.tree_leaves(param_shapes(cfg))
    return int(sum(np.prod(s.shape) for s in shapes))


# ---------------------------------------------------------------------------
# Input specs per (arch x shape) cell
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStructs for every input of the cell's step function.

    train:   {tokens, labels [, patches|frames]}
    prefill: {tokens [, patches|frames]}
    decode:  {tokens (B,1), cache, pos}
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)

    def frontend() -> dict[str, Any]:
        if cfg.family == "vlm":
            return {
                "patches": jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), dt)
            }
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((b, cfg.src_len, cfg.d_model), dt)
            }
        return {}

    if shape.kind == "train":
        return {
            "tokens": tok,
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            **frontend(),
        }
    if shape.kind == "prefill":
        return {"tokens": tok, **frontend()}
    if shape.kind == "decode":
        mod = family_module(cfg)
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "cache": mod.cache_specs(cfg, b, s, dt),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array) -> dict[str, Any]:
    """Concrete (small-scale) inputs matching ``input_specs`` — for smoke tests."""
    specs = input_specs(cfg, shape)
    out: dict[str, Any] = {}
    for name, sp in specs.items():
        if name == "cache":
            out[name] = family_module(cfg).init_cache(
                cfg, shape.global_batch, shape.seq_len, jnp.dtype(cfg.dtype)
            )
        elif name == "pos":
            out[name] = jnp.int32(0)
        elif sp.dtype == jnp.int32:
            key, k = jax.random.split(key)
            out[name] = jax.random.randint(k, sp.shape, 0, cfg.vocab, jnp.int32)
        else:
            key, k = jax.random.split(key)
            out[name] = jax.random.normal(k, sp.shape, jnp.float32).astype(sp.dtype)
    return out
