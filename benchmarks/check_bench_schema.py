"""Schema / sanity check of a committed wire-path bench artifact.

``BENCH_wirepath.json`` is both the perf-trajectory record and the baseline
the CI regression gate diffs against — a malformed commit (truncated sweep,
NaN ratio, missing headline row) would otherwise only surface after CI has
spent a full bench run, or worse, silently disable a gate.  This check is
pure JSON validation: it runs in milliseconds, before any bench, and it is
also exercised as a fast-lane unit test (``tests/test_bench_schema.py``)
so a bad artifact fails the cheapest job first.

With ``--ci path/to/ci.yml`` it additionally cross-checks the regression
gate's CLI flags against the headline catalogue: every ``--*tolerance`` /
``--min-*`` flag the workflow passes must key a required headline row, and
every required headline must be gated by at least one flag — so a gate
flag and its baseline row can never drift apart silently.

    PYTHONPATH=src python -m benchmarks.check_bench_schema \\
        BENCH_wirepath.json --ci .github/workflows/ci.yml
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys

# Headline rows the regression gate keys on: committing an artifact without
# them would silently skip (or permanently fail) a gate.
REQUIRED_HEADLINES = (
    "wirepath/speedup_pallas_vs_per_acceptor/",
    "wirepath/multigroup_scaling_pallas/",
    "wirepath/sharded_scaling_pallas/",
    "wirepath/skew_speedup_twotier/",
    "wirepath/sustained_ratio/",
    "wirepath/kv_read_write_ratio/",
    "wirepath/persistent_speedup/",
    "wirepath/trickle_persistent_ratio/",
    "wirepath/skew_sharded_pallas/",
)
RATIO_FIELDS = (
    "speedup", "scaling", "skew_speedup", "sustained_ratio", "kv_ratio",
    "persistent_speedup", "trickle_persistent_ratio",
    "persistent_amortization", "skew_sharded_ratio",
)

# Regression-gate CLI flag -> the headline prefix it gates.  The CI
# cross-check (--ci) fails on a flag with no headline (typo / stale gate)
# and on a headline no flag gates (silently ungated metric).
FLAG_HEADLINES = {
    "--tolerance": "wirepath/speedup_pallas_vs_per_acceptor/",
    "--min-mg-scaling": "wirepath/multigroup_scaling_pallas/",
    "--sharded-tolerance": "wirepath/sharded_scaling_pallas/",
    "--skew-tolerance": "wirepath/skew_speedup_twotier/",
    "--sustained-tolerance": "wirepath/sustained_ratio/",
    "--kv-tolerance": "wirepath/kv_read_write_ratio/",
    "--min-kv-ratio": "wirepath/kv_read_write_ratio/",
    "--persistent-tolerance": "wirepath/persistent_speedup/",
    "--min-persistent-speedup": "wirepath/persistent_speedup/",
    "--min-trickle-ratio": "wirepath/trickle_persistent_ratio/",
    "--min-skew-sharded-ratio": "wirepath/skew_sharded_pallas/",
}


def check_ci_gate_flags(ci_text: str) -> list[str]:
    """Cross-check the workflow's regression-gate invocation against the
    headline catalogue (pure text scan — no yaml dependency)."""
    errors: list[str] = []
    # isolate the gate invocation: from the module name to the end of the
    # backslash-continued command
    m = re.search(
        r"check_wirepath_regression(?:\s*\\\n|[^\n]|\n\s+-)*", ci_text
    )
    if m is None:
        return ["ci workflow never invokes check_wirepath_regression"]
    flags = re.findall(r"--[a-z][a-z-]*", m.group(0))
    if not flags:
        return ["regression gate invocation passes no --flags at all"]
    gated = set()
    for flag in flags:
        prefix = FLAG_HEADLINES.get(flag)
        if prefix is None:
            errors.append(
                f"gate flag {flag} has no headline mapping "
                f"(typo, or FLAG_HEADLINES needs the new metric)"
            )
        else:
            gated.add(prefix)
    for prefix in REQUIRED_HEADLINES:
        if prefix not in gated:
            errors.append(
                f"headline {prefix}* is required but no gate flag in "
                f"ci.yml exercises it (ungated metric)"
            )
    return errors


def _finite_positive(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0


def validate(doc: dict) -> list[str]:
    """Returns a list of human-readable schema violations (empty = valid)."""
    errors: list[str] = []
    meta = doc.get("meta")
    if not isinstance(meta, dict) or "backend" not in meta:
        errors.append("meta missing or has no 'backend' key")
    elif meta.get("partial"):
        errors.append(
            "artifact is a partial sweep (meta.partial) — the committed "
            "baseline must come from the full sweep"
        )
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return errors + ["rows missing or empty"]
    for i, row in enumerate(rows):
        name = row.get("name")
        if not isinstance(name, str) or not name.startswith("wirepath/"):
            errors.append(f"row {i}: bad name {name!r}")
            continue
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or not math.isfinite(us) or us < 0:
            errors.append(f"{name}: bad us_per_call {us!r}")
        if "msgs_per_s" in row and not _finite_positive(row["msgs_per_s"]):
            if not row.get("skipped"):
                errors.append(f"{name}: bad msgs_per_s {row['msgs_per_s']!r}")
        for field in RATIO_FIELDS:
            if field in row and not _finite_positive(row[field]):
                errors.append(f"{name}: bad {field} {row[field]!r}")
    names = [r.get("name", "") for r in rows]
    for prefix in REQUIRED_HEADLINES:
        if not any(
            n.startswith(prefix)
            and any(f in r for f in RATIO_FIELDS)
            for n, r in zip(names, rows, strict=True)
        ):
            errors.append(f"missing headline row {prefix}* (gate would skip)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.check_bench_schema", description=__doc__
    )
    ap.add_argument("artifact", help="committed bench JSON to validate")
    ap.add_argument(
        "--ci",
        default=None,
        help="workflow yaml to cross-check gate flags against headlines",
    )
    ns = ap.parse_args(sys.argv[1:] if argv is None else argv)
    with open(ns.artifact) as f:
        doc = json.load(f)
    errors = validate(doc)
    if ns.ci is not None:
        with open(ns.ci) as f:
            errors += check_ci_gate_flags(f.read())
    if errors:
        for e in errors:
            print(f"SCHEMA: {e}", file=sys.stderr)
        return 1
    print(
        f"bench schema OK: {len(doc['rows'])} rows, "
        f"backend={doc['meta'].get('backend')}"
        + (", ci gate flags cross-checked" if ns.ci else "")
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
