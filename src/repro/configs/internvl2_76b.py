"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    n_patches=256,           # stub ViT patch embeddings per image
    rope_theta=1_000_000.0,
)
