"""End-to-end training driver: a ~100M-param qwen3-family model, a few
hundred steps, with every fault-tolerance feature live:

  * quorum step-commit (straggler groups abstain; step still commits)
  * consensus-committed checkpoints (+ restart from the committed manifest)
  * coordinator failover mid-run

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

On this CPU container the default is a reduced step count; pass --steps for
the full run.  The identical driver scales to the production mesh with
--mesh prod in repro.launch.train.
"""
import argparse
import dataclasses
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.core import PaxosConfig, PaxosContext
from repro.models import registry
from repro.train import checkpoint as ckpt_mod
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import train_loop


def build_100m_config():
    """A ~100M-parameter member of the qwen3 family."""
    cfg = get_config("qwen3-4b")
    return dataclasses.replace(
        cfg,
        name="qwen3-100m",
        n_layers=8,
        d_model=896,
        n_heads=14,
        n_kv_heads=7,
        head_dim=64,
        d_ff=3584,
        vocab=512,             # tiny vocab: convergence visible in ~30 steps
        dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = build_100m_config()
    n = registry.count_params(cfg)
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")

    key = jax.random.PRNGKey(0)
    state = train_loop.init_state(cfg, key)
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=3, total_steps=args.steps)
    step_fn = jax.jit(train_loop.make_train_step(cfg, ocfg), donate_argnums=(0,))

    stream = data_mod.SyntheticStream(
        data_mod.DataConfig(vocab=cfg.vocab, global_batch=args.batch,
                            seq_len=args.seq, mode="arith")
    )
    paxos = PaxosContext(
        PaxosConfig(n_acceptors=3, n_instances=8192, batch=16), fused=True
    )
    with tempfile.TemporaryDirectory() as d:
        mgr = ckpt_mod.CheckpointManager(d, paxos_ctx=paxos)
        loop = train_loop.LoopConfig(
            steps=args.steps,
            checkpoint_every=max(args.steps // 3, 5),
            straggler_prob=0.1,           # 10% of groups miss the deadline
        )
        t0 = time.time()
        state, hist = train_loop.run_loop(
            cfg, state, iter(stream), loop=loop, train_step=step_fn,
            paxos_ctx=paxos, checkpoint_mgr=mgr,
        )
        dt = time.time() - t0
        committed = sum(hist["committed"])
        straggled = sum(hist["straggled"])
        k = max(min(4, args.steps // 3), 1)
        first, last = hist["loss"][:k], hist["loss"][-k:]
        print(
            f"{args.steps} steps in {dt:.1f}s "
            f"({dt/args.steps*1e3:.0f} ms/step); "
            f"loss {sum(first)/k:.3f} -> {sum(last)/k:.3f} "
            f"(window mean of {k}); "
            f"committed {committed}/{args.steps} steps despite "
            f"{straggled} straggler events"
        )
        assert sum(last) / k < sum(first) / k, (first, last)
        assert committed == args.steps  # quorum always reached w/ p=0.1

        # crash + restart from the committed checkpoint
        ck = mgr.latest_committed()
        assert ck is not None
        restored, at_step = mgr.restore(state)
        print(f"restart OK from committed checkpoint at step {at_step} ({ck})")

        # mid-run coordinator failover does not lose commit records
        paxos.fail_coordinator()
        paxos.submit(b"post-failover-probe")
        paxos.run_until_quiescent()
        print(f"consensus log: {paxos.stats['delivered']} records delivered "
              f"(step commits + checkpoint commits), coordinator failover OK")


if __name__ == "__main__":
    main()
