import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax-importing import: jax locks the
device count at first backend init, and the production meshes need 512
placeholder host devices.

Per cell this produces a JSON artifact with:
  * memory analysis (bytes per device: arguments / outputs / temps / peak)
  * cost analysis (HLO FLOPs, bytes accessed) of the partitioned module
  * collective schedule (bytes + op counts by collective type)
  * the roofline terms derived from the above (analysis/roofline.py)

Usage:
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/artifacts/dryrun
"""
import argparse
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp

from repro.analysis import analytic as analytic_mod
from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline as roofline_mod
from repro.configs import SHAPES, cell_is_applicable, get_config, list_archs
from repro.launch import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.serve.engine import make_prefill_step, make_serve_step
from repro.train import train_loop


def _mem_analysis(compiled) -> dict[str, float]:
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        return {
            "argument_bytes": float(getattr(m, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(m, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(m, "temp_size_in_bytes", 0)),
            "peak_bytes": float(
                getattr(m, "temp_size_in_bytes", 0)
                + getattr(m, "argument_size_in_bytes", 0)
                + getattr(m, "output_size_in_bytes", 0)
            ),
        }
    except Exception:
        return {}


def _arg_bytes_per_device(shardings_tree, shapes_tree, mesh) -> float:
    """Fallback per-device argument bytes computed from shapes x shardings."""
    import numpy as np

    total = 0.0
    shards = jax.tree_util.tree_leaves(
        shardings_tree, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
    )
    shapes = jax.tree_util.tree_leaves(shapes_tree)
    for sds, s in zip(shapes, shards, strict=False):
        if not hasattr(sds, "shape"):
            continue
        n = float(np.prod(sds.shape)) if sds.shape else 1.0
        n /= s.num_devices / _replication(s, sds.shape, mesh)
        total += n * jnp.dtype(sds.dtype).itemsize
    return total


def _replication(sharding, shape, mesh) -> float:
    try:
        spec = sharding.spec
        sharded = 1
        for part in spec:
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            import numpy as np

            sharded *= int(np.prod([mesh.shape[a] for a in axes]))
        return sharding.num_devices / sharded
    except Exception:
        return 1.0


# §Perf variants: named config transforms stacked on the baseline.
import dataclasses as _dc

VARIANTS = {
    "base": lambda cfg, mp: cfg,
    "dots_remat": lambda cfg, mp: _dc.replace(cfg, remat_policy="dots"),
    "ring_cache": lambda cfg, mp: _dc.replace(cfg, ring_local_cache=True),
    "moe_local": lambda cfg, mp: _dc.replace(
        cfg, dispatch_groups=32 if mp else 16
    ),
    "moe_local_dots": lambda cfg, mp: _dc.replace(
        cfg, dispatch_groups=32 if mp else 16, remat_policy="dots"
    ),
}


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    rules_name: str = "base",
    variant: str = "base",
    compile_it: bool = True,
    chunk_q: int | None = None,
) -> dict[str, Any]:
    cfg = VARIANTS[variant](get_config(arch), multi_pod)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "rules": rules_name,
        "variant": variant,
        "chips": 512 if multi_pod else 256,
    }
    if not cell_is_applicable(cfg, shape):
        rec["skipped"] = (
            "long_500k requires sub-quadratic sequence mixing; "
            f"family '{cfg.family}' is full-attention (see DESIGN.md §5)"
        )
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = {
        "base": sh.BASE_RULES,
        "opt": sh.OPT_RULES,
        "serve": sh.SERVE_RULES,
        "notp": sh.NOTP_RULES,
    }[rules_name]
    t0 = time.time()
    try:
        with sh.use_rules(mesh, rules):
            specs = registry.input_specs(cfg, shape)
            in_batch_sh = sh.batch_shardings(specs, cfg, rules, mesh)

            if shape.kind == "train":
                state_shapes = train_loop.state_shapes(cfg)
                state_axes = train_loop.state_axes(cfg)
                state_sh = sh.tree_shardings(state_shapes, state_axes, rules, mesh)
                step = train_loop.make_train_step(cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(state_sh, in_batch_sh),
                    donate_argnums=(0,),
                )
                lowered = jitted.lower(state_shapes, specs)
                rec["arg_bytes_per_dev_est"] = _arg_bytes_per_device(
                    (state_sh, in_batch_sh), (state_shapes, specs), mesh
                )
            else:
                pshapes = registry.param_shapes(cfg)
                paxes = registry.param_axes(cfg)
                psh = sh.tree_shardings(pshapes, paxes, rules, mesh)
                if shape.kind == "prefill":
                    step = make_prefill_step(cfg)
                    jitted = jax.jit(step, in_shardings=(psh, in_batch_sh))
                    lowered = jitted.lower(
                        pshapes, {k: v for k, v in specs.items()}
                    )
                else:  # decode
                    step = make_serve_step(cfg)
                    jitted = jax.jit(
                        step,
                        in_shardings=(
                            psh,
                            in_batch_sh["tokens"],
                            in_batch_sh["cache"],
                            in_batch_sh["pos"],
                        ),
                        donate_argnums=(2,),
                    )
                    lowered = jitted.lower(
                        pshapes, specs["tokens"], specs["cache"], specs["pos"]
                    )
                rec["arg_bytes_per_dev_est"] = _arg_bytes_per_device(
                    psh, pshapes, mesh
                )
            rec["lower_s"] = time.time() - t0

            if compile_it:
                t1 = time.time()
                compiled = lowered.compile()
                rec["compile_s"] = time.time() - t1
                rec.update(_mem_analysis(compiled))
                try:
                    cost = compiled.cost_analysis()
                    if isinstance(cost, list):
                        cost = cost[0]
                    rec["flops"] = float(cost.get("flops", 0.0))
                    rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
                except Exception as e:  # pragma: no cover
                    rec["cost_error"] = str(e)
                text = compiled.as_text()
                # trip hint: the layer scan (hybrid scans superblocks)
                if cfg.family == "hybrid":
                    trip = cfg.n_layers // len(cfg.block_pattern)
                else:
                    trip = cfg.n_layers
                total, by_op, counts = hlo_mod.collective_bytes(
                    text, loop_trip_hint=trip
                )
                rec["collective_bytes"] = float(total)
                rec["collective_by_op"] = by_op
                rec["collective_counts"] = counts
                raw_total, _, _ = hlo_mod.collective_bytes(text, loop_trip_hint=1)
                rec["collective_bytes_raw"] = float(raw_total)
            # analytic compute/memory terms (HLO cost_analysis undercounts
            # while-loop bodies — kept above as the cross-check columns)
            minfo = analytic_mod.MeshInfo.for_mesh(
                multi_pod, shape.global_batch, rules_name
            )
            at = analytic_mod.analytic_terms(cfg, shape, minfo)
            rec["flops_hlo_raw"] = rec.pop("flops", 0.0)
            rec["bytes_accessed_hlo_raw"] = rec.pop("bytes_accessed", 0.0)
            rec["flops"] = at["flops"]
            rec["bytes_accessed"] = at["hbm_bytes"]
            rec["model_flops"] = at["model_flops"]
            rl = roofline_mod.from_record(rec)
            rec["roofline"] = rl.row()
            rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument(
        "--rules", choices=["base", "opt", "serve", "notp"], default="base"
    )
    ap.add_argument("--variant", choices=sorted(VARIANTS), default="base")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape in (None, "all")) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}__{args.rules}"
                if args.variant != "base":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        old = json.load(f)
                    if old.get("ok") or old.get("skipped"):
                        print(f"[cached] {tag}")
                        n_ok += 1 if old.get("ok") else 0
                        n_skip += 1 if old.get("skipped") else 0
                        continue
                rec = lower_cell(
                    arch, shape, multi_pod=mp, rules_name=args.rules,
                    variant=args.variant,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("skipped"):
                    n_skip += 1
                    print(f"[skip] {tag}: {rec['skipped'][:60]}")
                elif rec.get("ok"):
                    n_ok += 1
                    rl = rec.get("roofline", {})
                    print(
                        f"[ok]   {tag}: compile={rec.get('compile_s', 0):.1f}s "
                        f"flops/dev={rec.get('flops', 0):.3g} "
                        f"coll={rec.get('collective_bytes', 0):.3g}B "
                        f"dominant={rl.get('dominant')}"
                    )
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag}: {rec.get('error')}")
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
