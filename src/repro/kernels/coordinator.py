"""Pallas TPU kernel: the CAANS coordinator (monotonic sequencer).

The paper's coordinator is a one-register P4 stage: bind each proposal to
``inst = next_inst++`` and stamp the coordinator round (header rewrite, no
packet synthesis).  Batched: ``inst = next_inst + iota(B)``; the new sequencer
watermark is ``next_inst + B``.  Trivial compute — the kernel exists because
the coordinator is a measured dataplane component in the paper (Table 1) and
because on TPU it fuses the header rewrite of a whole burst into one VMEM
pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import MSG_NOP, MSG_P2A

NO_ROUND = -1
DEFAULT_BLOCK_B = 128


def _coordinator_kernel(
    next_inst_ref,    # int32[1] scalar prefetch
    crnd_ref,         # int32[1] scalar prefetch
    active_ref,       # int32[BB]
    msgtype_ref,      # int32[BB] out
    inst_ref,         # int32[BB] out
    rnd_ref,          # int32[BB] out
    vrnd_ref,         # int32[BB] out
):
    i = pl.program_id(0)
    bb = active_ref.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)[:, 0]
    active = active_ref[...] != 0
    msgtype_ref[...] = jnp.where(active, MSG_P2A, MSG_NOP).astype(jnp.int32)
    inst_ref[...] = next_inst_ref[0] + i * bb + lane
    rnd_ref[...] = jnp.full((bb,), crnd_ref[0], jnp.int32)
    vrnd_ref[...] = jnp.full((bb,), NO_ROUND, jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def coordinator_sequence_window(
    next_inst: jax.Array,   # int32[]
    crnd: jax.Array,        # int32[]
    active: jax.Array,      # bool/int32[B]
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (msgtype[B], inst[B], rnd[B], vrnd[B], new_next_inst[])."""
    b = active.shape[0]
    bb = min(block_b, b)
    assert b % bb == 0
    grid = (b // bb,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[pl.BlockSpec((bb,), lambda i, *_: (i,))],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, *_: (i,)),
            pl.BlockSpec((bb,), lambda i, *_: (i,)),
            pl.BlockSpec((bb,), lambda i, *_: (i,)),
            pl.BlockSpec((bb,), lambda i, *_: (i,)),
        ],
    )
    out_shapes = [jax.ShapeDtypeStruct((b,), jnp.int32) for _ in range(4)]
    fn = pl.pallas_call(
        _coordinator_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )
    ni = jnp.asarray(next_inst, jnp.int32).reshape((1,))
    cr = jnp.asarray(crnd, jnp.int32).reshape((1,))
    msgtype, inst, rnd, vrnd = fn(ni, cr, active.astype(jnp.int32))
    return msgtype, inst, rnd, vrnd, (ni[0] + b).astype(jnp.int32)
