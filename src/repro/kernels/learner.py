"""Pallas TPU kernel: learner quorum count + decided-value select.

The learner receives the A (=2f+1) position-aligned vote batches produced by
the acceptor array for one P2A burst and must decide, per position, whether a
quorum voted the same round — and if so, which value was decided.  On the
switch targets this is the software half of CAANS; on TPU the vote batches
are already device-resident after the vote all-gather (core/fabric.py), so
the quorum count is a small reduction over the acceptor axis, fused in VMEM.

Value select without gather: one-hot of the *first* acceptor agreeing with
the winning round, contracted against the vote values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import MSG_P2B

NO_ROUND = -1
DEFAULT_BLOCK_B = 128


def _learner_kernel(
    quorum_ref,      # int32[1] scalar prefetch
    vote_type_ref,   # int32[A, BB]
    vote_vrnd_ref,   # int32[A, BB]
    vote_val_ref,    # int32[A, BB, V]
    deliver_ref,     # int32[BB] out (0/1)
    win_vrnd_ref,    # int32[BB] out
    value_ref,       # int32[BB, V] out
):
    vtype = vote_type_ref[...]
    vrnd = vote_vrnd_ref[...]
    vval = vote_val_ref[...]

    is_vote = vtype == MSG_P2B                                  # [A, BB]
    masked = jnp.where(is_vote, vrnd, NO_ROUND)
    win = jnp.max(masked, axis=0)                               # [BB]
    agree = is_vote & (vrnd == win[None, :])                    # [A, BB]
    count = jnp.sum(agree.astype(jnp.int32), axis=0)            # [BB]
    deliver_ref[...] = (count >= quorum_ref[0]).astype(jnp.int32)
    win_vrnd_ref[...] = win
    # first agreeing acceptor as one-hot (cumsum trick), then contract
    first = agree & (jnp.cumsum(agree.astype(jnp.int32), axis=0) == 1)  # [A, BB]
    value_ref[...] = jnp.sum(
        first.astype(jnp.int32)[:, :, None] * vval, axis=0
    )


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def learner_quorum_window(
    quorum: jax.Array,       # int32[]
    vote_type: jax.Array,    # int32[A, B]
    vote_vrnd: jax.Array,    # int32[A, B]
    vote_val: jax.Array,     # int32[A, B, V]
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (deliver[B] int32 0/1, win_vrnd[B], value[B, V])."""
    a, b = vote_type.shape
    v = vote_val.shape[-1]
    bb = min(block_b, b)
    assert b % bb == 0
    grid = (b // bb,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((a, bb), lambda i, *_: (0, i)),
            pl.BlockSpec((a, bb), lambda i, *_: (0, i)),
            pl.BlockSpec((a, bb, v), lambda i, *_: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, *_: (i,)),
            pl.BlockSpec((bb,), lambda i, *_: (i,)),
            pl.BlockSpec((bb, v), lambda i, *_: (i, 0)),
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b, v), jnp.int32),
    ]
    fn = pl.pallas_call(
        _learner_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        interpret=interpret,
    )
    q = jnp.asarray(quorum, jnp.int32).reshape((1,))
    return tuple(fn(q, vote_type, vote_vrnd, vote_val))
