"""§Perf lever correctness: each optimization must be numerics-preserving."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.train import train_loop


def test_grouped_ring_cache_matches_forward():
    cfg = dataclasses.replace(
        get_config("gemma3-27b").reduced(),
        remat=False, ring_local_cache=True,
        local_window=4, global_every=3, n_layers=8,
    )
    mod = registry.family_module(cfg)
    key = jax.random.PRNGKey(5)
    params = registry.init_params(cfg, key)
    B, T = 2, 12
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    ref, _ = mod.forward(cfg, params, {"tokens": tokens})
    cache = mod.init_cache(cfg, B, T, jnp.dtype(cfg.dtype))
    outs = []
    for t in range(T):
        logits, cache = mod.decode_step(cfg, params, tokens[:, t : t + 1],
                                        cache, jnp.int32(t))
        outs.append(np.asarray(logits).reshape(B, -1))
    err = np.abs(np.stack(outs, 1) - np.asarray(ref)).max()
    assert err < 5e-3, err
    # the ring actually wraps: cache window < T
    assert cache["lk"].shape[3] == 4 < T


def test_grouped_cache_is_smaller():
    cfg = dataclasses.replace(get_config("gemma3-27b"), ring_local_cache=True)
    mod = registry.family_module(cfg)
    import math

    base = mod.cache_specs(dataclasses.replace(cfg, ring_local_cache=False),
                           128, 32768)
    grp = mod.cache_specs(cfg, 128, 32768)
    def nbytes(sp):
        return sum(
            math.prod(s.shape) * s.dtype.itemsize
            for s in jax.tree_util.tree_leaves(sp)
        )
    ratio = nbytes(base) / nbytes(grp)
    assert ratio > 4.0, ratio   # ~5.3x for 5:1 local:global @ 32k


def test_moe_dispatch_groups_parity():
    cfg = dataclasses.replace(get_config("dbrx-132b").reduced(), remat=False,
                              capacity_factor=8.0)
    mod = registry.family_module(cfg)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    o1, _ = mod.forward(cfg, params, {"tokens": toks})
    o2, _ = mod.forward(dataclasses.replace(cfg, dispatch_groups=2), params,
                        {"tokens": toks})
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)


def test_dots_remat_same_gradients():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced())
    key = jax.random.PRNGKey(1)
    from repro.configs.base import ShapeConfig

    batch = registry.make_inputs(cfg, ShapeConfig("t", 16, 2, "train"), key)
    state = train_loop.init_state(cfg, key)
    s1, m1 = jax.jit(train_loop.make_train_step(cfg))(state, batch)
    cfg2 = dataclasses.replace(cfg, remat_policy="dots")
    s2, m2 = jax.jit(train_loop.make_train_step(cfg2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
