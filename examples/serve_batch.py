"""Batched serving example: continuous batching over a reduced qwen3.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.serve.engine import Request, ServeLoop


def main() -> None:
    cfg = get_config("qwen3-4b").reduced()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    requests = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new=8)
        for i in range(12)
    ]
    loop = ServeLoop(cfg, params, batch_size=4, max_len=24)
    t0 = time.time()
    out = loop.run(requests)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(requests)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, {loop.steps} decode steps)")
    for rid in sorted(out)[:3]:
        print(f"  req {rid}: {out[rid]}")
    # determinism across batches with identical prompts
    r2 = ServeLoop(cfg, params, batch_size=4, max_len=24).run(requests)
    assert r2 == out
    print("deterministic across re-serve: OK")


if __name__ == "__main__":
    main()
