"""Fast-lane unit tests for the replicated KV tier (serve/kv.py).

Covers the op codec (round-trip + malformed-frame rejection), the
deterministic apply loop's cas/tombstone semantics, lease validity and
read-watermark monotonicity, the consensus-free read path's dispatch-count
pin, the payload-width door guards, the typed Session surface, and
snapshot state transfer applying (never replaying) through the dataplane.
"""
import sys

import pytest

sys.path.insert(0, "src")

from repro.core.api import PaxosContext  # noqa: E402
from repro.core.snapshot import RingOverflowError  # noqa: E402
from repro.core.types import PaxosConfig  # noqa: E402
from repro.serve.engine import ConsensusService, Session, Ticket  # noqa: E402
from repro.serve.kv import (  # noqa: E402
    OP_CAS,
    OP_DELETE,
    OP_GET,
    OP_PUT,
    GroupReplica,
    KvCodecError,
    KvOp,
    ReplicatedKV,
    decode_op,
    encode_op,
)

A = 3
CFG = PaxosConfig(n_acceptors=A, n_instances=64, batch=8, n_groups=2)


def _service(cfg=CFG):
    return ConsensusService(PaxosContext(cfg))


# ---------------------------------------------------------------------------
# Op codec
# ---------------------------------------------------------------------------
def test_codec_round_trips_every_op_shape():
    ops = [
        KvOp(OP_PUT, b"key", b"value", None, 0xDEADBEEF, 7),
        KvOp(OP_PUT, b"", b"", None, 0, 0),               # empty key/value
        KvOp(OP_DELETE, b"gone", b"", None, 1, 2),
        KvOp(OP_CAS, b"k", b"new", b"old", 42, 3),        # expect a value
        KvOp(OP_CAS, b"k", b"new", b"", 42, 4),           # expect empty value
        KvOp(OP_CAS, b"k", b"new", None, 42, 5),          # expect ABSENT
        KvOp(OP_GET, b"", b"", None, 99, 6),              # read-index marker
    ]
    for op in ops:
        assert decode_op(encode_op(op)) == op, op
    # expect=None and expect=b"" are distinct frames (absent vs empty)
    assert encode_op(ops[4]) != encode_op(ops[5])


def test_codec_rejects_malformed_frames():
    good = encode_op(KvOp(OP_CAS, b"key", b"val", b"old", 5, 9))
    with pytest.raises(KvCodecError, match="truncated"):
        decode_op(good[:10])
    with pytest.raises(KvCodecError, match="magic"):
        decode_op(b"\x00" + good[1:])
    with pytest.raises(KvCodecError, match="version"):
        decode_op(good[:1] + b"\x7f" + good[2:])
    with pytest.raises(KvCodecError, match="opcode"):
        decode_op(good[:2] + b"\x7f" + good[3:])
    with pytest.raises(KvCodecError, match="flags"):
        decode_op(good[:3] + b"\x80" + good[4:])
    with pytest.raises(KvCodecError, match="length"):
        decode_op(good + b"extra")                         # trailing bytes
    with pytest.raises(KvCodecError, match="length"):
        decode_op(good[:-1])                               # short body
    # expect flag only makes sense on cas
    put = bytearray(encode_op(KvOp(OP_PUT, b"k", b"v")))
    put[3] |= 1                                            # forge expect flag
    with pytest.raises(KvCodecError, match="non-cas"):
        decode_op(bytes(put))
    # expect bytes without the flag
    cas = bytearray(good)
    cas[3] = 0
    with pytest.raises(KvCodecError, match="without the expect flag"):
        decode_op(bytes(cas))
    # unencodable ops are refused at the encoder door
    with pytest.raises(KvCodecError, match="unknown opcode"):
        encode_op(KvOp(99, b"k"))
    with pytest.raises(KvCodecError, match="only meaningful on cas"):
        encode_op(KvOp(OP_PUT, b"k", b"v", expect=b"x"))


# ---------------------------------------------------------------------------
# Apply loop: cas semantics, tombstones, versions, RYW counters
# ---------------------------------------------------------------------------
def _log(*ops):
    return [(i, encode_op(op)) for i, op in enumerate(ops)]


def test_replica_cas_and_tombstone_semantics():
    rep = GroupReplica()
    rep.apply_log(_log(
        KvOp(OP_CAS, b"k", b"v0", None, 1, 1),     # create iff absent: applies
        KvOp(OP_CAS, b"k", b"xx", None, 1, 2),     # expect-absent now fails
        KvOp(OP_CAS, b"k", b"v1", b"v0", 1, 3),    # matches: applies
        KvOp(OP_CAS, b"k", b"xx", b"v0", 1, 4),    # stale expect: no-op
        KvOp(OP_PUT, b"d", b"x", None, 2, 1),
        KvOp(OP_DELETE, b"d", b"", None, 2, 2),    # tombstone, not removal
        KvOp(OP_GET, b"", b"", None, 3, 1),        # marker: no state change
    ))
    assert rep.state[b"k"] == (b"v1", 2)           # two applied mutations
    assert rep.state[b"d"] == (None, 2)            # tombstone bumps version
    # every op advances its session's RYW counter, applied or not
    assert rep.applied_counter == {1: 4, 2: 2, 3: 1}
    # cas against a tombstone is expect-absent semantics
    rep.apply_log(_log(
        KvOp(OP_CAS, b"k", b"v0", None, 1, 1),
        KvOp(OP_CAS, b"k", b"xx", None, 1, 2),
        KvOp(OP_CAS, b"k", b"v1", b"v0", 1, 3),
        KvOp(OP_CAS, b"k", b"xx", b"v0", 1, 4),
        KvOp(OP_PUT, b"d", b"x", None, 2, 1),
        KvOp(OP_DELETE, b"d", b"", None, 2, 2),
        KvOp(OP_GET, b"", b"", None, 3, 1),
        KvOp(OP_CAS, b"d", b"back", None, 4, 1),   # revives the deleted key
    ))
    assert rep.state[b"d"] == (b"back", 3)
    assert rep.applied_len == 8
    # the cursor refuses a shrinking view of its segment
    with pytest.raises(ValueError, match="shrank"):
        rep.apply_log([])


def test_read_watermark_is_monotone_and_tracks_the_log():
    svc = _service()
    kv = ReplicatedKV(svc)
    s = kv.session("mono")
    gid = svc.group_of("mono")
    seen = [kv.read_watermark(gid)]
    for wave in range(3):
        for k in range(4):
            s.put(f"w{wave}k{k}".encode(), b"v")
        svc.run_until_quiescent()
        kv.refresh()
        seen.append(kv.read_watermark(gid))
        assert seen[-1] == len(svc.ctx.full_group_log(gid))
    assert seen == sorted(seen) and seen[-1] == 12
    # refresh is idempotent: no new entries, no watermark motion
    kv.refresh()
    assert kv.read_watermark(gid) == 12


# ---------------------------------------------------------------------------
# Consensus-free reads: lease validity and the dispatch-count pin
# ---------------------------------------------------------------------------
def test_leased_get_dispatches_nothing():
    svc = _service()
    kv = ReplicatedKV(svc)
    s = kv.session("alice")
    s.put(b"k", b"v1")
    svc.run_until_quiescent()
    base = svc.ctx.hw.dispatch_count
    assert s.lease_valid is False        # pending until refresh prunes it
    for _ in range(5):
        assert s.get(b"k") == b"v1"
        assert s.lease_valid
    assert s.get(b"missing") is None
    assert svc.ctx.hw.dispatch_count == base    # zero wire-path launches
    assert kv.stats == {"leased_gets": 6, "read_index_gets": 0,
                        "ops_submitted": 1}


def test_pending_write_forces_read_index():
    svc = _service()
    kv = ReplicatedKV(svc)
    s = kv.session("alice")
    s.put(b"k", b"v1")
    svc.run_until_quiescent()
    assert s.get(b"k") == b"v1"          # leased
    s.put(b"k", b"v2")                   # in flight: lease breaks
    base = svc.ctx.hw.dispatch_count
    assert s.get(b"k") == b"v2"          # read-index waits out the write
    assert svc.ctx.hw.dispatch_count > base
    assert kv.stats["read_index_gets"] == 1
    # the read-index round re-validated the lease
    assert s.lease_valid
    assert s.get(b"k") == b"v2"
    assert kv.stats["leased_gets"] == 2


def test_lease_survives_unrelated_retire_but_not_own():
    cfg = PaxosConfig(n_acceptors=A, n_instances=64, batch=8, n_groups=4)
    svc = _service(cfg)
    kv = ReplicatedKV(svc)
    sid = "alice"
    mine = svc.group_of(sid)
    other = next(g for g in range(4) if g != mine)
    s = kv.session(sid)
    s.put(b"k", b"v1")
    svc.run_until_quiescent()
    assert s.get(b"k") == b"v1"

    # membership event that does NOT move this session: epoch bumps, but the
    # segment is unchanged — the lease re-validates host-side, no dispatch
    svc.retire_group(other)
    base = svc.ctx.hw.dispatch_count
    assert s.get(b"k") == b"v1"
    assert svc.ctx.hw.dispatch_count == base
    assert kv.stats["read_index_gets"] == 0

    # retiring the session's OWN group moves it: stale lease, read-index
    # fallback, and the value survives via the stitched archive
    svc.retire_group(mine)
    assert s.get(b"k") == b"v1"
    assert svc.ctx.hw.dispatch_count > base
    assert kv.stats["read_index_gets"] == 1
    assert s.lease_valid                 # re-validated at the new epoch


# ---------------------------------------------------------------------------
# Payload-width door guards
# ---------------------------------------------------------------------------
def test_oversized_payload_rejected_at_every_door():
    svc = _service()
    limit = CFG.max_payload_bytes
    assert limit == CFG.value_words * 4 - 8
    fat = b"x" * (limit + 1)
    with pytest.raises(ValueError, match=f"at most {limit} payload"):
        svc.ctx.submit(fat, group=0)
    with pytest.raises(ValueError, match=f"at most {limit} payload"):
        svc.session("s").submit(fat)
    with pytest.raises(ValueError, match=f"at most {limit} payload"):
        with pytest.warns(DeprecationWarning):
            svc.submit("s", fat)
    # the limit itself still fits
    svc.session("s").submit(b"x" * limit)
    svc.run_until_quiescent()
    assert svc.session("s").read() == [b"x" * limit]


# ---------------------------------------------------------------------------
# Typed Session surface + deprecation shims
# ---------------------------------------------------------------------------
def test_session_handle_and_ticket():
    svc = _service()
    sess = svc.session("u1")
    assert isinstance(sess, Session)
    assert sess.group == svc.group_of("u1")
    t = sess.submit(b"op0")
    assert isinstance(t, Ticket)
    assert t.group == sess.group
    gid, seq = t                          # historical tuple unpacking
    assert (gid, seq) == (t.group, t.seq)
    svc.run_until_quiescent()
    assert sess.read() == [b"op0"]
    assert [p for _i, p in sess.delivered()] == [b"op0"]
    # the old loose surface still works, loudly
    with pytest.warns(DeprecationWarning, match="session_id"):
        t2 = svc.submit("u1", b"op1")
    assert isinstance(t2, Ticket) and t2.group == t.group
    svc.run_until_quiescent()
    with pytest.warns(DeprecationWarning):
        assert [p for _i, p in svc.delivered("u1")] == [b"op0", b"op1"]


def test_ring_overflow_context_dict():
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8)
    ctx = PaxosContext(cfg, fused=True, snapshots=True)
    for i in range(16):
        ctx.submit(f"m{i}".encode())
    ctx.run_until_quiescent()
    with pytest.raises(RingOverflowError) as ei:
        ctx.submit(b"overflow")
        ctx.pump()
    e = ei.value
    assert e.context == {
        "group": e.group,
        "base": e.base,
        "burst": e.burst,
        "boundary": e.boundary,
        "attempted": e.attempted,
    }
    assert e.context["attempted"] > e.context["boundary"]


# ---------------------------------------------------------------------------
# Snapshot state transfer: applied host-side, never replayed
# ---------------------------------------------------------------------------
def test_adopted_snapshot_is_applied_not_replayed():
    cfg = PaxosConfig(n_acceptors=A, n_instances=16, batch=8, n_groups=2)
    ctx1 = PaxosContext(cfg, snapshots=True)
    svc1 = ConsensusService(ctx1)
    kv1 = ReplicatedKV(svc1)
    sid = next(f"s{i}" for i in range(64) if svc1.group_of(f"s{i}") == 1)
    s = kv1.session(sid)
    for k in range(8):
        s.put(f"k{k}".encode(), f"v{k}".encode())
    svc1.run_until_quiescent()
    ctx1.snapshot_group(1)               # compact below the watermark
    for k in range(8):
        s.put(f"k{k}".encode(), f"w{k}".encode())
    svc1.run_until_quiescent()
    kv1.refresh()
    sig = kv1.replica(1).signature()
    assert sig[1] == 16

    snap = ctx1.snapshot_group(1)
    prefix = list(ctx1.snapshots.log_prefix(1))

    ctx2 = PaxosContext(cfg, snapshots=True)
    svc2 = ConsensusService(ctx2)
    kv2 = ReplicatedKV(svc2)
    svc2.retire_group(1)                 # free the slot for the transfer
    gid = svc2.adopt_group(snap, log_prefix=prefix)
    assert gid == 1
    kv2.refresh()
    # bit-identical replica state, reconstructed from the sealed prefix
    assert kv2.replica(1).signature() == sig
    # ...without a single wire-path launch: applied, not replayed
    assert ctx2.hw.dispatch_count == 0
