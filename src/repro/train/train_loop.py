"""Train-step factory + host loop: grad accumulation, CAANS quorum commit,
straggler masking, checkpoint hooks.

The quorum step-commit (DESIGN.md §3) is a first-class part of ``train_step``:
the gradient digest is computed inside the compiled program (one cheap pass
over the grads) and exposed in the metrics; the host loop feeds digests into
the consensus layer and a step only becomes durable once f+1 of 2f+1 replica
groups voted the same digest.  In the single-controller simulation the vote
is exercised through ``core.fabric.quorum_commit_digest`` (multi-device
tests) or the PaxosContext (host tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.models import registry

from . import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState
    step: jax.Array


def init_state(cfg, key, opt_cfg: opt.OptConfig | None = None) -> TrainState:
    params = registry.init_params(cfg, key)
    return TrainState(params=params, opt=opt.init(params), step=jnp.zeros((), jnp.int32))


def state_shapes(cfg) -> TrainState:
    """ShapeDtypeStruct state (dry-run: no allocation)."""
    ps = registry.param_shapes(cfg)
    return TrainState(
        params=ps,
        opt=opt.init_shapes(ps),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def state_axes(cfg) -> TrainState:
    """Logical-axes pytree matching TrainState (for sharding resolution)."""
    axes = registry.param_axes(cfg)
    return TrainState(
        params=axes,
        opt=opt.OptState(mu=axes, nu=axes, count=()),
        step=(),
    )


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def _grad_digest(grads) -> jax.Array:
    """Cheap order-sensitive digest of the grad pytree (bitwise, fp-exact).

    Same weighted-fold construction as kernels/digest.py (the kernel is the
    TPU dataplane version; inside the autodiff program we use the jnp form so
    the whole step stays one XLA computation).

    Sharding note (§Perf iteration 1): the fold must be *shape-preserving*.
    A ``reshape(-1)`` over a 2-axis-sharded gradient forces GSPMD to fully
    replicate the tensor (observed: 157 GiB all-gathers per MoE leaf on
    dbrx-132b).  The linear index is therefore built from broadcasted iotas
    at the leaf's own shape — elementwise + scalar reduction, fully
    partitionable; the only communication left is the scalar psum.
    """
    acc = jnp.int32(0)
    for leaf in jax.tree_util.tree_leaves(grads):
        if leaf.dtype.itemsize == 2:
            bits = leaf.view(jnp.int16).astype(jnp.int32)
        elif leaf.dtype.itemsize == 4:
            bits = leaf.view(jnp.int32)
        else:
            bits = leaf.astype(jnp.float32).view(jnp.int32)
        lin = jnp.zeros((), jnp.int32)
        stride = 1
        for axis in range(leaf.ndim - 1, -1, -1):
            lin = lin + jax.lax.broadcasted_iota(jnp.int32, bits.shape, axis) * stride
            stride *= leaf.shape[axis]
        acc = acc * jnp.int32(1000003) + jnp.sum(bits * (lin * 2 + 1))
    return acc


def make_loss_fn(cfg) -> Callable:
    mod = registry.family_module(cfg)

    def loss_fn(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _ = mod.forward(cfg, params, inputs)
        return _xent(logits, batch["labels"])

    return loss_fn


def make_train_step(
    cfg,
    opt_cfg: opt.OptConfig | None = None,
    *,
    grad_accum: int = 1,
    with_digest: bool = True,
) -> Callable[[TrainState, dict[str, jax.Array]], tuple[TrainState, dict]]:
    """Build the jit-able train step (microbatched when grad_accum > 1)."""
    ocfg = opt_cfg or opt.OptConfig()
    loss_fn = make_loss_fn(cfg)
    vg = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: dict[str, jax.Array]):
        if grad_accum == 1:
            loss, grads = vg(state.params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                l, g = vg(state.params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
                batch,
            )
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum

        new_params, new_opt, gnorm = opt.update(grads, state.opt, state.params, ocfg)
        metrics = {"loss": loss, "grad_norm": gnorm}
        if with_digest:
            metrics["digest"] = _grad_digest(grads)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# Host loop with CAANS-committed steps (single-controller simulation)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    commit_quorum: int = 2        # f+1 of 2f+1 replica groups
    replica_groups: int = 3       # 2f+1
    checkpoint_every: int = 0     # 0 = off
    straggler_prob: float = 0.0   # simulated straggling group probability


def run_loop(
    cfg,
    state: TrainState,
    data_iter,
    *,
    loop: LoopConfig,
    train_step: Callable | None = None,
    paxos_ctx=None,
    checkpoint_mgr=None,
    rng_seed: int = 0,
) -> tuple[TrainState, dict[str, list]]:
    """Drive training with quorum-committed steps.

    Every step, each replica group's digest is submitted as a consensus value;
    the step is durable once the consensus layer delivers a quorum agreement.
    A simulated straggler group abstains — the quorum still commits, which is
    the straggler-mitigation property inherited from the paper's f-of-2f+1
    resilience.
    """
    import numpy as np

    step_fn = train_step or jax.jit(make_train_step(cfg))
    history: dict[str, list] = {"loss": [], "committed": [], "straggled": []}
    rng = np.random.default_rng(rng_seed)

    for i in range(loop.steps):
        batch = next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        digest = int(jax.device_get(metrics.get("digest", jnp.int32(0))))

        # replica groups vote with their digest; deterministic data-parallel
        # math means healthy groups agree bit-exactly.
        votes = []
        straggled = 0
        for _g in range(loop.replica_groups):
            if rng.random() < loop.straggler_prob:
                straggled += 1
                continue  # group missed the deadline -> abstains
            votes.append(digest)
        committed = len(votes) >= loop.commit_quorum
        if paxos_ctx is not None and committed:
            paxos_ctx.submit(
                b"step:" + int(jax.device_get(state.step)).to_bytes(4, "little")
                + digest.to_bytes(4, "little", signed=True)
            )
            paxos_ctx.pump(2)

        history["loss"].append(float(jax.device_get(metrics["loss"])))
        history["committed"].append(committed)
        history["straggled"].append(straggled)

        if (
            checkpoint_mgr is not None
            and loop.checkpoint_every
            and (i + 1) % loop.checkpoint_every == 0
        ):
            checkpoint_mgr.save(state, step=int(jax.device_get(state.step)))

    return state, history
