from .engine import (  # noqa: F401
    ConsensusService,
    Request,
    ServeLoop,
    Session,
    Ticket,
    make_prefill_step,
    make_serve_step,
    session_hash,
)
from .kv import (  # noqa: F401
    OP_CAS,
    OP_DELETE,
    OP_GET,
    OP_PUT,
    GroupReplica,
    KvCodecError,
    KvOp,
    KVSession,
    ReplicatedKV,
    decode_op,
    encode_op,
)
