"""Fused dataplane (single-dispatch Phase-2 round) vs the staged path."""
from __future__ import annotations

from _hypothesis_compat import given, settings, st

from repro.core import FaultSpec, PaxosConfig, PaxosContext, SimNet

CFG = PaxosConfig(n_acceptors=3, n_instances=512, batch=16)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 999))
def test_fused_equals_staged_delivery(n, seed):
    payloads = [f"q{k}".encode() for k in range(n)]
    got = {}
    for mode in (False, True):
        out = []
        ctx = PaxosContext(
            CFG, deliver=lambda v, s, i: out.append(v), net=SimNet(seed=seed),
            fused=mode,
        )
        for p in payloads:
            ctx.submit(p)
        ctx.run_until_quiescent()
        got[mode] = out
    assert got[True] == got[False] == payloads


def test_fused_tolerates_acceptor_failure():
    out = []
    ctx = PaxosContext(CFG, deliver=lambda v, s, i: out.append(v), fused=True)
    ctx.hw.kill_acceptor(1)
    for k in range(8):
        ctx.submit(f"f{k}".encode())
    ctx.run_until_quiescent()
    assert len(out) == 8
    # two dead -> no quorum -> no deliveries
    ctx2 = PaxosContext(CFG, fused=True)
    ctx2.hw.kill_acceptor(0)
    ctx2.hw.kill_acceptor(1)
    ctx2.submit(b"never")
    ctx2.pump(20)
    assert ctx2.stats["delivered"] == 0


def test_fused_then_failover_switches_to_staged():
    out = []
    ctx = PaxosContext(CFG, deliver=lambda v, s, i: out.append(v), fused=True)
    for k in range(4):
        ctx.submit(f"a{k}".encode())
    ctx.run_until_quiescent()
    ctx.fail_coordinator()
    for k in range(4):
        ctx.submit(f"b{k}".encode())
    ctx.run_until_quiescent()
    assert len(out) == 8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_fused_duplicate_suppression_under_client_loss(seed):
    """Submit-path loss + retransmit may decide a payload in two instances;
    the application must still see it exactly once."""
    net = SimNet(FaultSpec(drop=0.3, dup=0.2), seed=seed)
    out = []
    ctx = PaxosContext(CFG, deliver=lambda v, s, i: out.append(v), net=net,
                       fused=True)
    for k in range(12):
        ctx.submit(f"d{k}".encode())
    ctx.run_until_quiescent(max_rounds=200)
    assert sorted(out) == sorted(f"d{k}".encode() for k in range(12))
