"""Batched multi-instance Paxos dataplane in JAX.

This is the jnp-level "hardware" implementation of the coordinator / acceptor
/ learner-quorum logic: every function processes a *batch* of Paxos headers
(``MsgBatch``) in one shot.  The Pallas kernels in ``repro.kernels`` implement
the same functions with explicit VMEM tiling; ``kernels/ref.py`` re-exports
these as the oracles.

Semantics notes
---------------
* ``coordinator_sequence`` assigns a contiguous instance window to each batch
  (monotonic sequencer).  Slots in a batch therefore hit *distinct* acceptor
  ring slots, which makes the vectorized scatter in ``acceptor_phase2`` exact.
* For adversarial traffic (recovery, duplicated instances inside one batch)
  use ``acceptor_sequential`` — a ``lax.scan`` with exact one-message-at-a-time
  semantics.  Tests check that on distinct-slot batches both paths agree.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .types import (
    MSG_NOP,
    MSG_P1A,
    MSG_P1B,
    MSG_P2A,
    MSG_P2B,
    MSG_REJECT,
    AcceptorState,
    CoordinatorState,
    MsgBatch,
)

NO_ROUND = jnp.int32(-1)


# ---------------------------------------------------------------------------
# Coordinator (sequencer)
# ---------------------------------------------------------------------------
def coordinator_sequence(
    cstate: CoordinatorState, values: jax.Array, active: jax.Array
) -> tuple[CoordinatorState, MsgBatch]:
    """Bind a batch of proposals to a contiguous window of instances.

    Inactive slots still consume an instance and carry a NOP marker — they are
    decided and discarded by the application layer (the paper's no-op values).
    This preserves window contiguity, the property the acceptor fast path and
    the Pallas kernel exploit.
    """
    b = values.shape[0]
    inst = cstate.next_inst + jnp.arange(b, dtype=jnp.int32)
    msgtype = jnp.where(active, MSG_P2A, MSG_NOP).astype(jnp.int32)
    out = MsgBatch(
        msgtype=msgtype,
        inst=inst,
        rnd=jnp.full((b,), cstate.crnd, jnp.int32),
        vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
        swid=jnp.zeros((b,), jnp.int32),
        value=values,
    )
    new = CoordinatorState(next_inst=cstate.next_inst + b, crnd=cstate.crnd)
    return new, out


# ---------------------------------------------------------------------------
# Acceptor — vectorized fast path (distinct ring slots per batch)
# ---------------------------------------------------------------------------
def acceptor_phase2(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> tuple[AcceptorState, MsgBatch]:
    """Vote on a batch of P2A requests against the instance ring.

    accept iff msgtype==P2A and msg.rnd >= promised rnd of the slot.
    NOP slots pass through as NOPs (they are *not* votes).
    """
    n = astate.n_instances
    slots = msgs.inst % n
    cur_rnd = astate.rnd[slots]
    is_p2a = (msgs.msgtype == MSG_P2A) | (msgs.msgtype == MSG_NOP)
    # NOP slots are sequenced instances carrying the no-op value: acceptors
    # still vote so the instance is decided (and later discarded upstream).
    accept = is_p2a & (msgs.rnd >= cur_rnd)

    new_rnd = jnp.where(accept, msgs.rnd, cur_rnd)
    new_vrnd = jnp.where(accept, msgs.rnd, astate.vrnd[slots])
    new_val = jnp.where(accept[:, None], msgs.value, astate.value[slots])

    astate = AcceptorState(
        rnd=astate.rnd.at[slots].set(new_rnd, mode="drop"),
        vrnd=astate.vrnd.at[slots].set(new_vrnd, mode="drop"),
        value=astate.value.at[slots].set(new_val, mode="drop"),
    )
    votes = MsgBatch(
        msgtype=jnp.where(accept, MSG_P2B, MSG_REJECT).astype(jnp.int32),
        inst=msgs.inst,
        rnd=jnp.where(accept, msgs.rnd, cur_rnd),
        vrnd=jnp.where(accept, msgs.rnd, astate.vrnd[slots]),
        swid=jnp.full_like(msgs.swid, aid),
        value=jnp.where(accept[:, None], msgs.value, 0),
    )
    return astate, votes


def acceptor_phase1(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> tuple[AcceptorState, MsgBatch]:
    """Promise on a batch of P1A prepares (recovery / takeover path)."""
    n = astate.n_instances
    slots = msgs.inst % n
    cur_rnd = astate.rnd[slots]
    cur_vrnd = astate.vrnd[slots]
    cur_val = astate.value[slots]
    is_p1a = msgs.msgtype == MSG_P1A
    promise = is_p1a & (msgs.rnd > cur_rnd)

    astate = AcceptorState(
        rnd=astate.rnd.at[slots].set(jnp.where(promise, msgs.rnd, cur_rnd), mode="drop"),
        vrnd=astate.vrnd,
        value=astate.value,
    )
    out = MsgBatch(
        msgtype=jnp.where(promise, MSG_P1B, MSG_REJECT).astype(jnp.int32),
        inst=msgs.inst,
        rnd=jnp.where(promise, msgs.rnd, cur_rnd),
        vrnd=cur_vrnd,
        swid=jnp.full_like(msgs.swid, aid),
        value=cur_val,
    )
    return astate, out


# ---------------------------------------------------------------------------
# Acceptor array — all 2f+1 acceptors in one dispatch (SoA stacked state)
# ---------------------------------------------------------------------------
def acceptor_phase2_all(
    stack: AcceptorState, msgs: MsgBatch, alive: jax.Array
) -> tuple[AcceptorState, MsgBatch]:
    """Phase-2 vote of the *whole* acceptor array on one P2A batch.

    ``stack`` holds the A register files stacked on a leading axis; ``alive``
    is a bool[A] runtime mask.  Dead acceptors neither vote (their rows come
    back MSG_REJECT) nor mutate their register file — exactly the semantics
    of a crashed switch: its BRAM is frozen and it emits nothing.

    Inherits ``acceptor_phase2``'s vectorized-scatter precondition: batch
    positions must hit *distinct* ring slots (``inst % N`` pairwise
    distinct), or slot updates race.  Use ``acceptor_sequential`` for
    adversarial duplicate-slot traffic.

    One dispatch replaces the historical per-acceptor Python loop (which
    rewrote the full stacked state with ``.at[aid].set`` per acceptor).
    Returns (stack', votes) with every vote field shaped [A, ...].
    """
    a = stack.rnd.shape[0]

    def vote_one(st, aid, alv):
        new_st, votes = acceptor_phase2(st, msgs, aid=aid)
        # crashed acceptor: register file frozen, and its vote row is exactly
        # what a pure rejecter would emit (so the kernel path can reproduce
        # it without special cases)
        slots = msgs.inst % st.n_instances
        votes = votes.replace(
            msgtype=jnp.where(alv, votes.msgtype, MSG_REJECT).astype(jnp.int32),
            rnd=jnp.where(alv, votes.rnd, st.rnd[slots]),
            vrnd=jnp.where(alv, votes.vrnd, st.vrnd[slots]),
            value=jnp.where(alv, votes.value, 0),
        )
        st = jax.tree_util.tree_map(
            lambda n, o: jnp.where(alv, n, o), new_st, st
        )
        return st, votes

    return jax.vmap(vote_one)(stack, jnp.arange(a), alive)


def acceptor_phase1_all(
    stack: AcceptorState, msgs: MsgBatch, alive: jax.Array
) -> tuple[AcceptorState, MsgBatch]:
    """Phase-1 promise of the whole acceptor array (recovery/takeover path)."""
    a = stack.rnd.shape[0]

    def prep_one(st, aid, alv):
        new_st, out = acceptor_phase1(st, msgs, aid=aid)
        slots = msgs.inst % st.n_instances
        out = out.replace(
            msgtype=jnp.where(alv, out.msgtype, MSG_REJECT).astype(jnp.int32),
            rnd=jnp.where(alv, out.rnd, st.rnd[slots]),
        )
        st = jax.tree_util.tree_map(
            lambda n, o: jnp.where(alv, n, o), new_st, st
        )
        return st, out

    return jax.vmap(prep_one)(stack, jnp.arange(a), alive)


# ---------------------------------------------------------------------------
# Acceptor — exact sequential semantics (any batch, incl. duplicate slots)
# ---------------------------------------------------------------------------
def acceptor_sequential(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> tuple[AcceptorState, MsgBatch]:
    """One-message-at-a-time semantics via lax.scan (recovery / adversarial)."""

    def step(state: AcceptorState, m):
        msgtype, inst, rnd, vrnd, swid, value = m
        n = state.n_instances
        slot = inst % n
        cur_rnd = state.rnd[slot]
        cur_vrnd = state.vrnd[slot]
        cur_val = state.value[slot]

        is_p2 = (msgtype == MSG_P2A) | (msgtype == MSG_NOP)
        is_p1 = msgtype == MSG_P1A
        accept = is_p2 & (rnd >= cur_rnd)
        promise = is_p1 & (rnd > cur_rnd)

        upd_rnd = jnp.where(accept | promise, rnd, cur_rnd)
        upd_vrnd = jnp.where(accept, rnd, cur_vrnd)
        upd_val = jnp.where(accept, value, cur_val)
        state = AcceptorState(
            rnd=state.rnd.at[slot].set(upd_rnd),
            vrnd=state.vrnd.at[slot].set(upd_vrnd),
            value=state.value.at[slot].set(upd_val),
        )
        out_type = jnp.where(
            accept, MSG_P2B, jnp.where(promise, MSG_P1B, MSG_REJECT)
        ).astype(jnp.int32)
        out = (
            out_type,
            inst,
            jnp.where(accept | promise, rnd, cur_rnd),
            jnp.where(accept, rnd, cur_vrnd),
            jnp.full_like(swid, aid),
            jnp.where(is_p1, cur_val, jnp.where(accept, value, jnp.zeros_like(value))),
        )
        return state, out

    ms = (msgs.msgtype, msgs.inst, msgs.rnd, msgs.vrnd, msgs.swid, msgs.value)
    astate, outs = jax.lax.scan(step, astate, ms)
    return astate, MsgBatch(*outs)


# ---------------------------------------------------------------------------
# Learner — quorum over stacked votes
# ---------------------------------------------------------------------------
def learner_quorum(
    vote_msgtype: jax.Array,   # int32[A, B]
    vote_inst: jax.Array,      # int32[A, B]
    vote_vrnd: jax.Array,      # int32[A, B]
    vote_value: jax.Array,     # int32[A, B, V]
    quorum: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Position-aligned quorum count over the acceptor axis.

    Votes arriving from the A acceptors for the same P2A batch are aligned by
    batch position.  deliver[b] iff >= quorum acceptors voted (P2B) with the
    same vrnd.  Value is taken from any acceptor voting the winning vrnd
    (Paxos guarantees value uniqueness per (inst, rnd)).
    """
    is_vote = vote_msgtype == MSG_P2B                       # [A, B]
    # winning round = max vrnd among votes (NO_ROUND where none)
    vrnd_masked = jnp.where(is_vote, vote_vrnd, NO_ROUND)
    win_vrnd = jnp.max(vrnd_masked, axis=0)                 # [B]
    agree = is_vote & (vote_vrnd == win_vrnd[None, :])      # [A, B]
    count = jnp.sum(agree.astype(jnp.int32), axis=0)        # [B]
    deliver = count >= quorum                               # [B]

    # first acceptor index voting the winning round
    first = jnp.argmax(agree, axis=0)                       # [B]
    b = vote_inst.shape[1]
    cols = jnp.arange(b)
    inst = vote_inst[first, cols]
    value = vote_value[first, cols]
    return deliver, inst, win_vrnd, value


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LearnerState:
    """Dedup memory over the instance ring: delivered mask (0/1 int32, the
    kernel-native layout), the absolute instance last decided into each slot,
    and the decided value.

    Tracking the absolute ``inst`` per slot makes the dedup *ring-correct*:
    re-delivery of the same instance is suppressed, but a later instance
    reusing the slot after wraparound is fresh again (bounded memory, paper
    Table 3's 65,535-instance BRAM).
    """

    delivered: jax.Array  # int32[N]  0/1 mask
    inst: jax.Array       # int32[N]  absolute instance decided into the slot
    value: jax.Array      # int32[N, V]

    def tree_flatten(self):
        return ((self.delivered, self.inst, self.value), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def init(cls, n_instances: int, value_words: int) -> "LearnerState":
        return cls(
            delivered=jnp.zeros((n_instances,), jnp.int32),
            inst=jnp.full((n_instances,), -1, jnp.int32),
            value=jnp.zeros((n_instances, value_words), jnp.int32),
        )


def learner_update(
    lstate: LearnerState,
    deliver: jax.Array,
    inst: jax.Array,
    value: jax.Array,
) -> tuple[LearnerState, jax.Array]:
    """Record deliveries; returns mask of *fresh* (not duplicate) deliveries."""
    n = lstate.delivered.shape[0]
    slots = inst % n
    dup = (lstate.delivered[slots] != 0) & (lstate.inst[slots] == inst)
    fresh = deliver & ~dup
    lstate = LearnerState(
        delivered=lstate.delivered.at[slots].set(
            lstate.delivered[slots] | deliver.astype(jnp.int32), mode="drop"
        ),
        inst=lstate.inst.at[slots].set(
            jnp.where(fresh, inst, lstate.inst[slots]), mode="drop"
        ),
        value=lstate.value.at[slots].set(
            jnp.where(fresh[:, None], value, lstate.value[slots]), mode="drop"
        ),
    )
    return lstate, fresh


# ---------------------------------------------------------------------------
# Fused wire path — one Phase-2 round, sequencer -> acceptor array -> learner
# ---------------------------------------------------------------------------
def fused_round(
    cstate: CoordinatorState,
    stack: AcceptorState,
    lstate: LearnerState,
    values: jax.Array,    # int32[B, V]
    active: jax.Array,    # bool[B]
    alive: jax.Array,     # bool[A]
    quorum: int | jax.Array,
    reclaim_limit: jax.Array | None = None,  # int32[]; None = no reclamation
) -> tuple[CoordinatorState, AcceptorState, LearnerState,
           jax.Array, jax.Array, jax.Array, jax.Array]:
    """The CAANS wire path as one jnp program: coordinator sequencing, the
    whole acceptor array's Phase-2 vote, learner quorum count, and ring-dedup
    update — no host round-trips between the stages.

    This is the semantic oracle (and CPU fallback) for the Pallas megakernel
    ``repro.kernels.wirepath.wirepath_round``; the two must agree bit-for-bit
    (DESIGN.md §3).  ``reclaim_limit`` is the first instance the ring may NOT
    sequence into (snapshot watermark + N, DESIGN.md §9): lanes at or past it
    are presented at NO_ROUND so every acceptor rejects them — the oracle of
    the kernel's reclamation permit gate.  Returns
    ``(cstate', stack', lstate', fresh[B], inst[B], win_vrnd[B], value[B,V])``.
    """
    cstate, p2a = coordinator_sequence(cstate, values, active)
    if reclaim_limit is not None:
        permit = p2a.inst < jnp.asarray(reclaim_limit, jnp.int32)
        p2a = p2a.replace(rnd=jnp.where(permit, p2a.rnd, NO_ROUND))
    stack, votes = acceptor_phase2_all(stack, p2a, alive)
    deliver, inst, win, value = learner_quorum(
        votes.msgtype, votes.inst, votes.vrnd, votes.value, quorum
    )
    lstate, fresh = learner_update(lstate, deliver, inst, value)
    return cstate, stack, lstate, fresh, inst, win, value


# ---------------------------------------------------------------------------
# Multi-group wire path — G independent Paxos groups, one dispatch
# ---------------------------------------------------------------------------
def multigroup_fused_round(
    cstate: CoordinatorState,   # leaves shaped (G,)
    stack: AcceptorState,       # leaves shaped (G, A, N[, V])
    lstate: LearnerState,       # leaves shaped (G, N[, V])
    values: jax.Array,          # int32[G, B, V]
    active: jax.Array,          # bool[G, B]
    alive: jax.Array,           # bool[G, A]
    quorum: int | jax.Array,
    enabled: jax.Array | None = None,        # 0/1 per group; None = all
    reclaim_limit: jax.Array | None = None,  # int32[G]; None = no reclamation
) -> tuple[CoordinatorState, AcceptorState, LearnerState,
           jax.Array, jax.Array, jax.Array, jax.Array]:
    """``fused_round`` vmapped over a leading group axis: G device-resident
    Paxos groups advance one Phase-2 round in a single jnp program.

    Groups are fully independent — per-group sequencer watermark and round,
    per-group acceptor rings, per-group learner ring and liveness row — so
    this is bit-identical to running ``fused_round`` per group in a loop.
    It is the semantic oracle (and CPU fallback) for the Pallas megakernel
    ``repro.kernels.wirepath.multigroup_wirepath_round`` (DESIGN.md §5).
    ``reclaim_limit`` carries each group's reclamation limit (DESIGN.md §9).

    ``enabled`` (0/1 per group) holds disabled groups inert exactly as the
    kernel path does: a disabled group is presented at NO_ROUND so every
    acceptor rejects its slots.  Like the kernel wrapper, the returned
    coordinator watermark still advances for every group — callers that mix
    enabled/disabled groups correct the watermark with their own
    ``jnp.where(enabled, ...)`` (see ``persistent_multigroup_rounds``).
    Returns the ``fused_round`` tuple with every output grown a (G,) axis.
    """
    if enabled is not None:
        cstate = CoordinatorState(
            next_inst=cstate.next_inst,
            crnd=jnp.where(
                jnp.asarray(enabled) != 0, cstate.crnd, NO_ROUND
            ),
        )
    if reclaim_limit is None:
        return jax.vmap(fused_round, in_axes=(0, 0, 0, 0, 0, 0, None))(
            cstate, stack, lstate, values, active, alive, quorum
        )
    return jax.vmap(fused_round, in_axes=(0, 0, 0, 0, 0, 0, None, 0))(
        cstate, stack, lstate, values, active, alive, quorum,
        jnp.asarray(reclaim_limit, jnp.int32),
    )


def persistent_multigroup_rounds(
    cstate: CoordinatorState,   # leaves shaped (G,)
    stack: AcceptorState,       # leaves shaped (G, A, N[, V])
    lstate: LearnerState,       # leaves shaped (G, N[, V])
    values: jax.Array,          # int32[K, G, B, V]
    active: jax.Array,          # bool[K, G, B]
    alive: jax.Array,           # bool[G, A]
    quorum: int | jax.Array,
    enabled_rounds: jax.Array | None = None,  # bool/int32[K, G]; None = all
    reclaim_limit: jax.Array | None = None,   # int32[G]; None = no reclamation
) -> tuple[CoordinatorState, AcceptorState, LearnerState,
           jax.Array, jax.Array, jax.Array, jax.Array]:
    """K Phase-2 rounds unrolled in ONE jnp program: the bit-exact oracle of
    the persistent wave kernel ``kernels.wirepath.persistent_wirepath_round``
    (DESIGN.md §11).

    Round ``k`` runs ``multigroup_fused_round`` on ``values[k]`` with the
    per-round participation mask ``enabled_rounds[k]`` applied exactly as
    the dataplane applies ``enabled`` to a single-round dispatch: a group
    sitting the round out is presented at NO_ROUND (its acceptors reject
    every slot) and its watermark does not advance — so the whole wave is
    bit-identical to K sequential single-round dispatches by construction.
    ``K`` is a trace-time constant (the leading axis of ``values``); the
    Python loop unrolls under jit, so the wave still costs one dispatch.

    Returns ``(cstate', stack', lstate', fresh[K, G, B], inst[K, G, B],
    win_vrnd[K, G, B], value[K, G, B, V])``.
    """
    k = values.shape[0]
    freshes, insts, wins, vals = [], [], [], []
    for r in range(k):
        if enabled_rounds is None:
            en = None
            eff = cstate
        else:
            en = jnp.asarray(enabled_rounds[r]) != 0
            eff = CoordinatorState(
                next_inst=cstate.next_inst,
                crnd=jnp.where(en, cstate.crnd, NO_ROUND),
            )
        new_c, stack, lstate, fresh, inst, win, value = multigroup_fused_round(
            eff, stack, lstate, values[r], active[r], alive, quorum,
            reclaim_limit=reclaim_limit,
        )
        if en is None:
            cstate = CoordinatorState(
                next_inst=new_c.next_inst, crnd=cstate.crnd
            )
        else:
            cstate = CoordinatorState(
                next_inst=jnp.where(
                    en, new_c.next_inst, cstate.next_inst
                ),
                crnd=cstate.crnd,
            )
        freshes.append(fresh)
        insts.append(inst)
        wins.append(win)
        vals.append(value)
    return (
        cstate, stack, lstate,
        jnp.stack(freshes), jnp.stack(insts), jnp.stack(wins),
        jnp.stack(vals),
    )


def packed_multigroup_round(
    stack: AcceptorState,       # leaves shaped (Gl, A, N[, V])
    lstate: LearnerState,       # leaves shaped (Gl, N[, V])
    segids: jax.Array,          # int32[C]  per-lane slab row (0..Gl)
    next_inst: jax.Array,       # int32[C]  per-lane window base
    crnd: jax.Array,            # int32[C]  per-lane coordinator round
    alive: jax.Array,           # int32[C, A]  per-lane liveness row
    quorum: int | jax.Array,
    values: jax.Array,          # int32[C, B, V]  packed burst values
    enabled: jax.Array,         # int32[C]  0 marks a pad lane
    reclaim_limit: jax.Array | None = None,  # int32[C]; None = no reclamation
) -> tuple[AcceptorState, LearnerState, jax.Array, jax.Array, jax.Array]:
    """Bit-exact jnp oracle of the packed ragged-shard kernel
    ``kernels.wirepath.packed_shard_round`` (DESIGN.md §13).

    ``C`` packed lanes each serve slab row ``segids[j]`` of one shard's
    ``(Gl, ...)`` state with their own per-lane scalars.  Enabled lanes must
    name pairwise-distinct rows (the caller packs one lane per resident
    enabled group); pad lanes (``enabled == 0``) ride inert and write
    nothing back.  Gather the lanes' rows, run ``fused_round`` vmapped over
    the lane axis, scatter enabled lanes' rows back (pads scattered into a
    dropped trash row) — identical arithmetic to the kernel's routed grid.

    Returns ``(stack', lstate', fresh[C, B], win_vrnd[C, B],
    value[C, B, V])`` with the state outputs full-slab ``(Gl, ...)``.
    """
    gl = stack.rnd.shape[0]
    seg = jnp.asarray(segids, jnp.int32).reshape((-1,))
    c = seg.shape[0]
    en = jnp.asarray(enabled, jnp.int32).reshape((c,)) != 0
    cr = jnp.where(en, jnp.asarray(crnd, jnp.int32).reshape((c,)), NO_ROUND)
    cstate = CoordinatorState(
        next_inst=jnp.asarray(next_inst, jnp.int32).reshape((c,)), crnd=cr
    )
    lane_stack = jax.tree_util.tree_map(lambda x: x[seg], stack)
    lane_lstate = jax.tree_util.tree_map(lambda x: x[seg], lstate)
    active = jnp.ones(values.shape[:2], bool)
    al = jnp.asarray(alive).reshape((c, -1)) != 0
    if reclaim_limit is None:
        _c, lane_stack, lane_lstate, fresh, _inst, win, value = jax.vmap(
            fused_round, in_axes=(0, 0, 0, 0, 0, 0, None)
        )(cstate, lane_stack, lane_lstate, values, active, al, quorum)
    else:
        _c, lane_stack, lane_lstate, fresh, _inst, win, value = jax.vmap(
            fused_round, in_axes=(0, 0, 0, 0, 0, 0, None, 0)
        )(
            cstate, lane_stack, lane_lstate, values, active, al, quorum,
            jnp.asarray(reclaim_limit, jnp.int32).reshape((c,)),
        )
    # scatter lanes back to their slab rows; pads land in a dropped trash
    # row (their lane state is bit-unchanged anyway — NO_ROUND rejects all)
    tgt = jnp.where(en, seg, gl)

    def scat(full: jax.Array, lanes: jax.Array) -> jax.Array:
        return full.at[tgt].set(lanes, mode="drop")

    stack = jax.tree_util.tree_map(scat, stack, lane_stack)
    lstate = jax.tree_util.tree_map(scat, lstate, lane_lstate)
    return stack, lstate, fresh, win, value


def init_multigroup_state(
    n_groups: int, n_acceptors: int, n_instances: int, value_words: int
) -> tuple[CoordinatorState, AcceptorState, LearnerState]:
    """Freshly initialized (G,)-stacked coordinator/acceptor/learner state."""
    cstate = CoordinatorState(
        next_inst=jnp.zeros((n_groups,), jnp.int32),
        crnd=jnp.zeros((n_groups,), jnp.int32),
    )
    one = AcceptorState.init(n_instances, value_words)
    stack = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_groups, n_acceptors) + x.shape).copy(),
        one,
    )
    lstate = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(),
        LearnerState.init(n_instances, value_words),
    )
    return cstate, stack, lstate
