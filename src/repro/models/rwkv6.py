"""RWKV6 "Finch" (attention-free SSM with data-dependent decay).

Time-mix: token-shift interpolated projections r/k/v/g plus the RWKV6
signature feature — a *data-dependent* per-channel decay ``w_t`` produced by
a low-rank (LoRA) head; the WKV recurrence per head is

    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Training runs the recurrence with ``lax.scan`` over time (O(T) sequential,
O(1) state); decode is a single recurrence step — which is what makes the
``long_500k`` cell runnable for this family.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import PSpec

LORA_R = 64


def _stack(spec: PSpec, n: int) -> PSpec:
    return PSpec((n,) + spec.shape, ("layers",) + spec.axes, spec.init, spec.scale)


def block_specs(cfg) -> dict[str, Any]:
    d = cfg.d_model
    h = cfg.n_heads
    hd = cfg.rwkv_head_dim
    dh = h * hd
    return {
        "ln1": PSpec((d,), ("embed",), init="zeros"),
        "ln2": PSpec((d,), ("embed",), init="zeros"),
        "tm": {
            # token-shift interpolation factors
            "mu_r": PSpec((d,), ("embed",), init="zeros"),
            "mu_k": PSpec((d,), ("embed",), init="zeros"),
            "mu_v": PSpec((d,), ("embed",), init="zeros"),
            "mu_g": PSpec((d,), ("embed",), init="zeros"),
            "mu_w": PSpec((d,), ("embed",), init="zeros"),
            "wr": PSpec((d, dh), ("embed", "heads_flat")),
            "wk": PSpec((d, dh), ("embed", "heads_flat")),
            "wv": PSpec((d, dh), ("embed", "heads_flat")),
            "wg": PSpec((d, dh), ("embed", "heads_flat")),
            # data-dependent decay (LoRA)
            "w0": PSpec((dh,), ("heads_flat",), init="zeros"),
            "wa": PSpec((d, LORA_R), ("embed", None)),
            "wb": PSpec((LORA_R, dh), (None, "heads_flat")),
            "u": PSpec((dh,), ("heads_flat",), init="zeros"),
            "ln_x": PSpec((dh,), ("heads_flat",), init="zeros"),
            "wo": PSpec((dh, d), ("heads_flat", "embed")),
        },
        "cm": {
            "mu_k": PSpec((d,), ("embed",), init="zeros"),
            "mu_r": PSpec((d,), ("embed",), init="zeros"),
            "wk": PSpec((d, cfg.d_ff), ("embed", "mlp")),
            "wv": PSpec((cfg.d_ff, d), ("mlp", "embed")),
            "wr": PSpec((d, d), ("embed", "embed_out")),
        },
    }


def specs(cfg) -> dict[str, Any]:
    blocks = jax.tree_util.tree_map(
        lambda s: _stack(s, cfg.n_layers),
        block_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )
    return {
        "embed": PSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "blocks": blocks,
        "ln_f": PSpec((cfg.d_model,), ("embed",), init="zeros"),
        "head": PSpec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


# ---------------------------------------------------------------------------
# WKV recurrence
# ---------------------------------------------------------------------------
def _wkv_scan(r, k, v, w, u, s0):
    """r/k/v/w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).

    Returns (y: (B, T, H, hd), s_T)."""

    def step(s, x):
        rt, kt, vt, wt = x                            # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]       # (B, H, hd, hd)
        y = jnp.einsum("bhj,bhji->bhi", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = jax.tree_util.tree_map(lambda a: a.transpose(1, 0, 2, 3), (r, k, v, w))
    s, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s


def _time_mix(p, x, xprev, cfg, s0):
    """x: (B, T, D); xprev: token-shifted x; s0: (B,H,hd,hd)."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.rwkv_head_dim

    def mix(mu):
        return x + (xprev - x) * mu

    r = jnp.einsum("btd,de->bte", mix(p["mu_r"]), p["wr"])
    k = jnp.einsum("btd,de->bte", mix(p["mu_k"]), p["wk"])
    v = jnp.einsum("btd,de->bte", mix(p["mu_v"]), p["wv"])
    g = jax.nn.silu(jnp.einsum("btd,de->bte", mix(p["mu_g"]), p["wg"]))
    # data-dependent decay in (0, 1): exp(-exp(.))
    wlog = p["w0"] + jnp.einsum(
        "btd,dr,re->bte", jnp.tanh(mix(p["mu_w"])), p["wa"], p["wb"]
    )
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32)))

    shp = (b, t, h, hd)
    y, s = _wkv_scan(
        r.reshape(shp).astype(jnp.float32),
        k.reshape(shp).astype(jnp.float32),
        v.reshape(shp).astype(jnp.float32),
        w.reshape(shp),
        (1.0 + p["u"].astype(jnp.float32)).reshape(h, hd),
        s0,
    )
    y = y.reshape(b, t, h * hd)
    y = L.rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    return jnp.einsum("bte,ed->btd", y * g, p["wo"]), s


def _channel_mix(p, x, xprev):
    xk = x + (xprev - x) * p["mu_k"]
    xr = x + (xprev - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["wk"])))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"])
    return jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"])) * kv


# ---------------------------------------------------------------------------
# Forward / decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int = 0, dtype=jnp.bfloat16):
    l, h, hd, d = cfg.n_layers, cfg.n_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "s": jnp.zeros((l, batch, h, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((l, batch, d), dtype),
        "x_cm": jnp.zeros((l, batch, d), dtype),
    }


def cache_specs(cfg, batch: int, max_len: int = 0, dtype=jnp.bfloat16):
    l, h, hd, d = cfg.n_layers, cfg.n_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "s": jax.ShapeDtypeStruct((l, batch, h, hd, hd), jnp.float32),
        "x_tm": jax.ShapeDtypeStruct((l, batch, d), dtype),
        "x_cm": jax.ShapeDtypeStruct((l, batch, d), dtype),
    }


CACHE_AXES = {
    "s": ("layers", "batch", "heads", None, None),
    "x_tm": ("layers", "batch", None),
    "x_cm": ("layers", "batch", None),
}


def forward(cfg, params, batch, *, collect_cache: bool = False):
    tokens = batch["tokens"]
    b, t = tokens.shape
    h = params["embed"][tokens].astype(params["embed"].dtype)
    h = L.shard(h, ("batch", "act_seq", None))
    hheads, hd = cfg.n_heads, cfg.rwkv_head_dim

    def body(carry, blk):
        x = carry
        x_in_last = x[:, -1]                     # raw input to time-mix (cache)
        xprev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
        s0 = jnp.zeros((b, hheads, hd, hd), jnp.float32)
        y, s = _time_mix(blk["tm"], L.rms_norm(x, blk["ln1"], cfg.norm_eps),
                         L.rms_norm(xprev, blk["ln1"], cfg.norm_eps), cfg, s0)
        x = x + y
        x_mid_last = x[:, -1]                    # raw input to channel-mix
        xn = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
        xnprev = jnp.pad(xn[:, :-1], ((0, 0), (1, 0), (0, 0)))
        x = x + _channel_mix(blk["cm"], xn, xnprev)
        x = L.shard(x, ("batch", "act_seq", None))
        ys = (s, x_in_last, x_mid_last) if collect_cache else None
        return x, ys

    body_fn = L.checkpoint_fn(body, cfg)
    h, caches = jax.lax.scan(body_fn, h, params["blocks"])
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["head"].astype(h.dtype))
    logits = L.shard(logits, ("batch", "act_seq", "vocab"))

    cache = None
    if collect_cache:
        s, x_tm, x_cm = caches
        cache = {"s": s, "x_tm": x_tm.astype(h.dtype), "x_cm": x_cm.astype(h.dtype)}
    return logits, cache


def prefill(cfg, params, batch):
    return forward(cfg, params, batch, collect_cache=True)


def decode_step(cfg, params, tokens, cache, pos):
    """One-token step: O(1) state update per layer (no KV cache)."""
    h = params["embed"][tokens[:, 0]].astype(params["embed"].dtype)  # (B, D)
    hheads, hd = cfg.n_heads, cfg.rwkv_head_dim

    def body(carry, xs):
        x = carry                                      # (B, D)
        blk, s, x_tm, x_cm = xs
        xn = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
        xp = L.rms_norm(x_tm, blk["ln1"], cfg.norm_eps)
        y, s_new = _time_mix(
            blk["tm"], xn[:, None], xp[:, None], cfg, s
        )
        x_tm_new = x
        x = x + y[:, 0]
        xn2 = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
        xp2 = L.rms_norm(x_cm, blk["ln2"], cfg.norm_eps)
        cmix = _channel_mix(blk["cm"], xn2[:, None], xp2[:, None])
        x_cm_new = x
        x = x + cmix[:, 0]
        return x, (s_new, x_tm_new, x_cm_new)

    h, (s, x_tm, x_cm) = jax.lax.scan(
        body, h, (params["blocks"], cache["s"], cache["x_tm"], cache["x_cm"])
    )
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h, params["head"].astype(h.dtype))
    return logits[:, None], {"s": s, "x_tm": x_tm, "x_cm": x_cm}
