"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,            # MQA
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    local_window=2048,
    d_rnn=2560,
    conv_width=4,
    block_pattern=("rec", "rec", "attn"),
)
