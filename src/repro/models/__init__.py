"""Model zoo: dense GQA / MoE / VLM transformer, RWKV6, Griffin, Whisper."""
from . import registry  # noqa: F401
from .registry import (  # noqa: F401
    count_params,
    family_module,
    init_params,
    input_specs,
    make_inputs,
    model_specs,
    param_axes,
    param_shapes,
)
