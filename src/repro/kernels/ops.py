"""Jit'd wrappers exposing the Pallas kernels with the ``core.batched``
signatures, so the hardware dataplane (``core.api.HardwareDataplane``) can be
switched between the jnp engine and the kernels with one flag.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python for correctness validation; on a real TPU
backend they compile to Mosaic.  ``INTERPRET`` auto-detects.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import AcceptorState, CoordinatorState, MsgBatch

from . import acceptor as _acceptor
from . import coordinator as _coordinator
from . import digest as _digest
from . import learner as _learner

NO_ROUND = -1
INTERPRET = jax.default_backend() == "cpu"


def coordinator_sequence(
    cstate: CoordinatorState, values: jax.Array, active: jax.Array
) -> Tuple[CoordinatorState, MsgBatch]:
    """Kernel-backed drop-in for ``batched.coordinator_sequence``."""
    b = values.shape[0]
    msgtype, inst, rnd, vrnd, new_next = _coordinator.coordinator_sequence_window(
        cstate.next_inst, cstate.crnd, jnp.asarray(active), interpret=INTERPRET
    )
    out = MsgBatch(
        msgtype=msgtype,
        inst=inst,
        rnd=rnd,
        vrnd=vrnd,
        swid=jnp.zeros((b,), jnp.int32),
        value=values,
    )
    return CoordinatorState(next_inst=new_next, crnd=cstate.crnd), out


def acceptor_phase2(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> Tuple[AcceptorState, MsgBatch]:
    """Kernel-backed drop-in for ``batched.acceptor_phase2``.

    Requires the contiguous-window invariant maintained by the sequencer:
    ``msgs.inst == base + iota(B)`` with ``base`` a multiple of the kernel
    batch block.  (The API layer always produces such batches.)
    """
    base = msgs.inst[0]
    (st_rnd, st_vrnd, st_val, vt, vr, vv, vs, vval) = (
        _acceptor.acceptor_phase2_window(
            astate.rnd,
            astate.vrnd,
            astate.value,
            base,
            jnp.asarray(aid, jnp.int32),
            msgs.msgtype,
            msgs.rnd,
            msgs.value,
            interpret=INTERPRET,
        )
    )
    votes = MsgBatch(
        msgtype=vt, inst=msgs.inst, rnd=vr, vrnd=vv, swid=vs, value=vval
    )
    return AcceptorState(st_rnd, st_vrnd, st_val), votes


def learner_quorum(
    vote_msgtype: jax.Array,
    vote_inst: jax.Array,
    vote_vrnd: jax.Array,
    vote_value: jax.Array,
    quorum: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Kernel-backed drop-in for ``batched.learner_quorum``."""
    deliver, win, value = _learner.learner_quorum_window(
        jnp.int32(quorum),
        vote_msgtype,
        vote_vrnd,
        vote_value,
        interpret=INTERPRET,
    )
    b = vote_inst.shape[1]
    inst = vote_inst[0]  # position-aligned batches: inst identical across A
    return deliver.astype(bool), inst, win, value


def digest(x: jax.Array) -> jax.Array:
    return _digest.digest(x, interpret=INTERPRET)


def tree_digest(tree) -> jax.Array:
    return _digest.tree_digest(tree, interpret=INTERPRET)
