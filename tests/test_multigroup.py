"""Multi-group consensus as a service: context-level parity and routing.

The contract under test (DESIGN.md §5): a ``PaxosContext`` over G
device-resident groups behaves exactly like G *independent* single-group
contexts — same per-group delivery logs, same device register files — while
actually advancing all groups through ONE fused dispatch per burst.  That
must hold through per-group acceptor death and a coordinator failover in one
group (which may not perturb any other group), on both the jnp oracle path
and the Pallas kernel path.  ``ConsensusService`` adds the serving tier:
deterministic session -> group hash routing.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core import MultiGroupDataplane, PaxosConfig, PaxosContext
from repro.serve.engine import ConsensusService, session_group

G = 4
CFG_MG = PaxosConfig(n_acceptors=3, n_instances=512, batch=16, n_groups=G)
CFG_1 = PaxosConfig(n_acceptors=3, n_instances=512, batch=16)


def _group_state(hw, gid: int):
    """Host copies of one group's acceptor + learner device state."""
    src = (hw.stack, hw.lstate)
    if isinstance(hw, MultiGroupDataplane):
        src = jax.tree_util.tree_map(lambda x: x[gid], src)
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(src)]


def _run_schedule(ctx, groups, waves, use_groups: bool):
    """Submit ``waves`` rounds of one payload per group, pumping each wave."""
    for w in range(waves):
        for gid in groups:
            payload = f"w{w}g{gid}".encode()
            if use_groups:
                ctx.submit(payload, group=gid)
            else:
                ctx.submit(payload)
        ctx.run_until_quiescent()


@pytest.mark.parametrize("use_kernels", [False, True])
def test_groups_match_independent_contexts(use_kernels):
    """G fused groups == G independent single-group contexts, bit for bit,
    including a dead acceptor in one group."""
    mg = PaxosContext(CFG_MG, use_kernels=use_kernels)
    singles = [
        PaxosContext(CFG_1, use_kernels=use_kernels, fused=True)
        for _ in range(G)
    ]
    mg.hw.kill_acceptor(2, 1)       # group 2 loses acceptor 1...
    singles[2].hw.kill_acceptor(1)  # ...and so does its independent twin

    _run_schedule(mg, range(G), waves=3, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=3, use_groups=False)

    for gid, ctx in enumerate(singles):
        assert mg.group_log[gid] == ctx.delivered_log, gid
        for a, b in zip(_group_state(mg.hw, gid), _group_state(ctx.hw, gid), strict=True):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_group_failover_does_not_perturb_others(use_kernels):
    """Coordinator failover in one group: that group fails over to software
    sequencing and back, while every other group's delivery log and device
    registers stay bit-identical to independent contexts that never saw a
    failover."""
    victim = 1
    mg = PaxosContext(CFG_MG, use_kernels=use_kernels)
    singles = [
        PaxosContext(CFG_1, use_kernels=use_kernels, fused=True)
        for _ in range(G)
    ]

    _run_schedule(mg, range(G), waves=2, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=2, use_groups=False)

    mg.fail_coordinator(group=victim)
    singles[victim].fail_coordinator()

    _run_schedule(mg, range(G), waves=2, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=2, use_groups=False)

    mg.restore_hardware_coordinator(group=victim)
    singles[victim].restore_hardware_coordinator()

    _run_schedule(mg, range(G), waves=2, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=2, use_groups=False)

    for gid, ctx in enumerate(singles):
        assert mg.group_log[gid] == ctx.delivered_log, gid
        for a, b in zip(_group_state(mg.hw, gid), _group_state(ctx.hw, gid), strict=True):
            np.testing.assert_array_equal(a, b)
    # every submission in every group was delivered exactly once
    assert all(len(log) == 6 for log in mg.group_log)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_idle_group_unperturbed_under_skewed_load(use_kernels):
    """All traffic to group 0, enough to lap its ring: the idle group 1 must
    burn no ring instances, accrete no learned entries, and keep device state
    bit-identical to a deployment that was never pumped — then still serve
    traffic when it finally arrives."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=64, batch=16, n_groups=2)
    ctx = PaxosContext(cfg, use_kernels=use_kernels)
    ref = PaxosContext(
        PaxosConfig(n_acceptors=3, n_instances=64, batch=16),
        use_kernels=use_kernels,
        fused=True,
    )
    for w in range(12):  # 12*16 = 192 instances: laps the 64-slot ring 3x
        for k in range(16):
            ctx.submit(f"w{w}k{k}".encode(), group=0)
        ctx.run_until_quiescent()
    assert len(ctx.group_log[0]) == 192 and len(ctx.group_log[1]) == 0
    assert ctx.hw.next_inst_host[1] == 0
    assert not ctx.learned_g[1]
    for a, b in zip(_group_state(ctx.hw, 1), _group_state(ref.hw, 0), strict=True):
        np.testing.assert_array_equal(a, b)
    ctx.submit(b"late", group=1)
    ctx.run_until_quiescent()
    assert [p for _i, p in ctx.group_log[1]] == [b"late"]


def test_group_recover_targets_one_group():
    """paxos_recover on a multi-group context fills the gap in the addressed
    group with a no-op without disturbing the other groups' rings."""
    mg = PaxosContext(CFG_MG)
    _run_schedule(mg, range(G), waves=2, use_groups=True)
    before = [_group_state(mg.hw, gid) for gid in range(G)]

    # instance beyond the watermark of group 3: phase 1 finds nothing voted,
    # a no-op is decided into it (and discarded by the application layer)
    mg.recover(100, group=3)
    mg.pump()

    after = [_group_state(mg.hw, gid) for gid in range(G)]
    for gid in range(G):
        if gid == 3:
            continue
        for a, b in zip(before[gid], after[gid], strict=True):
            np.testing.assert_array_equal(a, b)
    # group 3's ring now holds a vote for instance 100
    assert np.asarray(mg.hw.stack.vrnd)[3, :, 100 % CFG_MG.n_instances].max() >= 0
    # the no-op was never surfaced to the application
    assert all(len(log) == 2 for log in mg.group_log)


# ---------------------------------------------------------------------------
# Dynamic membership: the free-list over the group axis (DESIGN.md §7)
# ---------------------------------------------------------------------------
def test_membership_freelist_deterministic_and_bounded():
    """retire returns slots to a sorted free-list; create claims the lowest;
    capacity is a hard bound; retired groups reject every group op."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=64, batch=8, n_groups=4)
    hw = MultiGroupDataplane(cfg)
    with pytest.raises(RuntimeError):
        hw.create_group()                      # at capacity
    hw.retire_group(3)
    hw.retire_group(1)
    assert hw.live_groups() == [0, 2]
    with pytest.raises(ValueError):
        hw.retire_group(1)                     # already retired
    assert hw.create_group() == 1              # lowest free slot first
    assert hw.create_group() == 3
    assert hw.live_groups() == [0, 1, 2, 3]
    # context-level: submit/recover/failover on a retired group raise
    ctx = PaxosContext(cfg)
    ctx.retire_group(2)
    for call in (
        lambda: ctx.submit(b"x", group=2),
        lambda: ctx.recover(0, group=2),
        lambda: ctx.fail_coordinator(group=2),
        lambda: ctx.retire_group(2),
    ):
        with pytest.raises(ValueError):
            call()


def test_retire_flushes_in_flight_traffic_before_slot_reuse():
    """Regression: a submit queued on the net but not yet pumped, followed
    by retire + create before the next pump, must NOT leak the old tenant's
    payload into the recycled slot's log or poison its (group, seq) dedup
    space — the retire flushes the tenant's in-flight coordinator traffic."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=64, batch=8, n_groups=2)
    ctx = PaxosContext(cfg)
    ctx.submit(b"stale", group=1)          # queued in flight, never pumped
    ctx.retire_group(1)
    assert ctx.create_group() == 1
    ctx.pump()
    assert ctx.group_log[1] == []          # the old tenant's value is gone
    # the new tenant's seq space is clean: its seq-0 value delivers
    ctx.submit(b"fresh", group=1)
    ctx.run_until_quiescent()
    assert [p for _i, p in ctx.group_log[1]] == [b"fresh"]
    assert not ctx._pending
    # and an in-flight recover() to the dead group is flushed too
    ctx.submit(b"keep", group=0)
    ctx.recover(5, group=1)
    ctx.retire_group(1)
    ctx.run_until_quiescent()
    assert [p for _i, p in ctx.group_log[0]] == [b"keep"]


def test_retire_drains_learner_ring_and_touches_no_other_group():
    """The drained log carries the decided values still resident in the
    retiring group's dedup ring, in instance order; every other group's
    slab state is bit-untouched by retire AND by the subsequent create."""
    ctx = PaxosContext(CFG_MG)
    _run_schedule(ctx, range(G), waves=2, use_groups=True)
    others_before = [_group_state(ctx.hw, gid) for gid in range(G) if gid != 1]
    expect = [
        (inst, np.frombuffer(raw, "<i4")[0])
        for inst, raw in ctx.hw.retire_group(1)
        if np.frombuffer(raw, "<i4")[0] != -0x7FFFFFFF   # skip NOP fillers
    ]
    # decided client values of group 1 in instance order (2 waves, batch>=2)
    assert [inst for inst, _ in expect] == sorted(inst for inst, _ in expect)
    assert len(expect) == 2
    assert ctx.hw.create_group() == 1
    others_after = [_group_state(ctx.hw, gid) for gid in range(G) if gid != 1]
    for before, after in zip(others_before, others_after, strict=True):
        for a, b in zip(before, after, strict=True):
            np.testing.assert_array_equal(a, b)
    # the recycled slot is a fresh deployment
    fresh = MultiGroupDataplane(PaxosConfig(
        n_acceptors=3, n_instances=512, batch=16, n_groups=1))
    for a, b in zip(_group_state(ctx.hw, 1), _group_state(fresh, 0), strict=True):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_vacant_slot_rides_folded_dispatch_inert(use_kernels):
    """A vacant (retired) slot with a divergent watermark must not break the
    lockstep fold: the plan still folds the full width, the kernel's
    enabled-mask path substitutes the block's ring offset, and the vacant
    slot's slab stays bit-identical while live groups decide normally."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=64, batch=8, n_groups=4)
    ctx = PaxosContext(cfg, use_kernels=use_kernels)
    # advance all groups, then retire group 0 and recreate it: its fresh
    # watermark (0) diverges from the other groups' (8)
    for gid in range(4):
        ctx.submit(f"a{gid}".encode(), group=gid)
    ctx.run_until_quiescent()
    ctx.retire_group(0)
    assert ctx.create_group() == 0
    assert ctx.hw.next_inst_host == [0, 8, 8, 8]
    # a burst over groups 1..3 (group 0 idle): enabled lockstep folds wide
    enabled, use_k, gb = ctx.hw._plan_round(8, [False, True, True, True])
    assert gb == 4 and use_k == use_kernels
    vacant_before = [np.asarray(x) for x in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda s: s[0], (ctx.hw.stack, ctx.hw.lstate))
    )]
    for gid in range(1, 4):
        ctx.submit(f"b{gid}".encode(), group=gid)
    ctx.run_until_quiescent()
    for gid in range(1, 4):
        assert [p for _i, p in ctx.group_log[gid]] == [
            f"a{gid}".encode(), f"b{gid}".encode()
        ]
    vacant_after = [np.asarray(x) for x in jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(lambda s: s[0], (ctx.hw.stack, ctx.hw.lstate))
    )]
    for a, b in zip(vacant_before, vacant_after, strict=True):
        np.testing.assert_array_equal(a, b)
    # the recycled group then serves from its own (divergent) watermark
    ctx.submit(b"late", group=0)
    ctx.run_until_quiescent()
    assert [p for _i, p in ctx.group_log[0]] == [b"late"]


def test_session_routing_deterministic_and_balanced():
    n_groups = 8
    ids = [f"session-{i}" for i in range(400)]
    groups = [session_group(s, n_groups) for s in ids]
    # deterministic
    assert groups == [session_group(s, n_groups) for s in ids]
    # every group sees traffic, no group dominates
    counts = np.bincount(groups, minlength=n_groups)
    assert (counts > 0).all()
    assert counts.max() < len(ids) // 2
    # int and bytes session ids route too
    assert 0 <= session_group(12345, n_groups) < n_groups
    assert 0 <= session_group(b"\x00\xff", n_groups) < n_groups


def test_consensus_service_routes_and_delivers():
    svc = ConsensusService(PaxosContext(CFG_MG))
    sessions = [f"user-{i}" for i in range(12)]
    routed = {}
    for k in range(3):
        for s in sessions:
            ticket = svc.session(s).submit(f"{s}:op{k}".encode())
            assert routed.setdefault(s, ticket.group) == ticket.group
    svc.run_until_quiescent()

    assert svc.ctx.stats["delivered"] == 3 * len(sessions)
    assert sum(svc.group_loads()) == 3 * len(sessions)
    for s in sessions:
        log = svc.session(s).delivered()
        mine = [p for _inst, p in log if p.startswith(f"{s}:".encode())]
        # the session observes its own ops in submission order, totally
        # ordered within its group
        assert mine == [f"{s}:op{k}".encode() for k in range(3)]
    # group logs partition the traffic
    assert sum(len(log) for log in svc.ctx.group_log) == 3 * len(sessions)
