"""Persistent K-round waves + the double-buffered pump (DESIGN.md §11).

Four layers of the same bit-exactness pin, lowest first:

1. **Oracle** — ``batched.persistent_multigroup_rounds`` (the K-unrolled
   jnp program) against K sequential ``multigroup_fused_round`` calls,
   including a mid-wave freeze landing *between* rounds via
   ``enabled_rounds``.

2. **Kernel** — ``kernels.ops.persistent_cohort_rounds`` (one
   ``pallas_call``, grid ``(K, NB, B//BB)``) against both the oracle and
   K sequential ``cohort_fused_round`` dispatches, same chaos schedule.

3. **Dataplane** — ``pipeline_persistent`` against K ``pipeline_cohort``
   calls on all four backends (jnp/pallas x unsharded/sharded): outputs,
   register files and watermark mirrors all bit-identical; dispatch_count
   pins one launch per wave unsharded and the documented K-launch
   fallback sharded.

4. **Pump** — full ``PaxosContext`` runs with ``persistent_rounds`` and
   ``async_pump`` swept produce delivery logs bit-identical to the serial
   K=1 reference on every backend, including an async overlap schedule
   where the deliver callback submits fresh traffic mid-drain.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import batched
from repro.core.api import MultiGroupDataplane, PaxosContext, ShardedMultiGroupDataplane
from repro.core.plan import NOP_SENTINEL
from repro.core.types import NO_ROUND, CoordinatorState, PaxosConfig
from repro.kernels import ops as kops
from repro.launch.mesh import make_group_mesh

import jax
import jax.numpy as jnp

A = 3
QUORUM = 2


def _tree_equal(t1, t2):
    for l1, l2 in zip(jax.tree_util.tree_leaves(t1), jax.tree_util.tree_leaves(t2), strict=True):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def _wave_values(rng, k, g, b, v, fill=0.8):
    """Random wave values in the wire convention: inactive slots carry the
    NOP sentinel in word 0 (the kernel's only activity signal)."""
    vals = rng.integers(1, 1 << 20, size=(k, g, b, v)).astype(np.int32)
    active = rng.random((k, g, b)) < fill
    vals[~active, 0] = NOP_SENTINEL
    return vals, active


def _freeze_descriptor(k, g, b, marks, victim, at_round):
    """wni/wen for a wave where ``victim`` freezes between rounds
    ``at_round - 1`` and ``at_round``: its window stops walking and it
    sits out every later round (wni[k+1] = wni[k] + B * wen[k])."""
    wni = np.zeros((k, g), np.int32)
    wen = np.ones((k, g), np.int32)
    wen[at_round:, victim] = 0
    wni[0] = marks
    for r in range(1, k):
        wni[r] = wni[r - 1] + b * wen[r - 1]
    return wni, wen


# ---------------------------------------------------------------------------
# 1. Oracle: K-unrolled jnp program == K sequential fused rounds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("freeze_at", [None, 2])
def test_oracle_persistent_equals_sequential_rounds(freeze_at):
    g, n, b, v, k = 3, 256, 16, 4, 4
    rng = np.random.default_rng(7)
    vals, active = _wave_values(rng, k, g, b, v)
    alive = jnp.ones((g, A), bool)
    cstate, stack, lstate = batched.init_multigroup_state(g, A, n, v)

    victim = 1
    if freeze_at is None:
        enabled = None
    else:
        _, wen = _freeze_descriptor(k, g, b, [0] * g, victim, freeze_at)
        enabled = jnp.asarray(wen)

    pc, pstack, plstate, pfresh, pinst, pwin, pval = (
        batched.persistent_multigroup_rounds(
            cstate, stack, lstate, jnp.asarray(vals), jnp.asarray(active),
            alive, QUORUM, enabled_rounds=enabled,
        )
    )

    # the sequential reference: one fused round per k, the freeze applied
    # between rounds exactly as the dataplane masks a non-member cohort row
    sc, sstack, slstate = batched.init_multigroup_state(g, A, n, v)
    sf, si, sw, sv = [], [], [], []
    for r in range(k):
        if enabled is None:
            en = jnp.ones((g,), bool)
        else:
            en = enabled[r] != 0
        eff = CoordinatorState(
            next_inst=sc.next_inst, crnd=jnp.where(en, sc.crnd, NO_ROUND)
        )
        nc, sstack, slstate, fr, ii, wi, va = batched.multigroup_fused_round(
            eff, sstack, slstate, jnp.asarray(vals[r]),
            jnp.asarray(active[r]), alive, QUORUM,
        )
        sc = CoordinatorState(
            next_inst=jnp.where(en, nc.next_inst, sc.next_inst), crnd=sc.crnd
        )
        sf.append(fr), si.append(ii), sw.append(wi), sv.append(va)

    _tree_equal((pc, pstack, plstate), (sc, sstack, slstate))
    _tree_equal(
        (pfresh, pinst, pwin, pval),
        (jnp.stack(sf), jnp.stack(si), jnp.stack(sw), jnp.stack(sv)),
    )
    if freeze_at is not None:
        # the frozen group's watermark stopped at the freeze boundary
        assert int(pc.next_inst[victim]) == freeze_at * b
        assert not np.asarray(pfresh)[freeze_at:, victim].any()


# ---------------------------------------------------------------------------
# 2. Kernel: one pallas_call == oracle == K sequential cohort dispatches,
#    with a chaos freeze landing between rounds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("freeze_at", [None, 1])
def test_kernel_persistent_wave_chaos_parity(freeze_at):
    g, n, b, v, k = 3, 256, 16, 4, 4
    rng = np.random.default_rng(11)
    vals, active = _wave_values(rng, k, g, b, v)
    alive_i = jnp.ones((g, A), jnp.int32)
    crnd = jnp.zeros((g,), jnp.int32)
    _, stack, lstate = batched.init_multigroup_state(g, A, n, v)

    victim, marks = 2, [0] * g
    wni, wen = _freeze_descriptor(
        k, g, b, marks, victim, k if freeze_at is None else freeze_at
    )
    gsel = np.arange(g, dtype=np.int32)  # gb = 1: every group its own block

    kstack, klstate, kfresh, kwin, kval = kops.persistent_cohort_rounds(
        stack, lstate, jnp.asarray(gsel), jnp.asarray(wni), jnp.asarray(wen),
        crnd, alive_i, QUORUM, jnp.asarray(vals),
        group_block=1, block_b=b,
    )

    # oracle mirror of the same wave descriptor
    cstate, ostack, olstate = batched.init_multigroup_state(g, A, n, v)
    _, ostack, olstate, ofresh, _oi, owin, oval = (
        batched.persistent_multigroup_rounds(
            cstate, ostack, olstate, jnp.asarray(vals), jnp.asarray(active),
            jnp.ones((g, A), bool), QUORUM,
            enabled_rounds=jnp.asarray(wen),
        )
    )
    _tree_equal((kstack, klstate), (ostack, olstate))
    _tree_equal((kfresh, kwin, kval), (ofresh != 0, owin, oval))

    # sequential kernel reference: K cohort dispatches, the freeze applied
    # between dispatches (enabled mask + a watermark that stops walking)
    _, sstack, slstate = batched.init_multigroup_state(g, A, n, v)
    sf, sw, sv = [], [], []
    for r in range(k):
        sstack, slstate, fr, wi, va = kops.cohort_fused_round(
            sstack, slstate, jnp.asarray(gsel), jnp.asarray(wni[r]), crnd,
            alive_i, QUORUM, jnp.asarray(vals[r]), jnp.asarray(wen[r]),
            group_block=1,
        )
        sf.append(fr), sw.append(wi), sv.append(va)
    _tree_equal((kstack, klstate), (sstack, slstate))
    _tree_equal(
        (kfresh, kwin, kval),
        (jnp.stack(sf), jnp.stack(sw), jnp.stack(sv)),
    )


# ---------------------------------------------------------------------------
# 3. Dataplane: pipeline_persistent == K x pipeline_cohort, four backends
# ---------------------------------------------------------------------------
def _mk_plane(use_kernels, sharded, cfg):
    if sharded:
        return ShardedMultiGroupDataplane(
            cfg, mesh=make_group_mesh(), use_kernels=use_kernels
        )
    return MultiGroupDataplane(cfg, use_kernels=use_kernels)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("sharded", [False, True])
def test_pipeline_persistent_equals_k_cohorts(use_kernels, sharded):
    g, n, be, v, k = 2, 128, 16, 4, 3
    cfg = PaxosConfig(
        n_acceptors=A, n_instances=n, value_words=v, batch=be, n_groups=g
    )
    rng = np.random.default_rng(23)
    vals, active = _wave_values(rng, k, g, be, v)
    gids = (0, 1)

    hw_p = _mk_plane(use_kernels, sharded, cfg)
    fresh_p, inst_p, val_p = hw_p.pipeline_persistent(gids, vals, active)
    assert fresh_p.shape == (k, g, be)

    hw_s = _mk_plane(use_kernels, sharded, cfg)
    outs = [hw_s.pipeline_cohort(gids, vals[r], active[r]) for r in range(k)]

    np.testing.assert_array_equal(fresh_p, np.stack([o[0] for o in outs]))
    np.testing.assert_array_equal(inst_p, np.stack([o[1] for o in outs]))
    np.testing.assert_array_equal(val_p, np.stack([o[2] for o in outs]))
    _tree_equal(
        (hw_p.stack, hw_p.lstate, hw_p.cstate),
        (hw_s.stack, hw_s.lstate, hw_s.cstate),
    )
    assert hw_p.next_inst_host == hw_s.next_inst_host == [k * be] * g
    # one device launch per wave — except the documented sharded K=1
    # fallback, which dispatches per round
    assert hw_p.dispatch_count == (k if sharded else 1)
    assert hw_s.dispatch_count == k


def test_pipeline_persistent_rejects_ring_lap():
    cfg = PaxosConfig(
        n_acceptors=A, n_instances=64, value_words=4, batch=32, n_groups=1
    )
    hw = MultiGroupDataplane(cfg)
    vals = np.zeros((3, 1, 32, 4), np.int32)
    vals[..., 0] = NOP_SENTINEL
    act = np.zeros((3, 1, 32), bool)
    with pytest.raises(ValueError, match="lap"):
        hw.pipeline_persistent((0,), vals, act)


# ---------------------------------------------------------------------------
# 4. Pump: persistent waves + async double-buffering vs the serial reference
# ---------------------------------------------------------------------------
def _run_ctx(use_kernels, mesh, pr, async_pump, n_extra=0):
    cfg = PaxosConfig(
        n_acceptors=A, n_instances=1 << 10, value_words=4, batch=32,
        n_groups=2, persistent_rounds=pr, async_pump=async_pump,
    )
    ctx = PaxosContext(cfg, use_kernels=use_kernels, mesh=mesh)
    # group 0 deep enough for multi-round waves, group 1 a ragged tail —
    # the wave loop mints mixed cohorts and a trailing sub-batch burst
    for i in range(130):
        ctx.submit(f"a{i:04d}".encode(), group=0)
    for i in range(45):
        ctx.submit(f"b{i:04d}".encode(), group=1)
    ctx.run_until_quiescent()
    for i in range(n_extra):
        ctx.submit(f"x{i:04d}".encode(), group=i % 2)
    ctx.run_until_quiescent()
    return ctx


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("sharded", [False, True])
def test_pump_persistent_waves_bit_identical_four_backends(use_kernels, sharded):
    ref = _run_ctx(False, None, pr=1, async_pump=False)
    mesh = make_group_mesh() if sharded else None
    for pr in (4, 1):
        for ap in (True, False):
            ctx = _run_ctx(use_kernels, mesh, pr=pr, async_pump=ap)
            assert ctx.group_log == ref.group_log, (use_kernels, sharded, pr, ap)
            assert ctx.quiescent()


def test_pump_dispatch_count_one_launch_per_wave():
    # 130 submits / batch 32 -> one K=4 persistent wave (128) + one
    # 2-row tail burst = 2 launches; the K=1 pump needs 5
    ctx = _run_ctx(True, None, pr=4, async_pump=True)
    assert ctx.hw.dispatch_count == 2 + 2  # group-1 traffic adds 2 bursts
    assert ctx.planner.stats["persistent_waves"] == 1
    ref = _run_ctx(True, None, pr=1, async_pump=False)
    assert ref.planner.stats["persistent_waves"] == 0
    assert ctx.hw.dispatch_count < ref.hw.dispatch_count
    # sharded: the planner itself clamps wave depth to K=1 (DESIGN.md §13),
    # so no persistent wave is ever minted — the telemetry no longer
    # over-counts waves the dispatch layer would have unrolled anyway
    sh = _run_ctx(True, make_group_mesh(), pr=4, async_pump=True)
    assert sh.planner.stats["persistent_waves"] == 0
    assert sh.hw.dispatch_count == ref.hw.dispatch_count
    assert sh.group_log == ctx.group_log == ref.group_log


def test_async_pump_overlap_with_midstream_submissions():
    """The overlap pin: a deliver callback that submits fresh traffic while
    a wave is still in flight must not fork delivery between the
    double-buffered and the serial pump."""
    logs = {}
    for ap in (True, False):
        cfg = PaxosConfig(
            n_acceptors=A, n_instances=1 << 10, value_words=4, batch=32,
            n_groups=2, persistent_rounds=4, async_pump=ap,
        )
        fired = []

        def follow_up(payload, size, inst):
            if payload == b"a0000" and not fired:
                fired.append(inst)
                for j in range(40):
                    ctx.submit(f"f{j:04d}".encode(), group=1)

        ctx = PaxosContext(cfg, deliver=follow_up)
        for i in range(96):
            ctx.submit(f"a{i:04d}".encode(), group=0)
        ctx.run_until_quiescent()
        assert ctx.quiescent()
        assert fired, "overlap callback never fired"
        logs[ap] = ctx.group_log
    assert logs[True] == logs[False]
    assert len(logs[True][1]) == 40  # the mid-drain follow-ups all landed
