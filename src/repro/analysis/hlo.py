"""Post-optimization HLO parsing: collective bytes + op census.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but no collective
traffic, so we parse the partitioned HLO text and sum the payload bytes of
every collective op:

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
    (+ their async -start forms)

Payload per op = the largest ``dtype[dims]`` type on the defining line (for
async tuple types this is the gathered/transferred operand).  The partitioned
module is the *per-device* program, so the sums are per-device bytes — the
roofline divides by per-chip link bandwidth directly.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(r"\b([a-z]+\d+(?:e\d+m\d+\w*)?|pred)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*[^=]*?\b(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)


def _type_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")


def loop_bodies(hlo_text: str) -> set:
    """Names of computations used as while-loop bodies."""
    bodies = set()
    for line in hlo_text.splitlines():
        if " while(" in line:
            m = _WHILE_BODY_RE.search(line)
            if m:
                bodies.add(m.group(1))
    return bodies


def collective_bytes(
    hlo_text: str, loop_trip_hint: int = 1
) -> tuple[int, dict[str, int], dict[str, int]]:
    """Returns (total_bytes, bytes_by_op, count_by_op) for the module.

    XLA emits each while-loop body ONCE in the module text, but its
    collectives execute trip-count times.  We cannot recover trip counts from
    the partitioned HLO, but we know the dominant loop: the layer scan (and
    its backward twin), whose trip count the caller passes as
    ``loop_trip_hint``.  Collectives inside any while-body computation are
    multiplied by the hint; entry-level collectives count once.  (Inner
    chunked-attention loops carry no collectives under the baseline rules;
    if sequence parallelism puts any there, the hint under-counts them —
    noted in EXPERIMENTS.md.)
    """
    bodies = loop_bodies(hlo_text)
    by_op: dict[str, int] = defaultdict(int)
    count: dict[str, int] = defaultdict(int)
    current = ""
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = _COMPUTATION_RE.match(line.strip())
            if m:
                current = m.group(1)
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async completion — payload counted at -start
        op = m.group(1)
        sizes = [_type_bytes(d, s) for d, s in _TYPE_RE.findall(line)]
        if not sizes:
            continue
        mult = loop_trip_hint if current in bodies else 1
        by_op[op] += max(sizes) * mult
        count[op] += mult
    return sum(by_op.values()), dict(by_op), dict(count)


def op_census(hlo_text: str, ops=("fusion", "custom-call", "while", "convolution", "dot")) -> dict[str, int]:
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        for op in ops:
            if f" {op}(" in line:
                out[op] += 1
    return dict(out)
