"""Serving engine: batched prefill + decode over any registry architecture.

``prefill_step`` and ``serve_step`` are the two lowered entry points of the
inference shapes (``prefill_32k`` lowers prefill; ``decode_32k`` /
``long_500k`` lower one ``serve_step`` against a seq_len-deep cache).  The
host-side ``ServeLoop`` runs continuous batching over them for the examples
and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


def make_prefill_step(cfg) -> Callable:
    mod = registry.family_module(cfg)

    def prefill_step(params, batch: Dict[str, jax.Array]):
        logits, cache = mod.prefill(cfg, params, batch)
        return logits[:, -1], cache

    return prefill_step


def make_serve_step(cfg) -> Callable:
    mod = registry.family_module(cfg)

    def serve_step(params, tokens, cache, pos):
        logits, cache = mod.decode_step(cfg, params, tokens, cache, pos)
        return logits.reshape(tokens.shape[0], -1), cache

    return serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray         # (S,) int32
    max_new: int = 16
    generated: Optional[List[int]] = None


class ServeLoop:
    """Greedy continuous-batching loop (host side, CPU-scale)."""

    def __init__(self, cfg, params, batch_size: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.mod = registry.family_module(cfg)
        self._decode = jax.jit(make_serve_step(cfg))
        self.cache = self.mod.init_cache(cfg, batch_size, max_len, jnp.dtype(cfg.dtype))
        self.steps = 0

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Teacher-forced prefill via decode steps, then greedy generation."""
        out: Dict[int, List[int]] = {}
        for chunk_start in range(0, len(requests), self.batch):
            chunk = requests[chunk_start : chunk_start + self.batch]
            b = len(chunk)
            plen = max(len(r.prompt) for r in chunk)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(chunk):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            cache = self.mod.init_cache(
                self.cfg, self.batch, self.max_len, jnp.dtype(self.cfg.dtype)
            )
            last = None
            for t in range(plen):
                last, cache = self._decode(
                    self.params, jnp.asarray(toks[:, t : t + 1]), cache, jnp.int32(t)
                )
                self.steps += 1
            gen = [[] for _ in range(b)]
            cur = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            max_new = max(r.max_new for r in chunk)
            for s in range(max_new):
                for i in range(b):
                    if s < chunk[i].max_new:
                        gen[i].append(int(cur[i, 0]))
                last, cache = self._decode(
                    self.params, cur, cache, jnp.int32(plen + s)
                )
                self.steps += 1
                cur = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            for i, r in enumerate(chunk):
                out[r.rid] = gen[i]
        return out
