"""Fault-tolerance paths: consensus-committed checkpoints, restart,
coordinator failover (hardware -> software takeover), recover() gap fill,
replicated log trim, elastic membership views."""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import FaultSpec, PaxosConfig, PaxosContext, ReplicatedLog, SimNet
from repro.core.failover import allocate_round, takeover
from repro.train import checkpoint as ckpt_mod
from repro.train import elastic, train_loop
from repro.train.data import DataConfig, SyntheticStream

CFG = PaxosConfig(n_acceptors=3, n_instances=512, batch=16)


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_committed(tmp_path):
    cfg = get_config("qwen3-4b").reduced()
    state = train_loop.init_state(cfg, jax.random.PRNGKey(0))
    ctx = PaxosContext(CFG)
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), paxos_ctx=ctx)
    path = mgr.save(state, step=3)
    assert os.path.exists(os.path.join(path, "COMMITTED"))
    # the commit record went through consensus
    assert any(p.startswith(b"ckpt:3:") for _, p in ctx.delivered_log)

    restored, step = mgr.restore(state)
    assert step == 3
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    , strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_invisible(tmp_path):
    """If the consensus layer cannot decide (no quorum), the checkpoint must
    not become eligible for restart."""
    cfg = get_config("whisper-base").reduced()
    state = train_loop.init_state(cfg, jax.random.PRNGKey(0))
    ctx = PaxosContext(CFG)
    ctx.hw.kill_acceptor(0)
    ctx.hw.kill_acceptor(1)  # no quorum
    mgr = ckpt_mod.CheckpointManager(str(tmp_path), paxos_ctx=ctx)
    mgr.save(state, step=1)
    assert mgr.latest_committed() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore(state)


def test_restart_resumes_training(tmp_path):
    """Crash/restart: restore from latest committed step and keep training
    deterministically (counter-based data stream is restart-safe)."""
    cfg = get_config("qwen3-4b").reduced()
    ocfg_steps = 4
    state = train_loop.init_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(train_loop.make_train_step(cfg))
    stream = SyntheticStream(
        DataConfig(vocab=cfg.vocab, global_batch=2, seq_len=16, seed=1)
    )
    mgr = ckpt_mod.CheckpointManager(str(tmp_path))
    for i in range(ocfg_steps):
        state, _ = step(state, {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()})
    mgr.save(state, step=ocfg_steps)

    # "crash"; restore and continue
    state2, at = mgr.restore(train_loop.init_state(cfg, jax.random.PRNGKey(9)))
    assert at == ocfg_steps
    s_a, _ = step(state, {k: jnp.asarray(v) for k, v in stream.batch_at(at).items()})
    s_b, _ = step(state2, {k: jnp.asarray(v) for k, v in stream.batch_at(at).items()})
    for a, b in zip(jax.tree_util.tree_leaves(s_a.params),
                    jax.tree_util.tree_leaves(s_b.params), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# coordinator failover
# ---------------------------------------------------------------------------
def test_coordinator_failover_continues_and_preserves_agreement():
    got = []
    ctx = PaxosContext(CFG, deliver=lambda v, n, i: got.append(v))
    for k in range(5):
        ctx.submit(f"pre{k}".encode())
    ctx.run_until_quiescent()
    ctx.fail_coordinator()  # software takeover (paper Fig. 8b)
    for k in range(5):
        ctx.submit(f"post{k}".encode())
    ctx.run_until_quiescent()
    assert {f"pre{k}".encode() for k in range(5)} <= set(got)
    assert {f"post{k}".encode() for k in range(5)} <= set(got)
    # all delivered instances unique
    insts = [i for i, _ in ctx.delivered_log]
    assert len(insts) == len(set(insts))


def test_safe_takeover_reproposes_voted_values():
    """The takeover Phase-1 scan must re-propose (not lose) voted instances."""
    ctx = PaxosContext(CFG)
    for k in range(8):
        ctx.submit(f"val{k}".encode())
    ctx.run_until_quiescent()
    res = takeover(
        ctx.hw, coordinator_id=1, epoch=1,
        est_next_inst=0, window=32, quorum=CFG.quorum,
    )
    assert res.next_inst >= 16  # found the used window (one batch = 16)
    assert len(res.reproposed) >= 8
    assert res.crnd == allocate_round(1, 1)


def test_takeover_odd_window_never_touches_beyond_hi():
    """Regression: when (hi - lo) is not a multiple of cfg.batch, the final
    Phase-1/Phase-2 batch used to overhang the window — bumping promised
    rounds and re-proposing values into instances >= hi, and advancing
    next_inst past the window.  Out-of-window positions must stay
    bit-untouched and ``scanned`` must report the true count."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=64, batch=8)
    ctx = PaxosContext(cfg, fused=True)
    for k in range(16):                    # decide instances 0..15 at round 0
        ctx.submit(f"v{k}".encode())
    ctx.run_until_quiescent()
    before_rnd = np.asarray(ctx.hw.stack.rnd).copy()
    before_vrnd = np.asarray(ctx.hw.stack.vrnd).copy()
    before_val = np.asarray(ctx.hw.stack.value).copy()

    # window [0, 12): 12 is NOT a multiple of batch=8 — the second batch
    # covers [8, 16) and must mask positions 12..15
    res = takeover(
        ctx.hw, coordinator_id=1, epoch=1,
        est_next_inst=4, window=8, quorum=cfg.quorum,
    )
    assert res.scanned == 12               # the true scanned count
    # voted instances inside the window were re-proposed, none beyond it
    assert {i for i, _ in res.reproposed} == set(range(12))
    assert res.next_inst == 12             # not dragged past hi by overscan
    # out-of-window slots 12..15: promised round, vote round and value are
    # bit-identical to the pre-takeover register file
    rnd = np.asarray(ctx.hw.stack.rnd)
    vrnd = np.asarray(ctx.hw.stack.vrnd)
    val = np.asarray(ctx.hw.stack.value)
    np.testing.assert_array_equal(rnd[:, 12:16], before_rnd[:, 12:16])
    np.testing.assert_array_equal(vrnd[:, 12:16], before_vrnd[:, 12:16])
    np.testing.assert_array_equal(val[:, 12:16], before_val[:, 12:16])
    # in-window voted slots really moved to the takeover round
    assert (rnd[:, :12] == res.crnd).all()
    # and every slot outside the final batch's reach is untouched too
    np.testing.assert_array_equal(rnd[:, 16:], before_rnd[:, 16:])


def test_round_allocation_disjoint():
    r1 = {allocate_round(e, 0) for e in range(50)}
    r2 = {allocate_round(e, 1) for e in range(50)}
    assert not (r1 & r2)


# ---------------------------------------------------------------------------
# recover() + replicated log
# ---------------------------------------------------------------------------
def test_recover_fills_learner_gap():
    net = SimNet(FaultSpec(), seed=3)
    got = {}
    ctx = PaxosContext(CFG, deliver=lambda v, n, i: got.__setitem__(i, v), net=net)
    for k in range(4):
        ctx.submit(f"g{k}".encode())
    ctx.run_until_quiescent()
    # wipe learner 0's memory of instance 2 to simulate a missed decision
    inst = sorted(got)[2]
    val = ctx.learned[0].pop(inst)
    got.pop(inst)
    ctx.recover(inst, nop=b"\x00")
    ctx.run_until_quiescent()
    assert inst in ctx.learned[0]
    assert ctx.learned[0][inst] == val  # recovered the decided value, not nop


def test_recover_undetermined_instance_yields_nop():
    ctx = PaxosContext(CFG)
    ctx.recover(100, nop=b"\x00")
    ctx.run_until_quiescent()
    # decided (learned) but filtered from application deliveries as a no-op
    assert 100 in ctx.learned[0]
    assert ctx.stats["delivered"] == 0


def test_replicated_log_order_gaps_trim():
    log = ReplicatedLog(quorum=2)
    applied = []
    log.on_apply = lambda i, p: applied.append(i)
    log.offer(0, b"a")
    log.offer(2, b"c")
    assert applied == [0]
    assert log.gaps(3) == [1]
    log.offer(1, b"b")
    assert applied == [0, 1, 2]
    # trim requires a quorum of learner acks
    assert not log.ack_trim(0, upto=2)
    assert log.ack_trim(1, upto=2)
    assert log.trim_watermark == 2
    log.offer(1, b"zz")  # below watermark: ignored
    assert 1 not in log.entries


# ---------------------------------------------------------------------------
# elastic membership
# ---------------------------------------------------------------------------
def test_membership_view_change_through_consensus():
    # membership views are bigger than 64B: use a wide-value config
    ctx = PaxosContext(dataclasses.replace(CFG, value_words=64))
    v0 = elastic.MembershipView(0, ("h0", "h1", "h2", "h3"), (2, 2), ("data", "model"))
    vm = elastic.ViewManager(ctx, v0)
    view = vm.propose_view(["h0", "h1", "h3"], model_parallel=1)
    assert view.epoch == 1
    assert view.hosts == ("h0", "h1", "h3")
    assert view.mesh_shape == (3, 1)


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written on one 'mesh', restored against new shardings."""
    cfg = get_config("yi-9b").reduced()
    state = train_loop.init_state(cfg, jax.random.PRNGKey(0))
    mgr = ckpt_mod.CheckpointManager(str(tmp_path))
    mgr.save(state, step=1)
    # restore with explicit (single-device) shardings for the new mesh
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state
    )
    restored, step = mgr.restore(state, shardings=shardings)
    assert step == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_replan_mesh():
    assert elastic.replan_mesh(512)[0] == (32, 16)
    assert elastic.replan_mesh(496)[0] == (31, 16)   # lost a host: shrink data
    assert elastic.replan_mesh(8, model_parallel=16)[0] == (1, 8)
