"""Paper Fig. 8: throughput under (a) acceptor failure and (b) coordinator
failover to software.

Reports throughput in three phases: before failure, after failure, after
recovery — matching the paper's timeline plots.  Expected shape: acceptor
loss does not reduce (may slightly raise) throughput (fewer votes for the
learner to count); software-coordinator failover keeps the system live with
added host overhead."""
from __future__ import annotations

import time

from repro.core import PaxosConfig, PaxosContext

from .common import emit

CFG = PaxosConfig(n_acceptors=3, n_instances=1 << 14, batch=64)
PHASE = 1200


def _phase_tput(ctx, n) -> float:
    before = ctx.stats["delivered"]
    t0 = time.perf_counter()
    for i in range(n):
        ctx.submit(b"f" * 32)
        if i % 64 == 63:
            ctx.pump()
    ctx.run_until_quiescent(max_rounds=300)
    return (ctx.stats["delivered"] - before) / (time.perf_counter() - t0)


def run() -> None:
    # (a) acceptor failure
    ctx = PaxosContext(CFG, fused=True)
    _phase_tput(ctx, 128)  # jit warmup
    t1 = _phase_tput(ctx, PHASE)
    ctx.hw.kill_acceptor(2)
    t2 = _phase_tput(ctx, PHASE)
    ctx.hw.revive_acceptor(2)
    t3 = _phase_tput(ctx, PHASE)
    emit(
        "fig8a/acceptor_failure",
        1e6 / t2,
        f"before={t1:.0f}/s after_kill={t2:.0f}/s revived={t3:.0f}/s "
        f"(paper: throughput holds/rises after acceptor loss)",
    )

    # (b) coordinator failover to software (falls back to the staged path)
    ctx = PaxosContext(CFG, fused=True)
    _phase_tput(ctx, 128)  # jit warmup
    t1 = _phase_tput(ctx, PHASE)
    ctx.fail_coordinator()
    t2 = _phase_tput(ctx, PHASE)
    ctx.restore_hardware_coordinator()
    t3 = _phase_tput(ctx, PHASE)
    delivered_insts = [i for i, _ in ctx.delivered_log]
    emit(
        "fig8b/coordinator_failover",
        1e6 / t2,
        f"hw={t1:.0f}/s sw_takeover={t2:.0f}/s hw_restored={t3:.0f}/s "
        f"unique_instances={len(set(delivered_insts))==len(delivered_insts)}",
    )
