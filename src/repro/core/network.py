"""Deterministic simulated message fabric with UDP-like fault injection.

The paper's deployment carries Paxos headers in UDP datagrams: messages can
be dropped, duplicated, and reordered.  ICI collectives are reliable, so in
the TPU adaptation loss lives at the host/DCN boundary — which is exactly
where this simulator sits (between host-side role steps).  Faults are driven
by a seeded RNG so every adversarial schedule is reproducible.

Two fault modes:

* **Legacy (default)** — one shared RNG stream; each send consumes draws in
  arrival order.  Reproducible for a fixed schedule, but any change to the
  *interleaving* of sends (e.g. one multi-group fabric vs. G single-group
  twins) shifts every later decision.

* **Keyed** (pass ``key_fn``) — fault decisions are a pure function of
  ``(seed, message key, occurrence index)``: the same logical message suffers
  the same fate no matter how traffic from other endpoints interleaves.
  This is what lets chaos tests bit-compare a lossy multi-group fabric
  against independent per-group twins — ``key_fn`` must exclude any
  group-routing tag that differs between the two topologies while the
  payloads themselves stay distinct.  Keyed reordering is a deterministic
  defer-one-pump: the message sits out the current ``recv_all`` and rejoins
  the front of the queue for the next one (UDP reordering collapsed to its
  observable effect — a message overtaken by its successors).
"""
from __future__ import annotations

import dataclasses
import random
from collections import defaultdict, deque
from typing import Any
from collections.abc import Callable, Hashable


@dataclasses.dataclass
class FaultSpec:
    drop: float = 0.0       # probability a message is dropped
    dup: float = 0.0        # probability a message is duplicated
    reorder: float = 0.0    # probability a message is queued out of order

    def __post_init__(self) -> None:
        for name in ("drop", "dup", "reorder"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(
                    f"FaultSpec.{name} must be a probability in [0, 1], "
                    f"got {p!r}"
                )


class SimNet:
    """Point-to-point queues between named endpoints with fault injection."""

    def __init__(
        self,
        faults: FaultSpec | None = None,
        seed: int = 0,
        key_fn: Callable[[Hashable, Any], Hashable] | None = None,
    ):
        self.faults = faults or FaultSpec()
        self.seed = seed
        self.rng = random.Random(seed)
        self.key_fn = key_fn
        self.queues: dict[Hashable, deque[Any]] = defaultdict(deque)
        # keyed mode: per-(dst-key) occurrence counters (retransmits of the
        # same logical message get independent fates) and the defer-one-pump
        # side queue that realizes reordering
        self._occurrence: dict[Hashable, int] = defaultdict(int)
        self._deferred: dict[Hashable, list[Any]] = defaultdict(list)
        self.sent = 0
        self.dropped = 0
        self.partitioned: set = set()   # endpoints cut off from the fabric

    def partition(self, endpoint: Hashable, cut: bool = True) -> None:
        if cut:
            self.partitioned.add(endpoint)
        else:
            self.partitioned.discard(endpoint)

    # -- keyed fault decisions ----------------------------------------------
    def _fate(self, dst: Hashable, msg: Any) -> tuple[bool, bool, bool]:
        """(drop, dup, reorder) for one keyed send — a pure function of the
        seed, the message key and its occurrence index, independent of how
        other endpoints' traffic interleaves."""
        key = self.key_fn(dst, msg)  # type: ignore[misc]
        occ = self._occurrence[(dst, key)]
        self._occurrence[(dst, key)] = occ + 1
        # str seeds hash process-stably (unlike object identity); one fresh
        # Random per decision keeps draws independent of draw *order*
        r = random.Random(f"{self.seed}|{occ}|{key!r}")
        return (
            r.random() < self.faults.drop,
            r.random() < self.faults.dup,
            r.random() < self.faults.reorder,
        )

    def send(self, dst: Hashable, msg: Any) -> None:
        self.sent += 1
        if dst in self.partitioned:
            self.dropped += 1
            return
        if self.key_fn is not None:
            drop, dup, reorder = self._fate(dst, msg)
            if drop:
                self.dropped += 1
                return
            copies = 2 if dup else 1
            target = self._deferred[dst] if reorder else self.queues[dst]
            for _ in range(copies):
                target.append(msg)
            return
        if self.rng.random() < self.faults.drop:
            self.dropped += 1
            return
        copies = 2 if self.rng.random() < self.faults.dup else 1
        q = self.queues[dst]
        for _ in range(copies):
            if q and self.rng.random() < self.faults.reorder:
                pos = self.rng.randrange(len(q) + 1)
                q.insert(pos, msg)
            else:
                q.append(msg)

    def purge(self, dst: Hashable, predicate) -> int:
        """Drop every queued message at ``dst`` matching ``predicate``;
        returns the number dropped.  Models an endpoint flushing traffic
        that became undeliverable (e.g. addressed to a retired consensus
        group) without disturbing queue order for the survivors."""
        q = self.queues[dst]
        keep = [m for m in q if not predicate(m)]
        n = len(q) - len(keep)
        q.clear()
        q.extend(keep)
        d = self._deferred.get(dst)
        if d:
            dkeep = [m for m in d if not predicate(m)]
            n += len(d) - len(dkeep)
            self._deferred[dst] = dkeep
        self.dropped += n
        return n

    def recv(self, dst: Hashable) -> Any | None:
        q = self.queues[dst]
        return q.popleft() if q else None

    def recv_all(self, dst: Hashable) -> list[Any]:
        q = self.queues[dst]
        out = list(q)
        q.clear()
        # deferred (reordered) messages sat out this pump; they lead the
        # next one — overtaken by everything delivered above
        d = self._deferred.get(dst)
        if d:
            q.extend(d)
            d.clear()
        return out

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values()) + sum(
            len(d) for d in self._deferred.values()
        )
