"""§Roofline report: aggregate the dry-run artifacts into the roofline table.

Reads benchmarks/artifacts/dryrun/*.json (produced by repro.launch.dryrun)
and emits one row per (arch x shape x mesh x rules): the three terms, the
dominant bottleneck, and MODEL_FLOPS/HLO ratio.  This is the §Perf scoreboard.
"""
from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import from_record

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def run() -> None:
    files = sorted(glob.glob(os.path.join(ART, "*.json")))
    if not files:
        emit("roofline/no_artifacts", 0.0, "run: python -m repro.launch.dryrun --all")
        return
    n_ok = n_skip = 0
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        tag = os.path.basename(path)[:-5]
        if rec.get("skipped"):
            n_skip += 1
            continue
        if not rec.get("ok"):
            emit(f"roofline/{tag}", 0.0, f"FAILED: {rec.get('error','?')[:60]}")
            continue
        n_ok += 1
        rl = from_record(rec)
        emit(
            f"roofline/{tag}",
            rl.t_bound * 1e6,
            f"dom={rl.dominant} tc={rl.t_compute*1e3:.2f}ms "
            f"tm={rl.t_memory*1e3:.2f}ms tx={rl.t_collective*1e3:.2f}ms "
            f"useful={rl.useful_ratio:.2f} frac={rl.roofline_fraction:.3f}",
        )
    emit("roofline/summary", 0.0, f"cells_ok={n_ok} skipped={n_skip}")
