"""The drop-in CAANS application API (paper Fig. 4).

    submit(ctx, value, size)          -> propose a value
    ctx.deliver = cb(value, size, inst)  (registered callback)
    recover(ctx, inst, nop, size)     -> learn a previously decided instance

A ``PaxosContext`` wires software proposers/learners to the "hardware"
coordinator/acceptor dataplane.  The dataplane is the jitted batched engine
(or the Pallas kernels when ``use_kernels=True``) — the same hardware/software
divide as the paper: applications only ever see ``submit``/``deliver``/
``recover``; everything between is the network's problem.

Messages between the host roles travel over the fault-injected ``SimNet``;
retransmission on timeout (counted in ``pump`` rounds) and duplicate
suppression at learners implement the paper's §3.1 failure-handling contract.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import batched
from .network import SimNet
from .paxos import Coordinator as SoftCoordinator
from .types import (
    MSG_NOP,
    MSG_P1A,
    MSG_P2A,
    MSG_P2B,
    AcceptorState,
    CoordinatorState,
    MsgBatch,
    PaxosConfig,
    decode_value,
    encode_value,
)

NO_ROUND = -1
NOP_SENTINEL = -0x7FFFFFFF  # first value word marking an internal filler slot


@dataclasses.dataclass
class _Pending:
    payload: bytes
    age: int = 0


class HardwareDataplane:
    """The coordinator + acceptor array + learner dedup memory, executing as
    single-dispatch device programs.

    Two execution paths (DESIGN.md §3):

      * ``pipeline()`` — the fused wire path: the whole Phase-2 round
        (sequence -> all-A vote -> quorum -> ring dedup) as ONE program; the
        Pallas megakernel ``kernels.wirepath.wirepath_round`` when
        ``use_kernels``, else the jnp oracle ``batched.fused_round``.  All
        protocol state stays resident in device memory across pump rounds.
      * ``sequence()``/``vote()``/``prepare()`` — the staged path, used when
        votes must surface as messages (per-learner fan-out, recovery,
        software-coordinator failover).  Still one dispatch for the whole
        acceptor array: the historical per-acceptor Python loop (and its
        per-vote ``.at[aid].set`` full-stack rewrites) is gone.

    Liveness is a device-resident runtime mask (``alive_mask``), so
    ``kill_acceptor``/``revive_acceptor`` never trigger recompilation.
    """

    def __init__(self, cfg: PaxosConfig, use_kernels: bool = False):
        self.cfg = cfg
        self.cstate = CoordinatorState.init()
        # acceptor register files, permanently stacked (A, ...) — the paper's
        # per-device BRAM, one shard per acceptor
        one = AcceptorState.init(cfg.n_instances, cfg.value_words)
        self.stack: AcceptorState = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_acceptors,) + x.shape).copy(), one
        )
        self.lstate = batched.LearnerState.init(cfg.n_instances, cfg.value_words)
        self.alive = [True] * cfg.n_acceptors       # host mirror (introspection)
        self.alive_mask = jnp.ones((cfg.n_acceptors,), jnp.bool_)
        self.use_kernels = use_kernels
        # host mirror of the sequencer watermark — lets the kernel path check
        # its block-alignment invariant without a device sync
        self._next_inst_host = 0
        self._seq_base: Optional[int] = None        # provenance hint for vote()
        if use_kernels:
            from repro.kernels import ops as kops

            self._seq = kops.coordinator_sequence
            self._fused_k = jax.jit(kops.fused_round, donate_argnums=(1, 2))
            self._vote_all_k = jax.jit(
                kops.acceptor_phase2_all, donate_argnums=(0,)
            )
        else:
            self._seq = jax.jit(batched.coordinator_sequence)
        self._fused = jax.jit(batched.fused_round, donate_argnums=(1, 2))
        self._vote_all = jax.jit(batched.acceptor_phase2_all, donate_argnums=(0,))
        self._prep_all = jax.jit(batched.acceptor_phase1_all, donate_argnums=(0,))

    # -- wire-path invariants -------------------------------------------------
    def _block(self, b: int) -> int:
        from repro.kernels.wirepath import DEFAULT_BLOCK_B

        return min(DEFAULT_BLOCK_B, b)

    def _window_aligned(self, base: int, b: int) -> bool:
        """True iff a contiguous window [base, base+b) satisfies the Pallas
        ring-blocking invariants (BB | base, BB | B, BB | N, B <= N)."""
        bb = self._block(b)
        return (
            b % bb == 0
            and self.cfg.n_instances % bb == 0
            and b <= self.cfg.n_instances
            and base % bb == 0
        )

    # -- fused fast path: whole Phase-2 round in ONE device program ----------
    def pipeline(self, values: np.ndarray, active: np.ndarray):
        """One dispatch: sequence + all acceptor votes + quorum + dedup.

        This is the CAANS wire path — consensus logic fused end-to-end below
        the host boundary (DESIGN.md §3).  Returns host ``(fresh, inst,
        value)`` where ``fresh`` masks non-duplicate deliveries.
        """
        b = values.shape[0]
        use_k = self.use_kernels and self._window_aligned(self._next_inst_host, b)
        fn = self._fused_k if use_k else self._fused
        self.cstate, self.stack, self.lstate, fresh, inst, _win, value = fn(
            self.cstate,
            self.stack,
            self.lstate,
            jnp.asarray(values),
            jnp.asarray(active),
            self.alive_mask,
            self.cfg.quorum,
        )
        self._next_inst_host += b
        return np.asarray(fresh), np.asarray(inst), np.asarray(value)

    def kill_acceptor(self, aid: int) -> None:
        self.alive[aid] = False
        self.alive_mask = self.alive_mask.at[aid].set(False)

    def revive_acceptor(self, aid: int) -> None:
        self.alive[aid] = True
        self.alive_mask = self.alive_mask.at[aid].set(True)

    # -- staged path (votes surface as messages) -----------------------------
    def sequence(self, values: np.ndarray, active: np.ndarray) -> MsgBatch:
        self._seq_base = self._next_inst_host
        self.cstate, p2a = self._seq(
            self.cstate, jnp.asarray(values), jnp.asarray(active)
        )
        self._next_inst_host += values.shape[0]
        return p2a

    def vote(self, p2a: MsgBatch) -> List[Optional[MsgBatch]]:
        """Phase-2 vote of the whole acceptor array, one dispatch.

        Batches produced by ``sequence()`` (contiguous, block-aligned window)
        go through the Pallas wire-path kernel when ``use_kernels``; anything
        else (recovery singletons, software-coordinator batches at arbitrary
        watermarks) takes the general jnp scatter path.  Dead acceptors come
        back as ``None`` — their votes are never sent.
        """
        base, self._seq_base = self._seq_base, None
        b = p2a.batch
        use_k = (
            self.use_kernels
            and base is not None
            and self._window_aligned(base, b)
        )
        fn = self._vote_all_k if use_k else self._vote_all
        self.stack, votes = fn(self.stack, p2a, self.alive_mask)
        return self._split(votes)

    def prepare(self, p1a: MsgBatch) -> List[Optional[MsgBatch]]:
        self.stack, outs = self._prep_all(self.stack, p1a, self.alive_mask)
        return self._split(outs)

    def _split(self, stacked: MsgBatch) -> List[Optional[MsgBatch]]:
        """Stacked [A, ...] message batches -> per-acceptor list, None when
        dead (a crashed switch emits nothing)."""
        return [
            jax.tree_util.tree_map(lambda x, aid=aid: x[aid], stacked)
            if self.alive[aid]
            else None
            for aid in range(self.cfg.n_acceptors)
        ]


class PaxosContext:
    """Drop-in replacement context (the paper's ``paxos_ctx``)."""

    def __init__(
        self,
        cfg: Optional[PaxosConfig] = None,
        deliver: Optional[Callable[[bytes, int, int], None]] = None,
        net: Optional[SimNet] = None,
        use_kernels: bool = False,
        retransmit_after: int = 3,
        n_learners: int = 1,
        fused: bool = False,
    ):
        self.cfg = cfg or PaxosConfig()
        self.deliver_cb = deliver
        self.net = net or SimNet()
        self.hw = HardwareDataplane(self.cfg, use_kernels=use_kernels)
        self.fused = fused
        self._delivered_seqs: set = set()
        self.retransmit_after = retransmit_after
        self.n_learners = n_learners
        # learner state (software role), one per learner
        self.learned: List[Dict[int, bytes]] = [dict() for _ in range(n_learners)]
        self._partial: List[Dict[int, Dict[int, Tuple[int, bytes]]]] = [
            dict() for _ in range(n_learners)
        ]
        self.delivered_log: List[Tuple[int, bytes]] = []
        self._pending: Dict[int, _Pending] = {}   # client-seq -> payload
        self._next_client_seq = 0
        self._next_epoch = 1                      # round-allocator epochs
        self._softco: Optional[SoftCoordinator] = None  # failover coordinator
        self.stats = {"submitted": 0, "delivered": 0, "retransmits": 0}

    # -- paper API -----------------------------------------------------------
    def submit(self, payload: bytes) -> int:
        """paxos_submit(ctx, value, size)"""
        seq = self._next_client_seq
        self._next_client_seq += 1
        self._pending[seq] = _Pending(payload)
        self.net.send("coordinator", ("submit", seq, payload))
        self.stats["submitted"] += 1
        return seq

    def recover(self, inst: int, nop: bytes = b"\x00") -> None:
        """paxos_recover(ctx, iid, nop_value, size): phase 1+2 with a no-op."""
        self.net.send("coordinator", ("recover", inst, nop))

    # -- event loop ----------------------------------------------------------
    def pump(self, rounds: int = 1) -> None:
        """Drive the fabric: drain submits through the hardware dataplane,
        route votes to learners, fire deliver callbacks, retransmit losses."""
        for _ in range(rounds):
            self._pump_coordinator()
            self._pump_learners()
            self._retransmit()

    def run_until_quiescent(self, max_rounds: int = 64) -> None:
        for _ in range(max_rounds):
            if not self._pending and self.net.pending() == 0:
                return
            self.pump()

    # -- internals -----------------------------------------------------------
    def _pump_coordinator(self) -> None:
        inbox = self.net.recv_all("coordinator")
        submits = [(m[1], m[2]) for m in inbox if m[0] == "submit"]
        recovers = [(m[1], m[2]) for m in inbox if m[0] == "recover"]

        for inst, nop in recovers:
            self._run_recover(inst, nop)

        b = self.cfg.batch
        for i in range(0, len(submits), b):
            chunk = submits[i : i + b]
            if self.fused and not self.hw.use_kernels:
                # right-size the burst (next pow2): a half-empty wire batch
                # costs real dataplane time; the jnp path has no alignment
                # requirement
                be = 8
                while be < len(chunk):
                    be *= 2
                be = min(be, b)
            else:
                # kernel path: fixed wire batch, preserving the block-aligned
                # window invariant the Pallas ring blocking relies on
                be = b
            vals = np.full((be, self.cfg.value_words), 0, np.int32)
            active = np.zeros((be,), bool)
            for j, (seq, payload) in enumerate(chunk):
                vals[j] = self._encode(seq, payload)
                active[j] = True
            vals[len(chunk) :, 0] = NOP_SENTINEL
            if self.fused and self._softco is None:
                # the CAANS wire path: the whole Phase-2 round below the host
                # boundary, one dispatch — votes never surface as messages
                fresh, inst, value = self.hw.pipeline(vals, active)
                for j in range(len(fresh)):
                    if not fresh[j]:
                        continue
                    raw = value[j].tobytes()
                    for lid in range(self.n_learners):
                        if int(inst[j]) not in self.learned[lid]:
                            self.learned[lid][int(inst[j])] = raw
                    self._deliver(int(inst[j]), raw)
                continue
            if self._softco is not None:
                p2a = self._soft_sequence(vals, active)
            else:
                p2a = self.hw.sequence(vals, active)
            votes = self.hw.vote(p2a)
            for aid, v in enumerate(votes):
                if v is None:
                    continue
                for lid in range(self.n_learners):
                    self.net.send(("learner", lid), ("votes", aid, _to_host(v)))

    def _pump_learners(self) -> None:
        for lid in range(self.n_learners):
            for m in self.net.recv_all(("learner", lid)):
                _, aid, votes = m
                self._learn(lid, aid, votes)

    def _learn(self, lid: int, aid: int, votes: dict) -> None:
        quorum = self.cfg.quorum
        learned = self.learned[lid]
        partial = self._partial[lid]
        n = len(votes["msgtype"])
        for i in range(n):
            if votes["msgtype"][i] != MSG_P2B:
                continue
            inst = int(votes["inst"][i])
            if inst in learned:
                continue  # duplicate suppression
            slot = partial.setdefault(inst, {})
            slot[aid] = (int(votes["vrnd"][i]), votes["value"][i].tobytes())
            by_rnd: Dict[int, int] = {}
            for vr, _ in slot.values():
                by_rnd[vr] = by_rnd.get(vr, 0) + 1
            for vr, cnt in by_rnd.items():
                if cnt >= quorum:
                    raw = next(v for r, v in slot.values() if r == vr)
                    learned[inst] = raw
                    partial.pop(inst, None)
                    if lid == 0:
                        self._deliver(inst, raw)
                    break

    def _deliver(self, inst: int, raw: bytes) -> None:
        words = np.frombuffer(raw, "<i4")
        if words[0] == NOP_SENTINEL:
            return  # internal filler — discarded by the library
        seq = int(words[0])
        if seq in self._delivered_seqs:
            return  # duplicate (retransmit decided twice) — paper §3.1
        self._delivered_seqs.add(seq)
        payload = raw[8 : 8 + int(words[1])]
        self._pending.pop(seq, None)
        self.delivered_log.append((inst, payload))
        self.stats["delivered"] += 1
        if self.deliver_cb:
            self.deliver_cb(payload, len(payload), inst)

    def _retransmit(self) -> None:
        for seq, p in list(self._pending.items()):
            p.age += 1
            if p.age >= self.retransmit_after:
                p.age = 0
                self.stats["retransmits"] += 1
                self.net.send("coordinator", ("submit", seq, p.payload))

    def _encode(self, seq: int, payload: bytes) -> np.ndarray:
        nbytes = self.cfg.value_words * 4
        if len(payload) > nbytes - 8:
            raise ValueError(
                f"value too large: {len(payload)} > {nbytes - 8} "
                f"(increase PaxosConfig.value_words)"
            )
        head = np.array([seq, len(payload)], np.int32).tobytes()
        return np.frombuffer((head + payload).ljust(nbytes, b"\x00"), "<i4").copy()

    # -- failover ------------------------------------------------------------
    def fail_coordinator(self, est_next_inst: Optional[int] = None) -> None:
        """Hardware coordinator dies; a software coordinator takes over.

        Runs the *safe* takeover (core.failover): claims a globally unique
        higher round, Phase-1-scans the uncertainty window around the
        (possibly stale) sequencer estimate, re-proposes any voted values it
        finds, and resumes sequencing past them — the paper's §3.1/§6.4
        procedure with the catch-up made explicit.
        """
        from .failover import takeover

        est = (
            est_next_inst
            if est_next_inst is not None
            else int(jax.device_get(self.hw.cstate.next_inst))
        )
        epoch = self._next_epoch
        self._next_epoch += 1
        res = takeover(
            self.hw,
            coordinator_id=1,
            epoch=epoch,
            est_next_inst=est,
            window=self.cfg.batch * 2,
            quorum=self.cfg.quorum,
        )
        self._softco = SoftCoordinator(
            cid=1, crnd=res.crnd, next_inst=res.next_inst
        )
        return res

    def restore_hardware_coordinator(self) -> None:
        if self._softco is None:
            return
        nxt = int(self._softco.next_inst)
        if self.hw.use_kernels:
            # An arbitrary takeover watermark can break the kernel path's
            # block-alignment invariant — and since bursts advance in block
            # multiples it would never realign on its own, silently pinning
            # the dataplane to the jnp fallback forever.  Burn forward to the
            # next block boundary instead: the skipped instances are never
            # proposed and are recoverable as no-ops (paper §3.1 gap fill).
            bb = self.hw._block(self.cfg.batch)
            nxt = -(-nxt // bb) * bb
        self.hw.cstate = CoordinatorState(
            next_inst=jnp.int32(nxt),
            crnd=jnp.int32(self._softco.crnd),
        )
        self.hw._next_inst_host = nxt  # resync the host watermark mirror
        self._softco = None

    def _soft_sequence(self, vals: np.ndarray, active: np.ndarray) -> MsgBatch:
        co = self._softco
        assert co is not None
        b = vals.shape[0]
        inst = np.arange(co.next_inst, co.next_inst + b, dtype=np.int32)
        co.next_inst += b
        return MsgBatch(
            msgtype=jnp.where(jnp.asarray(active), MSG_P2A, MSG_NOP).astype(jnp.int32),
            inst=jnp.asarray(inst),
            rnd=jnp.full((b,), co.crnd, jnp.int32),
            vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
            swid=jnp.full((b,), co.cid, jnp.int32),
            value=jnp.asarray(vals),
        )

    def _run_recover(self, inst: int, nop: bytes) -> None:
        """Phase 1 + Phase 2 for one instance with a no-op value (paper §3.1)."""
        from .failover import allocate_round

        epoch = self._next_epoch
        self._next_epoch += 1
        crnd = allocate_round(epoch, coordinator_id=2)
        b = self.cfg.batch
        # Filler slots carry a contiguous inst window starting at the target:
        # the vectorized acceptor scatter requires distinct ring slots per
        # batch, and all-zero filler insts would collide with the recovered
        # instance whenever inst % n_instances == 0 (slot-0 clobber).  The
        # fillers' rnd stays NO_ROUND, so they never accept/promise anything.
        window = jnp.arange(inst, inst + b, dtype=jnp.int32)
        p1a = MsgBatch.nop(b, self.cfg.value_words)
        p1a = p1a.replace(
            msgtype=p1a.msgtype.at[0].set(MSG_P1A),
            inst=window,
            rnd=p1a.rnd.at[0].set(crnd),
        )
        promises = self.hw.prepare(p1a)
        best: Tuple[int, Optional[bytes]] = (NO_ROUND, None)
        got = 0
        for v in promises:
            if v is None:
                continue
            host = _to_host(v)
            if host["msgtype"][0] != 2:  # MSG_P1B
                continue
            got += 1
            vr = int(host["vrnd"][0])
            if vr > best[0]:
                best = (vr, host["value"][0].tobytes())
        if got < self.cfg.quorum:
            return  # cannot recover without a quorum
        if best[1] is not None and best[0] != NO_ROUND:
            value_words = np.frombuffer(best[1], "<i4").copy()
        else:
            value_words = self._encode(-1, nop)
            value_words[0] = NOP_SENTINEL
        p2a = MsgBatch.nop(b, self.cfg.value_words)
        p2a = p2a.replace(
            msgtype=p2a.msgtype.at[0].set(MSG_P2A),
            inst=window,  # distinct slots; fillers at NO_ROUND never accept
            rnd=p2a.rnd.at[0].set(crnd),
            value=p2a.value.at[0].set(jnp.asarray(value_words)),
        )
        votes = self.hw.vote(p2a)
        for aid, v in enumerate(votes):
            if v is None:
                continue
            for lid in range(self.n_learners):
                self.net.send(("learner", lid), ("votes", aid, _to_host(v)))


def _to_host(m: MsgBatch) -> dict:
    return {
        "msgtype": np.asarray(m.msgtype),
        "inst": np.asarray(m.inst),
        "rnd": np.asarray(m.rnd),
        "vrnd": np.asarray(m.vrnd),
        "swid": np.asarray(m.swid),
        "value": np.asarray(m.value),
    }
