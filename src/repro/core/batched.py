"""Batched multi-instance Paxos dataplane in JAX.

This is the jnp-level "hardware" implementation of the coordinator / acceptor
/ learner-quorum logic: every function processes a *batch* of Paxos headers
(``MsgBatch``) in one shot.  The Pallas kernels in ``repro.kernels`` implement
the same functions with explicit VMEM tiling; ``kernels/ref.py`` re-exports
these as the oracles.

Semantics notes
---------------
* ``coordinator_sequence`` assigns a contiguous instance window to each batch
  (monotonic sequencer).  Slots in a batch therefore hit *distinct* acceptor
  ring slots, which makes the vectorized scatter in ``acceptor_phase2`` exact.
* For adversarial traffic (recovery, duplicated instances inside one batch)
  use ``acceptor_sequential`` — a ``lax.scan`` with exact one-message-at-a-time
  semantics.  Tests check that on distinct-slot batches both paths agree.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .types import (
    MSG_NOP,
    MSG_P1A,
    MSG_P1B,
    MSG_P2A,
    MSG_P2B,
    MSG_REJECT,
    AcceptorState,
    CoordinatorState,
    MsgBatch,
)

NO_ROUND = jnp.int32(-1)


# ---------------------------------------------------------------------------
# Coordinator (sequencer)
# ---------------------------------------------------------------------------
def coordinator_sequence(
    cstate: CoordinatorState, values: jax.Array, active: jax.Array
) -> Tuple[CoordinatorState, MsgBatch]:
    """Bind a batch of proposals to a contiguous window of instances.

    Inactive slots still consume an instance and carry a NOP marker — they are
    decided and discarded by the application layer (the paper's no-op values).
    This preserves window contiguity, the property the acceptor fast path and
    the Pallas kernel exploit.
    """
    b = values.shape[0]
    inst = cstate.next_inst + jnp.arange(b, dtype=jnp.int32)
    msgtype = jnp.where(active, MSG_P2A, MSG_NOP).astype(jnp.int32)
    out = MsgBatch(
        msgtype=msgtype,
        inst=inst,
        rnd=jnp.full((b,), cstate.crnd, jnp.int32),
        vrnd=jnp.full((b,), NO_ROUND, jnp.int32),
        swid=jnp.zeros((b,), jnp.int32),
        value=values,
    )
    new = CoordinatorState(next_inst=cstate.next_inst + b, crnd=cstate.crnd)
    return new, out


# ---------------------------------------------------------------------------
# Acceptor — vectorized fast path (distinct ring slots per batch)
# ---------------------------------------------------------------------------
def acceptor_phase2(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> Tuple[AcceptorState, MsgBatch]:
    """Vote on a batch of P2A requests against the instance ring.

    accept iff msgtype==P2A and msg.rnd >= promised rnd of the slot.
    NOP slots pass through as NOPs (they are *not* votes).
    """
    n = astate.n_instances
    slots = msgs.inst % n
    cur_rnd = astate.rnd[slots]
    is_p2a = (msgs.msgtype == MSG_P2A) | (msgs.msgtype == MSG_NOP)
    # NOP slots are sequenced instances carrying the no-op value: acceptors
    # still vote so the instance is decided (and later discarded upstream).
    accept = is_p2a & (msgs.rnd >= cur_rnd)

    new_rnd = jnp.where(accept, msgs.rnd, cur_rnd)
    new_vrnd = jnp.where(accept, msgs.rnd, astate.vrnd[slots])
    new_val = jnp.where(accept[:, None], msgs.value, astate.value[slots])

    astate = AcceptorState(
        rnd=astate.rnd.at[slots].set(new_rnd, mode="drop"),
        vrnd=astate.vrnd.at[slots].set(new_vrnd, mode="drop"),
        value=astate.value.at[slots].set(new_val, mode="drop"),
    )
    votes = MsgBatch(
        msgtype=jnp.where(accept, MSG_P2B, MSG_REJECT).astype(jnp.int32),
        inst=msgs.inst,
        rnd=jnp.where(accept, msgs.rnd, cur_rnd),
        vrnd=jnp.where(accept, msgs.rnd, astate.vrnd[slots]),
        swid=jnp.full_like(msgs.swid, aid),
        value=jnp.where(accept[:, None], msgs.value, 0),
    )
    return astate, votes


def acceptor_phase1(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> Tuple[AcceptorState, MsgBatch]:
    """Promise on a batch of P1A prepares (recovery / takeover path)."""
    n = astate.n_instances
    slots = msgs.inst % n
    cur_rnd = astate.rnd[slots]
    cur_vrnd = astate.vrnd[slots]
    cur_val = astate.value[slots]
    is_p1a = msgs.msgtype == MSG_P1A
    promise = is_p1a & (msgs.rnd > cur_rnd)

    astate = AcceptorState(
        rnd=astate.rnd.at[slots].set(jnp.where(promise, msgs.rnd, cur_rnd), mode="drop"),
        vrnd=astate.vrnd,
        value=astate.value,
    )
    out = MsgBatch(
        msgtype=jnp.where(promise, MSG_P1B, MSG_REJECT).astype(jnp.int32),
        inst=msgs.inst,
        rnd=jnp.where(promise, msgs.rnd, cur_rnd),
        vrnd=cur_vrnd,
        swid=jnp.full_like(msgs.swid, aid),
        value=cur_val,
    )
    return astate, out


# ---------------------------------------------------------------------------
# Acceptor — exact sequential semantics (any batch, incl. duplicate slots)
# ---------------------------------------------------------------------------
def acceptor_sequential(
    astate: AcceptorState, msgs: MsgBatch, aid: int | jax.Array = 0
) -> Tuple[AcceptorState, MsgBatch]:
    """One-message-at-a-time semantics via lax.scan (recovery / adversarial)."""

    def step(state: AcceptorState, m):
        msgtype, inst, rnd, vrnd, swid, value = m
        n = state.n_instances
        slot = inst % n
        cur_rnd = state.rnd[slot]
        cur_vrnd = state.vrnd[slot]
        cur_val = state.value[slot]

        is_p2 = (msgtype == MSG_P2A) | (msgtype == MSG_NOP)
        is_p1 = msgtype == MSG_P1A
        accept = is_p2 & (rnd >= cur_rnd)
        promise = is_p1 & (rnd > cur_rnd)

        upd_rnd = jnp.where(accept | promise, rnd, cur_rnd)
        upd_vrnd = jnp.where(accept, rnd, cur_vrnd)
        upd_val = jnp.where(accept, value, cur_val)
        state = AcceptorState(
            rnd=state.rnd.at[slot].set(upd_rnd),
            vrnd=state.vrnd.at[slot].set(upd_vrnd),
            value=state.value.at[slot].set(upd_val),
        )
        out_type = jnp.where(
            accept, MSG_P2B, jnp.where(promise, MSG_P1B, MSG_REJECT)
        ).astype(jnp.int32)
        out = (
            out_type,
            inst,
            jnp.where(accept | promise, rnd, cur_rnd),
            jnp.where(accept, rnd, cur_vrnd),
            jnp.full_like(swid, aid),
            jnp.where(is_p1, cur_val, jnp.where(accept, value, jnp.zeros_like(value))),
        )
        return state, out

    ms = (msgs.msgtype, msgs.inst, msgs.rnd, msgs.vrnd, msgs.swid, msgs.value)
    astate, outs = jax.lax.scan(step, astate, ms)
    return astate, MsgBatch(*outs)


# ---------------------------------------------------------------------------
# Learner — quorum over stacked votes
# ---------------------------------------------------------------------------
def learner_quorum(
    vote_msgtype: jax.Array,   # int32[A, B]
    vote_inst: jax.Array,      # int32[A, B]
    vote_vrnd: jax.Array,      # int32[A, B]
    vote_value: jax.Array,     # int32[A, B, V]
    quorum: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Position-aligned quorum count over the acceptor axis.

    Votes arriving from the A acceptors for the same P2A batch are aligned by
    batch position.  deliver[b] iff >= quorum acceptors voted (P2B) with the
    same vrnd.  Value is taken from any acceptor voting the winning vrnd
    (Paxos guarantees value uniqueness per (inst, rnd)).
    """
    is_vote = vote_msgtype == MSG_P2B                       # [A, B]
    # winning round = max vrnd among votes (NO_ROUND where none)
    vrnd_masked = jnp.where(is_vote, vote_vrnd, NO_ROUND)
    win_vrnd = jnp.max(vrnd_masked, axis=0)                 # [B]
    agree = is_vote & (vote_vrnd == win_vrnd[None, :])      # [A, B]
    count = jnp.sum(agree.astype(jnp.int32), axis=0)        # [B]
    deliver = count >= quorum                               # [B]

    # first acceptor index voting the winning round
    first = jnp.argmax(agree, axis=0)                       # [B]
    b = vote_inst.shape[1]
    cols = jnp.arange(b)
    inst = vote_inst[first, cols]
    value = vote_value[first, cols]
    return deliver, inst, win_vrnd, value


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LearnerState:
    """Dedup memory: delivered bitmap + decided values over the instance ring."""

    delivered: jax.Array  # bool[N]
    value: jax.Array      # int32[N, V]

    def tree_flatten(self):
        return ((self.delivered, self.value), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def init(cls, n_instances: int, value_words: int) -> "LearnerState":
        return cls(
            delivered=jnp.zeros((n_instances,), jnp.bool_),
            value=jnp.zeros((n_instances, value_words), jnp.int32),
        )


def learner_update(
    lstate: LearnerState,
    deliver: jax.Array,
    inst: jax.Array,
    value: jax.Array,
) -> Tuple[LearnerState, jax.Array]:
    """Record deliveries; returns mask of *fresh* (not duplicate) deliveries."""
    n = lstate.delivered.shape[0]
    slots = inst % n
    fresh = deliver & ~lstate.delivered[slots]
    lstate = LearnerState(
        delivered=lstate.delivered.at[slots].set(
            lstate.delivered[slots] | deliver, mode="drop"
        ),
        value=lstate.value.at[slots].set(
            jnp.where(fresh[:, None], value, lstate.value[slots]), mode="drop"
        ),
    )
    return lstate, fresh
