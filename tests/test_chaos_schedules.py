"""Randomized chaos schedules over the multi-group service.

Property under test: a multi-group ``PaxosContext`` (unsharded or
groups-sharded) driven through an arbitrary interleaving of
submit / freeze / restore / kill / revive / pump operations produces
*exactly* the per-group delivery logs of G independent single-group
contexts fed the identical schedule — same payloads, same instances, same
order — and every submission is delivered exactly once after the service
heals.

The harness keeps the pump cadence identical on both sides (ops are applied
simultaneously; every ``pump`` op advances the multi-group context and all G
twins by one round), which makes retransmission timing — and therefore
instance consumption — deterministic, so logs can be compared bit for bit.
The configs pin ``batch=8`` so the wire-burst right-sizing resolves to the
same burst on both sides regardless of how skewed the per-group queues get.

Deterministic seeds always run; when hypothesis is installed (the
``_hypothesis_compat`` guard skip-marks otherwise) it searches the
seed/length space and shrinks failing schedules toward short ones.
"""
from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import PaxosConfig, PaxosContext
from repro.launch.mesh import make_group_mesh

A = 3
QUORUM = A // 2 + 1
CFG1 = PaxosConfig(n_acceptors=A, n_instances=64, batch=8)


def _cfg(g: int) -> PaxosConfig:
    return PaxosConfig(n_acceptors=A, n_instances=64, batch=8, n_groups=g)


def _schedule(seed: int, g: int, steps: int):
    """A random but always-legal op sequence, healed at the end (every
    acceptor revived, every frozen group restored) so full delivery is a
    checkable postcondition."""
    rng = np.random.default_rng(seed)
    frozen = [False] * g
    alive = [[True] * A for _ in range(g)]
    ops = []
    for _ in range(steps):
        r = rng.random()
        gid = int(rng.integers(g))
        if r < 0.45:
            ops.append(("submit", gid))
        elif r < 0.70:
            ops.append(("pump",))
        elif r < 0.78:
            aid = int(rng.integers(A))
            if alive[gid][aid]:
                alive[gid][aid] = False
                ops.append(("kill", gid, aid))
        elif r < 0.86:
            dead = [a for a in range(A) if not alive[gid][a]]
            if dead:
                aid = dead[int(rng.integers(len(dead)))]
                alive[gid][aid] = True
                ops.append(("revive", gid, aid))
        elif r < 0.93:
            # takeover needs a quorum of promises to discover voted values
            if not frozen[gid] and sum(alive[gid]) >= QUORUM:
                frozen[gid] = True
                ops.append(("freeze", gid))
        else:
            if frozen[gid]:
                frozen[gid] = False
                ops.append(("restore", gid))
    for gid in range(g):
        for aid in range(A):
            if not alive[gid][aid]:
                ops.append(("revive", gid, aid))
        if frozen[gid]:
            ops.append(("restore", gid))
    return ops


def run_chaos(
    seed: int,
    g: int = 3,
    use_kernels: bool = False,
    sharded: bool = False,
    steps: int = 30,
) -> None:
    mesh = make_group_mesh() if sharded else None
    mg = PaxosContext(_cfg(g), use_kernels=use_kernels, mesh=mesh)
    singles = [
        PaxosContext(CFG1, use_kernels=use_kernels, fused=True)
        for _ in range(g)
    ]
    sent = [[] for _ in range(g)]
    for op in _schedule(seed, g, steps):
        kind = op[0]
        if kind == "submit":
            gid = op[1]
            p = f"s{len(sent[gid])}g{gid}".encode()
            sent[gid].append(p)
            mg.submit(p, group=gid)
            singles[gid].submit(p)
        elif kind == "pump":
            mg.pump()
            for s in singles:
                s.pump()
        elif kind == "kill":
            _, gid, aid = op
            mg.hw.kill_acceptor(gid, aid)
            singles[gid].hw.kill_acceptor(aid)
        elif kind == "revive":
            _, gid, aid = op
            mg.hw.revive_acceptor(gid, aid)
            singles[gid].hw.revive_acceptor(aid)
        elif kind == "freeze":
            gid = op[1]
            mg.fail_coordinator(group=gid)
            singles[gid].fail_coordinator()
        elif kind == "restore":
            gid = op[1]
            mg.restore_hardware_coordinator(group=gid)
            singles[gid].restore_hardware_coordinator()
    # drain: everything is healed, so a few retransmit cycles deliver all
    for _ in range(30):
        mg.pump()
        for s in singles:
            s.pump()
    for gid in range(g):
        assert mg.group_log[gid] == singles[gid].delivered_log, (seed, gid)
        got = [p for _inst, p in mg.group_log[gid]]
        assert len(got) == len(set(got)), (seed, gid)          # exactly once
        assert sorted(got) == sorted(sent[gid]), (seed, gid)   # all delivered
    assert not mg._pending


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_deterministic(seed, use_kernels):
    run_chaos(seed, g=3, use_kernels=use_kernels, steps=30)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [3, 4])
def test_chaos_sharded(seed, use_kernels):
    """The groups-sharded dataplane under the same chaos contract."""
    run_chaos(seed, g=2, use_kernels=use_kernels, sharded=True, steps=24)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(4, 40))
def test_chaos_property_jnp(seed, steps):
    run_chaos(seed, g=3, use_kernels=False, steps=steps)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(4, 24))
def test_chaos_property_sharded(seed, steps):
    run_chaos(seed, g=2, use_kernels=False, sharded=True, steps=steps)
