"""Paper Table 1/2: per-message dataplane component latency & throughput.

Three implementations of the same coordinator/acceptor logic, mirroring the
paper's forwarding-vs-Paxos comparison:

  software   — scalar Python role step (libpaxos-like baseline)
  jit        — jnp batched dataplane (XLA-compiled, per-message amortized)
  pallas     — the TPU kernels (interpret mode on CPU: correctness-true,
               *not* a TPU latency claim — Table 2's computed numbers for the
               target come from the dry-run HLO instead)

"forwarding" is the no-op baseline (same batch moved through an identity
jit), matching the paper's forwarding-latency row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import batched
from repro.core.paxos import Acceptor, Coordinator, Msg
from repro.core.types import MSG_P2A, AcceptorState, CoordinatorState, MsgBatch

from .common import block, emit, time_fn

B = 512            # messages per burst
V = 16             # value words (64B, paper's value size)
N = 1 << 16        # instance ring (paper Table 3)


def _mk_batch(base: int) -> MsgBatch:
    return MsgBatch(
        msgtype=jnp.full((B,), MSG_P2A, jnp.int32),
        inst=jnp.arange(base, base + B, dtype=jnp.int32),
        rnd=jnp.zeros((B,), jnp.int32),
        vrnd=jnp.full((B,), -1, jnp.int32),
        swid=jnp.zeros((B,), jnp.int32),
        value=jnp.ones((B, V), jnp.int32),
    )


def run() -> None:
    # ---- software (scalar) --------------------------------------------------
    co = Coordinator()
    acc = Acceptor(aid=0, n_instances=N)
    msgs = [Msg(MSG_P2A, inst=i, rnd=0, value=b"x" * 64) for i in range(B)]

    def sw_coordinator():
        for m in msgs:
            co.on_submit(m)

    def sw_acceptor():
        for m in msgs:
            acc.on_p2a(m)

    us = time_fn(sw_coordinator) / B
    emit("table1/software/coordinator", us, f"{1e6/us:.0f} msg/s/core")
    us = time_fn(sw_acceptor) / B
    emit("table1/software/acceptor", us, f"{1e6/us:.0f} msg/s/core")

    # ---- jit batched dataplane ----------------------------------------------
    fwd = jax.jit(lambda m: jax.tree_util.tree_map(lambda x: x + 0, m))
    seq = jax.jit(batched.coordinator_sequence)
    vote = jax.jit(batched.acceptor_phase2)

    batch = _mk_batch(0)
    cstate = CoordinatorState.init()
    astate = AcceptorState.init(N, V)
    vals = jnp.ones((B, V), jnp.int32)
    active = jnp.ones((B,), bool)

    us = time_fn(lambda: block(fwd(batch))) / B
    emit("table1/jit/forwarding", us, f"{1e6/us:.0f} msg/s")
    us = time_fn(lambda: block(seq(cstate, vals, active))) / B
    emit("table1/jit/coordinator", us, f"{1e6/us:.0f} msg/s")
    us = time_fn(lambda: block(vote(astate, batch, 0))) / B
    emit("table1/jit/acceptor", us, f"{1e6/us:.0f} msg/s")

    q = jax.jit(lambda vt, vi, vr, vv: batched.learner_quorum(vt, vi, vr, vv, 2))
    vt = jnp.full((3, B), 4, jnp.int32)
    vi = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[None], (3, B))
    vr = jnp.zeros((3, B), jnp.int32)
    vv = jnp.ones((3, B, V), jnp.int32)
    us = time_fn(lambda: block(q(vt, vi, vr, vv))) / B
    emit("table1/jit/learner_quorum", us, f"{1e6/us:.0f} msg/s")

    # ---- pallas kernels (interpret mode: correctness path) -------------------
    from repro.kernels.acceptor import acceptor_phase2_window
    from repro.kernels.coordinator import coordinator_sequence_window

    us = time_fn(
        lambda: block(
            coordinator_sequence_window(
                jnp.int32(0), jnp.int32(0), active.astype(jnp.int32), interpret=True
            )
        )
    ) / B
    emit("table1/pallas_interpret/coordinator", us, "CPU interpret (not TPU time)")
    st = (astate.rnd, astate.vrnd, astate.value)
    us = time_fn(
        lambda: block(
            acceptor_phase2_window(
                *st, 0, 0, batch.msgtype, batch.rnd, batch.value, interpret=True
            )
        )
    ) / B
    emit("table1/pallas_interpret/acceptor", us, "CPU interpret (not TPU time)")
