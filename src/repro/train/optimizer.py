"""AdamW with warmup-cosine schedule, implemented as pure-pytree transforms.

Optimizer moments inherit the parameter logical axes, so FSDP sharding of
``mu``/``nu`` follows from the same rules as the parameters (ZeRO-style).
Moments are kept in float32 regardless of the parameter dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def init(params) -> OptState:
    def f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    return OptState(
        mu=jax.tree_util.tree_map(f32, params),
        nu=jax.tree_util.tree_map(f32, params),
        count=jnp.zeros((), jnp.int32),
    )


def init_shapes(param_shapes) -> OptState:
    """ShapeDtypeStruct version (dry-run)."""
    def f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    return OptState(
        mu=jax.tree_util.tree_map(f32, param_shapes),
        nu=jax.tree_util.tree_map(f32, param_shapes),
        count=jax.ShapeDtypeStruct((), jnp.int32),
    )


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def update(
    grads, state: OptState, params, cfg: OptConfig
) -> tuple[Any, OptState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(leaf, grads, state.mu, state.nu, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_mu, new_nu, count), gnorm
