"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.make_report > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import from_record

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
WIREPATH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_wirepath.json")


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.1f} GiB"
    if b >= 2**20:
        return f"{b/2**20:.1f} MiB"
    return f"{b/2**10:.0f} KiB"


def load(pattern: str):
    out = []
    for p in sorted(glob.glob(os.path.join(ART, pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def dryrun_table() -> None:
    print("### Dry-run results (per cell x mesh; baseline rules, fixed-digest)\n")
    print("| arch | shape | mesh | status | compile s | args/dev | temps/dev | coll bytes/dev | coll ops |")
    print("|---|---|---|---|---|---|---|---|---|")
    for rec in load("*__base.json"):
        tag = (rec["arch"], rec["shape"], rec["mesh"])
        if rec.get("skipped"):
            print(f"| {tag[0]} | {tag[1]} | {tag[2]} | SKIP (sub-quadratic rule) | — | — | — | — | — |")
            continue
        ops = ", ".join(
            f"{k}x{v}" for k, v in sorted(rec.get("collective_counts", {}).items())
        )
        print(
            f"| {tag[0]} | {tag[1]} | {tag[2]} | ok | {rec.get('compile_s', 0):.1f} "
            f"| {fmt_bytes(rec.get('arg_bytes_per_dev_est', 0))} "
            f"| {fmt_bytes(rec.get('temp_bytes', 0))} "
            f"| {fmt_bytes(rec.get('collective_bytes', 0))} | {ops} |"
        )
    print()


def roofline_table() -> None:
    print("### Roofline terms (single-pod 16x16, baseline rules, fixed-digest)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | dominant | MODEL_FLOPS | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    rows = []
    for rec in load("*single__base.json"):
        if not rec.get("ok"):
            continue
        rl = from_record(rec)
        rows.append(rl)
    rows.sort(key=lambda r: (r.arch, r.shape))
    for rl in rows:
        print(
            f"| {rl.arch} | {rl.shape} | {rl.t_compute*1e3:.2f} ms | "
            f"{rl.t_memory*1e3:.2f} ms | {rl.t_collective*1e3:.2f} ms | "
            f"**{rl.dominant}** | {rl.model_flops:.3g} | {rl.useful_ratio:.2f} | "
            f"{rl.roofline_fraction:.4f} |"
        )
    print()


def variants_table() -> None:
    print("### §Perf variant measurements (hillclimbed cells)\n")
    print("| cell | rules | variant | t_compute | t_memory | t_collective | dominant | frac |")
    print("|---|---|---|---|---|---|---|---|")
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("skipped") or not rec.get("ok"):
            continue
        if rec.get("variant", "base") == "base" and rec.get("rules") == "base":
            continue
        rl = from_record(rec)
        print(
            f"| {rl.arch} x {rl.shape} ({rl.mesh}) | {rec.get('rules')} | "
            f"{rec.get('variant')} | {rl.t_compute*1e3:.2f} ms | "
            f"{rl.t_memory*1e3:.2f} ms | {rl.t_collective*1e3:.3f} ms | "
            f"{rl.dominant} | {rl.roofline_fraction:.4f} |"
        )
    print()


def wirepath_table() -> None:
    """Render BENCH_wirepath.json (the perf trajectory artifact) as markdown.

    The msgs/s column is what subsequent PRs diff (DESIGN.md §4).
    """
    if not os.path.exists(WIREPATH_JSON):
        return
    with open(WIREPATH_JSON) as f:
        doc = json.load(f)
    meta = doc.get("meta", {})
    print(f"### Wire-path amortization curve (backend={meta.get('backend')}, "
          f"A={meta.get('A')}, N={meta.get('N')})\n")
    print("| path | burst | us/round | msgs/s |")
    print("|---|---|---|---|")
    for r in doc.get("rows", []):
        if "speedup" in r or "burst" not in r:
            continue
        if r.get("skipped"):
            print(f"| {r['path']} | {r['burst']} | — | skipped |")
            continue
        if "msgs_per_s" not in r or "us_per_round" not in r:
            continue
        print(f"| {r['path']} | {r['burst']} | {r['us_per_round']:.0f} "
              f"| {r['msgs_per_s']:,.0f} |")
    speedups = [r for r in doc.get("rows", []) if "speedup" in r]
    if speedups:
        line = ", ".join(f"{r['speedup']:.1f}x @ {r['burst']}" for r in speedups)
        print(f"\nPallas-fused over per-acceptor host loop: {line}")
    print()

    mg = [r for r in doc.get("rows", []) if "groups" in r and "msgs_per_s" in r]
    if mg:
        print(f"### Multi-group aggregate throughput "
              f"(per-group burst={meta.get('MG_BURST')}, "
              f"N={meta.get('MG_N')}; DESIGN.md §5)\n")
        print("| path | G | us/round | aggregate msgs/s |")
        print("|---|---|---|---|")
        for r in mg:
            print(f"| {r['path']} | {r['groups']} | {r['us_per_round']:.0f} "
                  f"| {r['msgs_per_s']:,.0f} |")
        scalings = [r for r in doc.get("rows", []) if "scaling" in r]
        if scalings:
            line = ", ".join(
                f"{r['scaling']:.1f}x ({r['name'].split('/')[1]})"
                for r in scalings
            )
            print(f"\nAggregate scaling G=8 vs G=1: {line}")
        print()

    kv = [r for r in doc.get("rows", []) if "us_per_op" in r]
    if kv:
        print("### Replicated KV tier (DESIGN.md §10)\n")
        print("| path | burst | us/op | ops/s |")
        print("|---|---|---|---|")
        for r in kv:
            print(f"| {r['path']} | {r['burst']} | {r['us_per_op']:.1f} "
                  f"| {r['msgs_per_s']:,.0f} |")
        ratio = next(
            (r for r in doc.get("rows", []) if "kv_ratio" in r), None
        )
        if ratio:
            print(f"\nLeased reads vs write round-trips: "
                  f"{ratio['kv_ratio']:.0f}x cheaper")
        print()


if __name__ == "__main__":
    dryrun_table()
    roofline_table()
    variants_table()
    wirepath_table()
