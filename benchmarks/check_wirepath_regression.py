"""CI regression gate over the wire-path benchmark (BENCH_wirepath.json).

Compares a fresh (possibly ``--quick``/partial) bench run against the
committed perf-trajectory artifact and fails on:

  * the pallas-fused vs per-acceptor speedup ratio regressing by more than
    ``--tolerance`` (default 30%) relative to the committed ratio at the
    largest burst both runs measured — ratios of two paths timed on the same
    machine are robust to runner speed, absolute msgs/s are not;
  * multi-group aggregate scaling (G=8 vs G=1, Pallas interpret path)
    dropping below ``--min-mg-scaling`` (default 3.0x) in the fresh run —
    the DESIGN.md §5 service-scaling claim;
  * groups-sharded aggregate scaling (``sharded_scaling_pallas``, the
    slab-partitioned shard_map dispatch of DESIGN.md §6) regressing by more
    than ``--sharded-tolerance`` (default 50%) relative to the committed
    ratio — the sharding layer must not eat the multi-group win;
  * the skewed-load two-tier speedup (``skew_speedup_twotier``, the cohort
    dispatch planner of DESIGN.md §8 vs the pre-refactor shared-burst
    dispatch) regressing by more than ``--skew-tolerance`` (default 50%)
    relative to the committed ratio — right-sized cold tiers and the
    compacted hot tier must keep beating one-size-fits-all bursts;
  * the sustained-uptime throughput ratio (``sustained_ratio``, >= 8 ring
    generations with snapshot drain + digest seal + watermark reclamation
    between generations, vs the same ring wrapping silently — DESIGN.md §9)
    regressing by more than ``--sustained-tolerance`` (default 50%)
    relative to the committed ratio — the reclamation tax a forever-running
    service pays must stay bounded;
  * the KV read:write economics (``kv_read_write_ratio``: write round-trip
    us / leased-read us — DESIGN.md §10) dropping below the absolute
    ``--min-kv-ratio`` floor (default 10x, the consensus-free-read claim)
    in the fresh run, or regressing by more than ``--kv-tolerance``
    (default 50%) relative to the committed ratio;
  * the persistent-wave economics (DESIGN.md §11): ``persistent_speedup``
    (the K-round Pallas wave vs the K-unrolled jnp oracle at matched
    burst-8192 shape) dropping below the absolute
    ``--min-persistent-speedup`` floor (default 1.0 — the kernel must at
    least match its oracle once dispatch is amortized) or regressing by
    more than ``--persistent-tolerance`` (default 50%); and
    ``trickle_persistent_ratio`` (one K=16 wave vs 16 per-round
    dispatches on the dispatch-bound trickle schedule) dropping below
    ``--min-trickle-ratio`` (default 2.0) or regressing by more than the
    same ``--persistent-tolerance``.  The persistent tolerance is wide
    (default 70%) by design: wave-vs-sequential ratios on shared CPU
    runners swing with allocator state (observed 3.0–5.9x for the same
    code), so the absolute floors carry the claims and the relative gate
    only catches collapses;
  * the sharded skewed-load economics (``skew_sharded_ratio``: the
    sharded dataplane's packed-hot/full-width-cold dispatch pair vs the
    unsharded two-tier cohort path on the identical schedule —
    DESIGN.md §13) dropping below the absolute
    ``--min-skew-sharded-ratio`` floor (default 0.5: sharded useful
    decided-instances/s must stay within 2x of unsharded) in the fresh
    run — the packed lane tables and crossover must not reintroduce the
    full-width cold tax the cohort planner removed.

    PYTHONPATH=src python -m benchmarks.check_wirepath_regression \
        BENCH_wirepath.json /tmp/fresh.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _speedups(doc: dict) -> dict[int, float]:
    """burst -> pallas_fused/per_acceptor speedup, from explicit speedup rows
    (preferred) or recomputed from msgs/s rows."""
    out: dict[int, float] = {}
    msgs: dict[tuple[str, int], float] = {}
    for row in doc["rows"]:
        if "speedup" in row:
            out[row["burst"]] = row["speedup"]
        elif "msgs_per_s" in row and "path" in row and "burst" in row:
            msgs[(row["path"], row["burst"])] = row["msgs_per_s"]
    for (path, burst), v in msgs.items():
        if path == "pallas_fused" and burst not in out:
            per_acc = msgs.get(("per_acceptor", burst))
            if per_acc:
                out[burst] = v / per_acc
    return out


def _mg_scaling(doc: dict, path: str = "multigroup_scaling_pallas") -> float | None:
    return _row_metric(doc, path, "scaling")


def _row_metric(doc: dict, path: str, field: str) -> float | None:
    for row in doc["rows"]:
        if row["name"].startswith(f"wirepath/{path}/") and field in row:
            return row[field]
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_wirepath.json")
    ap.add_argument("fresh", help="JSON from the fresh bench run")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional speedup regression (default 0.30)")
    ap.add_argument("--min-mg-scaling", type=float, default=3.0,
                    help="required G=8 vs G=1 aggregate scaling (default 3.0)")
    ap.add_argument("--sharded-tolerance", type=float, default=0.50,
                    help="allowed fractional regression of the sharded "
                         "scaling ratio vs the committed artifact "
                         "(default 0.50; scaling ratios on shared runners "
                         "are noisier than same-machine speedup ratios)")
    ap.add_argument("--skew-tolerance", type=float, default=0.50,
                    help="allowed fractional regression of the skewed-load "
                         "two-tier speedup (skew_speedup_twotier) vs the "
                         "committed artifact (default 0.50)")
    ap.add_argument("--sustained-tolerance", type=float, default=0.50,
                    help="allowed fractional regression of the sustained-"
                         "uptime throughput ratio (sustained_ratio) vs the "
                         "committed artifact (default 0.50)")
    ap.add_argument("--kv-tolerance", type=float, default=0.50,
                    help="allowed fractional regression of the KV "
                         "read:write cost ratio (kv_read_write_ratio) vs "
                         "the committed artifact (default 0.50)")
    ap.add_argument("--min-kv-ratio", type=float, default=10.0,
                    help="absolute floor on the fresh KV read:write ratio — "
                         "leased reads must stay at least this much cheaper "
                         "than write round-trips (default 10.0)")
    ap.add_argument("--persistent-tolerance", type=float, default=0.70,
                    help="allowed fractional regression of the persistent-"
                         "wave ratios (persistent_speedup and "
                         "trickle_persistent_ratio) vs the committed "
                         "artifact (default 0.70 — these ratios swing with "
                         "runner allocator state; the absolute floors carry "
                         "the claims)")
    ap.add_argument("--min-persistent-speedup", type=float, default=1.0,
                    help="absolute floor on persistent_speedup — the K-round "
                         "Pallas wave must at least match the K-unrolled jnp "
                         "oracle at matched shape (default 1.0)")
    ap.add_argument("--min-trickle-ratio", type=float, default=2.0,
                    help="absolute floor on trickle_persistent_ratio — one "
                         "K-round wave must beat K per-round dispatches on "
                         "the trickle schedule (default 2.0)")
    ap.add_argument("--min-skew-sharded-ratio", type=float, default=0.5,
                    help="absolute floor on skew_sharded_ratio — the sharded "
                         "dataplane's skewed-schedule throughput must stay "
                         "within 1/floor of the unsharded two-tier cohort "
                         "path (default 0.5, i.e. within 2x)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = []

    base_speed = _speedups(base)
    fresh_speed = _speedups(fresh)
    common = sorted(set(base_speed) & set(fresh_speed))
    if not common:
        failures.append(
            f"no common speedup burst between baseline {sorted(base_speed)} "
            f"and fresh {sorted(fresh_speed)}"
        )
    else:
        burst = common[-1]
        floor = base_speed[burst] * (1.0 - args.tolerance)
        status = "OK" if fresh_speed[burst] >= floor else "REGRESSION"
        print(
            f"speedup pallas_fused/per_acceptor @burst={burst}: "
            f"fresh {fresh_speed[burst]:.1f}x vs committed "
            f"{base_speed[burst]:.1f}x (floor {floor:.1f}x) -> {status}"
        )
        if fresh_speed[burst] < floor:
            failures.append(
                f"speedup @burst={burst} regressed >"
                f"{args.tolerance:.0%}: {fresh_speed[burst]:.2f}x < "
                f"floor {floor:.2f}x"
            )

    mg = _mg_scaling(fresh)
    if mg is None:
        failures.append("fresh run has no multigroup_scaling_pallas row")
    else:
        status = "OK" if mg >= args.min_mg_scaling else "REGRESSION"
        print(
            f"multigroup aggregate scaling G=8/G=1 (pallas): {mg:.1f}x "
            f"(required >= {args.min_mg_scaling:.1f}x) -> {status}"
        )
        if mg < args.min_mg_scaling:
            failures.append(
                f"multigroup scaling {mg:.2f}x < {args.min_mg_scaling:.1f}x"
            )

    base_sh = _mg_scaling(base, path="sharded_scaling_pallas")
    fresh_sh = _mg_scaling(fresh, path="sharded_scaling_pallas")
    if base_sh is None:
        # pre-§6 artifact: nothing committed to gate against (not a failure,
        # or old baselines would brick CI retroactively)
        print("sharded scaling: no committed row, gate skipped")
    elif fresh_sh is None:
        failures.append("fresh run has no sharded_scaling_pallas row")
    else:
        floor = base_sh * (1.0 - args.sharded_tolerance)
        status = "OK" if fresh_sh >= floor else "REGRESSION"
        print(
            f"sharded aggregate scaling (pallas): fresh {fresh_sh:.1f}x vs "
            f"committed {base_sh:.1f}x (floor {floor:.1f}x) -> {status}"
        )
        if fresh_sh < floor:
            failures.append(
                f"sharded scaling regressed >{args.sharded_tolerance:.0%}: "
                f"{fresh_sh:.2f}x < floor {floor:.2f}x"
            )

    base_sk = _row_metric(base, "skew_speedup_twotier", "skew_speedup")
    fresh_sk = _row_metric(fresh, "skew_speedup_twotier", "skew_speedup")
    if base_sk is None:
        # pre-§8 artifact: nothing committed to gate against
        print("skew speedup: no committed row, gate skipped")
    elif fresh_sk is None:
        failures.append("fresh run has no skew_speedup_twotier row")
    else:
        floor = base_sk * (1.0 - args.skew_tolerance)
        status = "OK" if fresh_sk >= floor else "REGRESSION"
        print(
            f"skewed-load two-tier speedup (pallas): fresh {fresh_sk:.1f}x "
            f"vs committed {base_sk:.1f}x (floor {floor:.1f}x) -> {status}"
        )
        if fresh_sk < floor:
            failures.append(
                f"skew speedup regressed >{args.skew_tolerance:.0%}: "
                f"{fresh_sk:.2f}x < floor {floor:.2f}x"
            )

    base_su = _row_metric(base, "sustained_ratio", "sustained_ratio")
    fresh_su = _row_metric(fresh, "sustained_ratio", "sustained_ratio")
    if base_su is None:
        # pre-§9 artifact: nothing committed to gate against
        print("sustained ratio: no committed row, gate skipped")
    elif fresh_su is None:
        failures.append("fresh run has no sustained_ratio row")
    else:
        floor = base_su * (1.0 - args.sustained_tolerance)
        status = "OK" if fresh_su >= floor else "REGRESSION"
        print(
            f"sustained-uptime throughput ratio (pallas): fresh "
            f"{fresh_su:.2f}x vs committed {base_su:.2f}x "
            f"(floor {floor:.2f}x) -> {status}"
        )
        if fresh_su < floor:
            failures.append(
                f"sustained ratio regressed >{args.sustained_tolerance:.0%}: "
                f"{fresh_su:.2f}x < floor {floor:.2f}x"
            )

    base_kv = _row_metric(base, "kv_read_write_ratio", "kv_ratio")
    fresh_kv = _row_metric(fresh, "kv_read_write_ratio", "kv_ratio")
    if base_kv is None:
        # pre-§10 artifact: nothing committed to gate against
        print("kv read:write ratio: no committed row, gate skipped")
    elif fresh_kv is None:
        failures.append("fresh run has no kv_read_write_ratio row")
    else:
        floor = max(base_kv * (1.0 - args.kv_tolerance), args.min_kv_ratio)
        status = "OK" if fresh_kv >= floor else "REGRESSION"
        print(
            f"kv leased-read vs write-round-trip ratio: fresh "
            f"{fresh_kv:.0f}x vs committed {base_kv:.0f}x "
            f"(floor {floor:.0f}x, absolute min {args.min_kv_ratio:.0f}x) "
            f"-> {status}"
        )
        if fresh_kv < floor:
            failures.append(
                f"kv read:write ratio {fresh_kv:.1f}x below floor "
                f"{floor:.1f}x (committed {base_kv:.1f}x, tolerance "
                f"{args.kv_tolerance:.0%}, absolute min "
                f"{args.min_kv_ratio:.1f}x)"
            )

    for path, field, abs_min, label in (
        ("persistent_speedup", "persistent_speedup",
         args.min_persistent_speedup,
         "persistent wave vs K-unrolled jnp oracle"),
        ("trickle_persistent_ratio", "trickle_persistent_ratio",
         args.min_trickle_ratio,
         "persistent wave vs per-round trickle pump"),
    ):
        base_p = _row_metric(base, path, field)
        fresh_p = _row_metric(fresh, path, field)
        if base_p is None:
            # pre-§11 artifact: nothing committed to gate against
            print(f"{field}: no committed row, gate skipped")
        elif fresh_p is None:
            failures.append(f"fresh run has no {path} row")
        else:
            floor = max(base_p * (1.0 - args.persistent_tolerance), abs_min)
            status = "OK" if fresh_p >= floor else "REGRESSION"
            print(
                f"{label}: fresh {fresh_p:.2f}x vs committed {base_p:.2f}x "
                f"(floor {floor:.2f}x, absolute min {abs_min:.1f}x) "
                f"-> {status}"
            )
            if fresh_p < floor:
                failures.append(
                    f"{field} {fresh_p:.2f}x below floor {floor:.2f}x "
                    f"(committed {base_p:.2f}x, tolerance "
                    f"{args.persistent_tolerance:.0%}, absolute min "
                    f"{abs_min:.1f}x)"
                )

    base_ss = _row_metric(base, "skew_sharded_pallas", "skew_sharded_ratio")
    fresh_ss = _row_metric(fresh, "skew_sharded_pallas", "skew_sharded_ratio")
    if base_ss is None:
        # pre-§13 artifact: nothing committed to gate against
        print("skew sharded ratio: no committed row, gate skipped")
    elif fresh_ss is None:
        failures.append("fresh run has no skew_sharded_pallas row")
    else:
        floor = args.min_skew_sharded_ratio
        status = "OK" if fresh_ss >= floor else "REGRESSION"
        print(
            f"sharded vs unsharded skewed-load ratio: fresh {fresh_ss:.2f}x "
            f"vs committed {base_ss:.2f}x (absolute floor {floor:.2f}x) "
            f"-> {status}"
        )
        if fresh_ss < floor:
            failures.append(
                f"skew_sharded_ratio {fresh_ss:.2f}x below absolute floor "
                f"{floor:.2f}x (committed {base_ss:.2f}x): sharded dispatch "
                f"is no longer within 1/{floor:.2f}x of the unsharded "
                f"two-tier path"
            )

    if failures:
        for f_ in failures:
            print(f"FAIL: {f_}", file=sys.stderr)
        return 1
    print("benchmark gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
