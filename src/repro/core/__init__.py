"""CAANS core: the paper's contribution — consensus as a (fabric) service.

Layers:
  * ``types``     — Paxos header/state as structure-of-arrays (paper Fig. 5)
  * ``paxos``     — scalar reference role semantics (the oracle + baseline)
  * ``batched``   — jnp batched multi-instance dataplane ("hardware" logic)
  * ``fabric``    — shard_map in-fabric consensus over a mesh axis
  * ``plan``      — cohort dispatch planner: burst tiers, fold widths,
                    lockstep realignment (DESIGN.md §8)
  * ``api``       — drop-in submit / deliver / recover (paper Fig. 4)
  * ``log``       — replicated log, gaps, quorum trim
  * ``snapshot``  — sealed snapshot store + ring reclamation (DESIGN.md §9)
  * ``failover``  — coordinator takeover (safe Phase-1 variant of §3.1)
                    and acceptor restore from snapshot + live suffix
  * ``network``   — seeded lossy message fabric (UDP loss model)
  * ``baseline``  — libpaxos-like software deployment (comparison baseline)
"""
from .types import (  # noqa: F401
    AcceptorState,
    CoordinatorState,
    MsgBatch,
    PaxosConfig,
    decode_value,
    encode_value,
)
from .api import (  # noqa: F401
    HardwareDataplane,
    MultiGroupDataplane,
    PaxosContext,
    ShardedMultiGroupDataplane,
)
from .plan import (  # noqa: F401
    Cohort,
    DispatchPlanner,
    RoundPlan,
)
from .baseline import SoftwarePaxos  # noqa: F401
from .log import ReplicatedLog  # noqa: F401
from .network import FaultSpec, SimNet  # noqa: F401
from .snapshot import (  # noqa: F401
    GroupSnapshot,
    RingOverflowError,
    SnapshotStore,
)
