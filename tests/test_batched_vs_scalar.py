"""Cross-layer consistency: jnp batched engine vs scalar oracle vs sequential
semantics — the three implementations of acceptor/coordinator logic must
agree wherever their contracts overlap."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import batched
from repro.core.paxos import Acceptor, Msg
from repro.core.types import (
    MSG_P1A,
    MSG_P2A,
    MSG_P2B,
    AcceptorState,
    CoordinatorState,
    MsgBatch,
)


def _batch_from(msgs, v_words=4):
    b = len(msgs)
    val = np.zeros((b, v_words), np.int32)
    for i, m in enumerate(msgs):
        val[i, 0] = m.get("val", 0)
    return MsgBatch(
        msgtype=jnp.asarray([m["t"] for m in msgs], jnp.int32),
        inst=jnp.asarray([m["i"] for m in msgs], jnp.int32),
        rnd=jnp.asarray([m["r"] for m in msgs], jnp.int32),
        vrnd=jnp.full((b,), -1, jnp.int32),
        swid=jnp.zeros((b,), jnp.int32),
        value=jnp.asarray(val),
    )


@settings(max_examples=40, deadline=None)
@given(
    msgs=st.lists(
        st.fixed_dictionaries(
            {
                "t": st.sampled_from([MSG_P2A, MSG_P1A]),
                "i": st.integers(0, 31),
                "r": st.integers(0, 4),
                "val": st.integers(-100, 100),
            }
        ),
        min_size=1,
        max_size=24,
    )
)
def test_sequential_engine_matches_scalar_oracle(msgs):
    """acceptor_sequential == the dict-based scalar Acceptor, message by message."""
    astate = AcceptorState.init(32, 4)
    oracle = Acceptor(aid=0, n_instances=32)

    batch = _batch_from(msgs)
    astate, outs = batched.acceptor_sequential(astate, batch, aid=0)

    for j, m in enumerate(msgs):
        scalar_msg = Msg(m["t"], inst=m["i"], rnd=m["r"],
                         value=int(m["val"]).to_bytes(4, "little", signed=True))
        if m["t"] == MSG_P2A:
            out = oracle.on_p2a(scalar_msg)
        else:
            out = oracle.on_p1a(scalar_msg)
        assert int(outs.msgtype[j]) == out.msgtype, (j, m)
        if out.msgtype == MSG_P2B:
            assert int(outs.vrnd[j]) == out.vrnd

    # final state agreement
    for slot, (rnd, vrnd, _value) in oracle.slots.items():
        assert int(astate.rnd[slot]) == rnd
        assert int(astate.vrnd[slot]) == vrnd


@settings(max_examples=40, deadline=None)
@given(
    n_msgs=st.integers(1, 32),
    base=st.integers(0, 100),
    rnd=st.integers(0, 3),
    seed=st.integers(0, 999),
)
def test_vectorized_matches_sequential_on_distinct_slots(n_msgs, base, rnd, seed):
    """On contiguous (distinct-slot) windows the vectorized fast path must be
    bit-identical to the sequential engine."""
    rng = np.random.default_rng(seed)
    astate0 = AcceptorState.init(256, 4)
    astate0 = AcceptorState(
        rnd=jnp.asarray(rng.integers(0, 3, 256).astype(np.int32)),
        vrnd=astate0.vrnd,
        value=astate0.value,
    )
    msgs = MsgBatch(
        msgtype=jnp.full((n_msgs,), MSG_P2A, jnp.int32),
        inst=jnp.arange(base, base + n_msgs, dtype=jnp.int32),
        rnd=jnp.full((n_msgs,), rnd, jnp.int32),
        vrnd=jnp.full((n_msgs,), -1, jnp.int32),
        swid=jnp.zeros((n_msgs,), jnp.int32),
        value=jnp.asarray(rng.integers(-9, 9, (n_msgs, 4)).astype(np.int32)),
    )
    a1, v1 = batched.acceptor_phase2(astate0, msgs, aid=1)
    a2, v2 = batched.acceptor_sequential(astate0, msgs, aid=1)
    for x, y in zip(
        (a1.rnd, a1.vrnd, a1.value, v1.msgtype, v1.rnd, v1.vrnd, v1.value),
        (a2.rnd, a2.vrnd, a2.value, v2.msgtype, v2.rnd, v2.vrnd, v2.value), strict=True,
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_coordinator_contiguity_and_nops():
    cstate = CoordinatorState.init(crnd=3, next_inst=17)
    vals = jnp.zeros((8, 4), jnp.int32)
    active = jnp.asarray([1, 0, 1, 1, 0, 0, 1, 1], bool)
    cstate2, out = batched.coordinator_sequence(cstate, vals, active)
    assert int(cstate2.next_inst) == 25
    np.testing.assert_array_equal(
        np.asarray(out.inst), np.arange(17, 25, dtype=np.int32)
    )
    # NOP filler still occupies an instance (sequenced no-op, paper §3.1)
    assert (np.asarray(out.msgtype) == np.where(np.asarray(active), 3, 0)).all()


def test_learner_quorum_and_dedup():
    a, b, v = 3, 8, 4
    vt = jnp.full((a, b), MSG_P2B, jnp.int32)
    vi = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[None], (a, b))
    vr = jnp.zeros((a, b), jnp.int32)
    vv = jnp.broadcast_to(
        jnp.arange(b, dtype=jnp.int32)[None, :, None], (a, b, v)
    ).astype(jnp.int32)
    deliver, inst, win, val = batched.learner_quorum(vt, vi, vr, vv, quorum=2)
    assert np.asarray(deliver).all()

    lstate = batched.LearnerState.init(64, v)
    lstate, fresh = batched.learner_update(lstate, deliver, inst, val)
    assert np.asarray(fresh).all()
    # duplicates suppressed on replay
    lstate, fresh2 = batched.learner_update(lstate, deliver, inst, val)
    assert not np.asarray(fresh2).any()
