"""Pallas TPU megakernel: the fused CAANS wire path.

One ``pallas_call`` executes a *complete* Phase-2 round — coordinator
sequencing, the Phase-2 vote of all ``A = 2f+1`` acceptors against the
stacked ``(A, N)`` instance ring, the learner quorum count, and the
``LearnerState`` ring-dedup update.  This is the TPU analogue of the paper's
core claim: once consensus logic lives below the host boundary, a Paxos round
costs barely more than forwarding the packets (PAPER.md; DESIGN.md §3).

Layout (DESIGN.md §3):

    grid = (B // BB,)            # one step per batch block — nothing else
    stacked rings  (A, N)[, V]   --BlockSpec (A, BB)-->   VMEM, in-place
    learner ring   (N,)[, V]     --BlockSpec (BB,)  -->   VMEM, in-place
    burst values   (B, V)        --BlockSpec (BB, V)-->   VMEM
    fresh/win/value outputs      <--                      VMEM

The acceptor axis rides the *sublane* dimension of one block: a single grid
step loads every acceptor's ring window, votes all of them in-register, and
reduces the quorum count straight down axis 0 — the entire round for a batch
block is one load -> VREG compare/select -> reduce -> store pass, with no
inner acceptor loop anywhere (host or grid).  All five state arrays are
passed through ``input_output_aliases``: coordinator/acceptor/learner state
never round-trips through host memory between pump rounds.

In-kernel sequencing collapses to round-stamping: the window
``[next_inst, next_inst + B)`` is implied by the grid, and sequenced NOP
fillers vote exactly like P2As (the application discards them by value), so
no per-message msgtype materializes on the fast path.

Invariants (maintained by ``core.api.HardwareDataplane``, asserted where
shapes are static): ``BB | B``, ``BB | N``, ``B <= N``, and the window base
``next_inst`` is BB-aligned.  Liveness is a *runtime* input — the ``alive``
mask rides in scalar-prefetch SMEM, so killing/reviving an acceptor never
recompiles the kernel.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import MSG_NOP, MSG_P2A, MSG_P2B, MSG_REJECT

NO_ROUND = -1

# Messages per grid step; 128 is the int32 lane width.
DEFAULT_BLOCK_B = 128


def _lane_iota(bb: int) -> jax.Array:
    # 1-D iota via 2-D broadcasted_iota (TPU requires >= 2D iota)
    return jax.lax.broadcasted_iota(jnp.int32, (bb, 1), 0)[:, 0]


def _alive_col(alive_ref, a: int) -> jax.Array:
    # scalar-prefetch liveness -> (A, 1) vector mask (A is static)
    return jnp.stack([alive_ref[i] for i in range(a)])[:, None] != 0


# ---------------------------------------------------------------------------
# The fused round megakernel
# ---------------------------------------------------------------------------
def _wirepath_kernel(
    # scalar prefetch (SMEM)
    ni_ref,         # int32[1]  next_inst: absolute window base, BB-aligned
    crnd_ref,       # int32[1]  coordinator round
    q_ref,          # int32[1]  quorum (f+1)
    alive_ref,      # int32[A]  runtime liveness mask
    # inputs (VMEM tiles)
    values_ref,     # int32[BB, V]     burst values
    st_rnd_ref,     # int32[A, BB]     acceptor ring blocks (aliased out)
    st_vrnd_ref,    # int32[A, BB]
    st_val_ref,     # int32[A, BB, V]
    ldel_ref,       # int32[BB]        learner ring block (aliased out)
    linst_ref,      # int32[BB]
    lval_ref,       # int32[BB, V]
    # outputs
    o_rnd_ref,      # int32[A, BB]
    o_vrnd_ref,     # int32[A, BB]
    o_val_ref,      # int32[A, BB, V]
    o_ldel_ref,     # int32[BB]
    o_linst_ref,    # int32[BB]
    o_lval_ref,     # int32[BB, V]
    fresh_ref,      # int32[BB]  out: fresh (non-duplicate) delivery mask
    win_ref,        # int32[BB]  out: winning vrnd (NO_ROUND if none)
    value_ref,      # int32[BB, V]  out: decided value
):
    i = pl.program_id(0)
    a, bb = st_rnd_ref.shape

    crnd = crnd_ref[0]
    mval = values_ref[...]
    alive = _alive_col(alive_ref, a)                      # (A, 1)

    # -- the acceptor array votes (Phase 2A -> 2B), all A at once ------------
    cur_rnd = st_rnd_ref[...]                             # (A, BB)
    cur_vrnd = st_vrnd_ref[...]
    cur_val = st_val_ref[...]
    accept = alive & (crnd >= cur_rnd)                    # (A, BB)

    o_rnd_ref[...] = jnp.where(accept, crnd, cur_rnd)
    o_vrnd_ref[...] = jnp.where(accept, crnd, cur_vrnd)
    o_val_ref[...] = jnp.where(accept[:, :, None], mval[None], cur_val)

    # -- learner quorum: reduce straight down the acceptor axis --------------
    vote_vrnd = jnp.where(accept, crnd, NO_ROUND)         # (A, BB)
    win = jnp.max(vote_vrnd, axis=0)                      # (BB,)
    agree = accept & (vote_vrnd == win[None, :])          # (A, BB)
    count = jnp.sum(agree.astype(jnp.int32), axis=0)      # (BB,)
    deliver = count >= q_ref[0]
    # decided value: first agreeing acceptor's vote, as a one-hot contraction
    first = agree & (jnp.cumsum(agree.astype(jnp.int32), axis=0) == 1)
    vote_val = jnp.where(accept[:, :, None], mval[None], 0)
    value = jnp.sum(first.astype(jnp.int32)[:, :, None] * vote_val, axis=0)

    # -- ring dedup (LearnerState), in place ---------------------------------
    inst = ni_ref[0] + i * bb + _lane_iota(bb)
    dup = (ldel_ref[...] != 0) & (linst_ref[...] == inst)
    fresh = deliver & ~dup
    o_ldel_ref[...] = ldel_ref[...] | deliver.astype(jnp.int32)
    o_linst_ref[...] = jnp.where(fresh, inst, linst_ref[...])
    o_lval_ref[...] = jnp.where(fresh[:, None], value, lval_ref[...])

    fresh_ref[...] = fresh.astype(jnp.int32)
    win_ref[...] = win
    value_ref[...] = value


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def wirepath_round(
    next_inst: jax.Array,   # int32[]  absolute window base (BB-aligned)
    crnd: jax.Array,        # int32[]
    quorum: jax.Array,      # int32[]
    alive: jax.Array,       # int32[A] (0/1)
    st_rnd: jax.Array,      # int32[A, N]   stacked acceptor rings
    st_vrnd: jax.Array,     # int32[A, N]
    st_val: jax.Array,      # int32[A, N, V]
    ldel: jax.Array,        # int32[N]      learner ring
    linst: jax.Array,       # int32[N]
    lval: jax.Array,        # int32[N, V]
    values: jax.Array,      # int32[B, V]   burst values
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """One fused Phase-2 round; single dispatch, state resident in place.

    Returns ``(st_rnd', st_vrnd', st_val', ldel', linst', lval',
    fresh[B], win_vrnd[B], value[B, V])``.
    """
    a, n = st_rnd.shape
    b, v = values.shape
    bb = min(block_b, b)
    assert b % bb == 0, (b, bb)
    assert n % bb == 0, (n, bb)
    assert b <= n, "burst may not lap the instance ring"
    nb_ring = n // bb
    grid = (b // bb,)

    def ring1(i, ni_ref, *_):
        return ((ni_ref[0] // bb + i) % nb_ring,)

    def ring2(i, ni_ref, *_):
        return ((ni_ref[0] // bb + i) % nb_ring, 0)

    def stack2(i, ni_ref, *_):
        return (0, (ni_ref[0] // bb + i) % nb_ring)

    def stack3(i, ni_ref, *_):
        return (0, (ni_ref[0] // bb + i) % nb_ring, 0)

    def batch1(i, *_):
        return (i,)

    def batch2(i, *_):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v), batch2),       # values
            pl.BlockSpec((a, bb), stack2),       # st_rnd
            pl.BlockSpec((a, bb), stack2),       # st_vrnd
            pl.BlockSpec((a, bb, v), stack3),    # st_val
            pl.BlockSpec((bb,), ring1),          # ldel
            pl.BlockSpec((bb,), ring1),          # linst
            pl.BlockSpec((bb, v), ring2),        # lval
        ],
        out_specs=[
            pl.BlockSpec((a, bb), stack2),       # st_rnd'
            pl.BlockSpec((a, bb), stack2),       # st_vrnd'
            pl.BlockSpec((a, bb, v), stack3),    # st_val'
            pl.BlockSpec((bb,), ring1),          # ldel'
            pl.BlockSpec((bb,), ring1),          # linst'
            pl.BlockSpec((bb, v), ring2),        # lval'
            pl.BlockSpec((bb,), batch1),         # fresh
            pl.BlockSpec((bb,), batch1),         # win_vrnd
            pl.BlockSpec((bb, v), batch2),       # value
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((a, n), jnp.int32),
        jax.ShapeDtypeStruct((a, n), jnp.int32),
        jax.ShapeDtypeStruct((a, n, v), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n, v), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b, v), jnp.int32),
    ]
    fn = pl.pallas_call(
        _wirepath_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        # all five state arrays update in place: inputs 5..10 (after the 4
        # scalar-prefetch args) alias outputs 0..5 — device-resident state
        input_output_aliases={5: 0, 6: 1, 7: 2, 8: 3, 9: 4, 10: 5},
        interpret=interpret,
    )
    ni = jnp.asarray(next_inst, jnp.int32).reshape((1,))
    cr = jnp.asarray(crnd, jnp.int32).reshape((1,))
    q = jnp.asarray(quorum, jnp.int32).reshape((1,))
    al = jnp.asarray(alive, jnp.int32)
    return tuple(
        fn(ni, cr, q, al, values, st_rnd, st_vrnd, st_val, ldel, linst, lval)
    )


# ---------------------------------------------------------------------------
# Staged variant: all-acceptor vote with per-acceptor vote output
# ---------------------------------------------------------------------------
def _vote_all_kernel(
    base_ref,       # int32[1]  window base slot (BB-aligned)
    alive_ref,      # int32[A]
    msgtype_ref,    # int32[BB]
    msg_rnd_ref,    # int32[BB]
    msg_val_ref,    # int32[BB, V]
    st_rnd_ref,     # int32[A, BB]  (aliased out)
    st_vrnd_ref,    # int32[A, BB]
    st_val_ref,     # int32[A, BB, V]
    o_rnd_ref,      # int32[A, BB]
    o_vrnd_ref,     # int32[A, BB]
    o_val_ref,      # int32[A, BB, V]
    vt_ref,         # int32[A, BB]  vote msgtype
    vr_ref,         # int32[A, BB]  vote rnd
    vv_ref,         # int32[A, BB]  vote vrnd
    vs_ref,         # int32[A, BB]  vote swid
    vval_ref,       # int32[A, BB, V]
):
    a, bb = st_rnd_ref.shape
    msgtype = msgtype_ref[...]
    mrnd = msg_rnd_ref[...]
    mval = msg_val_ref[...]
    cur_rnd = st_rnd_ref[...]
    cur_vrnd = st_vrnd_ref[...]
    cur_val = st_val_ref[...]

    alive = _alive_col(alive_ref, a)                             # (A, 1)
    is_p2 = (msgtype == MSG_P2A) | (msgtype == MSG_NOP)          # (BB,)
    accept = alive & is_p2[None, :] & (mrnd[None, :] >= cur_rnd)  # (A, BB)

    o_rnd_ref[...] = jnp.where(accept, mrnd[None, :], cur_rnd)
    o_vrnd_ref[...] = jnp.where(accept, mrnd[None, :], cur_vrnd)
    o_val_ref[...] = jnp.where(accept[:, :, None], mval[None], cur_val)

    vt_ref[...] = jnp.where(accept, MSG_P2B, MSG_REJECT).astype(jnp.int32)
    vr_ref[...] = jnp.where(accept, mrnd[None, :], cur_rnd)
    vv_ref[...] = jnp.where(accept, mrnd[None, :], cur_vrnd)
    vs_ref[...] = jax.lax.broadcasted_iota(jnp.int32, (a, bb), 0)
    vval_ref[...] = jnp.where(accept[:, :, None], mval[None], 0)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def acceptor_vote_all_window(
    st_rnd: jax.Array,      # int32[A, N]
    st_vrnd: jax.Array,     # int32[A, N]
    st_val: jax.Array,      # int32[A, N, V]
    base: jax.Array,        # int32[]  window base, BB-aligned
    alive: jax.Array,       # int32[A]
    msgtype: jax.Array,     # int32[B]
    msg_rnd: jax.Array,     # int32[B]
    msg_val: jax.Array,     # int32[B, V]
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """Whole-array Phase-2 vote on a contiguous window, one dispatch.

    The staged sibling of ``wirepath_round`` for when votes must surface as
    messages (per-learner fan-out over SimNet).  Returns
    ``(st_rnd', st_vrnd', st_val', vote_type[A,B], vote_rnd[A,B],
    vote_vrnd[A,B], vote_swid[A,B], vote_val[A,B,V])``.
    """
    a, n = st_rnd.shape
    b, v = msg_val.shape
    bb = min(block_b, b)
    assert b % bb == 0, (b, bb)
    assert n % bb == 0, (n, bb)
    assert b <= n, "burst may not lap the instance ring"
    nb_ring = n // bb
    grid = (b // bb,)

    def stack2(i, base_ref, *_):
        return (0, (base_ref[0] // bb + i) % nb_ring)

    def stack3(i, base_ref, *_):
        return (0, (base_ref[0] // bb + i) % nb_ring, 0)

    def vote2(i, *_):
        return (0, i)

    def vote3(i, *_):
        return (0, i, 0)

    def batch1(i, *_):
        return (i,)

    def batch2(i, *_):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), batch1),         # msgtype
            pl.BlockSpec((bb,), batch1),         # msg_rnd
            pl.BlockSpec((bb, v), batch2),       # msg_val
            pl.BlockSpec((a, bb), stack2),       # st_rnd
            pl.BlockSpec((a, bb), stack2),       # st_vrnd
            pl.BlockSpec((a, bb, v), stack3),    # st_val
        ],
        out_specs=[
            pl.BlockSpec((a, bb), stack2),       # st_rnd'
            pl.BlockSpec((a, bb), stack2),       # st_vrnd'
            pl.BlockSpec((a, bb, v), stack3),    # st_val'
            pl.BlockSpec((a, bb), vote2),        # vote_type
            pl.BlockSpec((a, bb), vote2),        # vote_rnd
            pl.BlockSpec((a, bb), vote2),        # vote_vrnd
            pl.BlockSpec((a, bb), vote2),        # vote_swid
            pl.BlockSpec((a, bb, v), vote3),     # vote_val
        ],
    )
    out_shapes = [
        jax.ShapeDtypeStruct((a, n), jnp.int32),
        jax.ShapeDtypeStruct((a, n), jnp.int32),
        jax.ShapeDtypeStruct((a, n, v), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b), jnp.int32),
        jax.ShapeDtypeStruct((a, b, v), jnp.int32),
    ]
    fn = pl.pallas_call(
        _vote_all_kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        # stacked rings in place: inputs 5,6,7 alias outputs 0,1,2
        input_output_aliases={5: 0, 6: 1, 7: 2},
        interpret=interpret,
    )
    base = jnp.asarray(base, jnp.int32).reshape((1,))
    al = jnp.asarray(alive, jnp.int32)
    return tuple(fn(base, al, msgtype, msg_rnd, msg_val, st_rnd, st_vrnd, st_val))
