"""The committed BENCH_wirepath.json must satisfy the bench schema.

A malformed bench commit (truncated sweep, NaN ratio, missing headline row)
would otherwise surface only after CI spends a full bench run — or silently
skip a regression gate forever.  This is the cheapest job that can catch
it: pure JSON validation in the fast ``-m "not slow"`` lane, sharing the
validator the bench-gate job runs (``benchmarks.check_bench_schema``).
"""
from __future__ import annotations

import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO_ROOT)

from benchmarks.check_bench_schema import (  # noqa: E402
    FLAG_HEADLINES,
    REQUIRED_HEADLINES,
    check_ci_gate_flags,
    validate,
)


def _load():
    with open(os.path.join(REPO_ROOT, "BENCH_wirepath.json")) as f:
        return json.load(f)


def test_committed_bench_artifact_is_schema_valid():
    assert validate(_load()) == []


def test_validator_catches_malformed_artifacts():
    doc = _load()
    # a NaN ratio in a headline row must be flagged
    bad = json.loads(json.dumps(doc))
    for row in bad["rows"]:
        if "skew_speedup" in row:
            row["skew_speedup"] = float("nan")
    assert any("skew_speedup" in e for e in validate(bad))
    # a missing headline row must be flagged
    bad = json.loads(json.dumps(doc))
    bad["rows"] = [
        r
        for r in bad["rows"]
        if not r["name"].startswith("wirepath/multigroup_scaling_pallas/")
    ]
    assert any("multigroup_scaling_pallas" in e for e in validate(bad))
    # a partial sweep must never be committed as the baseline
    bad = json.loads(json.dumps(doc))
    bad["meta"]["partial"] = True
    assert any("partial" in e for e in validate(bad))
    # empty rows
    assert validate({"meta": {"backend": "cpu"}, "rows": []})


def _ci_text() -> str:
    with open(
        os.path.join(REPO_ROOT, ".github", "workflows", "ci.yml")
    ) as f:
        return f.read()


def test_live_ci_gate_flags_match_headlines():
    assert check_ci_gate_flags(_ci_text()) == []


def test_every_headline_has_a_gate_flag_mapping():
    assert set(FLAG_HEADLINES.values()) == set(REQUIRED_HEADLINES)


def test_gate_flag_cross_check_catches_drift():
    text = _ci_text()
    # a flag the catalogue doesn't know (new metric without a headline)
    errs = check_ci_gate_flags(
        text.replace("--min-trickle-ratio", "--min-bft-ratio")
    )
    assert any("--min-bft-ratio" in e for e in errs)
    # dropping a flag leaves its headline ungated
    assert any("trickle_persistent_ratio" in e for e in errs)
    # a workflow that never runs the gate at all
    assert check_ci_gate_flags("jobs: {}")
