"""Deterministic synthetic data pipeline, shardable across hosts.

At 1000+-node scale every host feeds its own slice of the global batch; a
seeded counter-based generator (threefry on (step, host_slice)) gives every
host the same view of the global stream with zero coordination — the same
property a deterministic tokenized-shard layout gives a real run.  Batches
are yielded host-local and assembled into the global array by
``jax.make_array_from_process_local_data`` in a multi-process deployment
(single-process here: the full global batch).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    mode: str = "uniform"   # uniform (i.i.d. tokens) | arith (learnable)
    # modality stubs
    n_patches: int = 0
    src_len: int = 0
    d_model: int = 0


class SyntheticStream:
    """Counter-based deterministic token stream (restart-safe: indexable by step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
        if cfg.mode == "arith":
            # learnable stream: x_{t+1} = x_t + 1 (mod vocab); the model can
            # reach near-zero loss — used by convergence examples/tests
            start = rng.integers(0, cfg.vocab, size=(cfg.global_batch, 1))
            idx = np.arange(cfg.seq_len + 1)[None, :]
            tokens = ((start + idx) % cfg.vocab).astype(np.int32)
        else:
            tokens = rng.integers(
                0, cfg.vocab, size=(cfg.global_batch, cfg.seq_len + 1),
                dtype=np.int32,
            )
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if cfg.n_patches:
            out["patches"] = rng.standard_normal(
                (cfg.global_batch, cfg.n_patches, cfg.d_model), dtype=np.float32
            )
        if cfg.src_len:
            out["frames"] = rng.standard_normal(
                (cfg.global_batch, cfg.src_len, cfg.d_model), dtype=np.float32
            )
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
