"""The cohort dispatch planner: how a round of skewed multi-group load maps
onto device dispatches (DESIGN.md §8).

Before this module the plan was smeared across ``core.api``: the fold
decision was all-or-nothing (``group_block ∈ {G, 1}``), one shared burst
size padded every cold group's chunk with NOP filler up to the hottest
group's burst, and after divergent per-group failovers the folded mapping
never re-engaged.  ``plan.py`` owns all of those decisions in one place:

* **Burst quantization** — every wire burst is a power of two in
  ``[MIN_BURST, batch]``, regardless of execution engine (Pallas kernel or
  jnp oracle).  Engine choice never shapes a burst, which is what makes the
  planner's decisions — and therefore per-group delivery logs — identical
  across the jnp/pallas × sharded/unsharded backends *and* against G
  independent single-group oracles, even under arbitrarily skewed load.
  Bounded shape vocabulary also bounds jit-cache churn.

* **Lockstep cohorts** — the enabled groups of a round partition into
  watermark-equivalence classes; groups whose quantized burst agrees ride
  one dispatch (a *tier*): hot cohorts at the full block-aligned burst,
  cold cohorts coalesced into a shared right-sized burst.  One dispatch per
  distinct burst size, so a round costs at most ``log2(batch/MIN_BURST)+1``
  dispatches however skewed the load.

* **Per-cohort fold widths** — ``fold_width_full`` generalizes the old
  binary group-folding cliff: the largest divisor ``d`` of the fold cap
  such that every ``d``-aligned block's members share one watermark (the
  kernel substitutes the block's lockstep base for non-members).
  ``cohort_blocks`` additionally *compacts* the grid over the group axis
  for the unsharded kernel path: only the blocks containing cohort members
  are visited, so a one-hot-group tier costs one group's work, not G's.

* **Realignment sweep** — after ``realign_after`` consecutive fragmented
  rounds (enabled groups spread over >1 watermark class), divergent groups
  are burned forward to a common block boundary: the skipped instances are
  never proposed and are recoverable as no-ops (paper §3.1 gap fill),
  and the full-width folded mapping re-engages.  Off by default
  (``PaxosConfig.realign_after = None``) because burning forward changes
  instance numbering relative to an independent deployment — services opt
  in when they prefer amortization over twin-exact numbering.
"""
from __future__ import annotations

import dataclasses
from typing import Any
from collections.abc import Sequence

import numpy as np

NO_ROUND = -1
NOP_SENTINEL = -0x7FFFFFFF  # first value word marking an internal filler slot
MIN_BURST = 8               # smallest wire burst (pow2 quantization floor)


def wire_block(b: int) -> int:
    """Kernel batch-block size for a burst of ``b`` messages."""
    from repro.kernels.wirepath import DEFAULT_BLOCK_B

    return min(DEFAULT_BLOCK_B, b)


def window_aligned(n_instances: int, base: int, b: int) -> bool:
    """True iff a contiguous window [base, base+b) satisfies the Pallas
    ring-blocking invariants (BB | base, BB | B, BB | N, B <= N) — the ONE
    definition every dataplane consults (DESIGN.md §2)."""
    bb = wire_block(b)
    return (
        b % bb == 0
        and n_instances % bb == 0
        and b <= n_instances
        and base % bb == 0
    )


def quantize_burst(n: int, cap: int) -> int:
    """Wire-burst sizing: next power of two >= ``n`` in [MIN_BURST, cap].

    A half-empty wire batch costs real dataplane time, so bursts right-size
    down to the load; quantizing to a bounded pow2 vocabulary keeps the jit
    cache (one compiled program per distinct shape) bounded too.
    """
    be = MIN_BURST
    while be < n:
        be *= 2
    return min(be, cap)


def _divisors(cap: int) -> list[int]:
    return [d for d in range(1, cap + 1) if cap % d == 0]


def _block_lockstep(gids: Sequence[int], marks: Sequence[int], d: int) -> bool:
    """True iff every ``d``-aligned block's members (of ``gids``) share one
    watermark — the validity condition for folding ``d`` groups per grid
    step with cohort-base substitution for non-members."""
    classes: dict[int, int] = {}
    for g in gids:
        blk = g // d
        if classes.setdefault(blk, marks[g]) != marks[g]:
            return False
    return True


def fold_width_full(
    gids: Sequence[int], marks: Sequence[int], cap: int
) -> int:
    """Fold width for a *full-width* dispatch (every group block on the
    grid): the largest divisor of ``cap`` folding validly over ``gids``.

    Generalizes the historical ``group_block ∈ {cap, 1}`` cliff: cohorts
    that diverged after per-group failovers can still fold block-wise
    (e.g. groups [0..3] at one watermark and [4..7] at another fold at
    width 4), each block deriving its ring offset from its own lockstep
    base."""
    for d in sorted(_divisors(cap), reverse=True):
        if _block_lockstep(gids, marks, d):
            return d
    return 1


def cohort_blocks(
    gids: Sequence[int], marks: Sequence[int], cap: int
) -> tuple[int, list[int]]:
    """Group-axis *compaction* for a cohort dispatch: pick ``(gb, blocks)``
    so the kernel grid visits only the aligned ``gb``-blocks containing
    cohort members.

    Objective: minimize the number of visited blocks (grid steps along the
    group axis), then the fold width (block size — smaller blocks carry
    fewer inert filler rows).  A single hot group therefore costs one
    1-group block; a 7-of-8 cold cohort costs one folded 8-group block."""
    best: tuple[tuple[int, int], int, list[int]] | None = None
    for d in _divisors(cap):
        if not _block_lockstep(gids, marks, d):
            continue
        blocks = sorted({g // d for g in gids})
        key = (len(blocks), d)
        if best is None or key < best[0]:
            best = (key, d, blocks)
    assert best is not None  # d = 1 is always valid
    return best[1], best[2]


def pack_rows(
    rows: Sequence[np.ndarray], be: int, value_words: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pack encoded value rows into a ``(be, V)`` wire burst; unfilled
    slots carry the NOP sentinel and are inactive.

    Validated up front: an oversized chunk must fail *before* any wire
    array is built, never mid-write — the historical unguarded loop raised
    a bare ``IndexError`` after partially mutating the burst."""
    if len(rows) > be:
        raise ValueError(
            f"chunk of {len(rows)} rows exceeds quantized burst {be}"
        )
    vals = np.zeros((be, value_words), np.int32)
    active = np.zeros((be,), bool)
    vals[:, 0] = NOP_SENTINEL
    for j, row in enumerate(rows):
        vals[j] = row
        active[j] = True
    return vals, active


def scatter_rows(
    gids: Sequence[int],
    values: np.ndarray,
    active: np.ndarray | None,
    g: int,
    value_words: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Scatter compact cohort rows into a full-width ``(G, BE, V)`` burst:
    non-member rows carry the NOP sentinel and are inactive (they ride any
    dispatch inert).  The single definition of the full-width packing
    convention, shared by the jnp-oracle and sharded execution paths."""
    be = values.shape[1]
    vals_f = np.zeros((g, be, value_words), np.int32)
    vals_f[:, :, 0] = NOP_SENTINEL
    act_f = np.zeros((g, be), bool)
    for row, gid in enumerate(gids):
        vals_f[gid] = values[row]
        if active is not None:
            act_f[gid] = active[row]
    return vals_f, act_f


@dataclasses.dataclass(frozen=True)
class Cohort:
    """One dispatch of a round plan: the enabled groups sharing a quantized
    burst size.  ``gids`` may span several watermark classes — the dispatch
    folds block-wise where classes align and degrades to width-1 blocks
    where they don't (``fold_width_full`` / ``cohort_blocks``).

    ``rounds`` > 1 marks a *persistent wave* (DESIGN.md §11): the dispatch
    runs that many back-to-back full-batch Phase-2 rounds device-side,
    consuming ``rounds`` burst-sized chunks per member, and syncs results
    back to the host once."""

    gids: tuple[int, ...]
    burst: int
    rounds: int = 1


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """The resolved plan for one chunk wave.

    ``cohorts`` are ordered hot -> cold (burst descending); ``realign``
    lists ``(gid, target_watermark)`` burns the dataplane must apply before
    dispatching; ``fragmentation`` counts watermark classes among enabled
    groups (after burns); ``full_fold`` marks the highest-amortization
    state — one cohort, one watermark class — where the dispatch folds the
    full width."""

    cohorts: tuple[Cohort, ...]
    enabled: tuple[bool, ...]
    realign: tuple[tuple[int, int], ...]
    fragmentation: int
    full_fold: bool


@dataclasses.dataclass(frozen=True)
class PlacementMap:
    """Load-weighted group -> shard placement for the sharded dataplane
    (DESIGN.md §13): a permutation ``slot_of[gid] -> slot`` where slot
    ``s * Gl + r`` is physical slab row ``r`` on mesh shard ``s``.

    Device slabs are *slot*-indexed; group identity (and therefore session
    routing hashes, log segment names and twin-oracle numbering) never
    changes when a group moves — only its slot does.  The map is a plain
    permutation so membership events compose with placement: every group id,
    live or free, always owns exactly one slot, and a migration is a slot
    swap between a live group and a free one.

    Construction is deterministic and engine-agnostic: ``weighted`` is an
    LPT greedy over ``(-load, gid)`` with ties broken by (shard load sum,
    occupancy, shard id), so equal loads round-robin ``gid i -> shard
    i % n_shards`` and all four backends resolve the identical map from the
    identical ``group_loads()`` snapshot.
    """

    slot_of: tuple[int, ...]
    groups_per_shard: int

    def __post_init__(self) -> None:
        n = len(self.slot_of)
        if n % self.groups_per_shard:
            raise ValueError(
                f"{n} groups not divisible by Gl={self.groups_per_shard}"
            )
        if sorted(self.slot_of) != list(range(n)):
            raise ValueError(f"slot_of is not a permutation: {self.slot_of}")

    @property
    def n_groups(self) -> int:
        return len(self.slot_of)

    @property
    def n_shards(self) -> int:
        return len(self.slot_of) // self.groups_per_shard

    @property
    def group_of(self) -> tuple[int, ...]:
        """Inverse permutation: physical slot -> group id."""
        inv = [0] * len(self.slot_of)
        for gid, slot in enumerate(self.slot_of):
            inv[slot] = gid
        return tuple(inv)

    def shard_of(self, gid: int) -> int:
        return self.slot_of[gid] // self.groups_per_shard

    def row_of(self, gid: int) -> int:
        """Local slab row of ``gid`` within its owning shard."""
        return self.slot_of[gid] % self.groups_per_shard

    def identity_map(self) -> bool:
        return all(s == g for g, s in enumerate(self.slot_of))

    def swapped(self, gid: int, other: int) -> "PlacementMap":
        """The map with ``gid`` and ``other`` exchanging slots — the one
        placement mutation migration performs (both identities keep exactly
        one slot, so the result is again a permutation by construction)."""
        slots = list(self.slot_of)
        slots[gid], slots[other] = slots[other], slots[gid]
        return PlacementMap(tuple(slots), self.groups_per_shard)

    @classmethod
    def identity(cls, n_groups: int, groups_per_shard: int) -> "PlacementMap":
        return cls(tuple(range(n_groups)), groups_per_shard)

    @classmethod
    def weighted(
        cls,
        loads: Sequence[int],
        n_shards: int,
        groups_per_shard: int,
    ) -> "PlacementMap":
        """LPT greedy: heaviest group first onto the least-loaded non-full
        shard.  Ragged by construction — a hot shard may host one tenant
        while a cold shard hosts ``Gl`` — subject only to the ``Gl``-slot
        capacity.  Within a shard, rows fill in assignment order."""
        g = len(loads)
        if g != n_shards * groups_per_shard:
            raise ValueError(
                f"{g} loads for {n_shards} x {groups_per_shard} slots"
            )
        order = sorted(range(g), key=lambda i: (-int(loads[i]), i))
        sums = [0] * n_shards
        rows: list[list[int]] = [[] for _ in range(n_shards)]
        for gid in order:
            s = min(
                (s for s in range(n_shards) if len(rows[s]) < groups_per_shard),
                key=lambda s: (sums[s], len(rows[s]), s),
            )
            sums[s] += int(loads[gid])
            rows[s].append(gid)
        slots = [0] * g
        for s in range(n_shards):
            for r, gid in enumerate(rows[s]):
                slots[gid] = s * groups_per_shard + r
        return cls(tuple(slots), groups_per_shard)


class DispatchPlanner:
    """Owns the per-round dispatch policy for a multi-group context.

    Stateless per round except for the realignment counter (consecutive
    fragmented rounds) and introspection stats; the plan itself is a pure
    function of host-authoritative scalars (loads, watermark mirrors,
    membership, rounds), which is why unsharded, sharded and the jnp oracle
    resolve every round identically — the parity contract (DESIGN.md §8).
    """

    def __init__(
        self,
        batch: int,
        n_instances: int,
        realign_after: int | None = None,
        persistent_rounds: int = 1,
        sharded: bool = False,
    ) -> None:
        self.batch = batch
        self.n_instances = n_instances
        self.realign_after = realign_after
        self.persistent_rounds = max(1, int(persistent_rounds))
        # the sharded engine executes a K-round wave as K cohort dispatches
        # (DESIGN.md §11's documented fallback); the PLANNER owns that
        # clamp so ``persistent_waves`` telemetry counts only waves that
        # actually ran device-persistent, instead of the dispatch layer
        # silently unrolling K > 1 cohorts after they were counted
        self.sharded = sharded
        self._fragmented_rounds = 0
        self.last_plan: RoundPlan | None = None
        self.stats: dict[str, Any] = {
            "rounds": 0,
            "dispatches": 0,
            "full_fold_rounds": 0,
            "realignments": 0,
            "persistent_waves": 0,
            "burst_shapes": set(),
            "service_loads": None,
        }

    # -- bookkeeping hooks ---------------------------------------------------
    def note_burst(self, be: int) -> None:
        """Record a burst shape minted outside plan_round (staged paths)."""
        self.stats["burst_shapes"].add(be)

    def observe_service_loads(self, loads: Sequence[int]) -> None:
        """Serving-tier load snapshot (``ConsensusService.group_loads``) —
        introspection only; tiering uses per-wave queue depths so that the
        plan stays a pure function of the round's inputs."""
        self.stats["service_loads"] = list(loads)

    def report(self) -> dict[str, Any]:
        # Snapshot-copy every mutable value: a report is an observation,
        # not a window onto live planner state (callers mutating a report
        # must not perturb planning, and later observe_service_loads calls
        # must not rewrite already-returned reports).
        out = dict(self.stats)
        out["burst_shapes"] = sorted(self.stats["burst_shapes"])
        loads = self.stats["service_loads"]
        out["service_loads"] = None if loads is None else list(loads)
        out["fragmented_rounds"] = self._fragmented_rounds
        out["realign_after"] = self.realign_after
        return out

    def _wave_depth(
        self,
        burst: int,
        gids: Sequence[int],
        pending: Sequence[int] | None,
    ) -> int:
        """Persistent-wave depth K for one cohort (DESIGN.md §11).

        K > 1 only when the burst is the full batch — the wave's rounds are
        consecutive batch-sized queue slices, so numbering is identical to
        K single-round waves by construction — and every member has K full
        chunks queued.  Clamped by the ``persistent_rounds`` policy knob and
        by the ring (a wave may not lap itself: K * burst <= N).  On a
        sharded planner K is clamped to 1 up front: the wave would unroll
        into K cohort dispatches anyway (host-authoritative control scalars
        enter every dispatch), so minting K > 1 would only inflate the
        ``persistent_waves`` stat."""
        if (
            self.sharded
            or self.persistent_rounds <= 1
            or pending is None
            or burst != self.batch
        ):
            return 1
        k = min(pending[i] // burst for i in gids)
        k = min(k, self.persistent_rounds, self.n_instances // burst)
        return max(1, k)

    # -- the planner ---------------------------------------------------------
    def plan_round(
        self,
        loads: Sequence[int],
        marks: Sequence[int],
        live: Sequence[bool],
        crnd: Sequence[int],
        pending: Sequence[int] | None = None,
    ) -> RoundPlan:
        """Resolve one chunk wave: membership/frozen masking, the
        realignment sweep, and the hot->cold cohort tiering.

        ``loads`` are this wave's per-group chunk lengths; ``marks`` the
        host watermark mirrors; ``live`` membership; ``crnd`` the host
        round mirrors (``NO_ROUND`` = frozen under a software coordinator).
        ``pending`` gives per-group *total* queued lengths (first chunk
        included); when provided and ``persistent_rounds`` > 1, a cohort
        whose burst is the full batch and whose every member has K full
        batch-sized chunks queued is planned as a K-round persistent wave
        — burst quantization itself never changes, so engine-agnostic
        numbering is preserved round for round.
        """
        g = len(loads)
        enabled = tuple(
            loads[i] > 0 and bool(live[i]) and crnd[i] != NO_ROUND
            for i in range(g)
        )
        en_gids = [i for i in range(g) if enabled[i]]
        marks = list(marks)

        # A round is *fragmented* when it cannot run the highest-amortization
        # mapping: enabled watermarks spread over >1 class (fold breaks), OR
        # some enabled watermark off the full-batch block boundary (the
        # kernel window alignment a quantized sub-batch burst can cost —
        # engine-agnostic on purpose: the burn must fire identically on the
        # jnp oracle or backends' instance numbering would fork).
        bb = wire_block(self.batch)
        classes = {marks[i] for i in en_gids}
        fragmented = len(classes) > 1 or any(
            marks[i] % bb for i in en_gids
        )
        if fragmented:
            self._fragmented_rounds += 1
        elif en_gids:
            self._fragmented_rounds = 0

        realign: list[tuple[int, int]] = []
        if (
            self.realign_after is not None
            and fragmented
            and self._fragmented_rounds >= self.realign_after
        ):
            # burn every straggling enabled group forward to one common
            # block boundary: the skipped instances are never proposed and
            # are recoverable as no-ops (paper §3.1), and the full-width
            # folded block-aligned mapping re-engages on the next dispatch
            target = -(-max(classes) // bb) * bb
            for i in en_gids:
                if marks[i] != target:
                    realign.append((i, target))
                    marks[i] = target
            self._fragmented_rounds = 0
            self.stats["realignments"] += 1

        tiers: dict[int, list[int]] = {}
        for i in en_gids:
            be = quantize_burst(loads[i], self.batch)
            tiers.setdefault(be, []).append(i)
            self.stats["burst_shapes"].add(be)
        cohorts = tuple(
            Cohort(
                gids=tuple(gids),
                burst=be,
                rounds=self._wave_depth(be, gids, pending),
            )
            for be, gids in sorted(tiers.items(), reverse=True)
        )
        if any(c.rounds > 1 for c in cohorts):
            self.stats["persistent_waves"] += 1
        fragmentation = len({marks[i] for i in en_gids})
        plan = RoundPlan(
            cohorts=cohorts,
            enabled=enabled,
            realign=tuple(realign),
            fragmentation=fragmentation,
            full_fold=len(cohorts) == 1 and fragmentation == 1,
        )
        self.stats["rounds"] += 1
        self.stats["dispatches"] += len(cohorts)
        if plan.full_fold:
            self.stats["full_fold_rounds"] += 1
        self.last_plan = plan
        return plan
