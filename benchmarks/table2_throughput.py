"""Paper Table 2: computed throughput across targets/batch ("clock rates").

The paper computes throughput from SDNet cycle reports at three clock rates.
Our analogue: dataplane messages/s as a function of burst size — the batch
amortization curve is the TPU's "clock rate" lever.  Also derives the
target-TPU acceptor throughput bound from the kernel's bytes-touched per
message vs HBM bandwidth (819 GB/s): the acceptor is memory-bound, so
msgs/s = HBM_bw / bytes_per_msg.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import batched
from repro.core.types import MSG_P2A, AcceptorState, MsgBatch

from .common import block, emit, time_fn

V = 16
N = 1 << 16


def run() -> None:
    vote = jax.jit(batched.acceptor_phase2)
    astate = AcceptorState.init(N, V)
    for b in (64, 256, 1024, 4096):
        batch = MsgBatch(
            msgtype=jnp.full((b,), MSG_P2A, jnp.int32),
            inst=jnp.arange(b, dtype=jnp.int32),
            rnd=jnp.zeros((b,), jnp.int32),
            vrnd=jnp.full((b,), -1, jnp.int32),
            swid=jnp.zeros((b,), jnp.int32),
            value=jnp.ones((b, V), jnp.int32),
        )
        us = time_fn(lambda: block(vote(astate, batch, 0))) / b
        emit(f"table2/jit_acceptor/burst={b}", us, f"{1e6/us:.0f} msg/s (CPU)")

    # target-TPU analytical bound: bytes touched per message
    # state rw: (rnd+vrnd) 2x4B x2 + value 64B x2 ; msg read ~76B; vote write ~76B
    bytes_per_msg = (2 * 4 * 2) + (64 * 2) + 76 + 76
    hbm = 819e9
    emit(
        "table2/tpu_target_acceptor_bound",
        1e6 * bytes_per_msg / hbm,
        f"{hbm/bytes_per_msg/1e6:.0f} Mmsg/s @819GB/s (vs paper 9.3Mmsg/s @10G line rate)",
    )
