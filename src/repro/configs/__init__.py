"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture; each exports ``CONFIG``.
"""
from __future__ import annotations

import importlib

from .base import LONG_CONTEXT_FAMILIES, SHAPES, ModelConfig, ShapeConfig  # noqa: F401

_ARCH_MODULES = [
    "gemma3_27b",
    "yi_9b",
    "mistral_nemo_12b",
    "qwen3_4b",
    "rwkv6_3b",
    "recurrentgemma_2b",
    "llama4_scout_17b_a16e",
    "dbrx_132b",
    "internvl2_76b",
    "whisper_base",
]

_CACHE: dict[str, ModelConfig] = {}


def list_archs() -> list[str]:
    return [m.replace("_", "-") for m in _ARCH_MODULES]


def get_config(arch_id: str) -> ModelConfig:
    key = arch_id.replace("-", "_")
    if key not in _CACHE:
        mod = importlib.import_module(f"repro.configs.{key}")
        _CACHE[key] = mod.CONFIG
    return _CACHE[key]


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether (arch x shape) is a lowered cell or a documented skip."""
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True
