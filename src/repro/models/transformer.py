"""Decoder-only transformer LM (dense GQA / MoE / VLM-backbone).

One implementation covers gemma3 (5:1 local:global sliding window), yi /
mistral-nemo / qwen3 (dense GQA, optional qk_norm), llama4-scout & dbrx
(MoE, EP-sharded experts), and internvl2 (stub patch embeddings prefixed to
the token stream).

Layers are *stacked* (leading ``layers`` dim) and executed with
``jax.lax.scan`` + ``jax.checkpoint`` so the lowered HLO stays compact at any
depth and remat policy is explicit — both essential for the 512-device
dry-run compiles.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import PSpec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def _stack(spec: PSpec, n: int) -> PSpec:
    return PSpec((n,) + spec.shape, ("layers",) + spec.axes, spec.init, spec.scale)


def block_specs(cfg) -> dict[str, Any]:
    d = cfg.d_model
    sp: dict[str, Any] = {
        "ln1": PSpec((d,), ("embed",), init="zeros"),
        "ln2": PSpec((d,), ("embed",), init="zeros"),
        "attn": L.attention_specs(cfg),
    }
    if cfg.n_experts:
        sp["moe"] = L.moe_specs(cfg)
    else:
        sp["mlp"] = L.mlp_specs(cfg)
    return sp


def specs(cfg) -> dict[str, Any]:
    d = cfg.d_model
    blocks = jax.tree_util.tree_map(
        lambda s: _stack(s, cfg.n_layers),
        block_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )
    sp = {
        "embed": PSpec((cfg.vocab, d), ("vocab", "embed"), scale=1.0),
        "blocks": blocks,
        "ln_f": PSpec((d,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        sp["head"] = PSpec((d, cfg.vocab), ("embed", "vocab"))
    return sp


def window_schedule(cfg) -> jnp.ndarray:
    """Per-layer sliding window (0 = global/full attention)."""
    ls = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if cfg.local_window == 0:
        return jnp.zeros_like(ls)
    if cfg.global_every == 0:
        return jnp.full_like(ls, cfg.local_window)
    is_global = (ls + 1) % cfg.global_every == 0
    return jnp.where(is_global, 0, cfg.local_window)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _ffn(blk, x, cfg):
    if cfg.n_experts:
        return L.moe_fwd(blk["moe"], x, cfg)
    return L.mlp_fwd(blk["mlp"], x)


def _embed_inputs(cfg, params, batch) -> tuple[jax.Array, int]:
    """Token (+ modality-prefix) embedding.  Returns (h, n_prefix)."""
    tokens = batch["tokens"]
    h = params["embed"][tokens].astype(params["embed"].dtype)
    n_prefix = 0
    if cfg.n_patches and "patches" in batch:
        h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
        n_prefix = batch["patches"].shape[1]
    return h, n_prefix


def forward(
    cfg,
    params,
    batch: dict[str, jax.Array],
    *,
    collect_cache: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Full-sequence forward.  batch = {tokens: (B,S) [, patches: (B,P,D)]}.

    Returns (logits (B, S_total, V), cache or None).
    """
    h, n_prefix = _embed_inputs(cfg, params, batch)
    h = L.shard(h, ("batch", "act_seq", None))
    windows = window_schedule(cfg)

    def body(carry, xs):
        h = carry
        blk, win = xs
        a, (kk, vv) = L.attention_fwd(
            blk["attn"], L.rms_norm(h, blk["ln1"], cfg.norm_eps), cfg, window=win
        )
        h = h + a
        h = h + _ffn(blk, L.rms_norm(h, blk["ln2"], cfg.norm_eps), cfg)
        h = L.shard(h, ("batch", "act_seq", None))
        ys = (kk, vv) if collect_cache else None
        return h, ys

    body_fn = L.checkpoint_fn(body, cfg)
    h, caches = jax.lax.scan(body_fn, h, (params["blocks"], windows))
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)

    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
    logits = L.shard(logits, ("batch", "act_seq", "vocab"))

    cache = None
    if collect_cache:
        kk, vv = caches
        b, s = kk.shape[1], kk.shape[2]
        kpos = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None, :], (cfg.n_layers, b, s)
        )
        cache = {"k": kk, "v": vv, "kpos": kpos}
    return logits[:, n_prefix:], cache


# ---------------------------------------------------------------------------
# KV cache / decode
# ---------------------------------------------------------------------------
def _grouped(cfg) -> bool:
    return bool(cfg.ring_local_cache and cfg.local_window and cfg.global_every)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict[str, Any]:
    if _grouped(cfg):
        return grouped_init_cache(cfg, batch, max_len, dtype)
    l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((l, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((l, batch, max_len, kv, hd), dtype),
        "kpos": jnp.full((l, batch, max_len), -1, jnp.int32),
    }


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the cache (dry-run, no allocation)."""
    if _grouped(cfg):
        return grouped_cache_specs(cfg, batch, max_len, dtype)
    l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jax.ShapeDtypeStruct((l, batch, max_len, kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((l, batch, max_len, kv, hd), dtype),
        "kpos": jax.ShapeDtypeStruct((l, batch, max_len), jnp.int32),
    }


CACHE_AXES = {
    "k": ("layers", "batch", "cache_seq", "kv_heads", None),
    "v": ("layers", "batch", "cache_seq", "kv_heads", None),
    "kpos": ("layers", "batch", "cache_seq"),
    # grouped ring-cache layout (ring_local_cache)
    "lk": ("layers", None, "batch", "cache_seq", "kv_heads", None),
    "lv": ("layers", None, "batch", "cache_seq", "kv_heads", None),
    "lkp": ("layers", None, "batch", "cache_seq"),
    "gk": ("layers", "batch", "cache_seq", "kv_heads", None),
    "gv": ("layers", "batch", "cache_seq", "kv_heads", None),
    "gkp": ("layers", "batch", "cache_seq"),
    "rk": ("layers", "batch", "cache_seq", "kv_heads", None),
    "rv": ("layers", "batch", "cache_seq", "kv_heads", None),
    "rkp": ("layers", "batch", "cache_seq"),
}


def _decode_layer(cfg, blk, h, kc, vc, kp, pos, win):
    """One layer of single-token decode against (possibly ring) cache slices."""
    b = h.shape[0]
    kvh, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    c = kc.shape[1]
    slot = pos % c
    x = L.rms_norm(h, blk["ln1"], cfg.norm_eps)
    p = blk["attn"]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    kk = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    vv = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        kk = L.rms_norm(kk, p["k_norm"], cfg.norm_eps)
    posv = jnp.full((1,), pos, jnp.int32)
    q = L.rope(q, posv, cfg.rope_theta)
    kk = L.rope(kk, posv, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, kk.astype(kc.dtype), slot, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, vv.astype(vc.dtype), slot, 1)
    kp = jax.lax.dynamic_update_slice_in_dim(
        kp, jnp.full((b, 1), pos, jnp.int32), slot, 1
    )
    out = L.decode_attention(
        q.reshape(b, 1, kvh, g, hd), kc, vc, kp, pos, window=win
    )
    out = jnp.einsum("bshk,hkd->bsd", out.reshape(b, 1, cfg.n_heads, hd), p["wo"])
    h = h + out
    h = h + _ffn(blk, L.rms_norm(h, blk["ln2"], cfg.norm_eps), cfg)
    return h, kc, vc, kp


def decode_step(
    cfg,
    params,
    tokens: jax.Array,          # (B, 1)
    cache: dict[str, jax.Array],
    pos: jax.Array,             # int32[] absolute position of this token
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One-token decode with ring KV cache write at ``pos % C``."""
    if cfg.ring_local_cache and cfg.local_window and cfg.global_every:
        return _decode_step_grouped(cfg, params, tokens, cache, pos)
    h = params["embed"][tokens].astype(params["embed"].dtype)
    h = L.shard(h, ("batch", None, None))
    windows = window_schedule(cfg)

    def body(h, xs):
        blk, win, kc, vc, kp = xs
        h, kc, vc, kp = _decode_layer(cfg, blk, h, kc, vc, kp, pos, win)
        return h, (kc, vc, kp)

    h, (kc, vc, kp) = jax.lax.scan(
        body, h, (params["blocks"], windows, cache["k"], cache["v"], cache["kpos"])
    )
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
    return logits, {"k": kc, "v": vc, "kpos": kp}


def prefill(cfg, params, batch) -> tuple[jax.Array, dict[str, jax.Array]]:
    logits, cache = forward(cfg, params, batch, collect_cache=True)
    return logits, cache


# ---------------------------------------------------------------------------
# Grouped ring caches (§Perf lever: ring_local_cache)
#
# Local (sliding-window) layers only ever attend to the last ``window``
# positions, so their cache needs window slots, not seq_len.  Layers are
# grouped into superblocks of ``global_every`` (gemma3: 5 local + 1 global);
# the remainder layers are local.  For gemma3-27b @ 32k this shrinks the KV
# cache 62*S -> 52*W + 10*S  (~5.3x) and, since decode attention reads the
# whole cache every token, shrinks decode HBM traffic by the same factor.
# ---------------------------------------------------------------------------
def _grouped_layout(cfg) -> tuple[int, int, int]:
    ge = cfg.global_every
    return cfg.n_layers // ge, ge, cfg.n_layers % ge


def grouped_cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_super, ge, rem = _grouped_layout(cfg)
    kv, hd = cfg.n_kv_heads, cfg.hd
    w = min(cfg.local_window, max_len)
    sp = {
        "lk": jax.ShapeDtypeStruct((n_super, ge - 1, batch, w, kv, hd), dtype),
        "lv": jax.ShapeDtypeStruct((n_super, ge - 1, batch, w, kv, hd), dtype),
        "lkp": jax.ShapeDtypeStruct((n_super, ge - 1, batch, w), jnp.int32),
        "gk": jax.ShapeDtypeStruct((n_super, batch, max_len, kv, hd), dtype),
        "gv": jax.ShapeDtypeStruct((n_super, batch, max_len, kv, hd), dtype),
        "gkp": jax.ShapeDtypeStruct((n_super, batch, max_len), jnp.int32),
    }
    if rem:
        sp["rk"] = jax.ShapeDtypeStruct((rem, batch, w, kv, hd), dtype)
        sp["rv"] = jax.ShapeDtypeStruct((rem, batch, w, kv, hd), dtype)
        sp["rkp"] = jax.ShapeDtypeStruct((rem, batch, w), jnp.int32)
    return sp


def grouped_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda s: jnp.full(s.shape, -1, jnp.int32)
        if s.dtype == jnp.int32
        else jnp.zeros(s.shape, s.dtype),
        grouped_cache_specs(cfg, batch, max_len, dtype),
    )


def _regroup_blocks(cfg, blocks):
    """Split the (L, ...)-stacked block params into (super-local, super-global,
    remainder-local) views — pure reshapes/slices, free at trace time."""
    n_super, ge, rem = _grouped_layout(cfg)

    def main(x):
        return x[: n_super * ge].reshape((n_super, ge) + x.shape[1:])

    locals_ = jax.tree_util.tree_map(lambda x: main(x)[:, : ge - 1], blocks)
    globals_ = jax.tree_util.tree_map(lambda x: main(x)[:, ge - 1], blocks)
    rems = (
        jax.tree_util.tree_map(lambda x: x[n_super * ge :], blocks) if rem else None
    )
    return locals_, globals_, rems


def _decode_step_grouped(cfg, params, tokens, cache, pos):
    n_super, ge, rem = _grouped_layout(cfg)
    w = cfg.local_window
    h = params["embed"][tokens].astype(params["embed"].dtype)
    h = L.shard(h, ("batch", None, None))
    loc, glob, rems = _regroup_blocks(cfg, params["blocks"])

    def local_body(h, xs):
        blk, kc, vc, kp = xs
        h, kc, vc, kp = _decode_layer(cfg, blk, h, kc, vc, kp, pos, w)
        return h, (kc, vc, kp)

    def super_body(h, xs):
        lblk, gblk, lk, lv, lkp, gk, gv, gkp = xs
        h, (lk, lv, lkp) = jax.lax.scan(local_body, h, (lblk, lk, lv, lkp))
        h, gk, gv, gkp = _decode_layer(cfg, gblk, h, gk, gv, gkp, pos, 0)
        return h, (lk, lv, lkp, gk, gv, gkp)

    h, (lk, lv, lkp, gk, gv, gkp) = jax.lax.scan(
        super_body,
        h,
        (loc, glob, cache["lk"], cache["lv"], cache["lkp"],
         cache["gk"], cache["gv"], cache["gkp"]),
    )
    new_cache = dict(cache)
    new_cache.update({"lk": lk, "lv": lv, "lkp": lkp,
                      "gk": gk, "gv": gv, "gkp": gkp})
    if rem:
        h, (rk, rv, rkp) = jax.lax.scan(
            local_body, h, (rems, cache["rk"], cache["rv"], cache["rkp"])
        )
        new_cache.update({"rk": rk, "rv": rv, "rkp": rkp})

    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
    return logits, new_cache
