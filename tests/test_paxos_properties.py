"""Hypothesis property tests for the Paxos core — the paper's safety contract.

Properties (checked under adversarial drop/dup/reorder schedules and
concurrent coordinators):

  * Agreement:  no two learners deliver different values for one instance.
  * Validity:   every delivered value was proposed by some client.
  * Integrity:  each learner delivers an instance at most once.
  * Progress:   with a live quorum and retransmission, every submitted value
                is eventually delivered (liveness under fairness).
"""
from __future__ import annotations

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import FaultSpec, PaxosConfig, PaxosContext, SimNet, SoftwarePaxos
from repro.core.paxos import Acceptor, Msg
from repro.core.types import MSG_P2A

SMALL = PaxosConfig(n_acceptors=3, n_instances=256, batch=8)


@settings(max_examples=30, deadline=None)
@given(
    n_values=st.integers(1, 24),
    drop=st.floats(0.0, 0.35),
    dup=st.floats(0.0, 0.3),
    reorder=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**16),
)
def test_agreement_validity_integrity_under_faults(n_values, drop, dup, reorder, seed):
    net = SimNet(FaultSpec(drop=drop, dup=dup, reorder=reorder), seed=seed)
    delivered = []
    ctx = PaxosContext(
        SMALL,
        deliver=lambda v, n, i: delivered.append((i, v)),
        net=net,
        n_learners=3,
    )
    proposed = set()
    for k in range(n_values):
        payload = f"v{k}".encode()
        proposed.add(payload)
        ctx.submit(payload)
    ctx.run_until_quiescent(max_rounds=300)

    # validity
    for _, v in delivered:
        assert v in proposed
    # integrity (learner 0 delivers each instance at most once)
    insts = [i for i, _ in delivered]
    assert len(insts) == len(set(insts))
    # agreement across learners: all learned maps consistent per instance
    values_by_inst = {}
    for lid in range(3):
        for inst, raw in ctx.learned[lid].items():
            if inst in values_by_inst:
                assert values_by_inst[inst] == raw, f"learners disagree at {inst}"
            values_by_inst[inst] = raw
    # progress under fairness (retransmit active): everything delivered
    assert len({v for _, v in delivered}) == len(proposed)


@settings(max_examples=20, deadline=None)
@given(
    n_values=st.integers(1, 16),
    seed=st.integers(0, 2**16),
    kill=st.integers(0, 2),
)
def test_progress_with_f_failures(n_values, seed, kill):
    """f = 1 of 2f+1 = 3 acceptors may fail; consensus must still decide."""
    net = SimNet(FaultSpec(), seed=seed)
    delivered = []
    ctx = PaxosContext(SMALL, deliver=lambda v, n, i: delivered.append(v), net=net)
    ctx.hw.kill_acceptor(kill)
    for k in range(n_values):
        ctx.submit(f"x{k}".encode())
    ctx.run_until_quiescent(max_rounds=200)
    assert len(set(delivered)) == n_values


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_no_progress_without_quorum_then_recovers(seed):
    """2 of 3 acceptors dead -> no decisions; revive one -> progress resumes."""
    net = SimNet(FaultSpec(), seed=seed)
    delivered = []
    ctx = PaxosContext(SMALL, deliver=lambda v, n, i: delivered.append(v), net=net)
    ctx.hw.kill_acceptor(0)
    ctx.hw.kill_acceptor(1)
    ctx.submit(b"stuck")
    ctx.pump(20)
    assert delivered == []
    ctx.hw.revive_acceptor(0)
    ctx.run_until_quiescent(max_rounds=100)
    assert delivered and delivered[0] == b"stuck"


@settings(max_examples=20, deadline=None)
@given(
    rounds=st.lists(st.integers(0, 5), min_size=2, max_size=6),
    seed=st.integers(0, 1000),
)
def test_scalar_acceptor_single_vote_per_round_order(rounds, seed):
    """Scalar-oracle acceptor: higher rounds win, lower rounds rejected."""
    acc = Acceptor(aid=0, n_instances=64)
    best = -1
    for r in rounds:
        out = acc.on_p2a(Msg(MSG_P2A, inst=7, rnd=r, value=f"r{r}".encode()))
        if r >= best:
            assert out.msgtype == 4  # accepted
            best = r
        else:
            assert out.msgtype == 7  # rejected


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 12))
def test_software_baseline_agrees_with_hardware(seed, n):
    """libpaxos-like software baseline and the JAX dataplane deliver the same
    value sets under identical submissions (drop-in property)."""
    sw = SoftwarePaxos(SMALL, net=SimNet(seed=seed))
    hw_delivered = []
    hw = PaxosContext(SMALL, deliver=lambda v, s, i: hw_delivered.append(v),
                      net=SimNet(seed=seed))
    payloads = [f"p{k}".encode() for k in range(n)]
    for p in payloads:
        sw.submit(p)
        hw.submit(p)
    sw.run_until_quiescent()
    hw.run_until_quiescent()
    assert [v for _, v in sw.delivered] == payloads
    assert hw_delivered == payloads
