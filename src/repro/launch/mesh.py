"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Shapes:

  * single-pod: (16, 16)    axes (data, model)   — 256 chips (one v5e pod)
  * multi-pod:  (2, 16, 16) axes (pod, data, model) — 512 chips / 2 pods

The ``pod`` axis maps onto DCN-connected pod boundaries: pure data
parallelism with hierarchical gradient reduction.  ``data`` is the FSDP axis
(intra-pod ICI), ``model`` the tensor/expert/sequence-parallel axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int = 0, model_parallel: int = 1) -> jax.sharding.Mesh:
    """Small mesh over the locally available devices (tests / examples)."""
    n = n_devices or len(jax.devices())
    mp = model_parallel
    assert n % mp == 0
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_group_mesh(n_devices: int = 0) -> jax.sharding.Mesh:
    """1-D mesh with a single ``groups`` axis over the local devices.

    The placement domain of the groups-sharded consensus dataplane
    (``core.api.ShardedMultiGroupDataplane``, DESIGN.md §6): the G
    device-resident Paxos groups partition into contiguous slabs, one per
    mesh shard, so G scales with device count instead of one chip's
    VMEM/HBM.  On a single-device host this degenerates to a (1,) mesh and
    the sharded dataplane reduces bit-exactly to ``MultiGroupDataplane``.

    Capacity planning under dynamic membership (DESIGN.md §7): G is the
    *capacity* of the group axis, fixed at mesh/dataplane construction and
    divisible by the axis size.  Tenants create/retire over a free-list
    *within* that capacity — membership events flip replicated host scalars
    and never re-shard or move slab state — so size G for peak concurrent
    tenancy, not current tenancy.
    """
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("groups",))
