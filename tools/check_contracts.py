#!/usr/bin/env python
"""Path-free entry point for the dataplane contract checker.

Equivalent to ``PYTHONPATH=src python -m repro.analysis.contracts`` but
runnable from anywhere inside the repo without environment setup:

    python tools/check_contracts.py [--strict-advisory]

See ``src/repro/analysis/contracts.py`` and DESIGN.md §12.
"""
from __future__ import annotations

import os
import sys

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.analysis.contracts import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
