"""Multi-device integration tests, run in subprocesses with
--xla_force_host_platform_device_count=8 (the main test process must keep the
default single device for the smoke tests)."""
from __future__ import annotations

import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow   # subprocess suite: skipped in the fast lane


def _run(code: str, devices: int = 8) -> str:
    env_code = (
        f"import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"\n'
    )
    proc = subprocess.run(
        [sys.executable, "-c", env_code + textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_fabric_consensus_round_all_devices_agree():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.fabric import make_fabric_consensus
        mesh = jax.make_mesh((8,), ("acc",))
        init_fn, step = make_fabric_consensus(mesh, axis="acc", n_instances=256,
                                              value_words=4)
        astate, cstate = init_fn()
        values = jnp.arange(8 * 2 * 4, dtype=jnp.int32).reshape(16, 4)
        active = jnp.ones((16,), bool)
        alive = jnp.ones((8,), bool)
        astate, cstate, decided, inst, value = step(astate, cstate, values, active, alive)
        assert np.asarray(decided).all(), decided
        np.testing.assert_array_equal(np.asarray(inst), np.arange(16))
        np.testing.assert_array_equal(np.asarray(value), np.asarray(values))
        assert int(cstate.next_inst) == 16
        # second round continues the instance window
        astate, cstate, decided, inst, _ = step(astate, cstate, values, active, alive)
        assert np.asarray(inst)[0] == 16
        print("FABRIC_OK")
        """
    )
    assert "FABRIC_OK" in out


def test_fabric_consensus_tolerates_f_failures():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.fabric import make_fabric_consensus
        mesh = jax.make_mesh((8,), ("acc",))
        # quorum 5 of 8 -> tolerate 3 dead acceptors
        init_fn, step = make_fabric_consensus(mesh, axis="acc", quorum=5,
                                              n_instances=128, value_words=2)
        astate, cstate = init_fn()
        values = jnp.ones((8, 2), jnp.int32)
        active = jnp.ones((8,), bool)
        alive = jnp.asarray([True]*5 + [False]*3)
        astate, cstate, decided, inst, value = step(astate, cstate, values, active, alive)
        assert np.asarray(decided).all()
        # 4 alive < quorum 5 -> no decision
        alive = jnp.asarray([True]*4 + [False]*4)
        astate, cstate, decided, *_ = step(astate, cstate, values, active, alive)
        assert not np.asarray(decided).any()
        print("QUORUM_OK")
        """
    )
    assert "QUORUM_OK" in out


def test_quorum_commit_digest_straggler():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.core.fabric import _shard_map, quorum_commit_digest
        mesh = jax.make_mesh((8,), ("data",))
        fn = _shard_map(
            functools.partial(quorum_commit_digest, axis="data", quorum=5),
            mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P(), P()))
        # all groups agree
        d = jnp.full((8,), 1234, jnp.int32)
        h = jnp.ones((8,), bool)
        commit, win = jax.jit(fn)(d, h)
        assert bool(commit) and int(win) == 8
        # 3 stragglers abstain -> still commits
        h = jnp.asarray([True]*5 + [False]*3)
        commit, win = jax.jit(fn)(d, h)
        assert bool(commit) and int(win) == 5
        # a diverging (corrupt) group never joins the quorum: with 3
        # stragglers + 1 corrupt, only 4 agree < quorum 5 -> no commit
        d2 = d.at[0].set(999)
        commit, win = jax.jit(fn)(d2, h)
        assert not bool(commit) and int(win) == 4
        # too many stragglers -> no commit
        h = jnp.asarray([True]*4 + [False]*4)
        commit, win = jax.jit(fn)(d, h)
        assert not bool(commit)
        print("COMMIT_OK")
        """
    )
    assert "COMMIT_OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch import sharding as sh
        from repro.launch.mesh import make_host_mesh
        from repro.models import registry
        from repro.train import train_loop
        from repro.configs.base import ShapeConfig

        cfg = get_config("qwen3-4b").reduced()
        mesh = make_host_mesh(8, model_parallel=2)     # (4, 2) data x model
        key = jax.random.PRNGKey(0)
        tiny = ShapeConfig("t", 16, 4, "train")
        batch = registry.make_inputs(cfg, tiny, key)

        # single-device reference
        state0 = train_loop.init_state(cfg, key)
        step0 = jax.jit(train_loop.make_train_step(cfg))
        _, m0 = step0(state0, batch)

        # sharded
        rules = sh.BASE_RULES
        sh.install(mesh, rules)
        state_sh = sh.tree_shardings(
            train_loop.state_shapes(cfg), train_loop.state_axes(cfg), rules, mesh)
        batch_specs = registry.input_specs(cfg, tiny)
        batch_sh = sh.batch_shardings(batch_specs, cfg, rules, mesh)
        state = jax.device_put(train_loop.init_state(cfg, key), state_sh)
        gbatch = {k: jax.device_put(v, batch_sh[k]) for k, v in batch.items()}
        step = jax.jit(train_loop.make_train_step(cfg),
                       in_shardings=(state_sh, batch_sh))
        _, m1 = step(state, gbatch)
        sh.uninstall()
        a, b = float(m0["loss"]), float(m1["loss"])
        assert abs(a - b) / abs(a) < 1e-3, (a, b)
        print("SHARDED_OK", a, b)
        """
    )
    assert "SHARDED_OK" in out


def test_sharded_moe_expert_parallel():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.launch import sharding as sh
        from repro.launch.mesh import make_host_mesh
        from repro.models import registry
        from repro.configs.base import ShapeConfig

        cfg = get_config("dbrx-132b").reduced()   # 4 experts
        mesh = make_host_mesh(8, model_parallel=4)  # experts 4-way EP
        key = jax.random.PRNGKey(0)
        tiny = ShapeConfig("t", 16, 4, "train")
        batch = registry.make_inputs(cfg, tiny, key)
        mod = registry.family_module(cfg)
        params = registry.init_params(cfg, key)
        ref, _ = mod.forward(cfg, params, {"tokens": batch["tokens"]})

        sh.install(mesh, sh.BASE_RULES)
        psh = sh.tree_shardings(registry.param_shapes(cfg),
                                registry.param_axes(cfg), sh.BASE_RULES, mesh)
        p = jax.device_put(params, psh)
        f = jax.jit(lambda p, t: mod.forward(cfg, p, {"tokens": t})[0],
                    in_shardings=(psh, None))
        got = f(p, batch["tokens"])
        sh.uninstall()
        err = np.abs(np.asarray(got) - np.asarray(ref)).max()
        assert err < 5e-4, err
        print("EP_OK", err)
        """
    )
    assert "EP_OK" in out
