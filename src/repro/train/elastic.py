"""Elastic scaling: membership views + mesh rebuilds + state resharding.

At 1000+-node scale, node churn is routine.  The membership *view* (the set
of live hosts and the mesh shape built from them) is itself a decided value:
every view change is proposed through the consensus log, so all survivors
agree on the same new mesh before any collective runs on it (a disagreeing
straggler would hang a collective; an agreed view cannot).

The resharding path reuses the checkpoint machinery: state saved under the
old mesh restores against the new mesh's shardings (`CheckpointManager.
restore(shardings=...)`), and `replan_mesh` picks the largest usable mesh
from the surviving device count.
"""
from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class MembershipView:
    epoch: int
    hosts: tuple[str, ...]
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]

    def encode(self) -> bytes:
        return json.dumps(
            {
                "epoch": self.epoch,
                "hosts": list(self.hosts),
                "shape": list(self.mesh_shape),
                "axes": list(self.mesh_axes),
            }
        ).encode()

    @classmethod
    def decode(cls, raw: bytes) -> "MembershipView":
        d = json.loads(raw.decode())
        return cls(d["epoch"], tuple(d["hosts"]), tuple(d["shape"]), tuple(d["axes"]))


def replan_mesh(n_devices: int, *, model_parallel: int = 16) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (data, model) mesh from the surviving device count.

    Keeps the model axis fixed (TP degree is architecture-bound) and shrinks
    the data axis — dropping up to model_parallel-1 devices if the survivor
    count is not a multiple.
    """
    mp = min(model_parallel, n_devices)
    data = max(n_devices // mp, 1)
    return (data, mp), ("data", "model")


class ViewManager:
    """Drives membership-view agreement through the consensus layer."""

    def __init__(self, paxos_ctx, initial: MembershipView):
        self.ctx = paxos_ctx
        self.view = initial
        self._decided: list[MembershipView] = [initial]
        if paxos_ctx is not None:
            orig = paxos_ctx.deliver_cb

            def _cb(value: bytes, size: int, inst: int, _orig=orig):
                if value.startswith(b"view:"):
                    self._on_view(MembershipView.decode(value[5:]))
                if _orig:
                    _orig(value, size, inst)

            paxos_ctx.deliver_cb = _cb

    def _on_view(self, view: MembershipView) -> None:
        if view.epoch > self.view.epoch:
            self.view = view
            self._decided.append(view)

    def propose_view(self, hosts: list[str], model_parallel: int = 16) -> MembershipView:
        shape, axes = replan_mesh(len(hosts), model_parallel=model_parallel)
        view = MembershipView(
            epoch=self.view.epoch + 1,
            hosts=tuple(sorted(hosts)),
            mesh_shape=shape,
            mesh_axes=axes,
        )
        if self.ctx is not None:
            self.ctx.submit(b"view:" + view.encode())
            self.ctx.run_until_quiescent()
        else:
            self._on_view(view)
        return self.view
