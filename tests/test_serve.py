"""Serving-engine tests: prefill/decode consistency, the batching loop, and
session -> group -> shard routing of the consensus tier."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import PaxosConfig, PaxosContext
from repro.launch.mesh import make_group_mesh
from repro.models import registry
from repro.serve.engine import (
    ConsensusService,
    Request,
    ServeLoop,
    make_prefill_step,
    make_serve_step,
)

DECODE_FAMS = [
    "qwen3-4b",          # dense + qk_norm
    "gemma3-27b",        # local:global sliding window
    "rwkv6-3b",          # ssm: O(1) state
    "recurrentgemma-2b", # hybrid superblocks
    "whisper-base",      # enc-dec w/ cross cache
]


@pytest.mark.parametrize("arch", DECODE_FAMS)
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), remat=False,
                              capacity_factor=8.0)
    mod = registry.family_module(cfg)
    key = jax.random.PRNGKey(7)
    params = registry.init_params(cfg, key)
    B, T = 2, 10
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.src_len, cfg.d_model))
    ref_logits, _ = mod.forward(cfg, params, batch)

    cache = mod.init_cache(cfg, B, T, jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        _, pc = mod.prefill(cfg, params, {"tokens": tokens[:, :1],
                                          "frames": batch["frames"]})
        cache["cross_k"], cache["cross_v"] = pc["cross_k"], pc["cross_v"]
    outs = []
    step = jax.jit(make_serve_step(cfg))
    for t in range(T):
        logits, cache = step(params, tokens[:, t : t + 1], cache, jnp.int32(t))
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)
    err = np.abs(dec - np.asarray(ref_logits)).max()
    assert err < 5e-3, (arch, err)


def test_prefill_step_returns_last_logits_and_cache():
    cfg = dataclasses.replace(get_config("yi-9b").reduced(), remat=False)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    B, T = 2, 8
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    last, cache = jax.jit(make_prefill_step(cfg))(params, {"tokens": tokens})
    assert last.shape == (B, cfg.vocab)
    assert cache["k"].shape == (cfg.n_layers, B, T, cfg.n_kv_heads, cfg.hd)
    # prefill cache must continue identically to decode-built cache
    full, _ = registry.family_module(cfg).forward(cfg, params, {"tokens": tokens})
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, -1]), atol=2e-4
    )


def test_serve_loop_batched_requests():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(), remat=False)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32), max_new=4)
        for i in range(5)
    ]
    loop = ServeLoop(cfg, params, batch_size=3, max_len=16)
    out = loop.run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    assert all(len(v) == 4 for v in out.values())
    # determinism: same request set -> same generations
    out2 = ServeLoop(cfg, params, batch_size=3, max_len=16).run(reqs)
    assert out == out2


def test_serve_loop_mixed_lengths_match_per_request_decode():
    """Regression: mixed-length prompts in one chunk used to be left-padded
    and teacher-forced through the pad zeros with a shared position counter,
    so shorter requests decoded conditioned on leading pads.  A batched
    chunk must generate exactly what each request generates decoded alone."""
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(), remat=False)
    key = jax.random.PRNGKey(1)
    params = registry.init_params(cfg, key)
    rng = np.random.default_rng(7)
    lengths = [3, 7, 5, 2]                   # one chunk, four lengths
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(1, cfg.vocab, ln).astype(np.int32),
            max_new=4,
        )
        for i, ln in enumerate(lengths)
    ]
    batched = ServeLoop(cfg, params, batch_size=4, max_len=16).run(reqs)
    # per-request oracle at the same batch shape (idle rows cannot perturb a
    # row: caches and attention are per-row), so token ids must match exactly
    solo_loop = ServeLoop(cfg, params, batch_size=4, max_len=16)
    for r in reqs:
        solo = solo_loop.run([r])
        assert batched[r.rid] == solo[r.rid], r.rid
    # an empty prompt must not crash the chunk: it seeds an implicit BOS 0
    # and still generates max_new tokens alongside real requests
    mixed = [Request(rid=9, prompt=np.array([], np.int32), max_new=3)] + reqs
    out = ServeLoop(cfg, params, batch_size=4, max_len=16).run(mixed)
    assert len(out[9]) == 3
    for r in reqs:
        assert len(out[r.rid]) == r.max_new


# ---------------------------------------------------------------------------
# Consensus tier: session -> group routing vs group -> shard placement
# ---------------------------------------------------------------------------
def _slab_placements(n_groups):
    """Every contiguous-slab placement of G groups onto a shard count that
    tiles G — the placements ``ShardedMultiGroupDataplane`` can produce."""
    return [
        [gid // (n_groups // n_sh) for gid in range(n_groups)]
        for n_sh in range(1, n_groups + 1)
        if n_groups % n_sh == 0
    ]


class _FakeShardedHw:
    """A dataplane stub with an arbitrary group -> shard placement, so the
    routing property can be tested against placements the in-process
    single-device mesh cannot produce."""

    def __init__(self, placement):
        self._placement = list(placement)

    def group_placement(self):
        return list(self._placement)

    def shard_of_group(self, gid):
        return self._placement[gid]


def _service_with_placement(n_groups, placement):
    import types

    ctx = types.SimpleNamespace(
        cfg=PaxosConfig(n_groups=n_groups), hw=_FakeShardedHw(placement)
    )
    return ConsensusService(ctx)


@settings(max_examples=60, deadline=None)
@given(
    sid=st.one_of(
        st.text(max_size=64),
        st.binary(max_size=64),
        st.integers(min_value=-(2**128), max_value=2**128),
    )
)
def test_session_routing_stable_across_placements(sid):
    """Re-placing groups over a different mesh must never move a session
    between groups: the service's session -> group routing is identical
    under every placement (it consults only the session id and G), while
    the *shard* the session lands on is exactly the group's placement."""
    n_groups = 8
    services = [
        _service_with_placement(n_groups, p)
        for p in _slab_placements(n_groups)
    ]
    gids = [svc.group_of(sid) for svc in services]
    assert len(set(gids)) == 1                     # placement-independent
    gid = gids[0]
    assert 0 <= gid < n_groups
    for svc, placement in zip(services, _slab_placements(n_groups), strict=True):
        assert svc.shard_of(sid) == placement[gid]
        assert svc.group_placement() == placement


def test_session_routing_stable_across_placements_deterministic():
    """Hypothesis-free twin of the property above (runs in runtime-only
    environments where hypothesis is absent)."""
    n_groups = 8
    placements = _slab_placements(n_groups)
    services = [_service_with_placement(n_groups, p) for p in placements]
    for sid in [f"sess-{i}" for i in range(64)] + [b"\x00\xff", 12345, 0]:
        gids = {svc.group_of(sid) for svc in services}
        assert len(gids) == 1, sid
        gid = gids.pop()
        for svc, placement in zip(services, placements, strict=True):
            assert svc.shard_of(sid) == placement[gid]


def test_consensus_service_routing_stable_under_sharding():
    """End to end: the same session lands on the same group id whether the
    dataplane is unsharded or sharded, and ``shard_of`` is exactly the
    placement of that group."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=128, batch=16, n_groups=4)
    base = ConsensusService(PaxosContext(cfg))
    sharded = ConsensusService(PaxosContext(cfg, mesh=make_group_mesh()))
    assert base.group_placement() == [0] * 4       # degenerate one shard
    placement = sharded.group_placement()
    assert len(placement) == 4
    for i in range(50):
        s = f"sess-{i}"
        assert base.group_of(s) == sharded.group_of(s)
        assert sharded.shard_of(s) == placement[sharded.group_of(s)]
    # the sharded service still decides and orders per session
    sids = [f"u{i}" for i in range(6)]
    for k in range(2):
        for s in sids:
            sharded.session(s).submit(f"{s}:op{k}".encode())
    sharded.run_until_quiescent()
    for s in sids:
        mine = [
            p for p in sharded.session(s).read()
            if p.startswith(f"{s}:".encode())
        ]
        assert mine == [f"{s}:op{k}".encode() for k in range(2)]


# ---------------------------------------------------------------------------
# Routing epochs: dynamic membership through the serving tier
# ---------------------------------------------------------------------------
def test_delivered_uniform_group_log_g1():
    """The G == 1 special case is gone: ``delivered`` reads the group log on
    every context shape — ungrouped single-group, grouped single-group
    (mesh), and a multi-group service passing through G == 1 transiently."""
    cfg1 = PaxosConfig(n_acceptors=3, n_instances=128, batch=16)
    for ctx in (
        PaxosContext(cfg1, fused=True),                      # ungrouped
        PaxosContext(cfg1, mesh=make_group_mesh()),          # grouped G=1
    ):
        svc = ConsensusService(ctx)
        sess = svc.session("sess")
        for k in range(3):
            sess.submit(f"op{k}".encode())
        svc.run_until_quiescent()
        log = sess.delivered()
        assert [p for _i, p in log] == [f"op{k}".encode() for k in range(3)]
        # the uniform path and the historical delivered_log read agree
        assert log == list(ctx.delivered_log)


def test_routing_epoch_reroutes_and_stitches():
    """Retiring a group re-routes its sessions deterministically over the
    live set at the epoch bump, and ``delivered`` stitches the archived
    pre-retirement log in front of the new group's log.  Creating a group
    bumps the epoch again and restores the capacity routing."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=128, batch=16, n_groups=4)
    svc = ConsensusService(PaxosContext(cfg))
    sids = [f"sess-{i}" for i in range(32)]
    base_route = {s: svc.group_of(s) for s in sids}
    victim = base_route[sids[0]]
    victims = [s for s in sids if base_route[s] == victim]
    for s in sids:
        svc.session(s).submit(f"{s}:op0".encode())
    svc.run_until_quiescent()
    epoch0 = svc.routing_epoch

    svc.retire_group(victim)
    assert svc.routing_epoch == epoch0 + 1
    live = [g for g in range(4) if g != victim]
    for s in sids:
        gid = svc.group_of(s)
        assert gid in live
        if base_route[s] != victim:
            assert gid == base_route[s]      # survivors keep their pin
    # re-route is deterministic: same live set -> same resolution
    assert [svc.group_of(s) for s in sids] == [svc.group_of(s) for s in sids]

    for s in sids:
        svc.session(s).submit(f"{s}:op1".encode())
    svc.run_until_quiescent()
    for s in victims:
        log = svc.session(s).read()
        # pre-retirement log of the dead group stitched before the live log
        assert f"{s}:op0".encode() in log and f"{s}:op1".encode() in log
        assert log.index(f"{s}:op0".encode()) < log.index(f"{s}:op1".encode())

    gid = svc.create_group()
    assert gid == victim                       # lowest free slot
    assert svc.routing_epoch == epoch0 + 2
    # full capacity again: routing returns to the placement-independent hash
    for s in sids:
        assert svc.group_of(s) == base_route[s]
    # a victim session now routes back to the recycled slot; its view still
    # stitches generation 0's archive, the interim group, then the fresh log
    for s in victims:
        svc.session(s).submit(f"{s}:op2".encode())
    svc.run_until_quiescent()
    for s in victims:
        log = svc.session(s).read()
        ops = [
            log.index(f"{s}:op{k}".encode()) for k in range(3)
        ]
        assert ops == sorted(ops), (s, log)


def test_ring_cache_sliding_window_decode():
    """Window-limited cache (ring) must agree with full-window attention for
    positions within the window."""
    cfg = dataclasses.replace(
        get_config("recurrentgemma-2b").reduced(), remat=False
    )
    mod = registry.family_module(cfg)
    key = jax.random.PRNGKey(2)
    params = registry.init_params(cfg, key)
    B, T = 1, 12
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab)
    ref_logits, _ = mod.forward(cfg, params, {"tokens": tokens})
    # cache smaller than T but >= window: ring wrap must still be exact
    c = max(cfg.local_window, 8)
    cache = mod.init_cache(cfg, B, c, jnp.dtype(cfg.dtype))
    outs = []
    for t in range(T):
        logits, cache = mod.decode_step(cfg, params, tokens[:, t : t + 1], cache,
                                        jnp.int32(t))
        outs.append(np.asarray(logits).reshape(B, -1))
    err = np.abs(np.stack(outs, 1) - np.asarray(ref_logits)).max()
    assert err < 5e-3, err


def test_run_until_quiescent_refreshes_service_loads_per_round():
    """The planner's serving-tier load snapshot must be observed per pumped
    round, not once before the loop: delivery callbacks can submit fresh
    traffic mid-quiescence-run, and the final ``plan_report`` must reflect
    the loads as of the last pumped round (DESIGN.md §11 hardening)."""
    cfg = PaxosConfig(
        n_acceptors=3, n_instances=1 << 9, value_words=4, batch=16,
        n_groups=2,
    )
    ctx = PaxosContext(cfg)
    svc = ConsensusService(ctx)
    first, second = "load-a", "load-b"
    fired = []

    def follow_up(payload, size, inst):
        if not fired:
            fired.append(inst)
            for j in range(5):
                svc.session(second).submit(f"follow-{j}".encode())

    ctx.deliver_cb = follow_up
    for i in range(24):
        svc.session(first).submit(f"lead-{i}".encode())
    loads_before = svc.group_loads()
    svc.run_until_quiescent()
    assert fired and ctx.quiescent()
    report = svc.plan_report()
    # freshness: the report carries the loads INCLUDING the mid-run
    # follow-ups, exactly what group_loads() reads now
    assert report["service_loads"] == svc.group_loads()
    assert report["service_loads"] != loads_before
    assert len(svc.session(second).delivered()) == 5
