"""Pallas TPU kernel: flash attention (online-softmax, causal / sliding-window).

Beyond-paper hot-spot: every LM train/prefill cell spends its compute term in
attention + matmuls; the pure-JAX chunked implementation
(``models/layers.flash_attention``) bounds memory but leaves the score tile
materialization to XLA fusion.  This kernel makes the tiling explicit for the
TPU memory hierarchy:

  * grid = (batch x kv_head x group, Sq/bq, Sk/bk), innermost k-dim sequential
  * q/k/v tiles staged HBM->VMEM by BlockSpec; scores live in VREGs
  * the online-softmax state (acc, m, l) persists across the k-grid in VMEM
    scratch, written back once per q tile — one HBM pass over K/V per q tile
  * MXU-aligned tiles (bq, bk multiples of 128; head_dim 64..256)

Masking supports causal and sliding-window (the gemma3 5:1 pattern) via
absolute positions derived from the grid indices.  Validated in interpret
mode against ``kernels/ref.flash_attention`` over shape/dtype/window sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    # scalar prefetch
    scale_ref,      # f32[1]
    window_ref,     # i32[1]  0 = unbounded
    causal_ref,     # i32[1]
    # inputs
    q_ref,          # (bq, d)
    k_ref,          # (bk, d)
    v_ref,          # (bk, d)
    # output
    o_ref,          # (bq, d)
    # scratch
    acc_ref,        # f32 (bq, d)
    m_ref,          # f32 (bq, 1)
    l_ref,          # f32 (bq, 1)
):
    j = pl.program_id(2)
    nk = pl.num_programs(2)
    iq = pl.program_id(1)
    bq, d = q_ref.shape
    bk = k_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]
    k = k_ref[...]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale_ref[0]                                             # (bq, bk)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    mask &= jnp.where(causal_ref[0] > 0, kpos <= qpos, True)
    mask &= jnp.where(window_ref[0] > 0, kpos > qpos - window_ref[0], True)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                           # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                        # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                               # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                             # (bq, d)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, KVH, Sk, D)   H = KVH * G
    v: jax.Array,            # (B, KVH, Sk, D)
    *,
    window: jax.Array | int = 0,
    causal: bool = True,
    softmax_scale: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Returns attention output (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    grid = (b * h, sq // bq, sk // bk)

    def q_map(bh, i, j, *_):
        return (bh, i, 0)

    def kv_map(bh, i, j, *_):
        # collapse the group: head bh -> kv head (bh % h) // g
        return ((bh % h) // g + (bh // h) * kvh, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, i, j, *_: (bh, i, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j, *_: (((bh % (kvh * g)) // g) + (bh // (kvh * g)) * kvh, j, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, i, j, *_: (((bh % (kvh * g)) // g) + (bh // (kvh * g)) * kvh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, i, j, *_: (bh, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
    )

    def kernel(scale_r, win_r, caus_r, q_r, k_r, v_r, o_r, acc, m, l):
        _flash_kernel(
            scale_r, win_r, caus_r,
            q_r.at[0], k_r.at[0], v_r.at[0], o_r.at[0], acc, m, l,
        )

    fn = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * kvh, sk, d)
    vf = v.reshape(b * kvh, sk, d)
    out = fn(
        jnp.full((1,), scale, jnp.float32),
        jnp.asarray(window, jnp.int32).reshape((1,)),
        jnp.full((1,), 1 if causal else 0, jnp.int32),
        qf, kf, vf,
    )
    return out.reshape(b, h, sq, d)
