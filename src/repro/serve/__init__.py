from .engine import Request, ServeLoop, make_prefill_step, make_serve_step  # noqa: F401
