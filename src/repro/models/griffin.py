"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Block pattern (rec, rec, attn).  The recurrent mixer:

    gate = gelu(x W_gate)
    u    = causal_conv1d(x W_x, width 4)
    r_t  = sigmoid(u W_a + b_a);  i_t = sigmoid(u W_i + b_i)
    a_t  = exp(-c * softplus(Lambda) * r_t)            (c = 8)
    h_t  = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
    out  = (gate * h) W_o

Training evaluates the linear recurrence with ``jax.lax.associative_scan``
(parallel in T — the reason this family runs the ``long_500k`` cell is the
O(1)-state decode step plus the bounded attention window).

Layers are grouped into *superblocks* of the pattern length and scanned;
remainder layers (26 mod 3 = 2) run as a trailing mini-scan of rec blocks.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import PSpec

RG_C = 8.0


def _stack(spec: PSpec, n: int) -> PSpec:
    return PSpec((n,) + spec.shape, ("layers",) + spec.axes, spec.init, spec.scale)


def _rec_block_specs(cfg) -> dict[str, Any]:
    d, dr = cfg.d_model, cfg.d_rnn or cfg.d_model
    return {
        "ln1": PSpec((d,), ("embed",), init="zeros"),
        "ln2": PSpec((d,), ("embed",), init="zeros"),
        "w_gate": PSpec((d, dr), ("embed", "rnn")),
        "w_x": PSpec((d, dr), ("embed", "rnn")),
        "conv": PSpec((cfg.conv_width, dr), (None, "rnn"), init="zeros"),
        "w_a": PSpec((dr, dr), ("rnn", "rnn_out")),
        "w_i": PSpec((dr, dr), ("rnn", "rnn_out")),
        "lam": PSpec((dr,), ("rnn",), init="ones"),
        "w_o": PSpec((dr, d), ("rnn", "embed")),
        "mlp": L.mlp_specs(cfg),
    }


def _attn_block_specs(cfg) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": PSpec((d,), ("embed",), init="zeros"),
        "ln2": PSpec((d,), ("embed",), init="zeros"),
        "attn": L.attention_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def _layout(cfg) -> tuple[int, int]:
    """(n_super, n_rem): superblocks of len(pattern) + remainder rec layers."""
    p = len(cfg.block_pattern)
    return cfg.n_layers // p, cfg.n_layers % p


def specs(cfg) -> dict[str, Any]:
    n_super, n_rem = _layout(cfg)
    n_rec_per = cfg.block_pattern.count("rec")
    rec = jax.tree_util.tree_map(
        lambda s: _stack(_stack(s, n_rec_per), n_super),
        _rec_block_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )
    attn = jax.tree_util.tree_map(
        lambda s: _stack(s, n_super),
        _attn_block_specs(cfg),
        is_leaf=lambda x: isinstance(x, PSpec),
    )
    sp: dict[str, Any] = {
        "embed": PSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "super": {"rec": rec, "attn": attn},
        "ln_f": PSpec((cfg.d_model,), ("embed",), init="zeros"),
    }
    if n_rem:
        sp["rem_rec"] = jax.tree_util.tree_map(
            lambda s: _stack(s, n_rem),
            _rec_block_specs(cfg),
            is_leaf=lambda x: isinstance(x, PSpec),
        )
    return sp


# ---------------------------------------------------------------------------
# RG-LRU mixer
# ---------------------------------------------------------------------------
def _causal_conv(u: jax.Array, kernel: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. u: (B,T,C); kernel: (W,C); state: (B,W-1,C)."""
    w = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], w - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)              # (B, T+W-1, C)
    out = sum(
        ext[:, i : i + u.shape[1]] * kernel[i][None, None, :] for i in range(w)
    )
    new_state = ext[:, -(w - 1):] if w > 1 else None
    return out, new_state


def _rg_lru(u: jax.Array, p, h0: jax.Array | None = None):
    """u: (B,T,C) conv output.  Returns (h: (B,T,C), h_T)."""
    r = jax.nn.sigmoid(jnp.einsum("btc,ce->bte", u, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("btc,ce->bte", u, p["w_i"]).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    if h0 is not None:
        # fold the initial state in as a virtual step at t=0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(u.dtype), h[:, -1]


def _rec_mixer(p, x, cfg, conv_state=None, h0=None):
    """x: (B, T, D) normalized input.  Returns (out, (conv_state', h_T))."""
    gate = jax.nn.gelu(jnp.einsum("btd,dr->btr", x, p["w_gate"]))
    u = jnp.einsum("btd,dr->btr", x, p["w_x"])
    u = L.shard(u, ("batch", "act_seq", "rnn"))
    u, conv_state = _causal_conv(u, p["conv"] + _conv_id(p["conv"]), conv_state)
    h, h_last = _rg_lru(u, p, h0)
    out = jnp.einsum("btr,rd->btd", gate * h, p["w_o"])
    return out, (conv_state, h_last)


def _conv_id(kernel: jax.Array) -> jax.Array:
    """Identity-init helper: zero-initialized kernel + delta at the last tap."""
    ident = jnp.zeros_like(kernel)
    return ident.at[-1].set(1.0)


def _rec_block(blk, x, cfg, state=None):
    conv_state = state["conv"] if state is not None else None
    h0 = state["h"] if state is not None else None
    mix, (conv_state, h_last) = _rec_mixer(
        blk, L.rms_norm(x, blk["ln1"], cfg.norm_eps), cfg, conv_state, h0
    )
    x = x + mix
    x = x + L.mlp_fwd(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps))
    return x, {"conv": conv_state, "h": h_last}


def _attn_block(blk, x, cfg, positions=None):
    a, (kk, vv) = L.attention_fwd(
        blk["attn"], L.rms_norm(x, blk["ln1"], cfg.norm_eps), cfg,
        window=cfg.local_window, positions=positions,
    )
    x = x + a
    x = x + L.mlp_fwd(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps))
    return x, (kk, vv)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def forward(cfg, params, batch, *, collect_cache: bool = False):
    tokens = batch["tokens"]
    b, t = tokens.shape
    n_super, n_rem = _layout(cfg)
    n_rec_per = cfg.block_pattern.count("rec")
    h = params["embed"][tokens].astype(params["embed"].dtype)
    h = L.shard(h, ("batch", "act_seq", None))

    def super_body(carry, blk):
        x = carry
        rec_states = []
        for r in range(n_rec_per):
            rp = jax.tree_util.tree_map(lambda a, r=r: a[r], blk["rec"])
            x, st = _rec_block(rp, x, cfg)
            rec_states.append(st)
        x, (kk, vv) = _attn_block(blk["attn"], x, cfg)
        x = L.shard(x, ("batch", "act_seq", None))
        ys = None
        if collect_cache:
            ys = (
                jnp.stack([s["conv"] for s in rec_states]),
                jnp.stack([s["h"] for s in rec_states]),
                kk,
                vv,
            )
        return x, ys

    body_fn = L.checkpoint_fn(super_body, cfg)
    h, sc = jax.lax.scan(body_fn, h, params["super"])

    if n_rem:
        def rem_body(carry, blk):
            x, st = _rec_block(blk, carry, cfg)
            ys = (st["conv"], st["h"]) if collect_cache else None
            return x, ys

        rem_fn = jax.checkpoint(rem_body) if cfg.remat else rem_body
        h, rem_sc = jax.lax.scan(rem_fn, h, params["rem_rec"])

    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["embed"].T.astype(h.dtype))
    logits = L.shard(logits, ("batch", "act_seq", "vocab"))

    cache = None
    if collect_cache:
        conv, hs, kk, vv = sc
        s = kk.shape[2]
        kpos = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None, :], (n_super, b, s)
        )
        cache = {
            "rec_conv": conv, "rec_h": hs,
            "k": kk, "v": vv, "kpos": kpos,
        }
        if n_rem:
            cache["rem_conv"], cache["rem_h"] = rem_sc
    return logits, cache


def prefill(cfg, params, batch):
    return forward(cfg, params, batch, collect_cache=True)


# ---------------------------------------------------------------------------
# Cache / decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype)
        if s.dtype != jnp.int32
        else jnp.full(s.shape, -1, jnp.int32),
        cache_specs(cfg, batch, max_len, dtype),
    )


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_super, n_rem = _layout(cfg)
    n_rec_per = cfg.block_pattern.count("rec")
    dr = cfg.d_rnn or cfg.d_model
    w = cfg.conv_width
    kv, hd = cfg.n_kv_heads, cfg.hd
    c = min(max_len, cfg.local_window) if cfg.local_window else max_len
    sp = {
        "rec_conv": jax.ShapeDtypeStruct((n_super, n_rec_per, batch, w - 1, dr), dtype),
        "rec_h": jax.ShapeDtypeStruct((n_super, n_rec_per, batch, dr), jnp.float32),
        "k": jax.ShapeDtypeStruct((n_super, batch, c, kv, hd), dtype),
        "v": jax.ShapeDtypeStruct((n_super, batch, c, kv, hd), dtype),
        "kpos": jax.ShapeDtypeStruct((n_super, batch, c), jnp.int32),
    }
    if n_rem:
        sp["rem_conv"] = jax.ShapeDtypeStruct((n_rem, batch, w - 1, dr), dtype)
        sp["rem_h"] = jax.ShapeDtypeStruct((n_rem, batch, dr), jnp.float32)
    return sp


CACHE_AXES = {
    "rec_conv": ("layers", None, "batch", None, "rnn"),
    "rec_h": ("layers", None, "batch", "rnn"),
    "k": ("layers", "batch", "cache_seq", "kv_heads", None),
    "v": ("layers", "batch", "cache_seq", "kv_heads", None),
    "kpos": ("layers", "batch", "cache_seq"),
    "rem_conv": ("layers", "batch", None, "rnn"),
    "rem_h": ("layers", "batch", "rnn"),
}


def decode_step(cfg, params, tokens, cache, pos):
    b = tokens.shape[0]
    n_super, n_rem = _layout(cfg)
    n_rec_per = cfg.block_pattern.count("rec")
    kvh, g, hd = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd
    h = params["embed"][tokens].astype(params["embed"].dtype)   # (B, 1, D)
    c = cache["k"].shape[2]
    slot = pos % c

    def rec_step(blk, x, conv_state, h0):
        xn = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
        mix, (conv_state, h_last) = _rec_mixer(
            blk, xn, cfg, conv_state, h0
        )
        x = x + mix
        x = x + L.mlp_fwd(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps))
        return x, conv_state, h_last

    def super_body(carry, xs):
        x = carry
        blk, conv, hs, kc, vc, kp = xs
        new_conv, new_h = [], []
        for r in range(n_rec_per):
            rp = jax.tree_util.tree_map(lambda a, r=r: a[r], blk["rec"])
            x, cs, hl = rec_step(rp, x, conv[r], hs[r])
            new_conv.append(cs)
            new_h.append(hl)
        # local attention with ring cache
        ab = blk["attn"]
        xn = L.rms_norm(x, ab["ln1"], cfg.norm_eps)
        p = ab["attn"]
        q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
        kk = jnp.einsum("bsd,dhk->bshk", xn, p["wk"])
        vv = jnp.einsum("bsd,dhk->bshk", xn, p["wv"])
        posv = jnp.full((1,), pos, jnp.int32)
        q = L.rope(q, posv, cfg.rope_theta)
        kk = L.rope(kk, posv, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kk.astype(kc.dtype), slot, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vv.astype(vc.dtype), slot, 1)
        kp = jax.lax.dynamic_update_slice_in_dim(
            kp, jnp.full((b, 1), pos, jnp.int32), slot, 1
        )
        out = L.decode_attention(
            q.reshape(b, 1, kvh, g, hd), kc, vc, kp, pos, window=cfg.local_window
        )
        out = jnp.einsum("bshk,hkd->bsd", out.reshape(b, 1, cfg.n_heads, hd), p["wo"])
        x = x + out
        x = x + L.mlp_fwd(ab["mlp"], L.rms_norm(x, ab["ln2"], cfg.norm_eps))
        return x, (jnp.stack(new_conv), jnp.stack(new_h), kc, vc, kp)

    h, (conv, hs, kc, vc, kp) = jax.lax.scan(
        super_body,
        h,
        (
            params["super"],
            cache["rec_conv"],
            cache["rec_h"],
            cache["k"],
            cache["v"],
            cache["kpos"],
        ),
    )
    new_cache = dict(cache)
    new_cache.update({"rec_conv": conv, "rec_h": hs, "k": kc, "v": vc, "kpos": kp})

    if n_rem:
        def rem_body(carry, xs):
            blk, cs, h0 = xs
            x, cs2, hl = rec_step(blk, carry, cs, h0)
            return x, (cs2, hl)

        h, (rconv, rh) = jax.lax.scan(
            rem_body, h, (params["rem_rec"], cache["rem_conv"], cache["rem_h"])
        )
        new_cache["rem_conv"], new_cache["rem_h"] = rconv, rh

    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", h, params["embed"].T.astype(h.dtype))
    return logits, new_cache
