"""The contract checker checks the checker: green on the live repo, red on
known-bad fixtures (DESIGN.md §12).

Each fixture is a minimal source snippet seeded with exactly one contract
break — a misaligned alias map, a missing donation, a use-after-donate, an
oracle signature drift, a scalar-prefetch reorder, an unguarded mirror
write — and the test asserts the checker reports the expected rule id at
the fixture's defect, not merely *some* failure.
"""
from __future__ import annotations

import textwrap

import pytest

from repro.analysis import contracts
from repro.analysis.contracts import (
    CANONICAL_PREFETCH_ORDER,
    DELEGATING_ENTRY_POINTS,
    EXPECTED_PREFETCH,
    ContractEntry,
    check_dispatch_source,
    check_kernel_source,
    check_mirror_source,
    check_repo,
    pallas_sites,
    signature_violations,
)


def _rules(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# Live repo must be clean — the checker is a blocking CI step
# ---------------------------------------------------------------------------
def test_repo_is_contract_clean():
    violations = [v for v in check_repo() if not v.advisory]
    assert violations == [], "\n".join(str(v) for v in violations)


def test_wirepath_pallas_site_coverage_is_exhaustive():
    """The alias/prefetch audit provably covers every pallas_call in
    kernels/wirepath.py: each discovered site is audited with a resolved
    prefetch count and a non-empty alias map, and together with the
    delegating host entries the contract spans all wire-path entry
    points (wirepath_round, multigroup_, cohort_, shard_slab_,
    persistent_wirepath_round)."""
    sites = [
        s for s in pallas_sites() if s.file.endswith("wirepath.py")
    ]
    assert len(sites) >= 3
    entries = {s.entry for s in sites}
    assert entries == {
        "cohort_wirepath_round",
        "persistent_wirepath_round",
        "packed_shard_round",
        "acceptor_vote_all_window",
    }
    for s in sites:
        assert s.num_scalar_prefetch is not None, s
        assert s.aliases, f"{s.entry}: no input_output_aliases audited"
        assert s.kernel is not None, s
    covered = set(EXPECTED_PREFETCH) | set(DELEGATING_ENTRY_POINTS)
    assert covered >= {
        "wirepath_round",
        "multigroup_wirepath_round",
        "cohort_wirepath_round",
        "shard_slab_round",
        "persistent_wirepath_round",
        "packed_shard_round",
    }


def test_all_kernel_pallas_sites_are_audited():
    # every kernels/*.py pallas_call shows up in the exhaustiveness surface
    sites = pallas_sites()
    files = {s.file.rsplit("/", 1)[-1] for s in sites}
    assert {
        "acceptor.py", "coordinator.py", "learner.py", "digest.py",
        "wirepath.py", "flash_attention.py",
    } <= files


def test_canonical_order_is_self_consistent():
    for name, classes in EXPECTED_PREFETCH.items():
        assert contracts._is_subsequence(
            classes, CANONICAL_PREFETCH_ORDER
        ), name


# ---------------------------------------------------------------------------
# Red fixtures: alias map defects
# ---------------------------------------------------------------------------
_ALIAS_FIXTURE = """
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cohort_wirepath_kernel(gsel_ref, ni_ref, crnd_ref, q_ref, alive_ref,
                            lim_ref, *rest):
    pass


def cohort_wirepath_round(gs, ni, cr, q, al, lim, st, out_shape, idx):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, 8), idx)],
        out_specs=[pl.BlockSpec((1, 8), idx)],
    )
    fn = pl.pallas_call(
        _cohort_wirepath_kernel,
        grid_spec=grid_spec,
        out_shape=[out_shape],
        input_output_aliases={ALIASES},
    )
    return fn(DISPATCH)
"""


def _alias_fixture(aliases: str, dispatch: str = "gs, ni, cr, q, al, lim, st"):
    src = _ALIAS_FIXTURE.replace("{ALIASES}", aliases).replace(
        "DISPATCH", dispatch
    )
    return check_kernel_source(textwrap.dedent(src), "fixture.py")


def test_fixture_clean_alias_map_passes():
    violations, sites = _alias_fixture("{6: 0}")
    assert not violations
    assert len(sites) == 1
    assert sites[0].num_scalar_prefetch == 6
    assert sites[0].aliases == ((6, 0),)


def test_fixture_alias_inside_prefetch_window():
    # the off-by-one this checker exists for: a prefetch scalar grows the
    # vector but the alias map still points at the old input index
    violations, _ = _alias_fixture("{5: 0}")
    assert "ALIAS-OFFSET" in _rules(violations)


def test_fixture_alias_out_of_range():
    violations, _ = _alias_fixture("{7: 0}")
    assert "ALIAS-OFFSET" in _rules(violations)


def test_fixture_alias_not_onto_leading_outputs():
    violations, _ = _alias_fixture("{6: 1}")
    assert "ALIAS-BIJECTION" in _rules(violations)


def test_fixture_alias_spec_shape_mismatch():
    src = _ALIAS_FIXTURE.replace(
        "out_specs=[pl.BlockSpec((1, 8), idx)]",
        "out_specs=[pl.BlockSpec((2, 8), idx)]",
    ).replace("{ALIASES}", "{6: 0}").replace(
        "DISPATCH", "gs, ni, cr, q, al, lim, st"
    )
    violations, _ = check_kernel_source(textwrap.dedent(src), "fixture.py")
    assert "ALIAS-OFFSET" in _rules(violations)


def test_fixture_dispatch_arity_drift():
    # one operand short: a state input was dropped from the dispatch
    violations, _ = _alias_fixture("{6: 0}", dispatch="gs, ni, cr, q, al, lim")
    assert "ALIAS-ARITY" in _rules(violations)


# ---------------------------------------------------------------------------
# Red fixture: scalar-prefetch reorder
# ---------------------------------------------------------------------------
def test_fixture_prefetch_reorder():
    # watermark and round swapped at the dispatch site
    violations, _ = _alias_fixture(
        "{6: 0}", dispatch="gs, cr, ni, q, al, lim, st"
    )
    assert "PREFETCH-ORDER" in _rules(violations)


def test_fixture_prefetch_kernel_param_reorder():
    src = _ALIAS_FIXTURE.replace(
        "def _cohort_wirepath_kernel(gsel_ref, ni_ref, crnd_ref, q_ref, "
        "alive_ref,\n                            lim_ref, *rest):",
        "def _cohort_wirepath_kernel(gsel_ref, crnd_ref, ni_ref, q_ref, "
        "alive_ref,\n                            lim_ref, *rest):",
    ).replace("{ALIASES}", "{6: 0}").replace(
        "DISPATCH", "gs, ni, cr, q, al, lim, st"
    )
    violations, _ = check_kernel_source(textwrap.dedent(src), "fixture.py")
    assert "PREFETCH-ORDER" in _rules(violations)


def test_fixture_delegation_scalar_reorder():
    src = textwrap.dedent(
        """
        def wirepath_round(ni, cr, q, al, lim, values):
            return multigroup_wirepath_round(cr, ni, q, al, values, lim)
        """
    )
    violations, _ = check_kernel_source(src, "fixture.py")
    assert "PREFETCH-ORDER" in _rules(violations)


# ---------------------------------------------------------------------------
# Red fixtures: donation audit
# ---------------------------------------------------------------------------
def test_fixture_missing_donation():
    src = textwrap.dedent(
        """
        import jax
        from repro.kernels import ops as kops


        class Plane:
            def __init__(self):
                self._fused = jax.jit(kops.fused_round)
        """
    )
    violations = check_dispatch_source(src, "fixture.py")
    assert "DONATE-MISSING" in _rules(violations)


def test_fixture_donating_non_state_operand():
    src = textwrap.dedent(
        """
        import jax
        from repro.kernels import ops as kops


        class Plane:
            def __init__(self):
                self._fused = jax.jit(kops.fused_round, donate_argnums=(3,))
        """
    )
    violations = check_dispatch_source(src, "fixture.py")
    assert "DONATE-STATE" in _rules(violations)


def test_fixture_use_after_donate():
    src = textwrap.dedent(
        """
        import jax
        from repro.kernels import ops as kops


        class Plane:
            def __init__(self):
                self._fused = jax.jit(
                    kops.fused_round, donate_argnums=(1, 2)
                )

            def step(self, values, active, alive, q):
                out = self._fused(
                    self.cstate, self.stack, self.lstate,
                    values, active, alive, q,
                )
                stale = self.stack.rnd
                return out, stale
        """
    )
    violations = check_dispatch_source(src, "fixture.py")
    assert "DONATE-USE" in _rules(violations)


def test_fixture_donate_then_reassign_is_clean():
    src = textwrap.dedent(
        """
        import jax
        from repro.kernels import ops as kops


        class Plane:
            def __init__(self):
                self._fused = jax.jit(
                    kops.fused_round, donate_argnums=(1, 2)
                )

            def step(self, values, active, alive, q):
                c, self.stack, self.lstate, f, i, w, v = self._fused(
                    self.cstate, self.stack, self.lstate,
                    values, active, alive, q,
                )
                return f, i, self.stack.rnd
        """
    )
    violations = check_dispatch_source(src, "fixture.py")
    assert "DONATE-USE" not in _rules(violations)


# ---------------------------------------------------------------------------
# Red fixture: oracle signature drift
# ---------------------------------------------------------------------------
def _entry(fn, oracle, **kw):
    kw.setdefault("state_args", ())
    kw.setdefault("extra", ())
    kw.setdefault("oracle_extra", ())
    kw.setdefault("strict_order", True)
    kw.setdefault("reason", None)
    return ContractEntry(name=fn.__name__, fn=fn, oracle=oracle, **kw)


def test_fixture_oracle_default_drift():
    def wrapper(state, msgs, enabled=None, limit=None):
        pass

    def oracle(state, msgs, enabled=None, limit=0):
        pass

    violations = signature_violations(_entry(wrapper, oracle))
    assert _rules(violations) == {"ORACLE-PARITY"}
    assert any("limit" in v.message for v in violations)


def test_fixture_oracle_arity_drift():
    def wrapper(state, msgs, enabled=None):
        pass

    def oracle(state, msgs):
        pass

    violations = signature_violations(_entry(wrapper, oracle))
    assert "ORACLE-PARITY" in _rules(violations)


def test_fixture_oracle_name_drift():
    def wrapper(state, messages):
        pass

    def oracle(state, msgs):
        pass

    violations = signature_violations(_entry(wrapper, oracle))
    assert "ORACLE-PARITY" in _rules(violations)


def test_fixture_matching_signatures_pass():
    def wrapper(state, msgs, enabled=None, limit=None, group_block=1):
        pass

    def oracle(state, msgs, enabled=None, limit=None):
        pass

    violations = signature_violations(
        _entry(wrapper, oracle, extra=("group_block",))
    )
    assert violations == []


def test_fixture_unlinked_without_reason():
    def wrapper(state):
        pass

    violations = signature_violations(_entry(wrapper, None))
    assert "ORACLE-PARITY" in _rules(violations)


# ---------------------------------------------------------------------------
# Red fixtures: kernel purity + mirror guard
# ---------------------------------------------------------------------------
def test_fixture_kernel_python_branch_on_ref():
    src = textwrap.dedent(
        """
        def _bad_kernel(x_ref, o_ref):
            if x_ref[0] > 0:
                o_ref[0] = 1
        """
    )
    violations, _ = check_kernel_source(src, "fixture.py")
    assert "KERNEL-PURITY" in _rules(violations)


def test_fixture_kernel_static_metadata_branch_is_clean():
    src = textwrap.dedent(
        """
        def _ok_kernel(x_ref, o_ref):
            if x_ref.dtype == "int32":
                o_ref[0] = x_ref[0]
        """
    )
    violations, _ = check_kernel_source(src, "fixture.py")
    assert "KERNEL-PURITY" not in _rules(violations)


def test_fixture_kernel_host_idiom_is_advisory():
    src = textwrap.dedent(
        """
        import numpy as np


        def _chatty_kernel(x_ref, o_ref):
            o_ref[0] = np.sum(x_ref[0])
        """
    )
    violations, _ = check_kernel_source(src, "fixture.py")
    host = [v for v in violations if v.rule == "KERNEL-HOST"]
    assert host and all(v.advisory for v in host)


def test_fixture_unguarded_mirror_write():
    src = textwrap.dedent(
        """
        class Plane:
            def step(self):
                self.next_inst_host[0] = 5
        """
    )
    violations = check_mirror_source(src, "fixture.py")
    assert "MIRROR-GUARD" in _rules(violations)


def test_fixture_guarded_mirror_write_is_clean():
    src = textwrap.dedent(
        """
        from repro.analysis.contracts import mirror_guard


        class Plane:
            def __init__(self):
                self.next_inst_host = [0]

            @mirror_guard
            def step(self):
                self.next_inst_host[0] = 5
        """
    )
    violations = check_mirror_source(src, "fixture.py")
    assert violations == []


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------
def test_cli_exits_zero_on_live_repo(capsys):
    assert contracts.main([]) == 0
    out = capsys.readouterr().out
    assert "contracts OK" in out


@pytest.mark.parametrize("rule", sorted(contracts.RULES))
def test_rule_catalogue_has_descriptions(rule):
    assert contracts.RULES[rule]
