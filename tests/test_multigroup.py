"""Multi-group consensus as a service: context-level parity and routing.

The contract under test (DESIGN.md §5): a ``PaxosContext`` over G
device-resident groups behaves exactly like G *independent* single-group
contexts — same per-group delivery logs, same device register files — while
actually advancing all groups through ONE fused dispatch per burst.  That
must hold through per-group acceptor death and a coordinator failover in one
group (which may not perturb any other group), on both the jnp oracle path
and the Pallas kernel path.  ``ConsensusService`` adds the serving tier:
deterministic session -> group hash routing.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core import MultiGroupDataplane, PaxosConfig, PaxosContext
from repro.serve.engine import ConsensusService, session_group

G = 4
CFG_MG = PaxosConfig(n_acceptors=3, n_instances=512, batch=16, n_groups=G)
CFG_1 = PaxosConfig(n_acceptors=3, n_instances=512, batch=16)


def _group_state(hw, gid: int):
    """Host copies of one group's acceptor + learner device state."""
    src = (hw.stack, hw.lstate)
    if isinstance(hw, MultiGroupDataplane):
        src = jax.tree_util.tree_map(lambda x: x[gid], src)
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(src)]


def _run_schedule(ctx, groups, waves, use_groups: bool):
    """Submit ``waves`` rounds of one payload per group, pumping each wave."""
    for w in range(waves):
        for gid in groups:
            payload = f"w{w}g{gid}".encode()
            if use_groups:
                ctx.submit(payload, group=gid)
            else:
                ctx.submit(payload)
        ctx.run_until_quiescent()


@pytest.mark.parametrize("use_kernels", [False, True])
def test_groups_match_independent_contexts(use_kernels):
    """G fused groups == G independent single-group contexts, bit for bit,
    including a dead acceptor in one group."""
    mg = PaxosContext(CFG_MG, use_kernels=use_kernels)
    singles = [
        PaxosContext(CFG_1, use_kernels=use_kernels, fused=True)
        for _ in range(G)
    ]
    mg.hw.kill_acceptor(2, 1)       # group 2 loses acceptor 1...
    singles[2].hw.kill_acceptor(1)  # ...and so does its independent twin

    _run_schedule(mg, range(G), waves=3, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=3, use_groups=False)

    for gid, ctx in enumerate(singles):
        assert mg.group_log[gid] == ctx.delivered_log, gid
        for a, b in zip(_group_state(mg.hw, gid), _group_state(ctx.hw, gid)):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_group_failover_does_not_perturb_others(use_kernels):
    """Coordinator failover in one group: that group fails over to software
    sequencing and back, while every other group's delivery log and device
    registers stay bit-identical to independent contexts that never saw a
    failover."""
    victim = 1
    mg = PaxosContext(CFG_MG, use_kernels=use_kernels)
    singles = [
        PaxosContext(CFG_1, use_kernels=use_kernels, fused=True)
        for _ in range(G)
    ]

    _run_schedule(mg, range(G), waves=2, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=2, use_groups=False)

    mg.fail_coordinator(group=victim)
    singles[victim].fail_coordinator()

    _run_schedule(mg, range(G), waves=2, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=2, use_groups=False)

    mg.restore_hardware_coordinator(group=victim)
    singles[victim].restore_hardware_coordinator()

    _run_schedule(mg, range(G), waves=2, use_groups=True)
    for gid, ctx in enumerate(singles):
        _run_schedule(ctx, [gid], waves=2, use_groups=False)

    for gid, ctx in enumerate(singles):
        assert mg.group_log[gid] == ctx.delivered_log, gid
        for a, b in zip(_group_state(mg.hw, gid), _group_state(ctx.hw, gid)):
            np.testing.assert_array_equal(a, b)
    # every submission in every group was delivered exactly once
    assert all(len(log) == 6 for log in mg.group_log)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_idle_group_unperturbed_under_skewed_load(use_kernels):
    """All traffic to group 0, enough to lap its ring: the idle group 1 must
    burn no ring instances, accrete no learned entries, and keep device state
    bit-identical to a deployment that was never pumped — then still serve
    traffic when it finally arrives."""
    cfg = PaxosConfig(n_acceptors=3, n_instances=64, batch=16, n_groups=2)
    ctx = PaxosContext(cfg, use_kernels=use_kernels)
    ref = PaxosContext(
        PaxosConfig(n_acceptors=3, n_instances=64, batch=16),
        use_kernels=use_kernels,
        fused=True,
    )
    for w in range(12):  # 12*16 = 192 instances: laps the 64-slot ring 3x
        for k in range(16):
            ctx.submit(f"w{w}k{k}".encode(), group=0)
        ctx.run_until_quiescent()
    assert len(ctx.group_log[0]) == 192 and len(ctx.group_log[1]) == 0
    assert ctx.hw.next_inst_host[1] == 0
    assert not ctx.learned_g[1]
    for a, b in zip(_group_state(ctx.hw, 1), _group_state(ref.hw, 0)):
        np.testing.assert_array_equal(a, b)
    ctx.submit(b"late", group=1)
    ctx.run_until_quiescent()
    assert [p for _i, p in ctx.group_log[1]] == [b"late"]


def test_group_recover_targets_one_group():
    """paxos_recover on a multi-group context fills the gap in the addressed
    group with a no-op without disturbing the other groups' rings."""
    mg = PaxosContext(CFG_MG)
    _run_schedule(mg, range(G), waves=2, use_groups=True)
    before = [_group_state(mg.hw, gid) for gid in range(G)]

    # instance beyond the watermark of group 3: phase 1 finds nothing voted,
    # a no-op is decided into it (and discarded by the application layer)
    mg.recover(100, group=3)
    mg.pump()

    after = [_group_state(mg.hw, gid) for gid in range(G)]
    for gid in range(G):
        if gid == 3:
            continue
        for a, b in zip(before[gid], after[gid]):
            np.testing.assert_array_equal(a, b)
    # group 3's ring now holds a vote for instance 100
    assert np.asarray(mg.hw.stack.vrnd)[3, :, 100 % CFG_MG.n_instances].max() >= 0
    # the no-op was never surfaced to the application
    assert all(len(log) == 2 for log in mg.group_log)


def test_session_routing_deterministic_and_balanced():
    n_groups = 8
    ids = [f"session-{i}" for i in range(400)]
    groups = [session_group(s, n_groups) for s in ids]
    # deterministic
    assert groups == [session_group(s, n_groups) for s in ids]
    # every group sees traffic, no group dominates
    counts = np.bincount(groups, minlength=n_groups)
    assert (counts > 0).all()
    assert counts.max() < len(ids) // 2
    # int and bytes session ids route too
    assert 0 <= session_group(12345, n_groups) < n_groups
    assert 0 <= session_group(b"\x00\xff", n_groups) < n_groups


def test_consensus_service_routes_and_delivers():
    svc = ConsensusService(PaxosContext(CFG_MG))
    sessions = [f"user-{i}" for i in range(12)]
    routed = {}
    for k in range(3):
        for s in sessions:
            gid, _seq = svc.submit(s, f"{s}:op{k}".encode())
            assert routed.setdefault(s, gid) == gid  # stable affinity
    svc.run_until_quiescent()

    assert svc.ctx.stats["delivered"] == 3 * len(sessions)
    assert sum(svc.group_loads()) == 3 * len(sessions)
    for s in sessions:
        log = svc.delivered(s)
        mine = [p for _inst, p in log if p.startswith(f"{s}:".encode())]
        # the session observes its own ops in submission order, totally
        # ordered within its group
        assert mine == [f"{s}:op{k}".encode() for k in range(3)]
    # group logs partition the traffic
    assert sum(len(log) for log in svc.ctx.group_log) == 3 * len(sessions)
