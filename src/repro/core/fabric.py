"""In-fabric consensus: the whole Paxos Phase-2 round inside one shard_map.

This is the TPU analogue of the paper's central move — consensus logic
executing *on the interconnect* rather than in host software.  Acceptors are
shards of a device-mesh axis; a consensus round is one compiled collective
program:

    1. proposers (one per shard) contribute their local proposal batch,
    2. all_gather over the acceptor axis  == proposer->coordinator traffic,
    3. deterministic replicated sequencer == the coordinator,
    4. local acceptor vote (Pallas kernel / jnp fast path),
    5. psum of agree-bits over the axis  == acceptor->learner vote traffic,
    6. local quorum decision — every shard deterministically learns the
       decided values (every device is a learner).

No host round-trip happens anywhere in the round: "consensus messages travel
fewer hops", at ICI speed.  Acceptor failure is modelled by an ``alive`` mask
(a dead acceptor's votes never count); the round still decides while a quorum
(f+1 of 2f+1) lives.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import batched
from .types import MSG_P2B, AcceptorState, CoordinatorState

NO_ROUND = jnp.int32(-1)


def _shard_map(
    f: Callable[..., Any],
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
) -> Callable[..., Any]:
    """``shard_map`` across jax versions: the top-level export with
    ``check_vma`` (jax >= 0.6) or the experimental one with ``check_rep``
    (older releases, including this container's).  Replication checking is
    disabled either way — the replicated outputs here are replicated by
    construction (psum / identical sequencing), which the checker cannot
    always prove."""
    try:
        from jax import shard_map as sm

        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except (ImportError, TypeError):
        from jax.experimental.shard_map import shard_map as sm

        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def consensus_round(
    astate: AcceptorState,
    cstate: CoordinatorState,
    values: jax.Array,        # int32[b_local, V]   local proposals (sharded)
    active: jax.Array,        # bool [b_local]
    alive: jax.Array,         # bool []             this acceptor is alive
    *,
    axis: str,
    quorum: int,
) -> tuple[AcceptorState, CoordinatorState, jax.Array, jax.Array, jax.Array]:
    """One in-fabric consensus round (runs *inside* shard_map).

    Returns (astate', cstate', decided_mask[B], inst[B], value[B, V]) with
    B = b_local * n_acceptors (the gathered global batch), identical on every
    shard.
    """
    my_idx = jax.lax.axis_index(axis)

    # (2) proposers -> coordinator: gather proposals from every shard.
    all_values = jax.lax.all_gather(values, axis, tiled=True)    # [B, V]
    all_active = jax.lax.all_gather(active, axis, tiled=True)    # [B]

    # (3) replicated deterministic sequencer (the coordinator).
    cstate, p2a = batched.coordinator_sequence(cstate, all_values, all_active)

    # (4) local acceptor vote.
    astate, votes = batched.acceptor_phase2(astate, p2a, aid=my_idx)

    # (5)+(6) quorum by psum of agree bits.  A dead acceptor contributes 0
    # and must also not mutate its durable state (it is "off the fabric").
    voted = (votes.msgtype == MSG_P2B) & alive                    # [B]
    count = jax.lax.psum(voted.astype(jnp.int32), axis)           # [B]
    decided = count >= quorum

    # Decided value: under a single live coordinator every accept in this
    # round carries the P2A value itself.
    return astate, cstate, decided, p2a.inst, p2a.value


def make_fabric_consensus(
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    quorum: int | None = None,
    n_instances: int = 4096,
    value_words: int = 16,
) -> tuple[
    Callable[[], tuple[AcceptorState, CoordinatorState]],
    Callable[..., Any],
]:
    """Build a jitted in-fabric consensus step over ``mesh[axis]``.

    Returns ``(init_fn, step_fn)``:
      * ``init_fn()`` -> (astate_sharded, cstate)
      * ``step_fn(astate, cstate, values, active, alive)`` ->
        (astate', cstate', decided[B], inst[B], value[B,V])
    Acceptor state carries a leading per-acceptor shard dim; proposals are
    sharded over the same axis.
    """
    n_acc = mesh.shape[axis]
    q = quorum if quorum is not None else n_acc // 2 + 1

    shard = jax.sharding.NamedSharding(mesh, P(axis))
    replicated = jax.sharding.NamedSharding(mesh, P())

    def init_fn() -> tuple[AcceptorState, CoordinatorState]:
        astate = AcceptorState(
            rnd=jnp.zeros((n_acc, n_instances), jnp.int32),
            vrnd=jnp.full((n_acc, n_instances), NO_ROUND, jnp.int32),
            value=jnp.zeros((n_acc, n_instances, value_words), jnp.int32),
        )
        astate = jax.device_put(astate, shard)
        cstate = jax.device_put(CoordinatorState.init(), replicated)
        return astate, cstate

    def local_round(
        astate: AcceptorState,
        cstate: CoordinatorState,
        values: jax.Array,
        active: jax.Array,
        alive: jax.Array,
    ) -> tuple[
        AcceptorState, CoordinatorState, jax.Array, jax.Array, jax.Array
    ]:
        # strip the per-shard leading dim inside shard_map
        a = AcceptorState(astate.rnd[0], astate.vrnd[0], astate.value[0])
        a, cstate, decided, inst, value = consensus_round(
            a, cstate, values, active, alive[0], axis=axis, quorum=q
        )
        a = AcceptorState(a.rnd[None], a.vrnd[None], a.value[None])
        return a, cstate, decided, inst, value

    fn = _shard_map(
        local_round,
        mesh=mesh,
        # pytree containers double as spec pytrees here (the shard_map
        # convention), hence the arg-type ignores on Array-typed fields
        in_specs=(
            AcceptorState(P(axis), P(axis), P(axis)),  # type: ignore[arg-type]
            CoordinatorState(P(), P()),  # type: ignore[arg-type]
            P(axis, None),
            P(axis),
            P(axis),
        ),
        out_specs=(
            AcceptorState(P(axis), P(axis), P(axis)),  # type: ignore[arg-type]
            CoordinatorState(P(), P()),  # type: ignore[arg-type]
            P(),   # decided: replicated (every shard learns identically)
            P(),
            P(),
        ),
    )
    return init_fn, jax.jit(fn)


# ---------------------------------------------------------------------------
# Groups-sharded multi-group wire path: G groups partitioned over a mesh axis
# ---------------------------------------------------------------------------
def make_sharded_multigroup_round(
    mesh: jax.sharding.Mesh,
    *,
    n_groups: int,
    quorum: int,
    axis: str = "groups",
    use_kernels: bool = False,
    group_block: int = 1,
) -> Callable[..., Any]:
    """Build the groups-sharded fused dispatch (DESIGN.md §6): ONE compiled
    program advances all G groups one Phase-2 round, with the ``(G, A, N)``
    acceptor slabs and ``(G, N)`` learner slabs partitioned over
    ``mesh[axis]`` so G scales with device count instead of one chip's
    VMEM/HBM.

    Per-group scalar metadata — the ``(G,)`` watermark/round vectors, the
    ``(G, A)`` alive mask and the ``(G,)`` membership ``enabled`` mask —
    enters *replicated*: it is tiny, host-mutated control state, and each
    shard selects its own window by group offset
    (``kernels.wirepath.shard_slab_round``).  The ring slabs stay
    shard-local and nothing crosses the mesh axis during a round, because
    groups share no state; the quorum reduction runs down the acceptor axis
    *inside* each shard's slab.  Disabled (frozen/vacant/idle) groups ride
    along inert — see the enabled-mask path in ``kernels.wirepath``
    (DESIGN.md §7): membership events therefore never move slab state.

    Returns ``step(next_inst[G], crnd[G], enabled[G], alive[G, A], stack,
    lstate, values[G, B, V], active[G, B]) -> (stack', lstate',
    fresh[G, B], inst[G, B], win[G, B], value[G, B, V])`` with the state
    arguments donated (device-resident in place across rounds).

    Under the cohort dispatch planner (DESIGN.md §8) the same step serves
    every tier of a round plan: ``B`` is the tier's right-sized burst (the
    step retraces per distinct pow2 burst — a bounded vocabulary), the
    ``enabled`` mask is the tier's membership, and ``group_block`` is the
    per-cohort fold width (``core.plan.fold_width_full`` against the
    per-shard slab).  The group axis is *not* compacted here — shard_map
    needs uniform per-shard shapes, and a cohort may concentrate on one
    shard — so non-member slabs ride each tier inert; the unsharded
    dataplane additionally compacts via
    ``kernels.wirepath.cohort_wirepath_round``.
    """
    if axis not in mesh.shape:
        raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
    n_sh = mesh.shape[axis]
    if n_groups % n_sh:
        raise ValueError(
            f"n_groups={n_groups} must be divisible by the {axis!r} mesh "
            f"axis size {n_sh}"
        )
    gl = n_groups // n_sh
    if group_block > 1 and gl % group_block:
        raise ValueError(
            f"group_block={group_block} must divide the per-shard slab {gl}"
        )
    offsets = jnp.arange(n_sh, dtype=jnp.int32) * gl
    q = quorum

    def local(
        ni: jax.Array,
        cr: jax.Array,
        en: jax.Array,
        alive: jax.Array,
        lim: jax.Array,
        off: jax.Array,
        stack: AcceptorState,
        lstate: batched.LearnerState,
        values: jax.Array,
        active: jax.Array,
    ) -> tuple[
        AcceptorState, batched.LearnerState, jax.Array, jax.Array,
        jax.Array, jax.Array,
    ]:
        # off is this shard's (1,)-slice of the offset vector: the global id
        # of the slab's first group.  Scalar vectors stay global (including
        # the replicated reclaim-limit vector, DESIGN.md §9); slabs are local.
        ni_l = jax.lax.dynamic_slice(ni, (off[0],), (gl,))
        if use_kernels:
            from repro.kernels import ops as kops
            from repro.kernels import wirepath as kwp

            del active  # sequenced fillers vote like P2As (DESIGN.md §3)
            outs = kwp.shard_slab_round(
                off[0], ni, cr, jnp.int32(q), alive,
                stack.rnd, stack.vrnd, stack.value,
                lstate.delivered, lstate.inst, lstate.value, values, en, lim,
                group_block=group_block, interpret=kops.INTERPRET,
            )
            stack = AcceptorState(*outs[:3])
            lstate = batched.LearnerState(*outs[3:6])
            fresh, win, value = outs[6] != 0, outs[7], outs[8]
        else:
            cr_l = jax.lax.dynamic_slice(cr, (off[0],), (gl,))
            en_l = jax.lax.dynamic_slice(en, (off[0],), (gl,))
            cr_l = jnp.where(en_l != 0, cr_l, NO_ROUND)
            al_l = jax.lax.dynamic_slice(
                alive, (off[0], 0), (gl, alive.shape[1])
            )
            lim_l = jax.lax.dynamic_slice(lim, (off[0],), (gl,))
            cs = CoordinatorState(next_inst=ni_l, crnd=cr_l)
            _c, stack, lstate, fresh, _i, win, value = (
                batched.multigroup_fused_round(
                    cs, stack, lstate, values, active, al_l != 0, q,
                    reclaim_limit=lim_l,
                )
            )
        b = values.shape[1]
        inst = ni_l[:, None] + jnp.arange(b, dtype=jnp.int32)[None, :]
        return stack, lstate, fresh, inst, win, value

    sheet = P(axis)
    if n_sh == 1:
        # single-shard fast path, same argument as make_packed_sharded_round
        # below: one shard's local block IS the global array for every spec,
        # so the shard body runs bit-identically under plain jit and skips
        # shard_map's fixed per-call resharding of the slab state
        fn = local
    else:
        fn = _shard_map(
            local,
            mesh=mesh,
            in_specs=(
                P(),                               # next_inst (replicated)
                P(),                               # crnd (replicated)
                P(),                               # enabled (replicated)
                P(),                               # alive (replicated)
                P(),                               # reclaim limit (replicated)
                sheet,                             # offsets
                AcceptorState(sheet, sheet, sheet),  # type: ignore[arg-type]
                batched.LearnerState(sheet, sheet, sheet),  # type: ignore[arg-type]
                sheet,                             # values
                sheet,                             # active
            ),
            out_specs=(
                AcceptorState(sheet, sheet, sheet),  # type: ignore[arg-type]
                batched.LearnerState(sheet, sheet, sheet),  # type: ignore[arg-type]
                sheet,                             # fresh
                sheet,                             # inst
                sheet,                             # win
                sheet,                             # value
            ),
        )

    def step(
        next_inst: Any,
        crnd: Any,
        enabled: Any,
        alive: Any,
        stack: AcceptorState,
        lstate: batched.LearnerState,
        values: jax.Array,
        active: jax.Array,
        reclaim_limit: Any | None = None,
    ) -> Any:
        if reclaim_limit is None:
            # full permit: int32.max is unreachable, every lane passes the
            # reclamation gate (legacy overwrite-on-wrap mode)
            lim = jnp.full((n_groups,), jnp.iinfo(jnp.int32).max, jnp.int32)
        else:
            lim = jnp.asarray(reclaim_limit, jnp.int32).reshape((n_groups,))
        return fn(
            jnp.asarray(next_inst, jnp.int32).reshape((n_groups,)),
            jnp.asarray(crnd, jnp.int32).reshape((n_groups,)),
            jnp.asarray(enabled, jnp.int32).reshape((n_groups,)),
            jnp.asarray(alive, jnp.int32),
            lim,
            offsets,
            stack,
            lstate,
            values,
            active,
        )

    return jax.jit(step, donate_argnums=(4, 5))


def make_packed_sharded_round(
    mesh: jax.sharding.Mesh,
    *,
    quorum: int,
    axis: str = "groups",
    use_kernels: bool = False,
    block_b: int | None = None,
) -> Callable[..., Any]:
    """Build the *packed* groups-sharded cohort dispatch (DESIGN.md §13):
    each shard advances only its resident, enabled cohort lanes — packed
    into a uniform ``(n_sh, C)`` lane table — instead of walking its full
    ``Gl``-row slab with non-members held inert.

    Where ``make_sharded_multigroup_round`` satisfies shard_map's shape
    uniformity by running full-width slabs per tier (cold cohorts pay
    full-width slab cost), here uniformity comes from the GShard MoE
    input-packing idiom: ``C`` lanes per shard (the cohort's max per-shard
    residency), each lane routed to its slab row by a ``segids`` table
    riding scalar prefetch, with pad lanes (``enabled == 0``) inert.  All
    control tables are per-LANE, packed by the caller in lane order:

        step(segids[S, C], next_inst[S, C], crnd[S, C], enabled[S, C],
             alive[S, C, A], stack, lstate, values[S, C, B, V],
             reclaim_limit[S, C] | None)
          -> (stack', lstate', fresh[S*C, B], inst[S*C, B], win[S*C, B],
              value[S*C, B, V])

    with shard ``s``'s lane ``j`` at packed row ``s*C + j`` of the outputs,
    state donated in place, and the slab state updated bit-identically to
    the full-width dispatch (pads and absent rows untouched).  ``C`` is a
    trace-time shape: the step retraces per distinct (C, B) — both pow2-
    quantized vocabularies bounded by the planner.
    """
    if axis not in mesh.shape:
        raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
    q = quorum

    def local(
        ni: jax.Array,
        cr: jax.Array,
        en: jax.Array,
        alive: jax.Array,
        lim: jax.Array,
        seg: jax.Array,
        stack: AcceptorState,
        lstate: batched.LearnerState,
        values: jax.Array,
    ) -> tuple[
        AcceptorState, batched.LearnerState, jax.Array, jax.Array,
        jax.Array, jax.Array,
    ]:
        # every control table is a per-lane (1, C[, A]) sheet of this
        # shard's packed lanes; slabs are local (Gl rows, slot-indexed)
        if use_kernels:
            from repro.kernels import ops as kops
            from repro.kernels import wirepath as kwp

            # block_b is a kernel-path grid knob only (the oracle has no
            # blocks); None keeps the kernel's own default
            kw: dict[str, int] = {} if block_b is None else {"block_b": block_b}
            outs = kwp.packed_shard_round(
                seg[0], ni[0], cr[0], jnp.int32(q), alive[0],
                stack.rnd, stack.vrnd, stack.value,
                lstate.delivered, lstate.inst, lstate.value, values[0],
                en[0], lim[0], interpret=kops.INTERPRET, **kw,
            )
            stack = AcceptorState(*outs[:3])
            lstate = batched.LearnerState(*outs[3:6])
            fresh, win, value = outs[6] != 0, outs[7], outs[8]
        else:
            stack, lstate, fresh, win, value = (
                batched.packed_multigroup_round(
                    stack, lstate, seg[0], ni[0], cr[0], alive[0], q,
                    values[0], en[0], reclaim_limit=lim[0],
                )
            )
        b = values.shape[2]
        inst = ni[0][:, None] + jnp.arange(b, dtype=jnp.int32)[None, :]
        return stack, lstate, fresh, inst, win, value

    sheet = P(axis)
    if mesh.shape[axis] == 1:
        # a single-shard mesh partitions nothing: every global table equals
        # its one local block, so the shard body IS the global computation.
        # Dispatching through shard_map anyway would only buy its fixed
        # per-call resharding of the slab state — a pure copy tax on the
        # interpret backend — for zero layout change.  Multi-shard meshes
        # (the multidevice suite) take the shard_map path below and are
        # bit-identical by construction: same `local`, same operands.
        fn = local
    else:
        fn = _shard_map(
            local,
            mesh=mesh,
            in_specs=(
                sheet,                             # next_inst (per-lane)
                sheet,                             # crnd (per-lane)
                sheet,                             # enabled (per-lane)
                sheet,                             # alive (per-lane)
                sheet,                             # reclaim limit (per-lane)
                sheet,                             # segids (per-lane)
                AcceptorState(sheet, sheet, sheet),  # type: ignore[arg-type]
                batched.LearnerState(sheet, sheet, sheet),  # type: ignore[arg-type]
                sheet,                             # values (per-lane)
            ),
            out_specs=(
                AcceptorState(sheet, sheet, sheet),  # type: ignore[arg-type]
                batched.LearnerState(sheet, sheet, sheet),  # type: ignore[arg-type]
                sheet,                             # fresh
                sheet,                             # inst
                sheet,                             # win
                sheet,                             # value
            ),
        )

    def packed_step(
        segids: Any,
        next_inst: Any,
        crnd: Any,
        enabled: Any,
        alive: Any,
        stack: AcceptorState,
        lstate: batched.LearnerState,
        values: jax.Array,
        reclaim_limit: Any | None = None,
    ) -> Any:
        s, c = values.shape[0], values.shape[1]
        if reclaim_limit is None:
            lim = jnp.full((s, c), jnp.iinfo(jnp.int32).max, jnp.int32)
        else:
            lim = jnp.asarray(reclaim_limit, jnp.int32).reshape((s, c))
        return fn(
            jnp.asarray(next_inst, jnp.int32).reshape((s, c)),
            jnp.asarray(crnd, jnp.int32).reshape((s, c)),
            jnp.asarray(enabled, jnp.int32).reshape((s, c)),
            jnp.asarray(alive, jnp.int32),
            lim,
            jnp.asarray(segids, jnp.int32).reshape((s, c)),
            stack,
            lstate,
            values,
        )

    return jax.jit(packed_step, donate_argnums=(5, 6))


# ---------------------------------------------------------------------------
# Quorum step-commit for distributed training (straggler mitigation)
# ---------------------------------------------------------------------------
def quorum_commit_digest(
    digest: jax.Array,       # int32[] or int32[k]  this replica-group's digest
    healthy: jax.Array,      # bool []              this group voted in time
    *,
    axis: str,
    quorum: int,
) -> tuple[jax.Array, jax.Array]:
    """Decide a training step commit by digest agreement (inside shard_map).

    Each data-parallel replica group votes with the digest of its gradient
    contribution; the step commits iff >= quorum healthy groups hold the
    identical digest.  A straggling / dead group (healthy=False) cannot block
    the step — the paper's f-of-2f+1 resilience doubles as straggler
    mitigation.

    Returns (commit: bool[], winning_count: int32[]).
    """
    d = jnp.atleast_1d(digest)
    all_d = jax.lax.all_gather(d, axis)                      # [G, k]
    all_h = jax.lax.all_gather(healthy, axis)                # [G]
    eq = jnp.all(all_d[:, None, :] == all_d[None, :, :], -1)  # [G, G]
    eq = eq & all_h[None, :] & all_h[:, None]
    counts = jnp.sum(eq.astype(jnp.int32), axis=1)           # votes per digest
    win = jnp.max(counts)
    return win >= quorum, win
