"""Randomized chaos schedules over the multi-group service.

Property under test: a multi-group ``PaxosContext`` (unsharded or
groups-sharded) driven through an arbitrary interleaving of
submit / freeze / restore / kill / revive / pump / retire / create
operations produces *exactly* the per-group delivery logs of G independent
single-group contexts fed the identical schedule — same payloads, same
instances, same order — and every submission is delivered exactly once
after the service heals.

Dynamic membership (DESIGN.md §7) rides the same contract: a ``retire``
archives the group's log, which must equal its independent twin's at that
instant (the twin is then discarded — submissions still pending at
retirement are dropped on both sides); a ``create`` claims the lowest free
slot deterministically and starts a *fresh* twin, whose log and registers
the recycled slot must then match bit-for-bit.

The harness keeps the pump cadence identical on both sides (ops are applied
simultaneously; every ``pump`` op advances the multi-group context and all G
twins by one round), which makes retransmission timing — and therefore
instance consumption — deterministic, so logs can be compared bit for bit.
The configs pin ``batch=8`` so the wire-burst right-sizing resolves to the
same burst on both sides regardless of how skewed the per-group queues get.

Deterministic seeds always run; when hypothesis is installed (the
``_hypothesis_compat`` guard skip-marks otherwise) it searches the
seed/length space and shrinks failing schedules toward short ones.
"""
from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import PaxosConfig, PaxosContext
from repro.core.network import FaultSpec, SimNet
from repro.launch.mesh import make_group_mesh

pytestmark = pytest.mark.slow    # chaos suite: skipped in the fast CI lane

A = 3
QUORUM = A // 2 + 1
CFG1 = PaxosConfig(n_acceptors=A, n_instances=64, batch=8)


def _cfg(g: int) -> PaxosConfig:
    return PaxosConfig(n_acceptors=A, n_instances=64, batch=8, n_groups=g)


def _schedule(seed: int, g: int, steps: int, membership: bool = True):
    """A random but always-legal op sequence, healed at the end (every
    acceptor revived, every frozen group restored) so full delivery is a
    checkable postcondition.  ``membership`` mixes in retire/create events;
    the generator mirrors the dataplane's deterministic lowest-free-slot
    allocation so a ``create`` op can name the gid it will receive."""
    rng = np.random.default_rng(seed)
    frozen = [False] * g
    alive = [[True] * A for _ in range(g)]
    live = [True] * g
    free: list = []
    ops = []
    for _ in range(steps):
        r = rng.random()
        gid = int(rng.integers(g))
        if r < 0.40:
            if live[gid]:
                ops.append(("submit", gid))
        elif r < 0.62:
            ops.append(("pump",))
        elif r < 0.69:
            aid = int(rng.integers(A))
            if live[gid] and alive[gid][aid]:
                alive[gid][aid] = False
                ops.append(("kill", gid, aid))
        elif r < 0.76:
            dead = [a for a in range(A) if not alive[gid][a]]
            if live[gid] and dead:
                aid = dead[int(rng.integers(len(dead)))]
                alive[gid][aid] = True
                ops.append(("revive", gid, aid))
        elif r < 0.83:
            # takeover needs a quorum of promises to discover voted values
            if live[gid] and not frozen[gid] and sum(alive[gid]) >= QUORUM:
                frozen[gid] = True
                ops.append(("freeze", gid))
        elif r < 0.89:
            if live[gid] and frozen[gid]:
                frozen[gid] = False
                ops.append(("restore", gid))
        elif r < 0.95:
            # retire a live tenant (keep at least one group serving);
            # frozen/dead-acceptor state dies with the tenant
            if membership and live[gid] and sum(live) > 1:
                live[gid] = False
                frozen[gid] = False
                free.append(gid)
                ops.append(("retire", gid))
        else:
            if membership and free:
                ngid = min(free)        # the dataplane's allocation order
                free.remove(ngid)
                live[ngid] = True
                alive[ngid] = [True] * A
                ops.append(("create", ngid))
    for gid in range(g):
        if not live[gid]:
            continue
        for aid in range(A):
            if not alive[gid][aid]:
                ops.append(("revive", gid, aid))
        if frozen[gid]:
            ops.append(("restore", gid))
    return ops


def run_chaos(
    seed: int,
    g: int = 3,
    use_kernels: bool = False,
    sharded: bool = False,
    steps: int = 30,
    membership: bool = True,
) -> None:
    mesh = make_group_mesh() if sharded else None
    mg = PaxosContext(_cfg(g), use_kernels=use_kernels, mesh=mesh)
    singles = [
        PaxosContext(CFG1, use_kernels=use_kernels, fused=True)
        for _ in range(g)
    ]
    sent = [[] for _ in range(g)]
    retired = [0] * g          # retire count per slot: unique payload tags
    for op in _schedule(seed, g, steps, membership=membership):
        kind = op[0]
        if kind == "submit":
            gid = op[1]
            p = f"s{len(sent[gid])}g{gid}r{retired[gid]}".encode()
            sent[gid].append(p)
            mg.submit(p, group=gid)
            singles[gid].submit(p)
        elif kind == "pump":
            mg.pump()
            for s in singles:
                if s is not None:
                    s.pump()
        elif kind == "kill":
            _, gid, aid = op
            mg.hw.kill_acceptor(gid, aid)
            singles[gid].hw.kill_acceptor(aid)
        elif kind == "revive":
            _, gid, aid = op
            mg.hw.revive_acceptor(gid, aid)
            singles[gid].hw.revive_acceptor(aid)
        elif kind == "freeze":
            gid = op[1]
            mg.fail_coordinator(group=gid)
            singles[gid].fail_coordinator()
        elif kind == "restore":
            gid = op[1]
            mg.restore_hardware_coordinator(group=gid)
            singles[gid].restore_hardware_coordinator()
        elif kind == "retire":
            gid = op[1]
            # the archived log must equal the independent twin's at this
            # instant (same ops, same pump cadence); submissions still
            # pending die with the tenant on both sides
            log = mg.retire_group(gid)
            assert log == singles[gid].delivered_log, (seed, gid)
            got = [p for _inst, p in log]
            assert len(got) == len(set(got)), (seed, gid)
            assert set(got) <= set(sent[gid]), (seed, gid)
            singles[gid] = None
            sent[gid] = []
            retired[gid] += 1
        elif kind == "create":
            gid = op[1]
            assert mg.create_group() == gid, (seed, gid)  # lowest-free-first
            singles[gid] = PaxosContext(
                CFG1, use_kernels=use_kernels, fused=True
            )
    # drain: everything live is healed, so retransmit cycles deliver all
    for _ in range(30):
        mg.pump()
        for s in singles:
            if s is not None:
                s.pump()
    for gid in range(g):
        if singles[gid] is None:       # slot vacant at end of schedule
            assert not mg.hw.live_host[gid]
            continue
        assert mg.group_log[gid] == singles[gid].delivered_log, (seed, gid)
        got = [p for _inst, p in mg.group_log[gid]]
        assert len(got) == len(set(got)), (seed, gid)          # exactly once
        assert sorted(got) == sorted(sent[gid]), (seed, gid)   # all delivered
    assert not mg._pending


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_deterministic(seed, use_kernels):
    run_chaos(seed, g=3, use_kernels=use_kernels, steps=30)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [3, 4])
def test_chaos_sharded(seed, use_kernels):
    """The groups-sharded dataplane under the same chaos contract."""
    run_chaos(seed, g=2, use_kernels=use_kernels, sharded=True, steps=24)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("sharded", [False, True])
def test_membership_lifecycle_matches_oracles(use_kernels, sharded):
    """Scripted create/load/retire/recreate lifecycle on every backend
    (jnp + pallas, sharded + unsharded): the recycled slots must match fresh
    independent single-group twins bit-for-bit — logs AND device registers —
    including a transient pass through a single live group."""
    import jax

    g = 3
    mesh = make_group_mesh() if sharded else None
    mg = PaxosContext(_cfg(g), use_kernels=use_kernels, mesh=mesh)
    twins = [
        PaxosContext(CFG1, use_kernels=use_kernels, fused=True)
        for _ in range(g)
    ]

    def wave(tag, gids):
        for gid in gids:
            p = f"{tag}g{gid}".encode()
            mg.submit(p, group=gid)
            twins[gid].submit(p)
        mg.run_until_quiescent()
        for gid in gids:
            twins[gid].run_until_quiescent()

    wave("w0", [0, 1, 2])
    log = mg.retire_group(1)
    assert log == twins[1].delivered_log
    twins[1] = None
    wave("w1", [0, 2])                       # serve around the vacant slot
    assert mg.create_group() == 1            # lowest free slot
    twins[1] = PaxosContext(CFG1, use_kernels=use_kernels, fused=True)
    wave("w2", [0, 1, 2])                    # recycled slot serves fresh
    for gid in (0, 2):                       # transient G = 1
        mg.retire_group(gid)
        twins[gid] = None
    assert mg.live_groups() == [1]
    wave("w3", [1])
    assert mg.create_group() == 0            # deterministic free-list order
    assert mg.create_group() == 2
    for gid in (0, 2):
        twins[gid] = PaxosContext(CFG1, use_kernels=use_kernels, fused=True)
    wave("w4", [0, 1, 2])

    for gid in range(g):
        assert mg.group_log[gid] == twins[gid].delivered_log, gid
        mine = jax.tree_util.tree_map(
            lambda x, gid=gid: np.asarray(x)[gid], (mg.hw.stack, mg.hw.lstate)
        )
        ref = (twins[gid].hw.stack, twins[gid].hw.lstate)
        for a, b in zip(
            jax.tree_util.tree_leaves(mine), jax.tree_util.tree_leaves(ref)
        , strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(4, 40))
def test_chaos_property_jnp(seed, steps):
    run_chaos(seed, g=3, use_kernels=False, steps=steps)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(4, 24))
def test_chaos_property_sharded(seed, steps):
    run_chaos(seed, g=2, use_kernels=False, sharded=True, steps=steps)


# ---------------------------------------------------------------------------
# Skewed per-group load (DESIGN.md §8): pins the two-tier cohort dispatch
# ---------------------------------------------------------------------------
def run_skewed(
    seed: int,
    g: int = 3,
    use_kernels: bool = False,
    sharded: bool = False,
    waves: int = 8,
    batch: int = 32,
) -> None:
    """One hot group at full-batch load, G-1 cold groups trickling 0-2
    submissions per wave.  With ``batch > MIN_BURST`` the planner must
    split every wave into a hot tier (full block-aligned burst) and a cold
    tier (right-sized shared burst) — and because burst sizing is
    engine-agnostic and per-group, the multi-group logs must stay
    *bit-identical* (instances included) to G independent per-group
    oracles, on all four backends."""
    cfg = PaxosConfig(
        n_acceptors=A, n_instances=256, batch=batch, n_groups=g
    )
    cfg1 = PaxosConfig(n_acceptors=A, n_instances=256, batch=batch)
    mesh = make_group_mesh() if sharded else None
    mg = PaxosContext(cfg, use_kernels=use_kernels, mesh=mesh)
    singles = [
        PaxosContext(cfg1, use_kernels=use_kernels, fused=True)
        for _ in range(g)
    ]
    rng = np.random.default_rng(seed)
    hot = int(rng.integers(g))
    sent = [[] for _ in range(g)]
    for w in range(waves):
        for gid in range(g):
            k = batch if gid == hot else int(rng.integers(3))
            for j in range(k):
                p = f"w{w}g{gid}j{j}".encode()
                sent[gid].append(p)
                mg.submit(p, group=gid)
                singles[gid].submit(p)
        mg.pump()
        for s in singles:
            s.pump()
    for _ in range(10):
        mg.pump()
        for s in singles:
            s.pump()
    # the two-tier path actually engaged: hot and cold burst shapes minted
    assert {batch, 8} <= mg.planner.stats["burst_shapes"]
    for gid in range(g):
        # bit-equal logs — instances included: a cold group's burst is
        # right-sized exactly like its independent twin's, never padded to
        # the hot group's
        assert mg.group_log[gid] == singles[gid].delivered_log, (seed, gid)
        got = [p for _i, p in mg.group_log[gid]]
        assert got == sent[gid], (seed, gid)       # exactly once, in order
        # device registers too: per-group slabs match the twins bit-for-bit
        import jax

        mine = jax.tree_util.tree_map(
            lambda x, gid=gid: np.asarray(x)[gid], (mg.hw.stack, mg.hw.lstate)
        )
        ref = (singles[gid].hw.stack, singles[gid].hw.lstate)
        for a, b in zip(
            jax.tree_util.tree_leaves(mine), jax.tree_util.tree_leaves(ref)
        , strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not mg._pending


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [0, 1])
def test_skewed_load_unsharded(seed, use_kernels):
    run_skewed(seed, g=3, use_kernels=use_kernels)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [2, 3])
def test_skewed_load_sharded(seed, use_kernels):
    run_skewed(seed, g=2, use_kernels=use_kernels, sharded=True, waves=6)


# ---------------------------------------------------------------------------
# Lossy fabric (DESIGN.md §9): keyed faults + membership + snapshot/restore
# ---------------------------------------------------------------------------
def _msg_key(dst, msg):
    """Keyed-fault identity for a fabric message, EXCLUDING the group tag:
    the multi-group fabric tags submits with the gid while a single-group
    twin tags 0, so the tag must not reach the fault hash — payloads embed
    the gid, keeping keys distinct across groups either way."""
    return tuple(msg[:3])


LOSSY = FaultSpec(drop=0.1, dup=0.1, reorder=0.15)


def _lossy_schedule(seed: int, g: int, steps: int):
    """Like ``_schedule`` but with crash (state-loss), acceptor restore and
    snapshot events mixed in.  Crashed members are distinct from merely
    dead ones: they come back only via ``restore_acceptor`` (snapshot +
    live-suffix rebuild), never plain revive."""
    rng = np.random.default_rng(seed)
    alive = [[True] * A for _ in range(g)]
    wiped = [[False] * A for _ in range(g)]
    live = [True] * g
    free: list = []
    ops = []
    for _ in range(steps):
        r = rng.random()
        gid = int(rng.integers(g))
        if r < 0.36:
            if live[gid]:
                ops.append(("submit", gid))
        elif r < 0.60:
            ops.append(("pump",))
        elif r < 0.68:
            # crash WITH state loss — keep a quorum standing
            aid = int(rng.integers(A))
            if live[gid] and alive[gid][aid] and sum(alive[gid]) > QUORUM:
                alive[gid][aid] = False
                wiped[gid][aid] = True
                ops.append(("crash", gid, aid))
        elif r < 0.78:
            crashed = [a for a in range(A) if wiped[gid][a]]
            if live[gid] and crashed:
                aid = crashed[int(rng.integers(len(crashed)))]
                alive[gid][aid] = True
                wiped[gid][aid] = False
                ops.append(("restoreacc", gid, aid))
        elif r < 0.88:
            if live[gid]:
                ops.append(("snapshot", gid))
        elif r < 0.94:
            if live[gid] and sum(live) > 1:
                live[gid] = False
                alive[gid] = [True] * A
                wiped[gid] = [False] * A
                free.append(gid)
                ops.append(("retire", gid))
        else:
            if free:
                ngid = min(free)
                free.remove(ngid)
                live[ngid] = True
                ops.append(("create", ngid))
    for gid in range(g):
        if not live[gid]:
            continue
        for aid in range(A):
            if wiped[gid][aid]:
                ops.append(("restoreacc", gid, aid))
    return ops


def run_lossy(
    seed: int,
    g: int = 3,
    use_kernels: bool = False,
    sharded: bool = False,
    steps: int = 30,
) -> None:
    """A lossy fabric (keyed drop/dup/reorder) under membership churn,
    acceptor crash/restore and snapshot compaction: the multi-group context
    must still match G independent twins bit-for-bit.  Keyed fault
    decisions are a pure function of (seed, message, occurrence), so the
    same logical submit suffers the same fate on the shared fabric and on
    its twin's private one, regardless of interleaving.  The ring is sized
    so dup/retransmit inflation never hits the reclamation boundary — the
    snapshot events exercise drain/compaction under loss, not capacity."""
    cfg = PaxosConfig(n_acceptors=A, n_instances=256, batch=8, n_groups=g)
    cfg1 = PaxosConfig(n_acceptors=A, n_instances=256, batch=8)
    mesh = make_group_mesh() if sharded else None

    def _net():
        return SimNet(LOSSY, seed=seed, key_fn=_msg_key)

    def _twin():
        return PaxosContext(
            cfg1, use_kernels=use_kernels, fused=True, net=_net(),
            snapshots=True,
        )

    mg = PaxosContext(
        cfg, use_kernels=use_kernels, mesh=mesh, net=_net(), snapshots=True
    )
    singles = [_twin() for _ in range(g)]
    sent = [[] for _ in range(g)]
    retired = [0] * g
    for op in _lossy_schedule(seed, g, steps):
        kind = op[0]
        if kind == "submit":
            gid = op[1]
            p = f"s{len(sent[gid])}g{gid}r{retired[gid]}".encode()
            sent[gid].append(p)
            mg.submit(p, group=gid)
            singles[gid].submit(p)
        elif kind == "pump":
            mg.pump()
            for s in singles:
                if s is not None:
                    s.pump()
        elif kind == "crash":
            _, gid, aid = op
            mg.crash_acceptor(aid, group=gid)
            singles[gid].crash_acceptor(aid)
        elif kind == "restoreacc":
            _, gid, aid = op
            # identical watermarks + identical decided suffixes ⇒ the
            # rebuilt register rows adopt the same instance set
            assert mg.restore_acceptor(aid, group=gid) == singles[
                gid
            ].restore_acceptor(aid), (seed, gid, aid)
        elif kind == "snapshot":
            gid = op[1]
            snap = mg.snapshot_group(gid)
            twin_snap = singles[gid].snapshot_group()
            # equal watermarks must give equal seals (divergence check)
            assert snap.watermark == twin_snap.watermark, (seed, gid)
            assert snap.seal == twin_snap.seal, (seed, gid)
        elif kind == "retire":
            gid = op[1]
            log = mg.retire_group(gid)
            assert log == singles[gid].delivered_log, (seed, gid)
            singles[gid] = None
            sent[gid] = []
            retired[gid] += 1
        elif kind == "create":
            gid = op[1]
            assert mg.create_group() == gid, (seed, gid)
            singles[gid] = _twin()
    for _ in range(40):                # outlast retransmit cycles
        mg.pump()
        for s in singles:
            if s is not None:
                s.pump()
    for gid in range(g):
        if singles[gid] is None:
            assert not mg.hw.live_host[gid]
            continue
        assert mg.full_group_log(gid) == singles[gid].delivered_log, (
            seed, gid,
        )
        got = [p for _inst, p in mg.full_group_log(gid)]
        assert len(got) == len(set(got)), (seed, gid)          # exactly once
        assert sorted(got) == sorted(sent[gid]), (seed, gid)   # all delivered
    assert not mg._pending


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_lossy_chaos_deterministic(seed, use_kernels):
    run_lossy(seed, g=3, use_kernels=use_kernels, steps=30)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [3, 4])
def test_lossy_chaos_sharded(seed, use_kernels):
    run_lossy(seed, g=2, use_kernels=use_kernels, sharded=True, steps=24)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(4, 36))
def test_lossy_chaos_property_jnp(seed, steps):
    run_lossy(seed, g=3, use_kernels=False, steps=steps)


# ---------------------------------------------------------------------------
# Unbounded-uptime acceptance (DESIGN.md §9): ≥8 ring generations vs an
# unbounded-log oracle, with a mid-schedule crash + snapshot-restore
# ---------------------------------------------------------------------------
def run_wrap_generations(
    use_kernels: bool, sharded: bool, g: int = 2, waves: int = 66
) -> None:
    """Drive every learner ring through ≥8 generations (N=64, 8 instances
    per wave) with periodic snapshot/reclamation, crash one group member
    WITH state loss mid-schedule and restore it from snapshot + live
    suffix.  The stitched ``delivered()`` logs must be bit-identical to
    twins whose rings never wrap (the unbounded-log oracle), and equal
    watermarks must seal to equal digests on every backend."""
    n = 64
    cfg = PaxosConfig(n_acceptors=A, n_instances=n, batch=8, n_groups=g)
    cfg1 = PaxosConfig(n_acceptors=A, n_instances=1024, batch=8)
    mesh = make_group_mesh() if sharded else None
    mg = PaxosContext(cfg, use_kernels=use_kernels, mesh=mesh, snapshots=True)
    twins = [
        PaxosContext(cfg1, use_kernels=use_kernels, fused=True, snapshots=True)
        for _ in range(g)
    ]
    sent = [[] for _ in range(g)]
    crash_wave, restore_wave = waves // 2, waves // 2 + 3
    for w in range(waves):
        if w == crash_wave:
            mg.crash_acceptor(2, group=0)
            twins[0].crash_acceptor(2)
        if w == restore_wave:
            # a snapshot advanced the watermark since the crash: the rebuild
            # really is snapshot + live suffix, not a full-history replay
            assert mg.snapshots.watermark(0) > 0
            assert mg.restore_acceptor(2, group=0) == twins[
                0
            ].restore_acceptor(2)
        for gid in range(g):
            for j in range(8):
                p = f"w{w}g{gid}j{j}".encode()
                sent[gid].append(p)
                mg.submit(p, group=gid)
                twins[gid].submit(p)
        mg.pump()
        for t in twins:
            t.pump()
        if (w + 1) % 6 == 0:           # reclaim well before the boundary
            for gid in range(g):
                snap = mg.snapshot_group(gid)
                tsnap = twins[gid].snapshot_group()
                assert snap.watermark == tsnap.watermark, (w, gid)
                assert snap.seal == tsnap.seal, (w, gid)
    for _ in range(10):
        mg.pump()
        for t in twins:
            t.pump()
    for gid in range(g):
        # every ring wrapped ≥ 8 generations
        assert mg.hw.next_inst_host[gid] >= 8 * n, gid
        final = mg.snapshot_group(gid)
        tfinal = twins[gid].snapshot_group()
        assert final.seal == tfinal.seal != 0, gid
        assert mg.full_group_log(gid) == twins[gid].full_group_log(), gid
        got = [p for _i, p in mg.full_group_log(gid)]
        assert got == sent[gid], gid   # exactly once, in submit order
    assert not mg._pending


@pytest.mark.parametrize("use_kernels", [False, True])
def test_wrap_generations_unsharded(use_kernels):
    run_wrap_generations(use_kernels, sharded=False)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_wrap_generations_sharded(use_kernels):
    run_wrap_generations(use_kernels, sharded=True)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_skewed_load_with_failover(use_kernels):
    """Skew + a mid-run coordinator failover in a cold group: the staged
    path and the two-tier fused path interleave, and the logs still match
    the per-group oracles bit-for-bit."""
    g, batch = 3, 32
    cfg = PaxosConfig(n_acceptors=A, n_instances=256, batch=batch, n_groups=g)
    cfg1 = PaxosConfig(n_acceptors=A, n_instances=256, batch=batch)
    mg = PaxosContext(cfg, use_kernels=use_kernels)
    singles = [
        PaxosContext(cfg1, use_kernels=use_kernels, fused=True)
        for _ in range(g)
    ]
    sent = [[] for _ in range(g)]

    def wave(w):
        for gid in range(g):
            k = batch if gid == 0 else 2
            for j in range(k):
                p = f"w{w}g{gid}j{j}".encode()
                sent[gid].append(p)
                mg.submit(p, group=gid)
                singles[gid].submit(p)
        mg.pump()
        for s in singles:
            s.pump()

    wave(0)
    mg.fail_coordinator(group=1)
    singles[1].fail_coordinator()
    wave(1)
    wave(2)
    mg.restore_hardware_coordinator(group=1)
    singles[1].restore_hardware_coordinator()
    wave(3)
    for _ in range(10):
        mg.pump()
        for s in singles:
            s.pump()
    for gid in range(g):
        assert mg.group_log[gid] == singles[gid].delivered_log, gid
        assert sorted(p for _i, p in mg.group_log[gid]) == sorted(sent[gid])

@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("sharded", [False, True])
def test_migration_lifecycle_matches_oracles(use_kernels, sharded):
    """Scripted live-migration lifecycle on every backend (jnp + pallas,
    sharded + unsharded): skewed waves, a retire (membership event), then a
    live slab migration of the hot tenant — drain to watermark, sealed
    snapshot, slot swap, restore-at-watermark, seal re-verify — after which
    the stitched logs must stay bit-identical to unbounded per-group twins.

    The tier-1 test mesh is a single shard, so the swap leg degenerates to
    a same-shard no-op after the drain and seal checks ran; the real
    cross-shard copy is covered by test_multidevice.py on two devices.
    Unsharded dataplanes have no shards to migrate between and must refuse
    without touching any state."""
    g = 4
    cfg = _cfg(g)                       # batch=8: realign-free restores
    cfg1 = PaxosConfig(n_acceptors=A, n_instances=1024, batch=8)
    mesh = make_group_mesh() if sharded else None
    mg = PaxosContext(cfg, use_kernels=use_kernels, mesh=mesh, snapshots=True)
    twins = [
        PaxosContext(cfg1, use_kernels=use_kernels, fused=True, snapshots=True)
        for _ in range(g)
    ]
    sent = [[] for _ in range(g)]

    def wave(w, gids, hot=0):
        for gid in gids:
            for j in range(8 if gid == hot else 2):
                p = f"w{w}g{gid}j{j}".encode()
                sent[gid].append(p)
                mg.submit(p, group=gid)
                twins[gid].submit(p)
        mg.run_until_quiescent()
        for gid in gids:
            twins[gid].run_until_quiescent()

    wave(0, [0, 1, 2, 3])
    # membership event: a cold tenant retires mid-lifecycle
    log = mg.retire_group(3)
    assert log == twins[3].delivered_log
    twins[3] = None
    sent[3] = []
    wave(1, [0, 1, 2])

    if sharded:
        dst = mg.hw.shard_of_group(0)
        snap = mg.migrate_group(0, dst)
        tsnap = twins[0].snapshot_group()
        assert snap.watermark == tsnap.watermark
        assert snap.seal == tsnap.seal != 0
    else:
        with pytest.raises(ValueError):
            mg.migrate_group(0, 0)
        snap = mg.snapshot_group(0)      # keep the snapshot cadence aligned
        tsnap = twins[0].snapshot_group()
        assert snap.watermark == tsnap.watermark
        assert snap.seal == tsnap.seal != 0

    wave(2, [0, 1, 2])                   # the migrated tenant keeps serving
    assert mg.create_group() == 3        # recycled slot serves a fresh twin
    twins[3] = PaxosContext(
        cfg1, use_kernels=use_kernels, fused=True, snapshots=True
    )
    wave(3, [0, 1, 2, 3])
    for _ in range(10):
        mg.pump()
        for t in twins:
            t.pump()
    for gid in range(g):
        assert mg.full_group_log(gid) == twins[gid].delivered_log, gid
        got = [p for _i, p in mg.full_group_log(gid)]
        assert len(got) == len(set(got)), gid                  # exactly once
        assert sorted(got) == sorted(sent[gid]), gid           # all delivered
    assert not mg._pending
