"""The repo's Fig. 2 / Table 2 analogue: wire-path amortization curve.

Measures msgs/s and per-round latency of one complete Phase-2 round
(sequence -> all-A vote -> quorum -> dedup) across burst sizes for the four
generations of the dataplane:

  baseline       scalar ``core.paxos`` roles, one Python step per message —
                 the libpaxos-like software deployment
  per_acceptor   the historical staged path: jit per stage, but a host loop
                 over acceptors with a full ``.at[aid].set`` stacked-state
                 rewrite per vote, per-acceptor host transfer of the vote
                 batch, and the software learner's per-vote Python quorum
                 count (what ``HardwareDataplane.vote`` + ``PaxosContext
                 ._learn`` did before the fused wire path)
  jnp_fused      ``batched.fused_round`` — one jitted program, vmap over the
                 acceptor array, donated state
  pallas_fused   ``kernels.wirepath.wirepath_round`` — the single-dispatch
                 megakernel (interpret mode on CPU: correctness-true; on TPU
                 it compiles to Mosaic)

The amortization curve (msgs/s vs burst) is the TPU's "clock rate" lever:
bigger bursts amortize dispatch overhead until the path goes memory-bound.
Results also land in ``BENCH_wirepath.json`` so later PRs can diff msgs/s.

The multi-group section measures the second lever: aggregate throughput vs
the number of device-resident groups G served by ONE dispatch (DESIGN.md §5).
``multigroup_jnp`` is the vmapped fused dataplane, ``multigroup_pallas`` the
megakernel with all groups folded per grid step, and ``multigroup_looped``
the strawman of G independent single-group dispatches in a host loop.  The
headline `multigroup_scaling_*` rows divide G=8 aggregate msgs/s by G=1 —
CI gates on this staying >= 3x (check_wirepath_regression.py).

Ring sizing: the CPU Pallas interpreter materializes a full copy of the
aliased state arrays per grid step, an emulation artifact that scales with N
and would swamp the measurement at the paper's 64K ring; the bench therefore
uses an 8K ring and one grid step per 1024 messages.  On a real TPU the
aliased blocks stay in VMEM and neither artifact exists.

    PYTHONPATH=src python -m benchmarks.bench_wirepath [--quick]
"""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched
from repro.core.paxos import Acceptor, Coordinator, Learner, Msg
from repro.core.types import MSG_P2A, MSG_P2B, AcceptorState, CoordinatorState
from repro.kernels import wirepath

from .common import block, emit, time_fn, write_json

A = 3
V = 16
N = 1 << 13     # see "Ring sizing" in the module docstring
BLOCK_B = 1024  # messages per wire-path grid step
QUORUM = A // 2 + 1
BURSTS = (64, 256, 1024, 4096, 8192)
SCALAR_CAP = 1024  # scalar baseline measured up to here (Python is O(msgs))

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_wirepath.json")


def _mk_state():
    one = AcceptorState.init(N, V)
    stack = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (A,) + x.shape).copy(), one
    )
    return CoordinatorState.init(), stack, batched.LearnerState.init(N, V)


def _values(b: int) -> jnp.ndarray:
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(-99, 99, (b, V)).astype(np.int32))


# -- path: scalar software baseline -----------------------------------------
def bench_baseline(b: int) -> float:
    co = Coordinator(n_instances=N)
    accs = [Acceptor(aid=i, n_instances=N) for i in range(A)]
    learner = Learner(lid=0, n_acceptors=A)
    payload = b"x" * (V * 4)

    def round_():
        for _ in range(b):
            p2a = co.on_submit(Msg(5, value=payload))
            for aid, acc in enumerate(accs):
                out = acc.on_p2a(Msg(MSG_P2A, inst=p2a.inst, rnd=p2a.rnd,
                                     value=payload))
                if out.msgtype == MSG_P2B:
                    learner.on_p2b(Msg(MSG_P2B, inst=out.inst, rnd=out.rnd,
                                       vrnd=out.vrnd, swid=aid,
                                       value=out.value))

    return time_fn(round_, iters=3)


# -- path: per-acceptor host loop (the pre-fusion staged dataplane) ----------
def bench_per_acceptor(b: int) -> float:
    cstate, stack, _ = _mk_state()
    values, active = _values(b), jnp.ones((b,), bool)
    seq = jax.jit(batched.coordinator_sequence)
    vote = jax.jit(batched.acceptor_phase2)
    learned: dict = {}
    partial: dict = {}

    def round_():
        nonlocal cstate, stack
        cstate, p2a = seq(cstate, values, active)
        votes = []
        for aid in range(A):
            st = jax.tree_util.tree_map(lambda x, aid=aid: x[aid], stack)
            st, v = vote(st, p2a, aid)
            # the historical full-stack rewrite, one copy per acceptor
            stack = jax.tree_util.tree_map(
                lambda x, y, aid=aid: x.at[aid].set(y), stack, st
            )
            # ...and the per-acceptor host transfer of the vote batch
            votes.append({
                "msgtype": np.asarray(v.msgtype),
                "inst": np.asarray(v.inst),
                "vrnd": np.asarray(v.vrnd),
                "value": np.asarray(v.value),
            })
        # the software learner: per-vote Python quorum count (api._learn)
        for aid, v in enumerate(votes):
            mt, vi, vr, vv = v["msgtype"], v["inst"], v["vrnd"], v["value"]
            for i in range(b):
                if mt[i] != MSG_P2B:
                    continue
                inst = int(vi[i])
                if inst in learned:
                    continue
                slot = partial.setdefault(inst, {})
                slot[aid] = (int(vr[i]), vv[i])
                by_rnd: dict = {}
                for rnd, _ in slot.values():
                    by_rnd[rnd] = by_rnd.get(rnd, 0) + 1
                for rnd, cnt in by_rnd.items():
                    if cnt >= QUORUM:
                        learned[inst] = next(
                            val for r, val in slot.values() if r == rnd
                        )
                        partial.pop(inst, None)
                        break

    return time_fn(round_)


# -- path: jnp fused round ---------------------------------------------------
def bench_jnp_fused(b: int) -> float:
    cstate, stack, lstate = _mk_state()
    values, active = _values(b), jnp.ones((b,), bool)
    alive = jnp.ones((A,), bool)
    fused = jax.jit(batched.fused_round, donate_argnums=(1, 2),
                    static_argnums=(6,))

    def round_():
        nonlocal cstate, stack, lstate
        cstate, stack, lstate, fresh, *_ = fused(
            cstate, stack, lstate, values, active, alive, QUORUM
        )
        block(fresh)

    return time_fn(round_)


# -- path: Pallas megakernel -------------------------------------------------
def bench_pallas_fused(b: int) -> float:
    cstate, stack, lstate = _mk_state()
    values = _values(b)
    alive = jnp.ones((A,), jnp.int32)
    interpret = jax.default_backend() == "cpu"

    def round_():
        nonlocal cstate, stack, lstate
        outs = wirepath.wirepath_round(
            cstate.next_inst, cstate.crnd, jnp.int32(QUORUM), alive,
            stack.rnd, stack.vrnd, stack.value,
            lstate.delivered, lstate.inst, lstate.value,
            values, block_b=BLOCK_B, interpret=interpret,
        )
        stack = AcceptorState(*outs[:3])
        lstate = batched.LearnerState(*outs[3:6])
        cstate = CoordinatorState(
            next_inst=cstate.next_inst + b, crnd=cstate.crnd
        )
        block(outs[6])

    return time_fn(round_)


PATHS = (
    ("baseline", bench_baseline),
    ("per_acceptor", bench_per_acceptor),
    ("jnp_fused", bench_jnp_fused),
    ("pallas_fused", bench_pallas_fused),
)


# -- multi-group scaling: aggregate msgs/s vs G, one dispatch for all groups --
# The multi-group win is dispatch amortization: a service pumping G groups in
# one program pays ONE dispatch where G deployments pay G.  That shows in the
# latency-bound regime — small per-group bursts, where a round is dominated
# by fixed dispatch cost — so the sweep measures there (64-msg bursts, small
# rings).  At large bursts a CPU round is compute/copy-bound and aggregate
# scaling flattens toward 1x on this backend; on TPU the groups ride the
# grid (or the sublanes, when folded) in parallel instead.
MG_GROUPS = (1, 2, 4, 8)
MG_BURST = 64    # per-group burst: the latency-bound service regime
MG_N = 1 << 9    # small rings bound the interpreter's aliasing-copy artifact


def _mk_mg_state(g: int):
    return batched.init_multigroup_state(g, A, MG_N, V)


def _mg_values(g: int) -> jnp.ndarray:
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(-99, 99, (g, MG_BURST, V)).astype(np.int32))


def bench_multigroup_jnp(g: int) -> float:
    """All G groups advance one round in one jitted vmapped program."""
    cstate, stack, lstate = _mk_mg_state(g)
    values = _mg_values(g)
    active = jnp.ones((g, MG_BURST), bool)
    alive = jnp.ones((g, A), bool)
    fused = jax.jit(batched.multigroup_fused_round, donate_argnums=(1, 2),
                    static_argnums=(6,))

    def round_():
        nonlocal cstate, stack, lstate
        cstate, stack, lstate, fresh, *_ = fused(
            cstate, stack, lstate, values, active, alive, QUORUM
        )
        block(fresh)

    return time_fn(round_, iters=15, stat="min")


def bench_multigroup_pallas(g: int) -> float:
    """All G groups folded into each grid step of the megakernel (lockstep
    mapping), with donated device-resident state — exactly the
    ``MultiGroupDataplane`` production configuration.  Interpret mode on CPU,
    Mosaic on TPU."""
    from repro.kernels import ops as kops

    cstate, stack, lstate = _mk_mg_state(g)
    values = _mg_values(g)
    active = jnp.ones((g, MG_BURST), bool)
    alive = jnp.ones((g, A), bool)
    fused = jax.jit(
        kops.multigroup_fused_round,
        donate_argnums=(1, 2),
        static_argnames=("group_block",),
    )

    def round_():
        nonlocal cstate, stack, lstate
        cstate, stack, lstate, fresh, *_ = fused(
            cstate, stack, lstate, values, active, alive, QUORUM,
            group_block=g,
        )
        block(fresh)

    return time_fn(round_, iters=15, stat="min")


def bench_multigroup_looped(g: int) -> float:
    """The strawman: G independent single-group dispatches in a host loop
    (what G separate deployments of PR 1's dataplane would cost)."""
    states = []
    for _ in range(g):
        _c, st, ls = _mk_mg_state(1)
        states.append((
            CoordinatorState.init(),
            jax.tree_util.tree_map(lambda x: x[0], st),
            jax.tree_util.tree_map(lambda x: x[0], ls),
        ))
    values = _mg_values(g)
    active = jnp.ones((MG_BURST,), bool)
    alive = jnp.ones((A,), bool)
    fused = jax.jit(batched.fused_round, donate_argnums=(1, 2),
                    static_argnums=(6,))

    def round_():
        outs = []
        for gid in range(g):
            cstate, stack, lstate = states[gid]
            cstate, stack, lstate, fresh, *_ = fused(
                cstate, stack, lstate, values[gid], active, alive, QUORUM
            )
            states[gid] = (cstate, stack, lstate)
            outs.append(fresh)
        block(outs)

    return time_fn(round_, iters=15, stat="min")


# (msgs/s metric name, scaling headline name or None, bench fn) — the
# scaling name is spelled out per path so the emitted headline the CI gate
# keys on is grep-able here, not derived by string surgery at emit time.
MG_PATHS = (
    ("multigroup_jnp", "multigroup_scaling_jnp", bench_multigroup_jnp),
    ("multigroup_pallas", "multigroup_scaling_pallas", bench_multigroup_pallas),
    ("multigroup_looped", None, bench_multigroup_looped),
)


# -- groups-sharded scaling: the slab-partitioned dispatch on the host mesh --
# The sharded dataplane (DESIGN.md §6) wraps the same fused round in a
# shard_map over a ``groups`` mesh axis, so G scales past one chip.  On the
# CPU host mesh (usually 1 device) the sweep measures the sharding layer's
# dispatch amortization — the shard_map plumbing must not eat the
# multi-group win — and the scaling ratio is gated by CI against the
# committed artifact (check_wirepath_regression.py).
def _mk_sharded_step(g: int, use_kernels: bool):
    from repro.core.fabric import make_sharded_multigroup_round
    from repro.launch.mesh import make_group_mesh

    # Pinned to a 1-device mesh regardless of the host: the gated metric is
    # the shard_map layer's dispatch amortization (G=8 vs G=1), and a ratio
    # measured over a different shard count is not comparable to the
    # committed artifact (and G=8 over 8 shards has no G=1 at all).
    # Multi-device slab parallelism is exercised by the sharded test suite,
    # not this gate.
    mesh = make_group_mesh(1)
    return make_sharded_multigroup_round(
        mesh,
        n_groups=g,
        quorum=QUORUM,
        use_kernels=use_kernels,
        # lockstep sweep: fold each shard's whole slab per grid step, the
        # production configuration of ShardedMultiGroupDataplane
        group_block=g if use_kernels else 1,
    )


def _bench_sharded(g: int, use_kernels: bool) -> float:
    step = _mk_sharded_step(g, use_kernels)
    _c, stack, lstate = _mk_mg_state(g)
    values = _mg_values(g)
    active = jnp.ones((g, MG_BURST), bool)
    alive = np.ones((g, A), np.int32)
    enabled = np.ones((g,), np.int32)     # all slots live (full tenancy)
    ni = np.zeros((g,), np.int32)
    cr = np.zeros((g,), np.int32)

    def round_():
        nonlocal stack, lstate, ni
        stack, lstate, fresh, _inst, _win, _val = step(
            ni, cr, enabled, alive, stack, lstate, values, active
        )
        ni = ni + MG_BURST
        block(fresh)

    return time_fn(round_, iters=15, stat="min")


def bench_sharded_jnp(g: int) -> float:
    return _bench_sharded(g, use_kernels=False)


def bench_sharded_pallas(g: int) -> float:
    return _bench_sharded(g, use_kernels=True)


SHARDED_PATHS = (
    ("sharded_jnp", "sharded_scaling_jnp", bench_sharded_jnp),
    ("sharded_pallas", "sharded_scaling_pallas", bench_sharded_pallas),
)


# -- skewed load: two-tier cohort dispatch vs the shared-burst strawman ------
# One hot group saturating the full burst every wave, G-1 cold groups
# *trickling* — a small chunk every SKEW_COLD_EVERY-th wave, the service
# regime the ROADMAP item names.  The pre-refactor dispatch cost G x HOT_B
# slots of device work per wave regardless: cold chunks were NOP-padded up
# to the hottest group's burst, and waves with no cold traffic still swept
# the full (G, HOT_B) grid with the idle groups riding inert.  The cohort
# planner (DESIGN.md §8) splits the schedule into a hot tier — a
# group-axis-COMPACTED kernel round visiting one group's blocks, not G's —
# plus a cold tier only on waves that have cold traffic, folded at the
# right-sized burst.  Both paths decide identical useful instances; the
# gated metric is useful decided-instances/s over the schedule.
#
# CPU-interpret caveat: the interpreter materializes the full aliased state
# per dispatch (DESIGN.md §4), a fixed artifact that is *paid per dispatch
# and independent of how little the dispatch decides* — it therefore favors
# the shared-burst path (fewer, fatter dispatches).  The ratio below is a
# conservative floor for the real-hardware win, where the hot tier's grid
# touches 1/G of the slab traffic.
SKEW_G = 8
SKEW_HOT = 0           # the hot group's slot
SKEW_HOT_B = 8192      # hot burst (== the full block-aligned batch)
SKEW_COLD_B = 64       # right-sized cold burst
SKEW_N = 1 << 13       # ring (>= the hot burst)
SKEW_BLOCK = 8192      # messages per grid step
SKEW_WAVES = 6         # waves per timed schedule
SKEW_COLD_EVERY = 3    # cold groups trickle a chunk every 3rd wave


def _mk_skew_state():
    return batched.init_multigroup_state(SKEW_G, A, SKEW_N, V)


def _skew_values():
    rng = np.random.default_rng(0)
    hot = rng.integers(-99, 99, (1, SKEW_HOT_B, V)).astype(np.int32)
    cold = rng.integers(-99, 99, (SKEW_G, SKEW_COLD_B, V)).astype(np.int32)
    padded = np.zeros((SKEW_G, SKEW_HOT_B, V), np.int32)
    padded[:, :SKEW_COLD_B] = cold               # cold chunks, NOP-padded
    padded[SKEW_HOT] = hot[0]
    return jnp.asarray(hot), jnp.asarray(cold), jnp.asarray(padded)


def _skew_cold_waves():
    return [w for w in range(SKEW_WAVES) if w % SKEW_COLD_EVERY == 0]


SKEW_USEFUL = (
    SKEW_WAVES * SKEW_HOT_B
    + len(_skew_cold_waves()) * (SKEW_G - 1) * SKEW_COLD_B
)


def bench_skew_shared_pallas() -> float:
    """Pre-refactor shared-burst dispatch, modelled faithfully: every wave
    is one full-width (G, HOT_B) megakernel round — cold chunks padded to
    the hot burst, idle groups riding the grid inert — and the fold is the
    historical all-or-nothing plan: ``group_block = G`` while the enabled
    watermarks are in lockstep, ``group_block = 1`` once skew makes them
    diverge (exactly the two wastes the ROADMAP items name)."""
    _c, stack, lstate = _mk_skew_state()
    hot, _cold, padded = _skew_values()
    alive = jnp.ones((SKEW_G, A), jnp.int32)
    cr = jnp.zeros((SKEW_G,), jnp.int32)
    cold_waves = set(_skew_cold_waves())
    hot_only = np.zeros((SKEW_G,), np.int32)
    hot_only[SKEW_HOT] = 1
    hot_padded = jnp.zeros_like(padded).at[SKEW_HOT].set(hot[0])
    interpret = jax.default_backend() == "cpu"
    state = {"ni": np.zeros((SKEW_G,), np.int32)}

    def schedule():
        nonlocal stack, lstate
        ni = state["ni"]
        for w in range(SKEW_WAVES):
            with_cold = w in cold_waves
            en = np.ones((SKEW_G,), np.int32) if with_cold else hot_only
            # the historical binary fold decision, on enabled marks only
            marks = {ni[i] for i in range(SKEW_G) if en[i]}
            gb = SKEW_G if len(marks) <= 1 else 1
            outs = wirepath.multigroup_wirepath_round(
                jnp.asarray(ni), cr, jnp.int32(QUORUM), alive,
                stack.rnd, stack.vrnd, stack.value,
                lstate.delivered, lstate.inst, lstate.value,
                padded if with_cold else hot_padded, jnp.asarray(en),
                block_b=SKEW_BLOCK, group_block=gb, interpret=interpret,
            )
            stack = AcceptorState(*outs[:3])
            lstate = batched.LearnerState(*outs[3:6])
            # every dispatched group burns the shared burst
            ni = ni + en * SKEW_HOT_B
            block(outs[6])
        state["ni"] = ni

    return time_fn(schedule, iters=5, stat="min")


def bench_skew_twotier_pallas() -> float:
    """Cohort planner dispatch: per wave, the hot tier runs as a group-axis
    compacted kernel round (one group's blocks); the cold tier fires only
    on waves with cold traffic, folded at the right-sized burst — the
    ``pipeline_cohort`` production configuration."""
    _c, stack, lstate = _mk_skew_state()
    hot, cold, _padded = _skew_values()
    alive = jnp.ones((SKEW_G, A), jnp.int32)
    cr = jnp.zeros((SKEW_G,), jnp.int32)
    cold_waves = set(_skew_cold_waves())
    en_hot = np.zeros((SKEW_G,), np.int32)
    en_hot[SKEW_HOT] = 1
    en_cold = 1 - en_hot
    gsel_hot = jnp.asarray([SKEW_HOT], jnp.int32)
    gsel_cold = jnp.asarray([0], jnp.int32)
    interpret = jax.default_backend() == "cpu"
    state = {"ni": np.zeros((SKEW_G,), np.int32)}

    def schedule():
        nonlocal stack, lstate
        ni = state["ni"]
        for w in range(SKEW_WAVES):
            outs = wirepath.cohort_wirepath_round(
                gsel_hot, jnp.asarray(ni), cr, jnp.int32(QUORUM), alive,
                stack.rnd, stack.vrnd, stack.value,
                lstate.delivered, lstate.inst, lstate.value,
                hot, jnp.asarray(en_hot),
                block_b=SKEW_BLOCK, group_block=1, interpret=interpret,
            )
            stack = AcceptorState(*outs[:3])
            lstate = batched.LearnerState(*outs[3:6])
            ni = ni + en_hot * SKEW_HOT_B
            block(outs[6])
            if w in cold_waves:
                outs = wirepath.cohort_wirepath_round(
                    gsel_cold, jnp.asarray(ni), cr, jnp.int32(QUORUM),
                    alive, stack.rnd, stack.vrnd, stack.value,
                    lstate.delivered, lstate.inst, lstate.value,
                    cold, jnp.asarray(en_cold),
                    block_b=SKEW_BLOCK, group_block=SKEW_G,
                    interpret=interpret,
                )
                stack = AcceptorState(*outs[:3])
                lstate = batched.LearnerState(*outs[3:6])
                ni = ni + en_cold * SKEW_COLD_B
                block(outs[6])
        state["ni"] = ni

    return time_fn(schedule, iters=5, stat="min")


def bench_skew_sharded_pallas() -> float:
    """The same skewed schedule through the sharded dataplane's dispatch
    pair (DESIGN.md §13), on the pinned 1-device mesh: the hot tier is a
    1-lane *packed* segment-id round (the grid visits one slab row, not
    G), the cold tier a full-width folded round — the 7-group cold cohort
    saturates the slab (``C >= Gl``), so ``pipeline_cohort``'s crossover
    hands it to the fat folded dispatch rather than paying one grid step
    per lane.  This is the ``ShardedMultiGroupDataplane`` production
    configuration for both tiers.  The gated ``skew_sharded_ratio``
    divides this path's useful decided-instances/s by the unsharded
    two-tier cohort path's: the sharded plumbing (lane tables, segment-id
    prefetch, per-shard slabs, crossover) must not eat the cohort win."""
    from repro.core.fabric import (
        make_packed_sharded_round,
        make_sharded_multigroup_round,
    )
    from repro.launch.mesh import make_group_mesh

    mesh = make_group_mesh(1)
    step = make_packed_sharded_round(
        mesh, quorum=QUORUM, use_kernels=True, block_b=SKEW_BLOCK,
    )
    cold_step = make_sharded_multigroup_round(
        mesh, n_groups=SKEW_G, quorum=QUORUM, use_kernels=True,
        group_block=SKEW_G,
    )
    _c, stack, lstate = _mk_skew_state()
    hot, cold, _padded = _skew_values()
    cold_waves = set(_skew_cold_waves())
    # hot tier: one real lane naming the hot slab row
    seg_hot = np.asarray([[SKEW_HOT]], np.int32)
    en_hot1 = np.ones((1, 1), np.int32)
    cr_hot1 = np.zeros((1, 1), np.int32)
    al_hot1 = np.ones((1, 1, A), np.int32)
    vals_hot = jnp.asarray(hot)[None]            # (1, 1, HOT_B, V)
    # cold tier: full-width (G, COLD_B) burst, hot group masked inert
    en_cold = np.ones((SKEW_G,), np.int32)
    en_cold[SKEW_HOT] = 0
    cr_cold = np.zeros((SKEW_G,), np.int32)
    al_cold = np.ones((SKEW_G, A), np.int32)
    act_cold = jnp.zeros((SKEW_G, SKEW_COLD_B), jnp.int32)
    state = {"ni": np.zeros((SKEW_G,), np.int64)}

    def schedule():
        nonlocal stack, lstate
        ni = state["ni"]
        for w in range(SKEW_WAVES):
            nip = np.asarray([[ni[SKEW_HOT]]], np.int32)
            stack, lstate, fresh, _i, _win, _val = step(
                seg_hot, nip, cr_hot1, en_hot1, al_hot1, stack, lstate,
                vals_hot,
            )
            ni[SKEW_HOT] += SKEW_HOT_B
            block(fresh)
            if w in cold_waves:
                stack, lstate, fresh, _i, _win, _val = cold_step(
                    np.asarray(ni, np.int32), cr_cold, en_cold, al_cold,
                    stack, lstate, cold, act_cold,
                )
                ni += en_cold * SKEW_COLD_B
                block(fresh)
        state["ni"] = ni

    return time_fn(schedule, iters=5, stat="min")


def run_skewed() -> None:
    shared = bench_skew_shared_pallas()
    twotier = bench_skew_twotier_pallas()
    sharded = bench_skew_sharded_pallas()
    for path, us in (("skew_shared_pallas", shared),
                     ("skew_twotier_pallas", twotier)):
        msgs = SKEW_USEFUL / us * 1e6
        emit(
            f"wirepath/{path}/G={SKEW_G}",
            us,
            f"{msgs:.0f} useful msg/s",
            path=path,
            groups=SKEW_G,
            hot_burst=SKEW_HOT_B,
            cold_burst=SKEW_COLD_B,
            waves=SKEW_WAVES,
            cold_every=SKEW_COLD_EVERY,
            msgs_per_s=msgs,
            us_per_round=us,
        )
    ratio = shared / twotier
    emit(
        f"wirepath/skew_speedup_twotier/G={SKEW_G}",
        0.0,
        f"{ratio:.1f}x useful msgs/s vs shared burst",
        groups=SKEW_G,
        skew_speedup=ratio,
    )
    # headline: the packed sharded dispatch vs the unsharded cohort path on
    # the identical schedule — useful msgs/s ratio, CI-gated by the
    # absolute --min-skew-sharded-ratio floor (the shard_map + lane-table
    # plumbing must keep the sharded service within 2x of unsharded)
    sharded_msgs = SKEW_USEFUL / sharded * 1e6
    sharded_ratio = twotier / sharded            # = sharded_msgs / twotier's
    emit(
        f"wirepath/skew_sharded_pallas/G={SKEW_G}",
        sharded,
        f"{sharded_msgs:.0f} useful msg/s, "
        f"{sharded_ratio:.2f}x of unsharded two-tier",
        path="skew_sharded_pallas",
        groups=SKEW_G,
        hot_burst=SKEW_HOT_B,
        cold_burst=SKEW_COLD_B,
        waves=SKEW_WAVES,
        cold_every=SKEW_COLD_EVERY,
        msgs_per_s=sharded_msgs,
        us_per_round=sharded,
        skew_sharded_ratio=sharded_ratio,
    )


# -- sustained uptime: throughput across ring generations (DESIGN.md §9) -----
# The unbounded-uptime question: what does watermark-driven reclamation COST?
# The sustained path drives a small ring through >= 8 generations with the
# full §9 lifecycle between generations — drain the delivered prefix to the
# host, seal the drained chunk with the digest kernel, advance the
# reclamation watermark — while the unbounded baseline is the SAME ring
# wrapping silently (the pre-§9 dataplane, no guard, no drain).  The gated
# ``sustained_ratio`` row is sustained/unbounded msgs/s: the reclamation tax
# a forever-running service pays for never corrupting its log.
SUST_N = 512       # ring: small enough that generations are cheap to force
SUST_B = 256       # burst per round
SUST_GENS = 8      # ring generations per timed schedule
SUST_ROUNDS = SUST_GENS * SUST_N // SUST_B


def _mk_sust_hw(reclaim: bool):
    from repro.core.api import HardwareDataplane
    from repro.core.types import PaxosConfig

    cfg = PaxosConfig(
        n_acceptors=A, n_instances=SUST_N, batch=SUST_B, value_words=V
    )
    hw = HardwareDataplane(cfg, use_kernels=True)
    if reclaim:
        hw.enable_reclamation()
    return hw


def bench_sustained_pallas(reclaim: bool) -> float:
    from repro.kernels import ops as kops

    hw = _mk_sust_hw(reclaim)
    rng = np.random.default_rng(0)
    vals = rng.integers(-99, 99, (SUST_B, V)).astype(np.int32)
    act = np.ones((SUST_B,), np.int32)
    drain_every = SUST_N // SUST_B     # rounds per generation

    def drain():
        # generation boundary: drain the decided prefix, seal the drained
        # chunk with the digest kernel, advance the reclamation watermark
        lo = hw.reclaimed_host
        hi = hw._next_inst_host
        ld = np.asarray(hw.lstate.delivered)
        li = np.asarray(hw.lstate.inst)
        lv = np.asarray(hw.lstate.value)
        slots = np.nonzero((ld != 0) & (li >= lo) & (li < hi))[0]
        order = slots[np.argsort(li[slots], kind="stable")]
        block(kops.tree_digest((li[order], lv[order])))
        hw.set_reclaimed(hi)

    def schedule():
        fresh = None
        for r in range(SUST_ROUNDS):
            if reclaim and r % drain_every == 0 and r:
                drain()
            fresh, _inst, _val = hw.pipeline(vals, act)
        block(jnp.asarray(fresh))
        if reclaim:                     # final generation's drain
            drain()

    return time_fn(schedule, iters=3, stat="min")


def run_sustained() -> None:
    rows = (
        ("sustained_pallas", True),
        ("sustained_unbounded_pallas", False),
    )
    msgs = {}
    total = SUST_ROUNDS * SUST_B
    for path, reclaim in rows:
        us = bench_sustained_pallas(reclaim)
        msgs[path] = total / us * 1e6
        emit(
            f"wirepath/{path}/gens={SUST_GENS}",
            us,
            f"{msgs[path]:.0f} msg/s across {SUST_GENS} generations",
            path=path,
            gens=SUST_GENS,
            ring=SUST_N,
            burst=SUST_B,
            msgs_per_s=msgs[path],
            us_per_schedule=us,
        )
    ratio = msgs["sustained_pallas"] / msgs["sustained_unbounded_pallas"]
    emit(
        f"wirepath/sustained_ratio/gens={SUST_GENS}",
        0.0,
        f"{ratio:.2f}x of unbounded msgs/s",
        gens=SUST_GENS,
        ring=SUST_N,
        sustained_ratio=ratio,
    )


def run_sharded(groups=MG_GROUPS) -> None:
    agg = {}
    for path, _scaling, fn in SHARDED_PATHS:
        for g in groups:
            us = fn(g)
            msgs = g * MG_BURST / us * 1e6
            agg.setdefault(path, {})[g] = msgs
            emit(
                f"wirepath/{path}/G={g}",
                us,
                f"{msgs:.0f} msg/s aggregate",
                path=path,
                groups=g,
                burst_per_group=MG_BURST,
                msgs_per_s=msgs,
                us_per_round=us,
            )
    hi, lo = max(groups), min(groups)
    for path, scaling, _fn in SHARDED_PATHS:
        if hi in agg.get(path, {}) and lo in agg.get(path, {}) and hi > lo:
            scale = agg[path][hi] / agg[path][lo]
            emit(
                f"wirepath/{scaling}/G={hi}",
                0.0,
                f"{scale:.1f}x aggregate vs G={lo}",
                groups=hi,
                scaling=scale,
            )


def run_multigroup(groups=MG_GROUPS) -> None:
    agg = {}
    for path, _scaling, fn in MG_PATHS:
        for g in groups:
            us = fn(g)
            msgs = g * MG_BURST / us * 1e6
            agg.setdefault(path, {})[g] = msgs
            emit(
                f"wirepath/{path}/G={g}",
                us,
                f"{msgs:.0f} msg/s aggregate",
                path=path,
                groups=g,
                burst_per_group=MG_BURST,
                msgs_per_s=msgs,
                us_per_round=us,
            )
    hi = max(groups)
    for path, scaling, _fn in MG_PATHS:
        if scaling is None:       # the looped path has no scaling headline
            continue
        if hi in agg.get(path, {}) and 1 in agg.get(path, {}):
            scale = agg[path][hi] / agg[path][1]
            emit(
                f"wirepath/{scaling}/G={hi}",
                0.0,
                f"{scale:.1f}x aggregate vs G=1",
                groups=hi,
                scaling=scale,
            )


# -- path: KV tier — consensus write round-trips vs consensus-free reads -----
# The DESIGN.md §10 economics: a ``put`` pays one full wire-path round trip
# (submit -> fused Phase-2 -> deliver -> host apply) while a leased ``get``
# never leaves the host (replica lookup behind the read watermark).  The
# gated ``kv_read_write_ratio`` row is write-us / read-us — the NetChain
# claim that consensus-free reads are >= 10x cheaper than write round-trips.
KV_BURST = 128       # puts per timed schedule, one round-trip each
KV_READS = 4096      # leased gets per timed schedule, pure host path


def run_kv() -> None:
    from repro.core.api import PaxosContext
    from repro.core.types import PaxosConfig
    from repro.serve.engine import ConsensusService
    from repro.serve.kv import ReplicatedKV

    cfg = PaxosConfig(
        n_acceptors=A, n_instances=N, batch=KV_BURST, value_words=V,
        n_groups=2,
    )
    svc = ConsensusService(PaxosContext(cfg, use_kernels=True))
    kv = ReplicatedKV(svc)
    s = kv.session("bench")
    tick = [0]

    def write_burst():
        t = tick[0]
        tick[0] += 1
        for j in range(KV_BURST):
            s.put(f"k{j & 63}".encode(), f"t{t}j{j}".encode())
        svc.run_until_quiescent()
        kv.refresh()

    us_w = time_fn(write_burst, iters=3, stat="min") / KV_BURST
    emit(
        f"wirepath/kv_put_pallas/burst={KV_BURST}",
        us_w,
        f"{1e6 / us_w:.0f} write round-trips/s",
        path="kv_put_pallas",
        burst=KV_BURST,
        us_per_op=us_w,
        msgs_per_s=1e6 / us_w,
    )

    assert s.get(b"k1") is not None    # settle: lease validated
    d0 = svc.ctx.hw.dispatch_count

    def read_burst():
        for _ in range(KV_READS):
            s.get(b"k1")

    us_r = time_fn(read_burst, iters=3, stat="min") / KV_READS
    # the economics only count if the reads really were consensus-free
    assert svc.ctx.hw.dispatch_count == d0, "leased reads dispatched!"
    emit(
        f"wirepath/kv_read_leased/burst={KV_READS}",
        us_r,
        f"{1e6 / us_r:.0f} leased reads/s, zero dispatches",
        path="kv_read_leased",
        burst=KV_READS,
        us_per_op=us_r,
        msgs_per_s=1e6 / us_r,
    )
    ratio = us_w / us_r
    emit(
        f"wirepath/kv_read_write_ratio/burst={KV_BURST}",
        0.0,
        f"leased reads {ratio:.0f}x cheaper than write round-trips",
        burst=KV_BURST,
        kv_ratio=ratio,
    )


# -- path: persistent K-round waves (DESIGN.md §11) --------------------------
# Two questions, two gated headline ratios:
#
#   * ``persistent_speedup`` — the engine contest at matched wave shape:
#     the persistent Pallas kernel vs the K-unrolled jnp oracle, both
#     running one K=4 wave of burst-8192 rounds per dispatch with donated
#     state and one host upload/readback per WAVE.
#   * ``trickle_persistent_ratio`` — the dispatch-amortization claim on the
#     trickle schedule where the per-round pump is dispatch-bound: one
#     K=16 wave of burst-64 rounds vs 16 sequential single-round
#     dispatches, the baseline paying the honest per-round pump cost
#     (values upload + fresh/value readback every round, exactly what
#     ``pipeline_cohort`` costs the pump).
#
# The ungated ``persistent_amortization`` row tracks the same ratio at a
# mid curve point (K=16, burst=256).  Interpret-mode caveat (module
# docstring "Ring sizing"): the CPU interpreter copies the aliased state
# per grid step, so persistent waves under-read here relative to real TPU
# execution — the ratios below are conservative.
PERS_BIG = dict(k=4, b=8192, n=1 << 15)      # engine contest, matched shape
PERS_MID = dict(k=16, b=256, n=1 << 12)      # amortization curve point
PERS_TRICKLE = dict(k=16, b=64, n=1 << 10)   # dispatch-bound regime


def _pers_values(k: int, b: int) -> np.ndarray:
    rng = np.random.default_rng(5)
    return rng.integers(1, 1 << 20, size=(k, 1, b, V)).astype(np.int32)


def bench_persistent_pallas(k: int, b: int, n: int) -> float:
    """One K-round wave per dispatch: upload once, read back once."""
    from repro.kernels import ops as kops

    persist = jax.jit(
        kops.persistent_cohort_rounds,
        donate_argnums=(0, 1),
        static_argnames=("group_block", "block_b"),
    )
    _, stack, lstate = batched.init_multigroup_state(1, A, n, V)
    st = {"stack": stack, "lstate": lstate, "base": 0}
    gsel = jnp.zeros((1,), jnp.int32)
    crnd = jnp.zeros((1,), jnp.int32)
    alive = jnp.ones((1, A), jnp.int32)
    npv = _pers_values(k, b)
    steps = np.arange(k, dtype=np.int32)[:, None] * b

    def wave():
        wni = (st["base"] + steps).astype(np.int32)
        st["stack"], st["lstate"], fresh, _w, val = persist(
            st["stack"], st["lstate"], gsel, jnp.asarray(wni),
            jnp.ones((k, 1), jnp.int32), crnd, alive, QUORUM,
            jnp.asarray(npv), group_block=1, block_b=b,
        )
        st["base"] += k * b          # ring wraps silently (no reclamation)
        np.asarray(fresh), np.asarray(val)   # once-per-wave host sync

    return time_fn(wave, stat="min")


def bench_persistent_jnp(k: int, b: int, n: int) -> float:
    """The K-unrolled oracle at the same wave shape and sync contract."""
    persist = jax.jit(
        batched.persistent_multigroup_rounds, donate_argnums=(1, 2)
    )
    cstate, stack, lstate = batched.init_multigroup_state(1, A, n, V)
    st = {"c": cstate, "stack": stack, "lstate": lstate}
    npv = _pers_values(k, b)
    act = np.ones((k, 1, b), bool)
    alive = jnp.ones((1, A), bool)

    def wave():
        st["c"], st["stack"], st["lstate"], fresh, _i, _w, val = persist(
            st["c"], st["stack"], st["lstate"], jnp.asarray(npv),
            jnp.asarray(act), alive, QUORUM,
        )
        np.asarray(fresh), np.asarray(val)

    return time_fn(wave, stat="min")


def bench_persistent_k1(k: int, b: int, n: int) -> float:
    """The pre-§11 pump model: K sequential single-round dispatches, each
    paying the per-round host boundary (values upload + readback) that
    ``pipeline_cohort`` pays — the honest baseline a persistent wave
    replaces.  Same kernel, K=1, matched block size."""
    from repro.kernels import ops as kops

    persist = jax.jit(
        kops.persistent_cohort_rounds,
        donate_argnums=(0, 1),
        static_argnames=("group_block", "block_b"),
    )
    _, stack, lstate = batched.init_multigroup_state(1, A, n, V)
    st = {"stack": stack, "lstate": lstate, "base": 0}
    gsel = jnp.zeros((1,), jnp.int32)
    crnd = jnp.zeros((1,), jnp.int32)
    alive = jnp.ones((1, A), jnp.int32)
    wen1 = jnp.ones((1, 1), jnp.int32)
    npv = _pers_values(k, b)

    def wave():
        for r in range(k):
            wni = np.asarray([[st["base"]]], np.int32)
            st["stack"], st["lstate"], fresh, _w, val = persist(
                st["stack"], st["lstate"], gsel, jnp.asarray(wni), wen1,
                crnd, alive, QUORUM, jnp.asarray(npv[r : r + 1]),
                group_block=1, block_b=b,
            )
            st["base"] += b
            np.asarray(fresh), np.asarray(val)   # per-ROUND host sync

    return time_fn(wave, stat="min")


def run_persistent() -> None:
    rows = {}
    for path, fn, shape in (
        ("persistent_pallas_k4", bench_persistent_pallas, PERS_BIG),
        ("persistent_jnp_k4", bench_persistent_jnp, PERS_BIG),
        ("persistent_pallas_k16", bench_persistent_pallas, PERS_MID),
        ("persistent_pallas_k1", bench_persistent_k1, PERS_MID),
        ("trickle_persistent_pallas", bench_persistent_pallas, PERS_TRICKLE),
        ("trickle_pallas_k1", bench_persistent_k1, PERS_TRICKLE),
    ):
        us = fn(**shape)
        msgs = shape["k"] * shape["b"] / us * 1e6
        rows[path] = msgs
        emit(
            f"wirepath/{path}/burst={shape['b']}",
            us,
            f"{msgs:.0f} msg/s per {shape['k']}-round wave",
            path=path,
            burst=shape["b"],
            rounds=shape["k"],
            ring=shape["n"],
            msgs_per_s=msgs,
            us_per_wave=us,
        )
    speed = rows["persistent_pallas_k4"] / rows["persistent_jnp_k4"]
    emit(
        f"wirepath/persistent_speedup/burst={PERS_BIG['b']}",
        0.0,
        f"{speed:.2f}x pallas wave vs jnp K-unrolled oracle",
        burst=PERS_BIG["b"],
        rounds=PERS_BIG["k"],
        persistent_speedup=speed,
    )
    amort = rows["persistent_pallas_k16"] / rows["persistent_pallas_k1"]
    emit(
        f"wirepath/persistent_amortization/burst={PERS_MID['b']}",
        0.0,
        f"{amort:.2f}x vs {PERS_MID['k']} per-round dispatches",
        burst=PERS_MID["b"],
        rounds=PERS_MID["k"],
        persistent_amortization=amort,
    )
    ratio = rows["trickle_persistent_pallas"] / rows["trickle_pallas_k1"]
    emit(
        f"wirepath/trickle_persistent_ratio/burst={PERS_TRICKLE['b']}",
        0.0,
        f"{ratio:.2f}x useful msg/s vs the per-round pump",
        burst=PERS_TRICKLE["b"],
        rounds=PERS_TRICKLE["k"],
        trickle_persistent_ratio=ratio,
    )


def run(bursts=BURSTS, out: str | None = None) -> None:
    full_sweep = tuple(bursts) == BURSTS
    per_path = {}
    for b in bursts:
        for path, fn in PATHS:
            if path == "baseline" and b > SCALAR_CAP:
                # Python baseline is strictly O(msgs); extrapolating from the
                # capped burst is exact enough and keeps the suite fast.
                # (Recorded as skipped, not silently dropped.)
                emit(f"wirepath/{path}/burst={b}", 0.0, "skipped (scalar cap)",
                     path=path, burst=b, skipped=True)
                continue
            us = fn(b)
            msgs = b / us * 1e6
            per_path.setdefault(path, {})[b] = msgs
            emit(
                f"wirepath/{path}/burst={b}",
                us,
                f"{msgs:.0f} msg/s",
                path=path,
                burst=b,
                msgs_per_s=msgs,
                us_per_round=us,
            )
    # headline: fused speedup over the per-acceptor host loop.  The canonical
    # rows are burst >= 1024; partial sweeps also get one at their largest
    # burst so the CI regression gate has a ratio to diff (relative ratios
    # are robust to runner speed, absolute msgs/s are not).
    speedup_bursts = [b for b in bursts if b >= 1024] or [max(bursts)]
    for b in speedup_bursts:
        if b in per_path.get("pallas_fused", {}):
            speed = per_path["pallas_fused"][b] / per_path["per_acceptor"][b]
            emit(f"wirepath/speedup_pallas_vs_per_acceptor/burst={b}", 0.0,
                 f"{speed:.1f}x", burst=b, speedup=speed)
    run_multigroup()
    run_sharded()
    run_skewed()
    run_sustained()
    run_kv()
    run_persistent()
    if full_sweep:
        write_json(
            JSON_PATH,
            meta={"backend": jax.default_backend(), "A": A, "V": V, "N": N,
                  "MG_N": MG_N, "MG_BURST": MG_BURST},
            prefix="wirepath/",
        )
    elif out:
        # partial sweep for the CI gate: write to the side, never clobbering
        # the committed perf-trajectory artifact with truncated data
        write_json(
            out,
            meta={"backend": jax.default_backend(), "A": A, "V": V, "N": N,
                  "MG_N": MG_N, "MG_BURST": MG_BURST, "partial": True},
            prefix="wirepath/",
        )
    else:
        print(f"# partial sweep: not rewriting {os.path.basename(JSON_PATH)}")


if __name__ == "__main__":
    bursts = (64, 256) if "--quick" in sys.argv else BURSTS
    out_path = None
    if "--out" in sys.argv:
        out_path = sys.argv[sys.argv.index("--out") + 1]
    print("name,us_per_call,derived")
    run(bursts, out=out_path)
