"""Logical-axis sharding rules (DP / FSDP / TP / SP / EP + pod).

Every parameter / activation / cache dim carries a *logical* axis name; this
module maps names onto mesh axes with t5x-style rules, subject to:

  * divisibility — a dim is only sharded if the mesh-axis product divides it
    (otherwise the rule falls through to the next candidate, ending at
    replication).  This is what lets one rule set serve kv_heads=16 (sharded
    16-way) and kv_heads=4 (replicated) without per-arch special cases.
  * no axis reuse — a mesh axis is consumed by the first dim that takes it.

Rules are ordered candidate lists, so e.g. ``cache_seq`` can pick up the
``model`` axis exactly when ``kv_heads`` could not (sequence-sharded KV cache
for low-kv GQA architectures).
"""
from __future__ import annotations

import dataclasses
from typing import Any
from collections.abc import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as L

AxisCandidate = None | str | tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Ordered candidates per logical axis name."""

    rules: dict[str, tuple[AxisCandidate, ...]]

    def candidates(self, name: str | None) -> tuple[AxisCandidate, ...]:
        if name is None:
            return (None,)
        return self.rules.get(name, (None,))


# Paper-faithful baseline: DP+FSDP+TP+EP, no sequence parallelism.
BASE_RULES = ShardingRules(
    {
        # data / batch
        "batch": (("pod", "data"), "data", None),
        # FSDP: parameter embed dim over the data axis
        "embed": ("data", None),
        "embed_out": (None,),
        # tensor parallel
        "heads": ("model", None),
        "kv_heads": ("model", None),
        "heads_flat": ("model", None),
        "mlp": ("model", None),
        "expert_mlp": (None,),
        "vocab": ("model", None),
        "rnn": ("model", None),
        "rnn_out": (None,),
        # expert parallel
        "expert": ("model", None),
        # activations
        "act_seq": (None,),
        "mlp_act": ("model", None),
        "embed_act": (None,),
        # caches: kv_heads first, else shard the cache sequence dim
        "cache_seq": (None,),
        # never sharded
        "layers": (None,),
        "head_dim": (None,),
    }
)

# Optimized rules (§Perf): + sequence parallelism on the residual stream and
# sequence-sharded KV caches when kv_heads cannot take the model axis.
OPT_RULES = ShardingRules(
    {
        **BASE_RULES.rules,
        "act_seq": ("model", None),
        "cache_seq": ("model", None),
    }
)

# Small-model training rules (§Perf): TP=16 charges a per-layer activation
# all-reduce that dwarfs a <3B model's compute; run pure DP+FSDP instead
# (the model axis still shards the vocab/logits, which is where a 256k
# embedding actually needs it).
NOTP_RULES = ShardingRules(
    {
        **BASE_RULES.rules,
        "heads": (None,),
        "kv_heads": (None,),
        "heads_flat": (None,),
        "mlp": (None,),
        "mlp_act": (None,),
        "rnn": (None,),
        "expert": ("model", None),
    }
)

# Serving rules (§Perf): weight-stationary inference.  FSDP is a training
# optimization — during decode a parameter gathered per step costs ~16x its
# one-time residency.  Params shard over `model` only (replicated across
# `data`); TP-sized models fit per-device without gathers.
SERVE_RULES = ShardingRules(
    {
        **BASE_RULES.rules,
        "embed": (None,),          # no FSDP: weights resident
        "cache_seq": (None,),
    }
)


def abstract_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """jax-version-portable ``AbstractMesh`` constructor (no devices touched).

    Newer jax takes ``(shape, axis_names)``; 0.4.x takes one tuple of
    ``(name, size)`` pairs.  Spec resolution only needs ``mesh.shape``, which
    both construct identically.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, shape, strict=True)))


def resolve_spec(
    shape: Sequence[int], axes: Sequence[str | None], rules: ShardingRules, mesh: Mesh
) -> P:
    """Resolve one array's logical axes to a PartitionSpec."""
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    parts: list[AxisCandidate] = []
    for dim, name in zip(shape, axes, strict=True):
        chosen: AxisCandidate = None
        for cand in rules.candidates(name):
            if cand is None:
                chosen = None
                break
            cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a in used for a in cand_t):
                continue
            if any(a not in mesh.shape for a in cand_t):
                continue
            size = int(np.prod([mesh.shape[a] for a in cand_t]))
            if dim % size != 0:
                continue
            chosen = cand if isinstance(cand, str) else tuple(cand)
            used.update(cand_t)
            break
        parts.append(chosen)
    # trim trailing Nones for a tidy spec
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(shapes_tree, axes_tree, rules: ShardingRules, mesh: Mesh):
    """NamedSharding pytree for a (shapes, axes) pytree pair."""

    def leaf(shape_like, axes):
        shape = getattr(shape_like, "shape", None)
        if shape is None or axes is None or axes == ():
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, resolve_spec(shape, axes, rules, mesh))

    return jax.tree_util.tree_map(
        leaf, shapes_tree, axes_tree,
        is_leaf=lambda x: hasattr(x, "shape") or x is None,
    )


# ---------------------------------------------------------------------------
# Activation sharder installation
# ---------------------------------------------------------------------------
def install(mesh: Mesh, rules: ShardingRules = BASE_RULES) -> None:
    """Install the activation-constraint hook used by model code."""

    def sharder(x: jax.Array, axes: tuple) -> jax.Array:
        spec = resolve_spec(x.shape, axes, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    L.set_activation_sharder(sharder)


def uninstall() -> None:
    L.set_activation_sharder(None)


class use_rules:
    """Context manager: install/uninstall activation sharding."""

    def __init__(self, mesh: Mesh, rules: ShardingRules = BASE_RULES):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        install(self.mesh, self.rules)
        return self

    def __exit__(self, *exc):
        uninstall()
        return False


# ---------------------------------------------------------------------------
# Batch (input) shardings
# ---------------------------------------------------------------------------
BATCH_AXES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "patches": ("batch", None, None),
    "frames": ("batch", None, None),
    "pos": (),
}


def batch_shardings(input_specs: dict[str, Any], cfg, rules, mesh):
    """Shardings for a train/prefill/decode input-spec dict."""
    from repro.models import registry

    out = {}
    for k, v in input_specs.items():
        if k == "cache":
            cache_axes = registry.family_module(cfg).CACHE_AXES
            out[k] = {
                name: NamedSharding(
                    mesh, resolve_spec(sds.shape, cache_axes[name], rules, mesh)
                )
                for name, sds in v.items()
            }
        else:
            out[k] = NamedSharding(
                mesh, resolve_spec(v.shape, BATCH_AXES[k], rules, mesh)
            )
    return out
