"""Replicated key-value store — the paper's LevelDB case study (§5), served
by the KV tier (DESIGN.md §10).

    PYTHONPATH=src python examples/replicated_kv.py

Clients speak the typed session API: ``put`` / ``cas`` / ``delete`` ride the
consensus wire path exactly once, while ``get`` is **consensus-free** under
the session's read-your-writes lease — NetChain's read-path economics on
this dataplane.  When membership churn moves a session between groups, its
lease goes stale and ONE serialized read-index op re-validates it; the
session's view stitches seamlessly across the generations it spanned.
"""
import sys

sys.path.insert(0, "src")

from repro.core import PaxosConfig, PaxosContext
from repro.serve import ConsensusService, ReplicatedKV


def main() -> None:
    cfg = PaxosConfig(n_acceptors=3, n_instances=256, batch=16, n_groups=2)
    svc = ConsensusService(PaxosContext(cfg))
    kv = ReplicatedKV(svc)

    # -- writes ride consensus ----------------------------------------------
    alice = kv.session("alice")
    alice.put(b"user", b"alice")
    alice.put(b"city", b"lugano")
    alice.put(b"user", b"bob")           # overwrite decided later wins
    alice.delete(b"city")
    alice.cas(b"paper", None, b"caans")  # create iff absent
    svc.run_until_quiescent()

    # -- leased reads never touch the wire path -----------------------------
    before = svc.ctx.hw.dispatch_count
    assert alice.get(b"user") == b"bob"
    assert alice.get(b"city") is None    # tombstoned
    assert alice.get(b"paper") == b"caans"
    assert svc.ctx.hw.dispatch_count == before, "leased get dispatched!"
    print(f"3 leased gets, {svc.ctx.hw.dispatch_count - before} wire-path "
          f"dispatches — reads are consensus-free under the lease")

    # -- cas semantics ------------------------------------------------------
    alice.cas(b"paper", b"caans", b"netchain")   # matches: applies
    alice.cas(b"paper", b"caans", b"stale")      # stale expect: no-op
    svc.run_until_quiescent()
    assert alice.get(b"paper") == b"netchain"
    print(f"cas applied once: paper={alice.get(b'paper').decode()}")

    # -- churn: the lease breaks, the read-index heals it -------------------
    svc.retire_group(svc.group_of("alice"))      # alice's group retires
    value = alice.get(b"user")                   # stale lease -> read-index
    assert value == b"bob"                       # stitched across generations
    assert alice.lease_valid                     # re-validated, leased again
    print(f"after membership churn: user={value.decode()} "
          f"(read-index fallbacks: {kv.stats['read_index_gets']}, "
          f"leased gets: {kv.stats['leased_gets']})")


if __name__ == "__main__":
    main()
