"""Analytic per-device FLOPs / HBM-bytes models for the roofline.

Why this exists: XLA:CPU ``cost_analysis()`` counts each ``while``-loop body
ONCE, ignoring trip counts (verified: a 2-layer and a 4-layer scanned model
report identical FLOPs — see EXPERIMENTS.md §Roofline).  Every model here
scans over layers (and flash attention scans over chunks), so raw HLO
numbers undercount by ~L× and are useless for bottleneck ranking.  We
therefore derive the compute/memory terms analytically from the architecture
and the sharding, and keep the raw HLO numbers as a cross-check column.

Conventions:
  * FLOPs count multiply-adds as 2.
  * train  = fwd + bwd (3x fwd matmul FLOPs) + optimizer elementwise.
  * remat: the fwd is recomputed once inside bwd (policy: save only layer
    boundaries), so matmul FLOPs = 4x fwd instead of 3x.
  * bytes: parameter traffic (fwd read + bwd read + recompute read + Adam
    read/write) + activation traffic (layer-boundary saves r/w) + batch IO,
    all divided by the sharded degree where applicable.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    chips: int
    dp: int       # batch-sharding degree (pod*data) actually dividing batch
    fsdp: int     # parameter-sharding degree over 'data'
    tp: int       # tensor degree over 'model'

    @classmethod
    def for_mesh(
        cls, multi_pod: bool, global_batch: int, rules: str = "base"
    ) -> "MeshInfo":
        chips = 512 if multi_pod else 256
        dp_axes = 32 if multi_pod else 16
        dp = dp_axes if global_batch % dp_axes == 0 else 1
        # serve rules are weight-stationary: params shard over TP only
        fsdp = 1 if rules == "serve" else 16
        return cls(chips=chips, dp=dp, fsdp=fsdp, tp=16)


def _attn_flops_per_layer(cfg: ModelConfig, s: int, window: int) -> float:
    """Score+PV matmul FLOPs for one layer, one sequence (fwd)."""
    eff = min(window, s) if window else s
    # causal halves the full-window part; sliding window is ~s*eff
    pairs = s * eff / (2 if not window else 1)
    return 2.0 * 2.0 * pairs * cfg.n_heads * cfg.hd


def _layer_windows(cfg: ModelConfig) -> tuple[int, int]:
    """(n_global_layers, n_local_layers)."""
    if cfg.local_window == 0:
        return cfg.n_layers, 0
    if cfg.global_every == 0:
        return 0, cfg.n_layers
    n_global = cfg.n_layers // cfg.global_every
    return n_global, cfg.n_layers - n_global


def _seq_mix_flops(cfg: ModelConfig, s: int, batch: int, kind: str) -> float:
    """Sequence-mixing FLOPs beyond the 6N/2N param term (global, fwd)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        n_g, n_l = _layer_windows(cfg)
        per_seq = n_g * _attn_flops_per_layer(cfg, s, 0) + n_l * _attn_flops_per_layer(
            cfg, s, cfg.local_window
        )
        return batch * per_seq
    if fam == "ssm":
        # wkv: per token per layer: state update + readout ~ 4*H*hd^2
        h, hd = cfg.n_heads, cfg.rwkv_head_dim
        return batch * s * cfg.n_layers * 4.0 * h * hd * hd
    if fam == "hybrid":
        n_attn = cfg.n_layers // len(cfg.block_pattern)
        per_seq = n_attn * _attn_flops_per_layer(cfg, s, cfg.local_window)
        # RG-LRU elementwise + conv: ~ (2*conv_width + 10) * d_rnn per token
        rec = cfg.n_layers - n_attn
        per_seq += s * rec * (2.0 * cfg.conv_width + 10.0) * (cfg.d_rnn or cfg.d_model)
        return batch * per_seq
    if fam == "encdec":
        dec_self = cfg.n_layers * _attn_flops_per_layer(cfg, s, 0)
        f = cfg.src_len
        dec_cross = cfg.n_layers * 2.0 * 2.0 * s * f * cfg.n_heads * cfg.hd
        enc = cfg.n_enc_layers * 2.0 * 2.0 * f * f * cfg.n_heads * cfg.hd
        return batch * (dec_self + dec_cross + enc)
    raise ValueError(fam)


def _decode_seq_mix_flops(cfg: ModelConfig, ctx: int, batch: int) -> float:
    """One-token sequence mixing (fwd only)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        n_g, n_l = _layer_windows(cfg)
        eff_l = min(cfg.local_window or ctx, ctx)
        per_tok = (n_g * ctx + n_l * eff_l) * 4.0 * cfg.n_heads * cfg.hd
        return batch * per_tok
    if fam == "ssm":
        h, hd = cfg.n_heads, cfg.rwkv_head_dim
        return batch * cfg.n_layers * 4.0 * h * hd * hd
    if fam == "hybrid":
        n_attn = cfg.n_layers // len(cfg.block_pattern)
        eff = min(cfg.local_window, ctx)
        per_tok = n_attn * eff * 4.0 * cfg.n_heads * cfg.hd
        per_tok += (cfg.n_layers - n_attn) * (2.0 * cfg.conv_width + 10.0) * (
            cfg.d_rnn or cfg.d_model
        )
        return batch * per_tok
    if fam == "encdec":
        per_tok = cfg.n_layers * (ctx + cfg.src_len) * 4.0 * cfg.n_heads * cfg.hd
        return batch * per_tok
    raise ValueError(fam)


def _param_bytes(cfg: ModelConfig) -> float:
    return float(cfg.n_params) * 2.0  # bf16


def _cache_bytes(cfg: ModelConfig, batch: int, ctx: int) -> float:
    fam = cfg.family
    if fam == "ssm":
        h, hd = cfg.n_heads, cfg.rwkv_head_dim
        return batch * cfg.n_layers * (h * hd * hd * 4.0 + 2 * cfg.d_model * 2.0)
    if fam == "hybrid":
        n_super = cfg.n_layers // len(cfg.block_pattern)
        c = min(cfg.local_window, ctx)
        kv = n_super * batch * c * cfg.n_kv_heads * cfg.hd * 2 * 2.0
        rec = (cfg.n_layers - n_super) * batch * (cfg.d_rnn or cfg.d_model) * 4.0
        return kv + rec
    extra = 0.0
    if fam == "encdec":
        extra = cfg.n_layers * batch * cfg.src_len * cfg.n_kv_heads * cfg.hd * 2 * 2.0
    if cfg.ring_local_cache and cfg.local_window and cfg.global_every:
        # §Perf lever: local layers keep window-length ring caches
        n_g, n_l = _layer_windows(cfg)
        cells = n_g * ctx + n_l * min(cfg.local_window, ctx)
        return batch * cells * cfg.n_kv_heads * cfg.hd * 2 * 2.0 + extra
    # baseline: full-length KV for every layer
    return cfg.n_layers * batch * ctx * cfg.n_kv_heads * cfg.hd * 2 * 2.0 + extra


def analytic_terms(
    cfg: ModelConfig, shape: ShapeConfig, mesh: MeshInfo
) -> dict[str, float]:
    """Returns per-device {flops, hbm_bytes, model_flops} for the step."""
    b, s = shape.global_batch, shape.seq_len
    n_active = float(cfg.n_active_params)
    p_bytes = _param_bytes(cfg)
    shard = mesh.fsdp * mesh.tp          # parameter sharding degree
    d = cfg.d_model

    if shape.kind == "train":
        tokens = b * s
        matmul = 2.0 * n_active * tokens            # fwd
        mix = _seq_mix_flops(cfg, s, b, "train")
        # remat policy: full = fwd recomputed in bwd (4x fwd total);
        # dots = matmul outputs saved, no recompute (3x), more act traffic
        if cfg.remat and cfg.remat_policy == "full":
            flops_mult, act_mult = 4.0, 1.0
        else:
            flops_mult, act_mult = 3.0, 4.5
        flops_global = flops_mult * (matmul + mix)
        flops_global += 10.0 * (p_bytes / 2.0)      # Adam elementwise
        # memory per device: params fwd+recompute+bwd grads rw + Adam state
        p_loc = p_bytes / shard
        param_traffic = p_loc * (1 + 1 + 1) + (p_loc / 2) * (
            4 + 4
        ) * 2 + p_loc * 2  # reads fwd/remat/bwd + mu,nu rw(f32) + grad rw
        act_save = cfg.n_layers * (b / mesh.dp) * s * d * 2.0 * 2 * act_mult
        io = (b / mesh.dp) * s * 4.0 * 2
        logits = (b / mesh.dp) * s * (cfg.vocab / mesh.tp) * 2.0 * 2
        bytes_dev = param_traffic + act_save + io + logits
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = b * s
        flops_global = 2.0 * n_active * tokens + _seq_mix_flops(cfg, s, b, "prefill")
        p_loc = p_bytes / shard
        act = cfg.n_layers * (b / mesh.dp) * s * d * 2.0
        cache = _cache_bytes(cfg, b, s) / mesh.chips
        bytes_dev = p_loc + act + cache
        model_flops = 2.0 * n_active * tokens
    else:  # decode
        tokens = b
        flops_global = 2.0 * n_active * tokens + _decode_seq_mix_flops(cfg, s, b)
        p_loc = p_bytes / shard
        cache = _cache_bytes(cfg, b, s) / mesh.chips
        bytes_dev = p_loc + cache * 1.0  # read cache + write 1 slot (~read)
        model_flops = 2.0 * n_active * tokens

    return {
        "flops": flops_global / mesh.chips,
        "hbm_bytes": bytes_dev,
        "model_flops": model_flops,
    }
