"""Linearizability chaos suite for the replicated KV tier (DESIGN.md §10).

Two properties, each on all four backends (jnp + pallas kernels, unsharded +
groups-sharded):

* **Twin apply-state equality** — raw encoded KV ops (put / delete / cas,
  cas with both hit and deliberate miss expects) driven through the
  multi-group service under chaos (coordinator failover, acceptor crash
  WITH state loss + snapshot-restore, snapshot compaction, retire / create
  membership churn) produce replica state **bit-equal** to a fresh apply
  loop over independent single-group twins fed the identical schedule at
  identical pump cadence — at every retirement instant and at the end.

* **Zero stale reads** — KVSession clients under membership churn never
  observe a stale value: every ``get`` equals the session's last issued
  write (single-writer keys) AND an independent oracle that linearly
  decodes the session's stitched segment chain.  Every *leased* get is
  pinned consensus-free by the dataplane's dispatch counter; the schedule
  must exercise both the leased path and the read-index fallback.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import PaxosConfig, PaxosContext
from repro.launch.mesh import make_group_mesh
from repro.serve.engine import ConsensusService
from repro.serve.kv import (
    OP_CAS,
    OP_DELETE,
    OP_PUT,
    GroupReplica,
    KvOp,
    ReplicatedKV,
    decode_op,
    encode_op,
)

pytestmark = pytest.mark.slow    # chaos suite: skipped in the fast CI lane

A = 3
KEYS = [f"key{i}".encode() for i in range(4)]


def _cfg(g: int) -> PaxosConfig:
    return PaxosConfig(n_acceptors=A, n_instances=256, batch=8, n_groups=g)


CFG1 = PaxosConfig(n_acceptors=A, n_instances=256, batch=8)


def _oracle_sig(log):
    """One-shot fresh apply loop over a full twin log — the unbounded
    oracle the service-maintained incremental replica must bit-match."""
    rep = GroupReplica()
    rep.apply_log(list(log))
    return rep.signature()


# ---------------------------------------------------------------------------
# Part A: twin apply-state equality under chaos
# ---------------------------------------------------------------------------
def run_kv_twins(
    seed: int, g: int, use_kernels: bool, sharded: bool, waves: int = 12
) -> None:
    mesh = make_group_mesh() if sharded else None
    ctx = PaxosContext(_cfg(g), use_kernels=use_kernels, mesh=mesh,
                       snapshots=True)
    svc = ConsensusService(ctx)
    kv = ReplicatedKV(svc)
    twins = [
        PaxosContext(CFG1, use_kernels=use_kernels, fused=True,
                     snapshots=True)
        for _ in range(g)
    ]
    rng = np.random.default_rng(seed)
    counters = [0] * g                # synthetic per-group session counters

    def submit(gid: int) -> None:
        counters[gid] += 1
        c = counters[gid]
        key = KEYS[int(rng.integers(len(KEYS)))]
        r = rng.random()
        if r < 0.5:
            op = KvOp(OP_PUT, key, f"g{gid}c{c}".encode(), None,
                      1000 + gid, c)
        elif r < 0.7:
            op = KvOp(OP_DELETE, key, b"", None, 1000 + gid, c)
        else:
            # mix of expect-absent and (mostly-missing) value expects: both
            # the applied and the committed-no-op cas paths must replicate
            expect = None if r < 0.8 else f"g{gid}c{int(rng.integers(c))}".encode()
            op = KvOp(OP_CAS, key, f"cas{c}".encode(), expect, 1000 + gid, c)
        p = encode_op(op)
        ctx.submit(p, group=gid)
        twins[gid].submit(p)

    def pump() -> None:
        ctx.pump()
        for t in twins:
            if t is not None:
                t.pump()

    churn_gid = g - 1
    for w in range(waves):
        if w == 3:                    # coordinator failover in group 0
            ctx.fail_coordinator(group=0)
            twins[0].fail_coordinator()
        if w == 5:
            ctx.restore_hardware_coordinator(group=0)
            twins[0].restore_hardware_coordinator()
        if w == 6:                    # crash WITH state loss in group 0
            ctx.crash_acceptor(2, group=0)
            twins[0].crash_acceptor(2)
        if w == 9:
            # snapshot-advanced watermark: the rebuild is prefix + suffix
            assert ctx.snapshots.watermark(0) > 0
            assert ctx.restore_acceptor(2, group=0) == twins[
                0
            ].restore_acceptor(2), seed
        if w == 7:                    # membership churn, mid-traffic
            gen = svc.group_generation(churn_gid)
            svc.retire_group(churn_gid)
            kv.refresh()              # finalizes the archived segment
            assert kv.replica(churn_gid, gen).signature() == _oracle_sig(
                twins[churn_gid].delivered_log
            ), (seed, churn_gid)
            twins[churn_gid] = None
            counters[churn_gid] = 0
        if w == 10:
            assert svc.create_group() == churn_gid
            twins[churn_gid] = PaxosContext(
                CFG1, use_kernels=use_kernels, fused=True, snapshots=True
            )
        for gid in ctx.live_groups():
            for _ in range(int(rng.integers(1, 5))):
                submit(gid)
        pump()
        if (w + 1) % 4 == 0:          # compaction mid-stream, both sides
            for gid in ctx.live_groups():
                snap = ctx.snapshot_group(gid)
                tsnap = twins[gid].snapshot_group()
                assert snap.watermark == tsnap.watermark, (seed, gid)
                assert snap.seal == tsnap.seal, (seed, gid)
        kv.refresh()                  # incremental host-side apply
    for _ in range(30):               # heal: outlast retransmit cycles
        pump()
    kv.refresh()
    for gid in ctx.live_groups():
        # the log itself is bit-equal (the established chaos contract)...
        assert ctx.full_group_log(gid) == twins[gid].full_group_log(), (
            seed, gid,
        )
        # ...and so is the *applied state*: the incrementally-maintained
        # replica matches a one-shot oracle over the twin's unbounded log
        assert kv.replica(gid).signature() == _oracle_sig(
            twins[gid].full_group_log()
        ), (seed, gid)
    assert not ctx._pending


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [0, 1])
def test_kv_twins_unsharded(seed, use_kernels):
    run_kv_twins(seed, g=3, use_kernels=use_kernels, sharded=False)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [2, 3])
def test_kv_twins_sharded(seed, use_kernels):
    run_kv_twins(seed, g=2, use_kernels=use_kernels, sharded=True)


# ---------------------------------------------------------------------------
# Part B: zero stale reads through KVSession under churn
# ---------------------------------------------------------------------------
def _oracle_get(svc, sid, key):
    """Independent read oracle: linearly decode the session's stitched
    segment chain.  For single-writer keys this is exactly the last issued
    write that survived (a write pending at its group's retirement died on
    the wire — the schedule quiesces before every retire so none do)."""
    val = None
    for seg in svc.session_chain(sid):
        for _inst, payload in svc.log_segment(*seg):
            op = decode_op(payload)
            if op.key != key:
                continue
            if op.op == OP_PUT:
                val = op.value
            elif op.op == OP_DELETE:
                val = None
    return val


def run_kv_sessions(
    seed: int, g: int, use_kernels: bool, sharded: bool, waves: int = 6
) -> None:
    mesh = make_group_mesh() if sharded else None
    ctx = PaxosContext(_cfg(g), use_kernels=use_kernels, mesh=mesh,
                       snapshots=True)
    svc = ConsensusService(ctx)
    kv = ReplicatedKV(svc)
    rng = np.random.default_rng(seed)
    sids = [f"user-{i}" for i in range(2 * g)]
    last: dict = {}                   # sid -> last issued value for its key
    for w in range(waves):
        for sid in sids:
            s = kv.session(sid)
            key = f"k-{sid}".encode()  # single-writer: exact staleness oracle
            if rng.random() < 0.8:
                v = f"{sid}w{w}".encode()
                s.put(key, v)
                last[sid] = v
            else:
                s.delete(key)
                last[sid] = None
        svc.run_until_quiescent()
        for sid in sids:
            s = kv.session(sid)
            before = dict(kv.stats)
            base = ctx.hw.dispatch_count
            v = s.get(f"k-{sid}".encode())
            assert v == last[sid], (seed, w, sid)           # never stale
            assert v == _oracle_get(svc, sid, f"k-{sid}".encode()), (
                seed, w, sid,
            )
            if kv.stats["leased_gets"] > before["leased_gets"]:
                # the consensus-free claim, pinned: a leased get launched
                # NOTHING on the dataplane
                assert ctx.hw.dispatch_count == base, (seed, w, sid)
        # membership churn between waves (quiescent: no write dies)
        if w == 1:
            svc.retire_group(svc.group_of(sids[0]))
        if w == 3 and len(ctx.live_groups()) < g:
            svc.create_group()
    # the schedule exercised BOTH read paths
    assert kv.stats["leased_gets"] > 0
    assert kv.stats["read_index_gets"] > 0


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [0, 1])
def test_kv_sessions_unsharded(seed, use_kernels):
    run_kv_sessions(seed, g=3, use_kernels=use_kernels, sharded=False)


@pytest.mark.parametrize("use_kernels", [False, True])
@pytest.mark.parametrize("seed", [2, 3])
def test_kv_sessions_sharded(seed, use_kernels):
    run_kv_sessions(seed, g=2, use_kernels=use_kernels, sharded=True)
